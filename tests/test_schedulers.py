"""Scheduler invariants: Themis / Pollux / Random + CASSINI augmentation."""

import pytest

from repro.cluster import Topology
from repro.cluster.job import Job, JobState
from repro.sched import (
    CassiniAugmented,
    PolluxScheduler,
    RandomScheduler,
    ThemisScheduler,
)
from repro.sched.base import ClusterState


def _state(topo, n_jobs=5, workers=7):
    jobs = [
        Job(job_id=f"j{i}", model=["vgg16", "bert", "gpt1", "resnet50", "dlrm"][i % 5],
            num_workers=workers, duration_iters=100)
        for i in range(n_jobs)
    ]
    for j in jobs:
        j.state = JobState.RUNNING
    return ClusterState(topology=topo, now_ms=0.0, running=jobs, pending=[])


@pytest.mark.parametrize(
    "sched_cls", [ThemisScheduler, PolluxScheduler, RandomScheduler]
)
def test_allocation_never_oversubscribes(sched_cls):
    topo = Topology.paper_testbed()
    state = _state(topo, n_jobs=6, workers=9)  # 54 demanded > 24 GPUs
    sched = sched_cls()
    alloc = sched.allocate_workers(state)
    assert sum(alloc.values()) <= topo.num_gpus
    assert all(v >= 1 for v in alloc.values())


@pytest.mark.parametrize("sched_cls", [ThemisScheduler, PolluxScheduler])
def test_placements_disjoint_and_complete(sched_cls):
    topo = Topology.paper_testbed()
    state = _state(topo)
    sched = sched_cls()
    workers = sched.allocate_workers(state)
    cands = sched.propose(state, workers, k=8)
    assert cands, "must produce at least one candidate"
    for pl in cands:
        used = [s for servers in pl.values() for s in servers]
        assert len(used) == len(set(used)), "server assigned twice"
        for jid, servers in pl.items():
            assert len(servers) == workers[jid]


def test_candidates_are_distinct():
    topo = Topology.paper_testbed()
    state = _state(topo, n_jobs=4, workers=7)
    sched = ThemisScheduler()
    workers = sched.allocate_workers(state)
    cands = sched.propose(state, workers, k=10)
    keys = {tuple(sorted((j, s) for j, s in pl.items())) for pl in cands}
    assert len(keys) == len(cands) >= 2


def test_sticky_placement_respects_leases():
    """Running jobs keep their servers when their allocation is unchanged."""
    topo = Topology.paper_testbed()
    state = _state(topo, n_jobs=3, workers=6)
    state.running[0].placement = (0, 1, 2, 3, 4, 5)
    sched = ThemisScheduler()
    workers = sched.allocate_workers(state)
    if workers.get("j0", 0) == 6:
        cands = sched.propose(state, workers, k=3)
        for pl in cands:
            assert pl["j0"] == (0, 1, 2, 3, 4, 5)


def test_cassini_wrapper_respects_host_allocation():
    topo = Topology.paper_testbed()
    state = _state(topo)
    host = ThemisScheduler()
    wrapped = CassiniAugmented(host, num_candidates=5)
    assert wrapped.allocate_workers(state) == host.allocate_workers(state)
    decision = wrapped.schedule(state)
    host_workers = host.allocate_workers(state)
    for jid, servers in decision.placements.items():
        assert len(servers) == host_workers[jid]
    # every assigned shift is within the job's iteration time
    by_id = {j.job_id: j for j in state.running}
    for jid, t in decision.time_shifts_ms.items():
        assert 0 <= t <= by_id[jid].solo_iter_ms + 1e-6
