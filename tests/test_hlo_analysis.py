"""Unit tests for the HLO roofline analyzer (launch/hlo_analysis.py)."""

from repro.launch.hlo_analysis import analyze_hlo

HLO = """
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ivn = s32[] add(%iv, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ivn, %ar)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %k), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_counts_multiply_flops_and_collectives():
    st = analyze_hlo(HLO)
    # dot: 2 * 8*8 (result) * 8 (contraction) = 1024 flops, x5 trips
    assert st.flops == 1024 * 5
    # all-reduce result: 8*8*4 bytes, x5 trips
    assert st.collective_bytes == 256 * 5
    assert st.collective_count["all-reduce"] == 5
    assert 5 in st.while_trip_counts.values()


def test_bytes_include_dot_operands_once_per_trip():
    st = analyze_hlo(HLO)
    # per trip: dot reads two 256B operands + writes 256B, all-reduce 256+256
    assert st.bytes_accessed >= (256 * 3 + 512) * 5
