"""Serve mode: golden equivalence vs the batch simulator, queue bounds,
prefetch parity, stream semantics (ISSUE 6 tentpole)."""

from __future__ import annotations

import math
import threading
import time
from itertools import islice

import pytest

from repro.cluster import Topology, iter_poisson_trace, poisson_trace
from repro.engine import get_scenario
from repro.serve import (
    JobArrival,
    JobDeparture,
    LatencyRecorder,
    QueryPlacement,
    QueueFullError,
    SchedulerService,
)


def _decision_tuples(decisions):
    return [
        (t, d.placements, d.time_shifts_ms)
        for t, d in decisions
    ]


def _run_batch(spec, scheduler_name):
    built = spec.build(scheduler_name)
    metrics = built.simulator.run(built.jobs, horizon_ms=spec.horizon_ms)
    return metrics, built.simulator.decisions


def _run_served(spec, scheduler_name, *, prefetch=True):
    topo = spec.topology()
    svc = SchedulerService(
        topo,
        spec.make_scheduler(scheduler_name),
        epoch_ms=spec.epoch_ms,
        compute_jitter=spec.compute_jitter,
        vectorized=spec.vectorized,
        seed=spec.sim_seed,
        prefetch=prefetch,
    )
    with svc:
        for job in spec.arrival_stream(topo):
            svc.submit(JobArrival(job))
        metrics = svc.drain(spec.horizon_ms)
        telemetry = svc.telemetry()
    return metrics, svc.decisions, telemetry


# --------------------------------------------------------------------- #
# golden equivalence (acceptance criterion)
# --------------------------------------------------------------------- #
class TestGoldenEquivalence:
    def test_multitenant8_replay_matches_batch(self):
        """The served multitenant-8 arrival replay produces every placement,
        time-shift and metric identically to the batch pipeline."""
        spec = get_scenario("multitenant-8")
        m_batch, d_batch = _run_batch(spec, "cassini")
        m_serve, d_serve, telemetry = _run_served(spec, "cassini")
        assert m_batch.summary() == m_serve.summary()
        assert _decision_tuples(d_batch) == _decision_tuples(d_serve)
        # every epoch reconfiguration took the delta path (the replay only
        # appends arrivals / drops departures — no survivor reordering)
        assert telemetry["configure_delta"] == len(d_serve)
        assert telemetry.get("configure_rebuild", 0.0) == 0.0

    def test_dynamic_arrivals_match_batch_themis_cassini(self):
        """Arrival/departure churn with a real host scheduler (Themis):
        decisions may reorder survivors — the service must fall back to
        rebuilds where needed and still match the batch run exactly."""
        spec = get_scenario("dynamic-burst")
        m_batch, d_batch = _run_batch(spec, "th+cassini")
        m_serve, d_serve, _ = _run_served(spec, "th+cassini")
        assert m_batch.summary() == m_serve.summary()
        assert _decision_tuples(d_batch) == _decision_tuples(d_serve)

    def test_prefetch_off_parity(self):
        """Speculative cache warming must not change any decision."""
        spec = get_scenario("multitenant-4")
        m_on, d_on, tel_on = _run_served(spec, "cassini", prefetch=True)
        m_off, d_off, tel_off = _run_served(spec, "cassini", prefetch=False)
        assert m_on.summary() == m_off.summary()
        assert _decision_tuples(d_on) == _decision_tuples(d_off)
        assert tel_on["prefetch_launched"] > 0
        assert "prefetch_launched" not in tel_off


# --------------------------------------------------------------------- #
# service semantics
# --------------------------------------------------------------------- #
class TestServiceSemantics:
    def _spec(self):
        return get_scenario("multitenant-4")

    def test_query_placement(self):
        spec = self._spec()
        topo = spec.topology()
        with SchedulerService(
            topo, spec.make_scheduler("cassini"), epoch_ms=spec.epoch_ms,
            compute_jitter=0.0, seed=spec.sim_seed,
        ) as svc:
            jobs = list(spec.arrival_stream(topo))
            for job in jobs:
                svc.submit(JobArrival(job))
            # watermark past the t=0 batch forces its admission + decision
            view = svc.query(at_ms=1.0)
            assert set(view.placements) == {j.job_id for j in jobs}
            _, latest = svc.decisions[-1]
            assert view.placements == {
                jid: tuple(srv) for jid, srv in latest.placements.items()
            }
            one = svc.query(job_id=jobs[0].job_id)
            assert one.placements == {
                jobs[0].job_id: view.placements[jobs[0].job_id]
            }
            with pytest.raises(KeyError):
                svc.query(job_id="no-such-job")

    def test_same_timestamp_arrivals_admitted_as_one_batch(self):
        """All t=0 tenants must enter with ONE scheduling decision, exactly
        like the batch simulator — not one decision per submit."""
        spec = self._spec()
        topo = spec.topology()
        with SchedulerService(
            topo, spec.make_scheduler("cassini"), epoch_ms=spec.epoch_ms,
            compute_jitter=0.0, seed=spec.sim_seed,
        ) as svc:
            for job in spec.arrival_stream(topo):
                svc.submit(JobArrival(job))
            assert svc.query().placements == {}  # watermark still at t=0
            svc.query(at_ms=1.0)
            tel = svc.telemetry()
            assert tel["reschedule_arrival"] == 1.0

    def test_departure_cancels_job(self):
        spec = self._spec()
        topo = spec.topology()
        with SchedulerService(
            topo, spec.make_scheduler("cassini"), epoch_ms=spec.epoch_ms,
            compute_jitter=0.0, seed=spec.sim_seed,
        ) as svc:
            jobs = list(spec.arrival_stream(topo))
            for job in jobs:
                svc.submit(JobArrival(job))
            victim = jobs[0].job_id
            svc.submit(JobDeparture(job_id=victim, at_ms=5_000.0)).result()
            view = svc.query()
            assert victim not in view.placements
            metrics = svc.drain(spec.horizon_ms)
            by_id = {j.job_id: j for j in metrics.jobs}
            assert by_id[victim].finish_ms is None  # cancelled, not finished

    def test_out_of_order_events_rejected(self):
        spec = self._spec()
        topo = spec.topology()
        with SchedulerService(
            topo, spec.make_scheduler("cassini"), epoch_ms=spec.epoch_ms,
            compute_jitter=0.0, seed=spec.sim_seed,
        ) as svc:
            svc.query(at_ms=10_000.0)
            with pytest.raises(ValueError, match="watermark"):
                svc.query(at_ms=5_000.0)

    def test_bounded_queue_backpressure(self):
        spec = self._spec()
        topo = spec.topology()
        svc = SchedulerService(
            topo, spec.make_scheduler("cassini"), epoch_ms=spec.epoch_ms,
            queue_size=2, start=False,  # no worker: the queue can only fill
        )
        jobs = poisson_trace(topo, num_jobs=3, seed=1)
        svc.submit(JobArrival(jobs[0]))
        svc.submit(JobArrival(jobs[1]))
        with pytest.raises(QueueFullError):
            svc.submit(JobArrival(jobs[2]))
        assert svc.metrics.counter("queue_rejected") == 1
        assert svc.metrics.snapshot()["queue_depth_peak"] == 2.0

    def test_closed_service_rejects_submissions(self):
        spec = self._spec()
        topo = spec.topology()
        svc = SchedulerService(
            topo, spec.make_scheduler("cassini"), epoch_ms=spec.epoch_ms,
        )
        svc.close()
        with pytest.raises(RuntimeError, match="closed"):
            svc.submit(QueryPlacement())


# --------------------------------------------------------------------- #
# worker thread lifecycle (crash propagation + bounded shutdown)
# --------------------------------------------------------------------- #
class TestWorkerLifecycle:
    def _spec(self):
        return get_scenario("multitenant-2")

    def _service(self, **kw):
        spec = self._spec()
        return SchedulerService(
            spec.topology(), spec.make_scheduler("cassini"),
            epoch_ms=spec.epoch_ms, seed=spec.sim_seed, **kw,
        )

    @staticmethod
    def _crash(svc):
        """Kill the worker loop *outside* the per-request handler: result
        delivery succeeds, then latency recording blows up the loop."""
        def boom(*a, **kw):
            raise ZeroDivisionError("telemetry exploded")

        svc.metrics.observe = boom
        fut = svc.submit(QueryPlacement())
        fut.result(timeout=10)  # the request itself completed fine
        for _ in range(500):    # …then the loop died recording it
            if svc._worker_exc is not None:
                return
            time.sleep(0.01)
        raise AssertionError("worker did not record its crash")

    def test_worker_crash_reraises_on_submit(self):
        svc = self._service()
        self._crash(svc)
        with pytest.raises(RuntimeError, match="worker crashed") as ei:
            svc.submit(QueryPlacement())
        assert isinstance(ei.value.__cause__, ZeroDivisionError)
        assert svc.metrics.counter("worker_crashed") == 1
        svc.close()

    def test_worker_crash_reraises_on_drain(self):
        svc = self._service()
        self._crash(svc)
        with pytest.raises(RuntimeError, match="worker crashed"):
            svc.drain(1_000.0)
        svc.close()

    def test_worker_crash_fails_queued_futures(self):
        """Requests already queued behind the crash must error out, not
        leave their callers blocked on a Future nobody will resolve."""
        svc = self._service(start=False)
        svc.metrics.observe = lambda *a, **kw: (_ for _ in ()).throw(
            ZeroDivisionError("telemetry exploded")
        )
        first = svc.submit(QueryPlacement())
        stuck = [svc.submit(QueryPlacement()) for _ in range(3)]
        svc.start()
        first.result(timeout=10)
        for fut in stuck:
            with pytest.raises(RuntimeError, match="worker crashed"):
                fut.result(timeout=10)
        svc.close()

    def test_close_idempotent_after_crash(self):
        svc = self._service()
        self._crash(svc)
        svc.close()  # dead worker: join returns immediately, no hang
        svc.close()  # and again — idempotent
        assert svc._worker is None

    def test_close_timeout_on_wedged_worker(self):
        svc = self._service()
        gate = threading.Event()
        orig = svc._handle
        svc._handle = lambda ev: (gate.wait(), orig(ev))[1]
        svc.submit(QueryPlacement())
        try:
            with pytest.raises(RuntimeError, match="did not stop"):
                svc.close(timeout_s=0.2)
        finally:
            gate.set()  # release the worker so the daemon thread exits


# --------------------------------------------------------------------- #
# streaming traces (satellite: O(1)-memory arrival streams)
# --------------------------------------------------------------------- #
class TestArrivalStreams:
    def test_iter_poisson_prefix_matches_list(self):
        topo = Topology.paper_testbed()
        lst = poisson_trace(topo, num_jobs=10, seed=5)
        stream = list(islice(iter_poisson_trace(topo, num_jobs=None, seed=5), 10))
        assert [
            (j.job_id, j.model, j.num_workers, j.duration_iters, j.arrival_ms)
            for j in lst
        ] == [
            (j.job_id, j.model, j.num_workers, j.duration_iters, j.arrival_ms)
            for j in stream
        ]

    def test_scenario_arrival_stream_matches_trace(self):
        for name in ("poisson-paper", "arrival-burst", "multitenant-8"):
            spec = get_scenario(name)
            topo = spec.topology()
            lst = spec.trace(topo)
            stream = list(spec.arrival_stream(topo))
            assert [(j.job_id, j.arrival_ms) for j in lst] == [
                (j.job_id, j.arrival_ms) for j in stream
            ]

    def test_unbounded_stream_is_lazy(self):
        topo = Topology.paper_testbed()
        it = iter_poisson_trace(topo, num_jobs=None, seed=0)
        head = [next(it) for _ in range(100)]
        assert len({j.job_id for j in head}) == 100
        assert all(
            a.arrival_ms <= b.arrival_ms for a, b in zip(head, head[1:])
        )


# --------------------------------------------------------------------- #
# latency recorder
# --------------------------------------------------------------------- #
class TestLatencyRecorder:
    def test_percentiles_nearest_rank(self):
        rec = LatencyRecorder()
        for v in range(1, 101):  # 1..100 ms
            rec.observe("query", float(v))
        pct = rec.percentiles("query")
        assert pct == {"p50": 50.0, "p95": 95.0, "p99": 99.0}

    def test_empty_kind_is_nan(self):
        rec = LatencyRecorder()
        assert all(math.isnan(v) for v in rec.percentiles("nope").values())

    def test_snapshot_counters_and_gauges(self):
        rec = LatencyRecorder()
        rec.count("hits", 3)
        rec.gauge("depth", 5.0)
        rec.gauge("depth", 2.0)
        snap = rec.snapshot()
        assert snap["hits"] == 3.0
        assert snap["depth"] == 2.0
        assert snap["depth_peak"] == 5.0

    def test_window_bounds_memory(self):
        rec = LatencyRecorder(window=16)
        for v in range(1000):
            rec.observe("q", float(v))
        snap = rec.snapshot()
        assert snap["q_count"] == 1000.0
        assert rec.percentiles("q")["p50"] >= 984.0  # only the tail kept

    def test_single_sample_is_every_percentile(self):
        # nearest-rank over n=1: ceil(q/100)-1 == 0 for every q — the one
        # sample answers p50, p95 and p99 alike (no interpolation to NaN)
        rec = LatencyRecorder()
        rec.observe("q", 7.5)
        assert rec.percentiles("q") == {"p50": 7.5, "p95": 7.5, "p99": 7.5}

    def test_two_samples_split_by_rank(self):
        # n=2: p50 → ceil(1.0)-1 = index 0 (the smaller sample), p95/p99
        # → ceil(1.9)/ceil(1.98)-1 = index 1 (the larger) — well-defined,
        # order-independent
        rec = LatencyRecorder()
        rec.observe("q", 9.0)
        rec.observe("q", 3.0)
        assert rec.percentiles("q") == {"p50": 3.0, "p95": 9.0, "p99": 9.0}

    def test_snapshot_never_raises_on_sparse_kinds(self):
        # telemetry() calls snapshot() mid-incident: 0/1/2-sample kinds
        # must export cleanly alongside warm ones
        rec = LatencyRecorder()
        rec.observe("one", 1.0)
        rec.observe("two", 2.0)
        rec.observe("two", 4.0)
        snap = rec.snapshot()
        assert snap["one_p99_ms"] == 1.0
        assert snap["two_p50_ms"] == 2.0 and snap["two_p99_ms"] == 4.0
        assert snap["one_count"] == 1.0

    def test_invalid_window_rejected_at_construction(self):
        # fail fast (not mid-incident on the first observe())
        with pytest.raises(ValueError, match="window must be >= 1"):
            LatencyRecorder(window=0)
        with pytest.raises(ValueError, match="-3"):
            LatencyRecorder(window=-3)
