"""Property tests for ``segments_from_pattern``: exact tiling of
``[0, iter_time_ms)`` and Gbit conservation, including wrapped and
overlapping phases (the cases whose sub-ε cut slivers used to be dropped
and desynchronize iteration boundaries)."""

import math
import random

import pytest

from repro.cluster.network import segments_from_pattern
from repro.core.circle import CommPattern, Phase


def _check_invariants(pattern: CommPattern) -> None:
    segs = segments_from_pattern(pattern)
    t = pattern.iter_time_ms
    # every segment carries real width — slivers are folded, never emitted
    assert all(s.duration_ms > 0.0 for s in segs)
    # exact tiling: widths sum to the iteration period
    total = sum(s.duration_ms for s in segs)
    assert math.isclose(total, t, rel_tol=0.0, abs_tol=1e-6), (total, t)
    # Gbit conservation: overlapping demands add, wrapped phases keep
    # their full duration, so the integral equals the per-phase sum.
    # Slivers are billed at a neighbour's level — error ≤ gbps·ε each.
    want = sum(ph.gbps * ph.duration_ms for ph in pattern.phases)
    got = sum(s.gbps * s.duration_ms for s in segs if s.kind == "comm")
    assert math.isclose(
        got, want, rel_tol=1e-9, abs_tol=1e-6 * max(1.0, want)
    ), (got, want)
    # merge predicate: adjacent segments never share (kind, level)
    for a, b in zip(segs, segs[1:]):
        assert (a.kind, a.gbps) != (b.kind, b.gbps)


@pytest.mark.parametrize(
    "phases",
    [
        (),                                        # pure compute
        ((0.0, 100.0, 40.0),),                     # whole-iteration comm
        ((20.0, 30.0, 25.0),),                     # interior phase
        ((80.0, 40.0, 25.0),),                     # wraps past the period
        ((90.0, 95.0, 10.0),),                     # wraps almost fully
        ((10.0, 50.0, 20.0), (30.0, 50.0, 15.0)),  # overlapping, adds
        ((80.0, 40.0, 25.0), (10.0, 30.0, 10.0)),  # wrap over a phase
        ((250.0, 30.0, 18.0),),                    # start beyond period
        # nearly-coincident cut points: sub-ε slivers must fold, not drop
        ((20.0, 30.0, 25.0), (20.0 + 1e-12, 30.0, 5.0)),
        ((0.0, 100.0 - 1e-12, 40.0),),
    ],
)
def test_segment_invariants_explicit(phases):
    pattern = CommPattern(
        100.0, tuple(Phase(*p) for p in phases), name="t"
    )
    _check_invariants(pattern)


@pytest.mark.parametrize("seed", range(40))
def test_segment_invariants_seeded(seed):
    rng = random.Random(seed)
    t = rng.choice((50.0, 100.0, 250.0, 1000.0))
    phases = tuple(
        Phase(
            start_ms=rng.uniform(0.0, 3.0 * t),
            duration_ms=rng.uniform(1e-9, t),
            gbps=rng.uniform(0.1, 50.0),
        )
        for _ in range(rng.randint(0, 5))
    )
    _check_invariants(CommPattern(t, phases, name=f"s{seed}"))


def test_segment_invariants_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    finite = {"allow_nan": False, "allow_infinity": False}

    @settings(max_examples=150, deadline=None)
    @given(
        t=st.floats(min_value=1.0, max_value=10_000.0, **finite),
        raw=st.lists(
            st.tuples(
                st.floats(min_value=0.0, max_value=30_000.0, **finite),
                st.floats(min_value=1e-9, max_value=1.0, **finite),
                st.floats(min_value=0.01, max_value=100.0, **finite),
            ),
            max_size=6,
        ),
    )
    def run(t, raw):
        phases = tuple(
            # duration as a fraction of the period keeps phases ≤ one lap
            Phase(start_ms=s, duration_ms=frac * t, gbps=g)
            for s, frac, g in raw
        )
        _check_invariants(CommPattern(t, phases, name="h"))

    run()
