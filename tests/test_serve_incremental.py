"""Incremental cache parity: delta add/remove/update sequences must
reproduce the full-rebuild state bit for bit — incidence tables, exec
state, water-filling allocations, advance traces, plugin link cache
(ISSUE 6 tentpole, property-tested; the hypothesis harness deepens the
seeded sweeps when hypothesis is installed)."""

from __future__ import annotations

import copy
import random

import numpy as np
import pytest

from repro.cluster import FluidNetworkSim, Topology, poisson_trace
from repro.cluster.job import JobState
from repro.core.plugin import CassiniModule


def _placed_jobs(topo, n, seed, workers_cap=3):
    jobs = poisson_trace(topo, num_jobs=n, seed=seed)
    g = 0
    for j in jobs:
        take = min(j.num_workers, workers_cap)
        j.placement = tuple(range(g, g + take))
        g += take
    return jobs


def _state_sig(sim):
    if sim.vectorized and sim._inc is not None:
        sim._sync_execs()
    return {
        jid: (
            ex.seg_idx, ex.remaining, ex.delay_ms, ex.marks,
            ex.iter_start_ms, ex.applied_shift_ms, ex.ideal_next_ms,
            ex.consec_adjust, ex.skip_record,
        )
        for jid, ex in sim._execs.items()
    }


def _incidence_sig(sim):
    """Live rows of the delta engine's incidence, in exec order."""
    if not sim.vectorized:
        return None
    return [
        sim._inc.rows[sim._slot_of[jid]].tolist() for jid in sim._execs
    ]


def _assert_equal(rebuild, delta):
    assert _state_sig(rebuild) == _state_sig(delta)
    assert rebuild._allocate() == delta._allocate()
    assert rebuild._mark_rates() == delta._mark_rates()
    if rebuild.vectorized:
        # a rebuilt incidence row set over the same running order
        rows = [r.tolist() for r in rebuild._inc.rows]
        assert rows == _incidence_sig(delta)


def _apply_script(topo, script, *, advance_ms=400.0):
    """Run one op script through rebuild-only and delta engines in
    lockstep, checking bit-exact parity after every step.

    ``script`` is a list of ("add", job) / ("remove", job_id) /
    ("migrate", job_id, new_placement) /
    ("resize", job_id, new_num_workers, new_placement) /
    ("cutoff", job_id) / ("advance",) ops over deep-copied job
    populations.
    """
    A = FluidNetworkSim(topo, seed=0)           # rebuild reference
    B = FluidNetworkSim(topo, seed=0)           # delta engine
    jobs_a: list = []
    jobs_b: list = []

    def by_id(jobs, jid):
        return next(j for j in jobs if j.job_id == jid)

    for op in script:
        if op[0] == "add":
            ja, jb = copy.deepcopy(op[1]), copy.deepcopy(op[1])
            jobs_a.append(ja)
            jobs_b.append(jb)
            A.configure(list(jobs_a))
            assert B.configure_incremental(list(jobs_b)) == "delta"
        elif op[0] == "remove":
            jobs_a = [j for j in jobs_a if j.job_id != op[1]]
            jobs_b = [j for j in jobs_b if j.job_id != op[1]]
            A.configure(list(jobs_a))
            assert B.configure_incremental(list(jobs_b)) == "delta"
        elif op[0] == "migrate":
            by_id(jobs_a, op[1]).placement = tuple(op[2])
            by_id(jobs_b, op[1]).placement = tuple(op[2])
            A.configure(list(jobs_a))
            assert B.configure_incremental(list(jobs_b)) == "delta"
        elif op[0] == "resize":
            # elastic resize (chaos JobResize follow-through): the worker
            # count changes the comm pattern/segments, the placement the
            # link columns — update_job must drop the alloc cache for both
            for jobs in (jobs_a, jobs_b):
                j = by_id(jobs, op[1])
                j.num_workers = op[2]
                j.placement = tuple(op[3])
            A.configure(list(jobs_a))
            assert B.configure_incremental(list(jobs_b)) == "delta"
        elif op[0] == "cutoff":
            by_id(jobs_a, op[1]).state = JobState.CUTOFF
            by_id(jobs_b, op[1]).state = JobState.CUTOFF
        elif op[0] == "advance":
            fa = A.advance(A.now_ms + advance_ms)
            fb = B.advance(B.now_ms + advance_ms)
            assert [j.job_id for j in fa] == [j.job_id for j in fb]
            assert A.now_ms == B.now_ms
        else:  # pragma: no cover
            raise AssertionError(op)
        _assert_equal(A, B)
    return A, B


# --------------------------------------------------------------------- #
# seeded sweeps (always run)
# --------------------------------------------------------------------- #
class TestDeltaParitySeeded:
    def test_arrival_departure_churn(self):
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 8, seed=3)
        script = []
        for j in jobs[:5]:
            script += [("add", j), ("advance",)]
        script += [
            ("remove", jobs[1].job_id), ("advance",),
            ("add", jobs[5]), ("advance",),
            ("remove", jobs[3].job_id),
            ("remove", jobs[0].job_id), ("advance",),
            ("add", jobs[6]), ("add", jobs[7]), ("advance",),
        ]
        _apply_script(topo, script)

    def test_cutoff_jobs_stay_frozen(self):
        """CUTOFF jobs hold no link share in either engine — the delta
        path must agree through cutoff churn too."""
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 4, seed=9)
        script = [("add", j) for j in jobs]
        script += [
            ("advance",),
            ("cutoff", jobs[0].job_id), ("advance",),
            ("cutoff", jobs[2].job_id), ("advance",),
            ("remove", jobs[0].job_id), ("advance",),
        ]
        _apply_script(topo, script)

    def test_inplace_migration_clears_cache(self):
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 4, seed=5)
        script = [("add", j) for j in jobs] + [("advance",)]
        # move job 1 to a different rack: link columns change in place
        script += [
            ("migrate", jobs[1].job_id, tuple(range(18, 18 + len(jobs[1].placement)))),
            ("advance",),
        ]
        A, B = _apply_script(topo, script)
        assert B._execs  # sanity: still running

    def test_departure_keeps_alloc_cache(self):
        """remove_job only clears the alive bit — the water-filling cache
        survives, and post-departure solves reuse it where sound."""
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 5, seed=7)
        B = FluidNetworkSim(topo, seed=0)
        for i, j in enumerate(jobs):
            assert B.configure_incremental(jobs[: i + 1]) == "delta"
        B.advance(B.now_ms + 1000.0)
        cache_before = len(B._alloc_cache)
        B.configure_incremental([j for j in jobs if j is not jobs[2]])
        assert len(B._alloc_cache) == cache_before  # retained, not cleared

    def test_compaction_after_heavy_departures(self):
        """Dead slots outnumbering live ones trigger a compacting rebuild;
        parity must hold across the compaction boundary."""
        topo = Topology(num_racks=8, servers_per_rack=6)
        jobs = _placed_jobs(topo, 14, seed=11, workers_cap=2)
        script = [("add", j) for j in jobs] + [("advance",)]
        for j in jobs[:11]:  # 11 dead vs 3 live → compaction fires
            script.append(("remove", j.job_id))
        script += [("advance",), ("add", _placed_jobs(topo, 15, seed=12)[-1])]
        A, B = _apply_script(topo, script)
        assert len(B._slots) == int(np.count_nonzero(B._alive))  # compacted

    def test_resize_churn_matches_rebuild(self):
        """Mid-epoch elastic resizes (grow and shrink) mixed with
        arrivals/departures: the update_job resize path must stay
        bit-exact against the full rebuild (ISSUE 8 satellite)."""
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 6, seed=13)
        script = [("add", j) for j in jobs[:4]] + [("advance",)]
        script += [
            # shrink job 0 (device loss), same base slot
            ("resize", jobs[0].job_id, 2, (0, 1)), ("advance",),
            # grow job 2 onto a wider span (crosses a rack boundary)
            ("resize", jobs[2].job_id, 4, (10, 11, 12, 13)), ("advance",),
            ("add", jobs[4]), ("remove", jobs[1].job_id), ("advance",),
            # resize straight after membership churn
            ("resize", jobs[3].job_id, 3, (18, 19, 20)),
            ("add", jobs[5]), ("advance",),
            # resize back to the original width: no stale cache reuse
            ("resize", jobs[0].job_id, 3, (0, 1, 2)), ("advance",),
        ]
        _apply_script(topo, script)

    def test_resize_same_placement_drops_cache(self):
        """A resize that keeps the placement (pattern change only) must
        still invalidate the allocation cache — the (mask, seg) keys
        would otherwise serve rates for the old segment list."""
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 3, seed=21)
        B = FluidNetworkSim(topo, seed=0)
        assert B.configure_incremental(copy.deepcopy(jobs)) == "delta"
        B.advance(B.now_ms + 500.0)
        assert B._alloc_cache  # warmed
        resized = copy.deepcopy(jobs)
        resized[1].num_workers = max(1, resized[1].num_workers - 1)
        assert B.configure_incremental(resized) == "delta"
        assert not B._alloc_cache  # dropped, not reused
        B.advance(B.now_ms + 500.0)  # re-solves cleanly on the new pattern
        assert B._alloc_cache

    def test_reorder_falls_back_to_rebuild(self):
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 4, seed=2)
        B = FluidNetworkSim(topo, seed=0)
        assert B.configure_incremental(list(jobs)) == "delta"
        assert B.configure_incremental(list(reversed(jobs))) == "rebuild"
        A = FluidNetworkSim(topo, seed=0)
        A.configure(list(reversed(copy.deepcopy(jobs))))
        assert _state_sig(A) == _state_sig(B)

    def test_add_existing_job_rejected(self):
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 2, seed=0)
        B = FluidNetworkSim(topo, seed=0)
        B.configure_incremental(jobs)
        with pytest.raises(ValueError, match="already configured"):
            B.add_job(jobs[0])
        with pytest.raises(KeyError):
            B.remove_job("nope")

    def test_scalar_engine_delta_parity(self):
        """The delta path is engine-agnostic: the scalar oracle under
        configure_incremental matches its own rebuild too."""
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 5, seed=4)
        A = FluidNetworkSim(topo, seed=0, vectorized=False)
        B = FluidNetworkSim(topo, seed=0, vectorized=False)
        ja, jb = copy.deepcopy(jobs), copy.deepcopy(jobs)
        for i in range(len(jobs)):
            A.configure(ja[: i + 1])
            assert B.configure_incremental(jb[: i + 1]) == "delta"
            A.advance(A.now_ms + 300.0)
            B.advance(B.now_ms + 300.0)
            assert _state_sig(A) == _state_sig(B)


# --------------------------------------------------------------------- #
# topology incidence deltas
# --------------------------------------------------------------------- #
class TestIncidenceDeltas:
    def test_with_row_matches_rebuild(self):
        topo = Topology.paper_testbed()
        placements = [(0, 6), (1, 7), (2, 13)]
        inc = topo.incidence(placements[:2])
        grown = inc.with_row(topo.job_link_ids(placements[2]))
        full = topo.incidence(placements)
        assert (grown.matrix == full.matrix).all()
        assert grown.num_links == full.num_links

    def test_without_row_matches_rebuild(self):
        topo = Topology.paper_testbed()
        placements = [(0, 6), (1, 7), (2, 13)]
        inc = topo.incidence(placements)
        shrunk = inc.without_row(1)
        full = topo.incidence([placements[0], placements[2]])
        assert (shrunk.matrix == full.matrix).all()
        with pytest.raises(IndexError):
            inc.without_row(3)


# --------------------------------------------------------------------- #
# plugin link-cache deltas
# --------------------------------------------------------------------- #
class TestPluginCacheDeltas:
    def _score_pair(self, module, topo, placements, jobs):
        from repro.core.plugin import PlacementCandidate

        patterns = {j.job_id: j.pattern(num_workers=len(j.placement)) for j in jobs}
        caps = {}
        job_links = {}
        for j in jobs:
            links = topo.job_links(j.placement)
            job_links[j.job_id] = [l.name for l in links]
            caps.update({l.name: l.capacity_gbps for l in links})
        cand = PlacementCandidate(job_links=job_links, meta={})
        return module.score_candidates([cand], patterns, caps)

    def test_remove_job_evicts_and_resolves_identically(self):
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 3, seed=1)
        # force link sharing: put everyone on the same uplink-heavy span
        for i, j in enumerate(jobs):
            j.placement = (i, 6 + i, 12 + i)
        module = CassiniModule(seed=0)
        first = self._score_pair(module, topo, None, jobs)
        hits0, misses0 = module.cache_hits, module.cache_misses
        assert misses0 > 0
        again = self._score_pair(module, topo, None, jobs)
        assert module.cache_hits > hits0          # warm second pass
        assert module.cache_misses == misses0
        evicted = module.remove_job(jobs[0].pattern(num_workers=3))
        assert evicted > 0
        cold = self._score_pair(module, topo, None, jobs)
        # re-solving after eviction reproduces the same frozen results
        assert [cand.link_scores for cand, _, _ in cold] == [
            cand.link_scores for cand, _, _ in again
        ]

    def test_add_job_is_documented_noop(self):
        module = CassiniModule(seed=0)
        topo = Topology.paper_testbed()
        jobs = _placed_jobs(topo, 1, seed=1)
        module.add_job(jobs[0].pattern(num_workers=2))
        assert module.remove_job("not-cached-model") == 0


# --------------------------------------------------------------------- #
# hypothesis harness (property-based churn; the seeded sweeps above run
# regardless, so the module keeps coverage where hypothesis is absent)
# --------------------------------------------------------------------- #
def _random_script(topo, seed: int, length: int):
    """Random churn script: arrivals, departures, migrations, elastic
    resizes, cutoffs and advances over a 10-job population (shared by
    hypothesis and the seeded fuzz fallback)."""
    rng = random.Random(seed)
    jobs = _placed_jobs(topo, 10, seed=seed % 50)
    alive: list = []
    pool = list(jobs)
    script = []
    widths: dict[str, int] = {}
    for _ in range(length):
        choices = ["advance"]
        if pool:
            choices += ["add", "add"]
        if alive:
            choices += ["remove", "migrate", "resize", "cutoff"]
        op = rng.choice(choices)
        if op == "add":
            j = pool.pop(0)
            alive.append(j)
            widths[j.job_id] = len(j.placement)
            script.append(("add", j))
        elif op == "remove":
            j = alive.pop(rng.randrange(len(alive)))
            script.append(("remove", j.job_id))
        elif op == "migrate":
            j = rng.choice(alive)
            w = widths[j.job_id]
            base = rng.randrange(0, topo.num_gpus - w)
            script.append(
                ("migrate", j.job_id, tuple(range(base, base + w)))
            )
        elif op == "resize":
            # elastic grow/shrink to a fresh width, chaos-JobResize style
            j = rng.choice(alive)
            w = rng.randint(1, 4)
            widths[j.job_id] = w
            base = rng.randrange(0, topo.num_gpus - w)
            script.append(
                ("resize", j.job_id, w, tuple(range(base, base + w)))
            )
        elif op == "cutoff":
            script.append(("cutoff", rng.choice(alive).job_id))
        else:
            script.append(("advance",))
    return script


@pytest.mark.parametrize("seed", [0, 17, 4242])
def test_random_churn_scripts_match_rebuild(seed):
    topo = Topology(num_racks=6, servers_per_rack=6)
    script = _random_script(topo, seed, length=14)
    _apply_script(topo, script, advance_ms=250.0)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - optional dev dependency
    pass
else:

    @given(seed=st.integers(0, 10_000), length=st.integers(4, 18))
    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_delta_sequences_match_rebuild(seed, length):
        topo = Topology(num_racks=6, servers_per_rack=6)
        script = _random_script(topo, seed, length)
        _apply_script(topo, script, advance_ms=250.0)
