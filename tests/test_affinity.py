"""Affinity graph tests: Algorithm 1 + Theorem 1 (property-based)."""

import random

import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.affinity import AffinityGraph


def _chain_graph():
    g = AffinityGraph()
    g.add_edge("j1", "l1", 0.0, 40.0)
    g.add_edge("j2", "l1", 15.0, 60.0)
    g.add_edge("j2", "l2", 5.0, 60.0)
    g.add_edge("j3", "l2", 25.0, 80.0)
    return g


def test_chain_no_loop_and_theorem1():
    g = _chain_graph()
    assert not g.has_loop()
    shifts = g.bfs_time_shifts(seed=0)
    assert set(shifts) == {"j1", "j2", "j3"}
    assert g.check_theorem1(shifts)


def test_loop_detection():
    g = _chain_graph()
    g.add_edge("j1", "l2", 3.0, 40.0)  # j1–l1–j2–l2–j1 cycle
    assert g.has_loop()


def test_corrupted_shift_fails_theorem1():
    g = _chain_graph()
    shifts = g.bfs_time_shifts(seed=0)
    bad = dict(shifts)
    bad["j3"] = (bad["j3"] + 7.0) % 80.0
    assert not g.check_theorem1(bad)


def test_disconnected_components_handled():
    g = _chain_graph()
    g.add_edge("j4", "l9", 11.0, 100.0)
    g.add_edge("j5", "l9", 31.0, 100.0)
    shifts = g.bfs_time_shifts(seed=1)
    assert set(shifts) == {"j1", "j2", "j3", "j4", "j5"}
    assert g.check_theorem1(shifts)


def test_reference_seed_changes_are_still_correct():
    g = _chain_graph()
    for seed in range(5):
        shifts = g.bfs_time_shifts(seed=seed)
        assert g.check_theorem1(shifts), f"seed {seed}"


# -------------------- property: random loop-free trees ----------------- #
@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_theorem1_on_random_trees(data):
    """Build a random bipartite TREE (jobs/links), random weights and
    iteration times; Algorithm 1's output must satisfy Theorem 1."""
    rng = random.Random(data.draw(st.integers(0, 10_000)))
    n_jobs = data.draw(st.integers(2, 8))
    iter_times = [rng.choice([40.0, 60.0, 80.0, 100.0, 120.0]) for _ in range(n_jobs)]

    g = AffinityGraph()
    # attach each new job to an existing job through a fresh link (tree!)
    for j in range(1, n_jobs):
        k = rng.randrange(j)  # existing job
        link = f"l{j}"
        w_k = rng.uniform(0, iter_times[k])
        w_j = rng.uniform(0, iter_times[j])
        g.add_edge(f"j{k}", link, w_k, iter_times[k])
        g.add_edge(f"j{j}", link, w_j, iter_times[j])
        # occasionally add a third job to the same link (star pattern)
        if j >= 2 and rng.random() < 0.3:
            m = rng.randrange(j)
            if f"j{m}" not in g.link_jobs[link]:
                g.add_edge(f"j{m}", link, rng.uniform(0, iter_times[m]),
                           iter_times[m])

    if g.has_loop():  # star additions can close cycles; skip those draws
        return
    shifts = g.bfs_time_shifts(seed=0)
    assert set(shifts) == set(g.jobs)
    assert g.check_theorem1(shifts, unit_ms=1e-4)
    # uniqueness: every job got exactly one value in [0, iter_time)
    for j, t in shifts.items():
        assert 0.0 <= t < g.iter_time_ms[j] + 1e-9
