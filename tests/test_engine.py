"""Engine tests: pipeline stages, AlignmentPlan, batched scoring golden
equivalence, and the scenario registry."""

import numpy as np
import pytest

from repro.cluster import ClusterSimulator, Topology, snapshot_trace
from repro.cluster.job import Job, JobState
from repro.core.circle import CommPattern, Phase
from repro.core.compat import find_rotations, find_rotations_batched
from repro.core.plugin import CassiniModule, PlacementCandidate
from repro.engine import (
    AlignmentPlan,
    JobAlignment,
    SchedulingPipeline,
    ScenarioSpec,
    get_scenario,
    list_scenarios,
    register_scenario,
)
from repro.engine.pipeline import (
    AllocateStage,
    Allocation,
    ProposalSet,
    ProposeStage,
    ScoredProposals,
    ScoreStage,
)
from repro.engine.scenarios import _REGISTRY
from repro.sched import CassiniAugmented, ThemisScheduler
from repro.sched.base import ClusterState, Decision
from repro.sched.fixed import FixedPlacementScheduler


def _state(topo, n_jobs=5, workers=7):
    jobs = [
        Job(job_id=f"j{i}", model=["vgg16", "bert", "gpt1", "resnet50", "dlrm"][i % 5],
            num_workers=workers, duration_iters=100)
        for i in range(n_jobs)
    ]
    for j in jobs:
        j.state = JobState.RUNNING
    return ClusterState(topology=topo, now_ms=0.0, running=jobs, pending=[])


def _problems():
    """A mix of 2-job (batchable) and 3-job (scalar-fallback) link problems."""
    def pat(it, start_frac, dur_frac, gbps, name):
        return CommPattern(it, (Phase(start_frac * it, dur_frac * it, gbps),), name)

    out = []
    for i, it in enumerate((320.0, 280.0, 200.0, 450.0)):
        out.append((
            [pat(it, 0.35, 0.4, 45.0, f"a{i}"), pat(it, 0.55, 0.35, 40.0, f"b{i}")],
            50.0,
        ))
    out.append((
        [pat(300.0, 0.1, 0.3, 40.0, "x"), pat(300.0, 0.4, 0.3, 40.0, "y"),
         pat(300.0, 0.7, 0.2, 40.0, "z")],
        50.0,
    ))
    out.append(([pat(250.0, 0.2, 0.5, 45.0, "solo")], 50.0))
    return out


# ---------------------------------------------------------------------- #
# batched scoring golden equivalence
# ---------------------------------------------------------------------- #
def test_find_rotations_batched_matches_scalar():
    problems = _problems()
    scalar = [find_rotations(p, c) for p, c in problems]
    batched = find_rotations_batched(problems)
    assert len(batched) == len(scalar)
    for s, b in zip(scalar, batched):
        assert b.score == pytest.approx(s.score, abs=1e-9)
        assert b.shifts_steps == s.shifts_steps
        assert np.allclose(b.shifts_ms, s.shifts_ms)
        assert np.allclose(b.paced_periods_ms, s.paced_periods_ms)


def test_module_batched_path_matches_scalar_path():
    def pats():
        return {
            "a": CommPattern(320.0, (Phase(160.0, 140.0, 45.0),), "a"),
            "b": CommPattern(320.0, (Phase(170.0, 130.0, 45.0),), "b"),
            "c": CommPattern(200.0, (Phase(40.0, 150.0, 45.0),), "c"),
        }

    caps = {"l1": 50.0, "l2": 50.0}

    def cands():
        return [
            PlacementCandidate(job_links={"a": ["l1"], "c": ["l1"], "b": []}),
            PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"], "c": []}),
            PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"], "c": ["l2", "l1"]}),
        ]

    d_scalar = CassiniModule().decide(cands(), pats(), caps, batched=False)
    d_batched = CassiniModule().decide(cands(), pats(), caps, batched=True)
    assert [c.score for c in d_batched.candidates] == pytest.approx(
        [c.score for c in d_scalar.candidates]
    )
    assert d_batched.time_shifts_ms == pytest.approx(d_scalar.time_shifts_ms)
    assert d_batched.paced_periods_ms == pytest.approx(d_scalar.paced_periods_ms)
    assert d_batched.job_min_score == pytest.approx(d_scalar.job_min_score)


def test_batched_path_populates_shared_cache():
    pats = {
        "a": CommPattern(320.0, (Phase(160.0, 140.0, 45.0),), "a"),
        "b": CommPattern(320.0, (Phase(170.0, 130.0, 45.0),), "b"),
    }
    mod = CassiniModule()
    cands = [PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"]})
             for _ in range(4)]
    mod.score_candidates_batched(cands, pats, {"l1": 50.0})
    assert len(mod._link_cache) == 1


def test_pipeline_golden_equivalence_with_scalar_schedule():
    """The batched pipeline reproduces the scalar path's decisions on a
    fragmented cluster (same placements, same shifts)."""
    topo = Topology.paper_testbed()
    d_batched = CassiniAugmented(ThemisScheduler(), num_candidates=8).schedule(
        _state(topo)
    )
    d_scalar = CassiniAugmented(
        ThemisScheduler(), num_candidates=8, batched=False
    ).schedule(_state(topo))
    assert d_batched.placements == d_scalar.placements
    assert d_batched.compat_score == pytest.approx(d_scalar.compat_score)
    for jid, t in d_scalar.time_shifts_ms.items():
        assert d_batched.time_shifts_ms[jid] == pytest.approx(t, abs=1e-6)


# ---------------------------------------------------------------------- #
# stages
# ---------------------------------------------------------------------- #
def test_allocate_and_propose_stages_typed_outputs():
    topo = Topology.paper_testbed()
    state = _state(topo)
    host = ThemisScheduler()
    alloc = AllocateStage(host).run(state)
    assert isinstance(alloc, Allocation)
    assert alloc.workers == host.allocate_workers(state)
    props = ProposeStage(host, num_candidates=6).run(state, alloc)
    assert isinstance(props, ProposalSet)
    assert 1 <= len(props.placements) <= 6
    for pl in props.placements:
        for jid, servers in pl.items():
            assert len(servers) == alloc.workers[jid]


def test_score_stage_builds_and_scores_candidates():
    topo = Topology.paper_testbed()
    state = _state(topo)
    host = ThemisScheduler()
    props = ProposeStage(host, 5).run(state, AllocateStage(host).run(state))
    scored = ScoreStage(CassiniModule()).run(state, props)
    assert isinstance(scored, ScoredProposals)
    assert len(scored.evaluated) == len(props.placements)
    for cand, graph, _ in scored.evaluated:
        assert cand.discarded_loop or np.isfinite(cand.score)
    assert set(scored.patterns) <= {j.job_id for j in state.running}


def test_score_stage_rejects_mismatched_worker_counts():
    """CASSINI scores one pattern per job: candidates that disagree on a
    job's worker count must be rejected, not silently mis-scored."""
    topo = Topology.paper_testbed()
    state = _state(topo, n_jobs=1, workers=4)
    props = ProposalSet(
        workers={"j0": 2}, placements=({"j0": (0, 6)}, {"j0": (0, 1, 6, 7)})
    )
    with pytest.raises(ValueError, match="disagree on worker count"):
        ScoreStage(CassiniModule()).run(state, props)


def test_scenario_run_respects_zero_horizon():
    run = get_scenario("fig2-interleave").run("fair-share", horizon_ms=0)
    assert run.metrics.iter_times() == []


def test_align_stage_emits_plan_not_meta():
    topo = Topology.paper_testbed()
    state = _state(topo)
    decision = SchedulingPipeline.cassini(ThemisScheduler()).schedule(state)
    assert isinstance(decision, Decision)
    assert "align_ok" not in decision.meta and "paced_ms" not in decision.meta
    plan = decision.plan
    assert isinstance(plan, AlignmentPlan)
    assert plan.num_candidates >= 1
    for jid, shift in plan.time_shifts_ms.items():
        d = plan.directive_for(jid)
        assert isinstance(d, JobAlignment)
        assert d.shift_ms == pytest.approx(shift)
        assert d.hold == plan.align_ok(jid)
    assert plan.directive_for("no-such-job") is None


def test_empty_cluster_yields_empty_decision():
    topo = Topology.paper_testbed()
    state = ClusterState(topology=topo, now_ms=0.0, running=[], pending=[])
    decision = SchedulingPipeline.cassini(ThemisScheduler()).schedule(state)
    assert decision.placements == {}
    assert decision.plan is None or not decision.plan.time_shifts_ms


def test_plan_flows_into_job_alignment():
    """End-to-end: the simulator applies typed directives from the plan."""
    topo = Topology.paper_testbed()
    pl = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}
    jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=30)
    sched = CassiniAugmented(FixedPlacementScheduler(pl), num_candidates=1)
    sim = ClusterSimulator(topo, sched)
    m = sim.run(jobs, horizon_ms=600_000)
    _, first = sim.decisions[0]
    assert isinstance(first.plan, AlignmentPlan)
    # the contended pair gets shifts + pacing periods in the typed plan
    assert set(first.plan.time_shifts_ms) == set(pl)
    assert set(first.plan.paced_periods_ms) == set(pl)
    shifted = [j for j in m.jobs if j.alignment.shift_ms > 0]
    assert shifted, "one of the two jobs must carry a non-zero shift"
    assert all(j.state == JobState.DONE for j in m.jobs)


# ---------------------------------------------------------------------- #
# scenario registry
# ---------------------------------------------------------------------- #
def test_builtin_scenarios_registered():
    names = set(list_scenarios())
    assert {"fig2-interleave", "poisson-paper", "dynamic-burst",
            "modelpar-burst", "multigpu", "hetero-16rack",
            "rack-scaling-16", "rack-scaling-32", "rack-scaling-64",
            "arrival-poisson", "arrival-burst", "arrival-diurnal"} <= names


def test_rack_scaling_sweep_registered():
    """Registry smoke test for the scaling sweep: 16/32/64-rack fabrics
    with alternating NIC generations and a load that grows with the
    fabric; the smallest entry actually simulates."""
    from repro.engine.scenarios import RACK_SCALING_SWEEP

    assert RACK_SCALING_SWEEP == (16, 32, 64)
    jobs_by_racks = {}
    for racks in RACK_SCALING_SWEEP:
        spec = get_scenario(f"rack-scaling-{racks}")
        topo = spec.topology()
        assert topo.num_racks == racks and topo.servers_per_rack == 4
        assert {l.capacity_gbps for l in topo.links.values()} == {50.0, 100.0}
        assert topo.rack_nic(0) == 50.0 and topo.rack_nic(1) == 100.0
        jobs_by_racks[racks] = spec.trace(topo)
    # multi-tenant load grows with the fabric
    assert (len(jobs_by_racks[16]) < len(jobs_by_racks[32])
            < len(jobs_by_racks[64]))

    run = get_scenario("rack-scaling-16").run("themis", horizon_ms=600_000.0)
    assert run.metrics.iter_times(), "scaling scenario must actually simulate"


@pytest.mark.slow
def test_rack_scaling_64_smoke():
    """The 64-rack entry builds and simulates end to end (capped horizon);
    jobs make progress across the large fabric."""
    run = get_scenario("rack-scaling-64").run("themis", horizon_ms=600_000.0)
    assert len(run.metrics.jobs) == 56
    assert sum(j.iters_done for j in run.metrics.jobs) > 1000


def test_arrival_sweep_registered():
    """The arrival-pattern variants share one job population and differ
    only in arrival times; burst arrivals are clustered."""
    from repro.engine.scenarios import ARRIVAL_SWEEP

    assert ARRIVAL_SWEEP == ("poisson", "burst", "diurnal")
    topo = Topology.paper_testbed()
    traces = {
        pat: get_scenario(f"arrival-{pat}").trace(topo) for pat in ARRIVAL_SWEEP
    }
    pops = {
        pat: [(j.model, j.num_workers, j.duration_iters) for j in js]
        for pat, js in traces.items()
    }
    assert pops["poisson"] == pops["burst"] == pops["diurnal"]
    arrivals = {
        pat: [j.arrival_ms for j in js] for pat, js in traces.items()
    }
    assert arrivals["poisson"] != arrivals["burst"]
    assert arrivals["poisson"] != arrivals["diurnal"]
    # bursts arrive in 4-job clusters (same instant within a burst)
    burst = arrivals["burst"]
    for i in range(0, len(burst) - 3, 4):
        assert burst[i] == burst[i + 1] == burst[i + 2] == burst[i + 3]
    # arrival times are sorted in every variant (the simulator requires it)
    for t in arrivals.values():
        assert t == sorted(t)

    run = get_scenario("arrival-burst").run("themis", horizon_ms=420_000.0)
    assert run.metrics.iter_times()


def test_arrival_burst_cassini_beats_host():
    """CASSINI-vs-host under the bursty arrival pattern: clustered
    arrivals maximise transient contention, so the time-shift alignment
    must recover avg JCT relative to the Themis host (the registry-driven
    comparison the bench's ``arrival`` family gates across all three
    patterns)."""
    spec = get_scenario("arrival-burst")
    host = spec.run("themis", horizon_ms=600_000.0)
    cass = spec.run("th+cassini", horizon_ms=600_000.0)
    assert cass.metrics.avg_jct_ms <= host.metrics.avg_jct_ms
    # the win comes from removing congestion, not from finishing fewer jobs
    assert (cass.metrics.summary()["jobs_finished"]
            >= host.metrics.summary()["jobs_finished"])


def test_hetero_16rack_topology_and_cassini_beats_host():
    """Registry smoke test: the heterogeneous 16-rack fabric builds with
    mixed 50/100 Gbps NIC rates and CASSINI is no worse than the Themis
    host on average JCT (deterministic trace + simulator seeds)."""
    spec = get_scenario("hetero-16rack")
    topo = spec.topology()
    assert topo.num_racks == 16
    assert {l.capacity_gbps for l in topo.links.values()} == {50.0, 100.0}
    assert topo.rack_nic(0) == 50.0 and topo.rack_nic(1) == 100.0

    host = spec.run("themis")
    cass = spec.run("th+cassini")
    assert cass.metrics.avg_jct_ms <= host.metrics.avg_jct_ms
    # the win comes from removing congestion, not from running fewer jobs
    assert (cass.metrics.summary()["jobs_finished"]
            >= host.metrics.summary()["jobs_finished"])


def test_multitenant_sweep_registered_and_contended():
    """Registry smoke test for the Table-2-style multi-tenant sweep: the
    2/4/8-tenant scenarios exist on the hetero-16rack fabric, the half-rack
    chain splits every tenant across two racks so interior uplinks carry
    two tenants — without any two tenants sharing a server — and CASSINI's
    time-shifts are no worse than fair-share on avg JCT at 4 tenants."""
    from repro.engine.scenarios import MULTITENANT_SWEEP

    assert MULTITENANT_SWEEP == (2, 4, 8)
    for n in MULTITENANT_SWEEP:
        spec = get_scenario(f"multitenant-{n}")
        assert set(spec.scheduler_names()) == {"fair-share", "cassini"}
        built = spec.build("fair-share")
        assert built.topology.num_racks == 16
        assert len(built.jobs) == n
        assert all(j.num_workers == 4 for j in built.jobs)
        placements = built.scheduler.placements
        assert len(placements) == n
        # no GPU double-booked across tenants
        all_servers = [s for srv in placements.values() for s in srv]
        assert len(all_servers) == len(set(all_servers))
        # every tenant crosses two racks, chained: tenant i's front-half
        # servers sit in tenant i+1's home rack (shared uplink)
        homes = [built.topology.rack_of(min(srv)) for srv in placements.values()]
        spills = [built.topology.rack_of(max(srv)) for srv in placements.values()]
        assert all(s == h + 1 for h, s in zip(homes, spills))
        assert spills[:-1] == homes[1:]

    spec4 = get_scenario("multitenant-4")
    fair = spec4.run("fair-share")
    cass = spec4.run("cassini")
    assert cass.metrics.avg_jct_ms <= fair.metrics.avg_jct_ms


def test_get_scenario_unknown_name():
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("no-such-scenario")


def test_scenario_build_wires_everything():
    built = get_scenario("fig2-interleave").build("cassini")
    assert built.topology.num_servers == 24
    assert len(built.jobs) == 2
    assert built.scheduler.name.endswith("+cassini")
    assert built.simulator.scheduler is built.scheduler
    with pytest.raises(KeyError, match="no scheduler"):
        get_scenario("fig2-interleave").build("themis")


def test_register_scenario_roundtrip():
    spec = ScenarioSpec(
        name="test-tiny",
        description="registry round-trip",
        topology=Topology.paper_testbed,
        trace=lambda topo: snapshot_trace([("vgg19", 2, 1400)], iters=5),
        compute_jitter=0.0,
    )
    try:
        register_scenario(spec)
        assert get_scenario("test-tiny") is spec
        with pytest.raises(ValueError, match="already registered"):
            register_scenario(spec)
        run = spec.run("th+cassini", horizon_ms=120_000)
        assert run.metrics.jobs and run.metrics.jobs[0].iters_done == 5
    finally:
        _REGISTRY.pop("test-tiny", None)
