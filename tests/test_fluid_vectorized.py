"""Vectorized fluid-engine equivalence harness.

The array-resident engine (``vectorized=True``, the default) must be an
*exact replay* of the scalar dict-of-dicts oracle — same rates, same event
sequence, same ``Metrics.summary()`` — not an approximation.  These tests
pin that contract:

  - allocator parity on random job/link incidences (seeded always;
    hypothesis-driven when available), compared exactly;
  - end-to-end scenario equivalence: identical per-job iteration-time /
    ECN traces and identical summaries, on cheap scenarios always and on
    every registered scenario under the ``slow`` marker;
  - the allocation-cache invalidation rule: segment transitions of
    compute-only jobs must NOT trigger re-allocation;
  - the fluid invariants (capacity, ECN monotonicity, CUTOFF release) on
    a 64-rack fabric through the vectorized path.
"""

import random

import numpy as np
import pytest

from repro.cluster import FluidNetworkSim, Topology, snapshot_trace
from repro.cluster.job import JobState
from repro.engine.scenarios import _REGISTRY, get_scenario

MODELS = ["vgg19", "wideresnet101", "dlrm", "gpt2", "resnet50", "bert"]


# ------------------------------------------------------------------ #
# topology incidence layer
# ------------------------------------------------------------------ #
def test_topology_incidence_arrays():
    t = Topology.paper_testbed()
    placements = [(0, 1, 6), (2, 8), (3,)]
    inc = t.incidence(placements)
    assert inc.num_links == len(t.links)
    assert inc.capacities.shape == (len(t.links),)
    # rows mirror job_links exactly (same links, same order)
    for p, cols in zip(placements, inc.rows):
        names = [l.name for l in t.job_links(p)]
        assert [list(t.links)[c] for c in cols.tolist()] == names
    # single-GPU job: no network links
    assert inc.rows[2].size == 0
    m = inc.matrix
    assert m.shape == (3, len(t.links))
    assert m.sum() == sum(r.size for r in inc.rows)


def test_job_links_cache_returns_consistent_results():
    t = Topology.paper_testbed()
    a = t.job_links((0, 6, 1))
    b = t.job_links((1, 0, 6))  # same worker set, different order
    assert [l.name for l in a] == [l.name for l in b]
    # cached lists are defensive copies
    a.append(None)
    assert None not in t.job_links((0, 1, 6))


# ------------------------------------------------------------------ #
# allocator parity on random incidences
# ------------------------------------------------------------------ #
def _random_state(seed: int):
    """Random topology + contended running set, both engine flavours."""
    rng = random.Random(seed)
    topo_args = dict(
        num_racks=rng.choice((2, 3, 4, 8)),
        servers_per_rack=rng.choice((2, 4)),
        nic_gbps=rng.choice((25.0, 50.0)),
        oversubscription=rng.choice((1.0, 2.0, 4.0)),
    )
    n_jobs = rng.randint(2, 8)
    specs = [
        (rng.choice(MODELS), rng.randint(1, 4), None) for _ in range(n_jobs)
    ]
    jobs_pair = []
    for _ in range(2):
        topo = Topology(**topo_args)
        jobs = snapshot_trace(
            [(m, w, 1400 if m.startswith("vgg") else 8) for m, w, _ in specs],
            iters=10_000,
        )
        r = random.Random(seed + 1)
        for j in jobs:
            j.placement = tuple(
                r.sample(range(topo.num_gpus), j.num_workers)
            )
            j.state = JobState.RUNNING
        jobs_pair.append((topo, jobs))
    return jobs_pair


def _assert_engine_parity(seed: int, windows=(50.0, 400.0, 1500.0)):
    (topo_v, jobs_v), (topo_s, jobs_s) = _random_state(seed)
    sim_v = FluidNetworkSim(topo_v, vectorized=True, seed=seed)
    sim_s = FluidNetworkSim(topo_s, vectorized=False, seed=seed)
    sim_v.configure(jobs_v)
    sim_s.configure(jobs_s)
    t = 0.0
    for w in windows:
        t += w
        # exact dict parity at every probe point: same members, same floats
        assert sim_v._allocate() == sim_s._allocate()
        assert sim_v._mark_rates() == sim_s._mark_rates()
        sim_v.advance(t)
        sim_s.advance(t)
        assert sim_v.now_ms == sim_s.now_ms
    for jv, js in zip(jobs_v, jobs_s):
        assert jv.iter_times_ms == js.iter_times_ms
        assert jv.ecn_marks == js.ecn_marks
        assert jv.iters_done == js.iters_done


@pytest.mark.parametrize("seed", range(12))
def test_allocator_parity_seeded(seed):
    _assert_engine_parity(seed)


def test_allocator_parity_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=100, max_value=100_000))
    def run(seed):
        _assert_engine_parity(seed, windows=(120.0, 900.0))

    run()


# ------------------------------------------------------------------ #
# end-to-end scenario equivalence
# ------------------------------------------------------------------ #
def _assert_scenario_equivalent(
    name: str,
    scheduler: str,
    horizon_cap: float,
    incremental: bool | None = None,
):
    spec = get_scenario(name)
    horizon = min(spec.horizon_ms, horizon_cap)
    rv = spec.run(
        scheduler, horizon_ms=horizon, vectorized=True,
        incremental=incremental,
    )
    rs = spec.run(
        scheduler, horizon_ms=horizon, vectorized=False,
        incremental=incremental,
    )
    # identical event sequences: every job's recorded iteration history,
    # marks, state and completion time match exactly
    by_v = {j.job_id: j for j in rv.metrics.jobs}
    by_s = {j.job_id: j for j in rs.metrics.jobs}
    assert by_v.keys() == by_s.keys()
    for jid, jv in by_v.items():
        js = by_s[jid]
        assert jv.iter_times_ms == js.iter_times_ms, jid
        assert jv.ecn_marks == js.ecn_marks, jid
        assert (jv.state, jv.finish_ms) == (js.state, js.finish_ms), jid
    # identical Metrics.summary() — bit for bit, NaNs matching by position
    sv, ss = rv.metrics.summary(), rs.metrics.summary()
    assert sv.keys() == ss.keys()
    for key in sv:
        assert sv[key] == ss[key] or (
            np.isnan(sv[key]) and np.isnan(ss[key])
        ), key


@pytest.mark.parametrize(
    "name,scheduler",
    [
        ("fig2-interleave", "cassini"),
        ("multitenant-2", "fair-share"),
        ("arrival-burst", "themis"),
    ],
)
def test_scenario_equivalence_fast(name, scheduler):
    _assert_scenario_equivalent(name, scheduler, horizon_cap=600_000.0)


@pytest.mark.slow
@pytest.mark.parametrize(
    "name",
    sorted(n for n, s in _REGISTRY.items() if not s.incremental),
)
def test_scenario_equivalence_all_registered(name):
    """Every registered bit-exact scenario (first scheduler in its
    line-up) produces identical metrics with the vectorized engine and
    the scalar oracle.  Specs that opt into the incremental re-solver are
    tolerance-band equivalent, not bit-exact — their escape hatch is
    covered below and their tolerance parity in
    tests/test_fluid_incremental.py."""
    spec = get_scenario(name)
    _assert_scenario_equivalent(
        name, spec.scheduler_names()[0], horizon_cap=600_000.0
    )


@pytest.mark.slow
@pytest.mark.parametrize("racks,horizon", [(256, 20_000.0), (1024, 5_000.0)])
def test_rack_scaling_xl_escape_hatch_bit_exact(racks, horizon):
    """``incremental=False`` on the 256/1024-rack scenarios must stay
    bit-exact against the scalar oracle — the escape hatch the XL specs
    promise (short horizon: the oracle is the slow side here)."""
    name = f"rack-scaling-{racks}"
    spec = get_scenario(name)
    assert spec.incremental  # the XL specs opt in by default
    _assert_scenario_equivalent(
        name, spec.scheduler_names()[0], horizon_cap=horizon,
        incremental=False,
    )


# ------------------------------------------------------------------ #
# allocation cache invalidation
# ------------------------------------------------------------------ #
def test_compute_only_segment_churn_hits_alloc_cache():
    """The comm-competing set keys the allocation cache: a linkless job
    cycling through its compute segments must never force a re-solve."""
    t = Topology.paper_testbed()
    jobs = snapshot_trace(
        [("vgg19", 2, 1400), ("vgg19", 2, 1400), ("bert", 1, 8)], iters=250
    )
    jobs[0].placement = (0, 6)
    jobs[1].placement = (1, 7)   # same rack pair: contended uplink
    jobs[2].placement = (2,)     # single worker: no network links
    for j in jobs:
        j.state = JobState.RUNNING
    sim = FluidNetworkSim(t)
    sim.configure(jobs)
    sim.advance(120_000.0)
    # the linkless job iterated plenty (many compute-segment events) …
    assert jobs[2].iters_done > 100
    # … yet the distinct comm sets are just the on/off combinations of the
    # two comm jobs' segments: a handful of solves, not one per event
    assert sim.alloc_solves <= 8


def test_cutoff_flip_changes_comm_set_and_rates():
    """CUTOFF membership is part of the cache key: flipping a job's state
    must produce a fresh allocation where the survivor gets the link."""
    t = Topology.paper_testbed()
    jobs = snapshot_trace([("vgg19", 2, 1400)] * 2, iters=4000)
    jobs[0].placement = (0, 6)
    jobs[1].placement = (1, 7)
    for j in jobs:
        j.state = JobState.RUNNING
    sim = FluidNetworkSim(t)
    sim.configure(jobs)
    sim.advance(30_000.0)
    jobs[0].state = JobState.CUTOFF
    sim.advance(60_000.0)
    alloc = sim._allocate()
    assert jobs[0].job_id not in alloc
    post = jobs[1].iter_times_ms[-5:]
    assert sum(post) / len(post) == pytest.approx(jobs[1].solo_iter_ms, rel=0.02)


# ------------------------------------------------------------------ #
# fluid invariants at rack scale (vectorized path)
# ------------------------------------------------------------------ #
def _contending_jobs_64rack(n_per_uplink=3, iters=40):
    """Jobs chained across racks of a 64-rack hetero fabric so every other
    uplink carries ``n_per_uplink`` tenants."""
    topo = Topology(
        num_racks=64,
        servers_per_rack=4,
        nic_gbps=50.0,
        rack_nic_gbps=tuple(100.0 if r % 2 else 50.0 for r in range(64)),
        oversubscription=4.0,  # one uplink per rack: guaranteed sharing
    )
    jobs = snapshot_trace(
        [("vgg19", 4, 1400)] * (16 * n_per_uplink), iters=iters
    )
    for i, j in enumerate(jobs):
        rack = (i // n_per_uplink) * 4   # every 4th rack pair
        k = i % n_per_uplink
        j.placement = (
            4 * rack + k, 4 * rack + 3 - k if k < 2 else 4 * rack + 2,
            4 * (rack + 1) + k, 4 * (rack + 1) + 3 - k if k < 2 else 4 * (rack + 1) + 2,
        )
        j.placement = tuple(dict.fromkeys(j.placement))  # de-dup, keep order
        j.state = JobState.RUNNING
    return topo, jobs


def test_capacity_never_exceeded_vectorized_64rack():
    topo, jobs = _contending_jobs_64rack()
    sim = FluidNetworkSim(topo)
    assert sim.vectorized
    sim.configure(jobs)
    probes = 0
    while sim.now_ms < 8_000.0 and sim._execs:
        rates = sim._allocate()
        per_link: dict[str, float] = {}
        for jid, ex in sim._execs.items():
            for l in ex.links:
                per_link[l.name] = per_link.get(l.name, 0.0) + rates.get(jid, 0.0)
        for lname, total in per_link.items():
            assert total <= topo.links[lname].capacity_gbps + 1e-6, lname
        probes += sum(1 for r in rates.values() if r > 0)
        sim.advance(sim.now_ms + 40.0)
    assert probes > 0


def test_ecn_monotone_vectorized_64rack():
    def marks_job0(n):
        topo, jobs = _contending_jobs_64rack(n_per_uplink=n, iters=25)
        sim = FluidNetworkSim(topo)
        sim.configure(jobs)
        sim.advance(200_000.0)
        assert jobs[0].iters_done == 25
        return sum(jobs[0].ecn_marks)

    two, three = marks_job0(2), marks_job0(3)
    assert two > 0
    assert three >= two


def test_cutoff_releases_share_vectorized_64rack():
    topo, jobs = _contending_jobs_64rack(n_per_uplink=2, iters=600)
    sim = FluidNetworkSim(topo)
    sim.configure(jobs)
    sim.advance(30_000.0)
    survivor = jobs[1]
    assert sum(survivor.iter_times_ms) / len(survivor.iter_times_ms) > (
        survivor.solo_iter_ms * 1.10
    )
    jobs[0].state = JobState.CUTOFF
    recorded = len(survivor.iter_times_ms)
    frozen_iters = jobs[0].iters_done
    sim.advance(90_000.0)
    assert jobs[0].job_id not in sim._allocate()
    assert jobs[0].iters_done == frozen_iters
    assert jobs[0].state is JobState.CUTOFF and jobs[0].finish_ms is None
    post = survivor.iter_times_ms[recorded + 2:]
    assert post, "survivor must keep iterating after the cutoff"
    assert sum(post) / len(post) == pytest.approx(survivor.solo_iter_ms, rel=0.02)
