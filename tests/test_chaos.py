"""repro.chaos: deterministic fault injection, bit-identical replays and
graceful degradation (ISSUE 8 tentpole).

Covers the fault taxonomy + schedule determinism, injector semantics
against a live fluid sim, the typed mutation errors, batch-vs-serve
replay parity on every churn-* scenario, engine symmetry (scalar /
vectorized / incremental) under faults, and the serve fallback path —
the worker must answer every query while the pipeline is on fire.
"""

from __future__ import annotations

import math

import pytest

from repro.chaos import FaultInjector, FaultSchedule
from repro.chaos.events import (
    JobResize,
    LinkDegrade,
    LinkDown,
    LinkRecover,
    NicFlap,
    PhaseJitter,
)
from repro.chaos.inject import DOWN_GBPS
from repro.cluster import (
    FluidNetworkSim,
    Topology,
    poisson_trace,
    snapshot_trace,
)
from repro.cluster.errors import UnknownJobError, UnknownLinkError
from repro.engine import get_scenario
from repro.sched.base import ClusterState, Decision, Scheduler
from repro.serve import JobArrival, SchedulerService

CHURN = ("churn-linkfail", "churn-elastic", "churn-jitter")


def _decision_tuples(decisions):
    return [(t, d.placements, d.time_shifts_ms) for t, d in decisions]


def _run_batch(spec, scheduler_name):
    built = spec.build(scheduler_name)
    metrics = built.simulator.run(built.jobs, horizon_ms=spec.horizon_ms)
    return metrics, built.simulator.decisions, built.simulator.chaos


def _run_served(spec, scheduler_name, **kw):
    topo = spec.topology()
    jobs = list(spec.arrival_stream(topo))
    svc = SchedulerService(
        topo, spec.make_scheduler(scheduler_name), epoch_ms=spec.epoch_ms,
        compute_jitter=spec.compute_jitter, vectorized=spec.vectorized,
        seed=spec.sim_seed,
        fault_schedule=spec.make_fault_schedule(topo, jobs), **kw,
    )
    with svc:
        for job in jobs:
            svc.submit(JobArrival(job))
        metrics = svc.drain(spec.horizon_ms)
        telemetry = svc.telemetry()
    return metrics, svc.decisions, telemetry


# --------------------------------------------------------------------- #
# schedules: validation, determinism, resolution
# --------------------------------------------------------------------- #
class TestFaultSchedule:
    def test_of_sorts_by_time(self):
        s = FaultSchedule.of(
            LinkRecover(500.0, "up:r0-sp0"),
            LinkDown(100.0, "up:r0-sp0"),
            PhaseJitter(300.0, "j0", 2.0),
        )
        assert [ev.at_ms for ev in s] == [100.0, 300.0, 500.0]
        assert len(s) == 3 and not s.empty

    def test_validation(self):
        with pytest.raises(ValueError, match="before t=0"):
            FaultSchedule.of(LinkDown(-1.0, "x"))
        with pytest.raises(ValueError, match="factor"):
            FaultSchedule.of(LinkDegrade(0.0, "x", 1.0))
        with pytest.raises(ValueError, match="factor"):
            FaultSchedule.of(LinkDegrade(0.0, "x", 0.0))
        with pytest.raises(ValueError, match="down_ms"):
            FaultSchedule.of(NicFlap(0.0, 0, 0.0))

    def test_generators_are_deterministic(self):
        topo = Topology.paper_testbed()
        jobs = poisson_trace(topo, num_jobs=6, seed=3)
        for mk in (
            lambda s: FaultSchedule.linkfail(topo, seed=s, horizon_ms=1e5),
            lambda s: FaultSchedule.elastic(jobs, seed=s, horizon_ms=1e5),
            lambda s: FaultSchedule.jitter(
                jobs, seed=s, horizon_ms=1e5, magnitude_ms=5.0
            ),
        ):
            assert mk(7).events == mk(7).events
            assert mk(7).events != mk(8).events

    def test_zero_magnitude_jitter_is_empty(self):
        topo = Topology.paper_testbed()
        jobs = poisson_trace(topo, num_jobs=3, seed=0)
        assert FaultSchedule.jitter(
            jobs, seed=1, horizon_ms=1e5, magnitude_ms=0.0
        ).empty

    def test_resolve_expands_nicflap(self):
        topo = Topology.paper_testbed()
        link = topo.host_link(3).name
        s = FaultSchedule.of(
            NicFlap(1_000.0, 3, 500.0), PhaseJitter(1_200.0, "j", 1.0)
        )
        resolved = s.resolve(topo)
        kinds = [(type(ev).__name__, ev.at_ms) for ev in resolved]
        assert kinds == [
            ("LinkDown", 1_000.0),
            ("PhaseJitter", 1_200.0),
            ("LinkRecover", 1_500.0),
        ]
        assert resolved[0].link == resolved[2].link == link


# --------------------------------------------------------------------- #
# typed mutation errors (satellite 1)
# --------------------------------------------------------------------- #
class TestTypedErrors:
    def test_unknown_link_names_id_and_live_set(self):
        topo = Topology.paper_testbed()
        with pytest.raises(UnknownLinkError) as ei:
            topo.set_link_capacity("up:nope", 10.0)
        assert ei.value.link == "up:nope"
        assert "unknown link 'up:nope'" in str(ei.value)
        assert "live:" in str(ei.value)
        assert isinstance(ei.value, KeyError)  # historical contract

    def test_unknown_job_on_remove_and_update(self):
        topo = Topology.paper_testbed()
        sim = FluidNetworkSim(topo)
        jobs = poisson_trace(topo, num_jobs=2, seed=1)
        for i, j in enumerate(jobs):
            j.placement = (2 * i, 2 * i + 1)
        sim.configure(jobs)
        with pytest.raises(UnknownJobError) as ei:
            sim.remove_job("ghost")
        assert ei.value.job_id == "ghost"
        assert jobs[0].job_id in str(ei.value)  # live set summarized
        with pytest.raises(KeyError):  # historical contract
            sim.remove_job("ghost")
        with pytest.raises(UnknownJobError):
            sim.perturb_job("ghost", 1.0)

    def test_incidence_row_errors_are_index_errors_too(self):
        topo = Topology.paper_testbed()
        inc = topo.incidence([(0, 6), (1, 7)])
        with pytest.raises(UnknownJobError) as ei:
            inc.without_row(5)
        assert isinstance(ei.value, IndexError)
        assert isinstance(ei.value, KeyError)
        assert ei.value.job_id == 5
        with pytest.raises(IndexError):
            inc.replace_row(9, topo.job_link_ids((0, 1)))

    def test_negative_capacity_rejected(self):
        topo = Topology.paper_testbed()
        name = next(iter(topo.links))
        with pytest.raises(ValueError, match="negative"):
            topo.set_link_capacity(name, -1.0)


# --------------------------------------------------------------------- #
# injector semantics on a live sim
# --------------------------------------------------------------------- #
def _two_job_sim(iters=50):
    topo = Topology.paper_testbed()
    jobs = snapshot_trace(
        [("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=iters
    )
    jobs[0].placement = (0, 6)
    jobs[1].placement = (1, 7)
    sim = FluidNetworkSim(topo)
    sim.configure(jobs)
    return topo, jobs, sim


class TestFaultInjector:
    def test_down_degrade_recover_against_pristine(self):
        topo, jobs, sim = _two_job_sim()
        name = topo.host_link(0).name
        pristine = topo.links[name].capacity_gbps
        inj = FaultInjector(sim, FaultSchedule.of(
            LinkDown(0.0, name),
            LinkDegrade(10.0, name, 0.5),
            LinkRecover(20.0, name),
        ))
        assert inj.next_ms == 0.0
        inj.apply_due(0.0, jobs)
        assert topo.links[name].capacity_gbps == DOWN_GBPS
        inj.apply_due(10.0, jobs)
        # degrade is relative to the PRISTINE capacity, not the downed one
        assert topo.links[name].capacity_gbps == pytest.approx(
            pristine * 0.5
        )
        inj.apply_due(20.0, jobs)
        assert topo.links[name].capacity_gbps == pristine
        assert inj.applied_count == 3 and inj.skipped == 0
        assert inj.next_ms == math.inf

    def test_capacity_mutation_reaches_allocation(self):
        topo, jobs, sim = _two_job_sim()
        sim.advance(50.0)
        name = topo.host_link(0).name
        inj = FaultInjector(sim, FaultSchedule.of(LinkDown(50.0, name)))
        before = dict(sim._allocate())
        inj.apply_due(sim.now_ms, jobs)
        after = dict(sim._allocate())
        # job 0 crosses the downed host link: its rate collapses to the
        # trickle while job 1 keeps a real allocation
        j0, j1 = jobs[0].job_id, jobs[1].job_id
        if j0 in before and before[j0] > 1e-6:
            assert after.get(j0, 0.0) <= DOWN_GBPS + 1e-12
        if j1 in after and j1 in before:
            assert after[j1] > DOWN_GBPS

    def test_resize_routes_through_remesh_planner(self):
        topo = Topology.paper_testbed()
        jobs = poisson_trace(topo, num_jobs=2, seed=5)
        jobs[0].num_workers = 4
        jobs[0].placement = (0, 1, 2, 3)
        jobs[1].placement = (6, 7)
        sim = FluidNetworkSim(topo)
        sim.configure(jobs)
        inj = FaultInjector(sim, FaultSchedule.of(
            JobResize(0.0, jobs[0].job_id, -2)
        ))
        realign = inj.apply_due(0.0, jobs)
        assert realign  # shape changes request a re-alignment pass
        assert jobs[0].num_workers == 2
        (plan,) = inj.remesh_plans
        assert plan.old_shape == (4,) and plan.new_shape == (2,)

    def test_resize_never_kills_last_worker(self):
        topo = Topology.paper_testbed()
        jobs = poisson_trace(topo, num_jobs=1, seed=5)
        jobs[0].num_workers = 3
        jobs[0].placement = (0, 1, 2)
        sim = FluidNetworkSim(topo)
        sim.configure(jobs)
        inj = FaultInjector(sim, FaultSchedule.of(
            JobResize(0.0, jobs[0].job_id, -99)
        ))
        inj.apply_due(0.0, jobs)
        assert jobs[0].num_workers == 1  # clamped, not zero

    def test_stale_targets_are_skipped_not_raised(self):
        topo, jobs, sim = _two_job_sim()
        inj = FaultInjector(sim, FaultSchedule.of(
            JobResize(0.0, "finished-long-ago", +2),
            PhaseJitter(0.0, "never-placed", 3.0),
        ))
        realign = inj.apply_due(0.0, jobs)
        assert not realign
        assert inj.applied_count == 0 and inj.skipped == 2

    def test_jitter_perturbs_delay(self):
        topo, jobs, sim = _two_job_sim()
        jid = jobs[0].job_id
        d0 = sim._execs[jid].delay_ms
        inj = FaultInjector(sim, FaultSchedule.of(
            PhaseJitter(0.0, jid, 7.5),
            PhaseJitter(1.0, jid, -1e9),  # floor at zero, never negative
        ))
        realign = inj.apply_due(0.0, jobs)
        assert not realign  # jitter is absorbed by the drift agent
        assert sim._execs[jid].delay_ms == pytest.approx(d0 + 7.5)
        inj.apply_due(1.0, jobs)
        assert sim._execs[jid].delay_ms == 0.0

    def test_pristine_snapshot_defeats_stacked_faults(self):
        topo, jobs, sim = _two_job_sim()
        name = topo.host_link(1).name
        pristine = topo.links[name].capacity_gbps
        inj = FaultInjector(sim, FaultSchedule.of(
            LinkDegrade(0.0, name, 0.5),
            LinkDegrade(1.0, name, 0.5),  # does NOT compound to 0.25
            LinkRecover(2.0, name),
        ))
        inj.apply_due(1.0, jobs)
        assert topo.links[name].capacity_gbps == pytest.approx(
            pristine * 0.5
        )
        inj.apply_due(2.0, jobs)
        assert topo.links[name].capacity_gbps == pristine


# --------------------------------------------------------------------- #
# capacity deltas × the incremental water-filling machinery
# --------------------------------------------------------------------- #
class TestIncrementalCapacityDeltas:
    def test_incremental_matches_rebuild_after_capacity_change(self):
        """A set_link_capacity between advances must flow into the delta
        re-solve: rates after the mutation match a from-scratch sim that
        saw the same capacities."""
        topo_a = Topology.paper_testbed()
        topo_b = Topology.paper_testbed()
        sims = []
        for topo, incremental in ((topo_a, True), (topo_b, False)):
            jobs = poisson_trace(topo, num_jobs=6, seed=13)
            g = 0
            for j in jobs:
                take = min(j.num_workers, 3)
                j.placement = tuple(range(g, g + take))
                g += take
            sim = FluidNetworkSim(topo, incremental=incremental)
            sim.configure(jobs)
            sim.advance(300.0)
            name = topo.host_link(0).name
            sim.set_link_capacity(name, 12.5)
            sim.advance(600.0)
            sims.append(sim)
        inc, full = sims
        ra, rb = inc._allocate(), full._allocate()
        assert set(ra) == set(rb)
        for jid in ra:
            assert ra[jid] == pytest.approx(rb[jid], rel=1e-9)

    def test_set_link_capacity_clears_alloc_cache(self):
        topo, jobs, sim = _two_job_sim()
        sim.advance(100.0)
        assert sim._alloc_cache
        old = sim.set_link_capacity(topo.host_link(0).name, 1.0)
        assert old > 1.0
        assert not sim._alloc_cache  # stale rates can't be served


# --------------------------------------------------------------------- #
# replay determinism: batch vs serve, scalar vs vectorized
# --------------------------------------------------------------------- #
class TestReplayParity:
    @pytest.mark.parametrize("name", CHURN)
    def test_batch_run_is_reproducible(self, name):
        spec = get_scenario(name)
        m1, d1, c1 = _run_batch(spec, "themis")
        m2, d2, c2 = _run_batch(spec, "themis")
        assert m1.summary() == m2.summary()
        assert _decision_tuples(d1) == _decision_tuples(d2)
        assert c1.applied_count == c2.applied_count > 0

    def test_serve_replay_matches_batch_linkfail(self):
        spec = get_scenario("churn-linkfail")
        m_batch, d_batch, chaos = _run_batch(spec, "th+cassini")
        m_serve, d_serve, tel = _run_served(spec, "th+cassini")
        assert m_batch.summary() == m_serve.summary()
        assert _decision_tuples(d_batch) == _decision_tuples(d_serve)
        assert tel["faults_applied"] == chaos.applied_count > 0
        assert tel["degraded_decisions"] == 0.0

    def test_serve_replay_matches_batch_elastic(self):
        spec = get_scenario("churn-elastic")
        m_batch, d_batch, chaos = _run_batch(spec, "th+cassini")
        m_serve, d_serve, tel = _run_served(spec, "th+cassini")
        assert m_batch.summary() == m_serve.summary()
        assert _decision_tuples(d_batch) == _decision_tuples(d_serve)
        assert tel["faults_applied"] == chaos.applied_count > 0

    def test_serve_replay_matches_batch_jitter(self):
        spec = get_scenario("churn-jitter")
        m_batch, d_batch, chaos = _run_batch(spec, "th+cassini")
        m_serve, d_serve, tel = _run_served(spec, "th+cassini")
        assert m_batch.summary() == m_serve.summary()
        assert _decision_tuples(d_batch) == _decision_tuples(d_serve)
        assert tel["faults_applied"] == chaos.applied_count > 0

    @pytest.mark.slow
    @pytest.mark.parametrize("name", CHURN)
    def test_scalar_vectorized_parity_under_faults(self, name):
        """Fault application is engine-symmetric: the scalar oracle and
        the vectorized engine replay a schedule bit-identically.  (The
        all-registered equivalence sweep covers this too — this row keeps
        a named, per-scenario failure when it breaks.)"""
        spec = get_scenario(name)
        rv = spec.run("themis", vectorized=True)
        rs = spec.run("themis", vectorized=False)
        assert rv.metrics.summary() == rs.metrics.summary()

    def test_empty_cluster_gap_does_not_stall_clock(self):
        """A fault window where every job is queued (e.g. a grow past the
        fabric) leaves the cluster empty mid-run; advance must jump the
        clock instead of spinning the event loop."""
        topo = Topology.paper_testbed()
        sim = FluidNetworkSim(topo)
        sim.advance(5_000.0)
        assert sim.now_ms == 5_000.0


# --------------------------------------------------------------------- #
# graceful degradation: the serve worker never dies
# --------------------------------------------------------------------- #
class _FlakyScheduler(Scheduler):
    """Raises on every Nth schedule() call; trivial placements otherwise."""

    name = "flaky"

    def __init__(self, every: int = 2) -> None:
        self.calls = 0
        self.every = every

    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        return {j.job_id: min(j.num_workers, 2) for j in state.running}

    def propose(self, state, workers, k):
        placements = {}
        g = 0
        for job in state.running:
            take = workers.get(job.job_id, 0)
            placements[job.job_id] = tuple(range(g, g + take))
            g += take
        return [placements]

    def schedule(self, state: ClusterState) -> Decision:
        self.calls += 1
        if self.calls % self.every == 0:
            raise RuntimeError("pipeline exploded")
        return super().schedule(state)


class TestGracefulDegradation:
    def _arrivals(self, topo, n=4):
        jobs = poisson_trace(topo, num_jobs=n, seed=21)
        for i, j in enumerate(jobs):
            j.num_workers = min(j.num_workers, 2)
            j.arrival_ms = i * 1_000.0  # keep the stream ahead of queries
        return jobs

    def test_pipeline_exception_falls_back_and_recovers(self):
        """Every other decision raises: the worker survives, counts the
        fallbacks, answers every query, and the healthy epochs go back to
        the real pipeline."""
        topo = Topology.paper_testbed()
        sched = _FlakyScheduler(every=2)
        svc = SchedulerService(
            topo, sched, epoch_ms=10_000.0, compute_jitter=0.0,
        )
        with svc:
            for job in self._arrivals(topo):
                svc.submit(JobArrival(job))
            for k in range(1, 9):
                view = svc.query(at_ms=k * 12_000.0)  # never raises
                assert view.placements is not None
            tel = svc.telemetry()
        assert tel["pipeline_errors"] > 0
        assert tel["degraded_decisions"] == tel["pipeline_errors"]
        # healthy epochs outnumber the failures: the service recovered
        assert tel["decisions"] > tel["degraded_decisions"]
        assert svc._worker_exc is None  # the worker never died

    def test_fallback_uses_host_scheduler(self):
        """CassiniAugmented pipeline that raises → the host (Themis)
        placement is used, not the frozen last decision."""
        from repro.sched import CassiniAugmented, ThemisScheduler

        topo = Topology.paper_testbed()
        sched = CassiniAugmented(ThemisScheduler())
        calls = {"n": 0}

        def boom(state):
            calls["n"] += 1
            raise ValueError("scoring blew up")

        sched.pipeline.schedule = boom  # break the CASSINI stages only
        svc = SchedulerService(topo, sched, epoch_ms=30_000.0)
        with svc:
            for job in self._arrivals(topo, n=3):
                svc.submit(JobArrival(job))
            view = svc.query(at_ms=3_000.0)
            tel = svc.telemetry()
        # the fallback produced a real placement via the Themis host
        assert any(view.placements.values())
        assert tel["degraded_decisions"] > 0

    def test_realign_timeout_counts_as_degraded(self):
        topo = Topology.paper_testbed()

        class Slow(_FlakyScheduler):
            name = "slow"

            def schedule(self, state):
                import time as _t

                _t.sleep(0.02)
                return super(_FlakyScheduler, self).schedule(state)

        svc = SchedulerService(
            topo, Slow(), epoch_ms=30_000.0, realign_timeout_ms=1.0,
        )
        with svc:
            for job in self._arrivals(topo, n=2):
                svc.submit(JobArrival(job))
            svc.query(at_ms=1_000.0)
            tel = svc.telemetry()
        assert tel["realign_timeouts"] > 0
        assert tel["degraded_decisions"] >= tel["realign_timeouts"]

    def test_fallback_off_propagates(self):
        """fallback=False restores the old contract: the pipeline error
        kills the worker (and surfaces on the next submit)."""
        topo = Topology.paper_testbed()
        svc = SchedulerService(
            topo, _FlakyScheduler(every=1), epoch_ms=10_000.0,
            fallback=False,
        )
        with svc:
            for job in self._arrivals(topo, n=2):
                svc.submit(JobArrival(job))
            with pytest.raises(Exception):
                svc.query(at_ms=1_000.0)

    def test_faults_plus_flaky_pipeline_answers_everything(self):
        """Faults and pipeline failures together: every QueryPlacement is
        answered and the books balance in telemetry()."""
        topo = Topology.paper_testbed()
        jobs = self._arrivals(topo, n=4)
        schedule = FaultSchedule.linkfail(
            topo, seed=3, horizon_ms=80_000.0, events=4
        )
        svc = SchedulerService(
            topo, _FlakyScheduler(every=3), epoch_ms=10_000.0,
            fault_schedule=schedule,
        )
        with svc:
            for job in jobs:
                svc.submit(JobArrival(job))
            for k in range(1, 11):
                svc.query(at_ms=k * 10_000.0)
            metrics = svc.drain(200_000.0)
            tel = svc.telemetry()
        assert tel["faults_applied"] > 0
        assert tel["degraded_decisions"] > 0
        assert svc._worker_exc is None
        assert metrics.jobs  # drained to a real Metrics


# --------------------------------------------------------------------- #
# telemetry hardening (satellite 2 rides here: see also test_serve.py)
# --------------------------------------------------------------------- #
class TestTelemetryUnderFire:
    def test_telemetry_never_raises_mid_incident(self):
        """telemetry() with a half-broken service (net counters gone,
        scheduler module missing) still returns the core counters."""
        topo = Topology.paper_testbed()
        svc = SchedulerService(
            topo, _FlakyScheduler(), epoch_ms=10_000.0, start=False,
        )
        svc.net.alloc_solves = None  # poison the net-counter section
        tel = svc.telemetry()
        assert tel["degraded_decisions"] == 0.0
        assert tel["decisions"] == 0.0
        assert "alloc_cache_solves" not in tel  # degraded to fewer keys
