"""CassiniModule (Algorithm 2) tests."""

import pytest

from repro.core.circle import CommPattern, Phase
from repro.core.plugin import CassiniModule, PlacementCandidate


def _patterns():
    return {
        "a": CommPattern(320.0, (Phase(160.0, 140.0, 45.0),), "a"),
        "b": CommPattern(320.0, (Phase(170.0, 130.0, 45.0),), "b"),
        "c": CommPattern(200.0, (Phase(40.0, 150.0, 45.0),), "c"),  # 75 % duty
    }


def test_prefers_compatible_candidate():
    pats = _patterns()
    caps = {"l1": 50.0, "l2": 50.0}
    good = PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"], "c": []})
    bad = PlacementCandidate(job_links={"a": ["l1"], "c": ["l1"], "b": []})
    mod = CassiniModule()
    decision = mod.decide([bad, good], pats, caps)
    assert decision.top_placement is good
    assert decision.score > mod.decide([bad], pats, caps).score
    # unique shifts for the contending pair, reference at 0
    assert set(decision.time_shifts_ms) == {"a", "b"}


def test_loop_candidate_discarded():
    pats = _patterns()
    caps = {"l1": 50.0, "l2": 50.0, "l3": 50.0}
    # a–l1–b, b–l2–c, c–l3–a: a 3-cycle with DIFFERENT job pairs per link
    loopy = PlacementCandidate(
        job_links={"a": ["l1", "l3"], "b": ["l1", "l2"], "c": ["l2", "l3"]}
    )
    clean = PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"], "c": []})
    mod = CassiniModule()
    decision = mod.decide([loopy, clean], pats, caps)
    assert decision.top_placement is clean
    assert loopy.discarded_loop


def test_all_loops_falls_back_to_first():
    pats = _patterns()
    caps = {"l1": 50.0, "l2": 50.0, "l3": 50.0}
    loopy = PlacementCandidate(
        job_links={"a": ["l1", "l3"], "b": ["l1", "l2"], "c": ["l2", "l3"]}
    )
    mod = CassiniModule()
    decision = mod.decide([loopy], pats, caps)
    assert decision.time_shifts_ms == {}


def test_parallel_links_with_identical_jobset_merged_not_discarded():
    pats = _patterns()
    caps = {"up1": 50.0, "up2": 50.0}
    # both jobs traverse BOTH uplinks (same rack pair): a 2-cycle that must
    # be merged into one constraint, not discarded
    cand = PlacementCandidate(job_links={"a": ["up1", "up2"], "b": ["up1", "up2"]})
    mod = CassiniModule()
    decision = mod.decide([cand], pats, caps)
    assert not cand.discarded_loop
    assert decision.score == pytest.approx(1.0, abs=0.05)
    assert set(decision.time_shifts_ms) == {"a", "b"}


def test_no_contention_scores_one():
    pats = _patterns()
    cand = PlacementCandidate(job_links={"a": ["l1"], "b": ["l2"], "c": []})
    mod = CassiniModule()
    decision = mod.decide([cand], pats, {"l1": 50.0, "l2": 50.0})
    assert decision.score == pytest.approx(1.0)
    assert decision.time_shifts_ms == {}


def test_link_cache_reused_across_candidates():
    pats = _patterns()
    caps = {"l1": 50.0}
    cands = [
        PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"]})
        for _ in range(4)
    ]
    mod = CassiniModule()
    mod.decide(cands, pats, caps)
    assert len(mod._link_cache) == 1
