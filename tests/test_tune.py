"""Autotune subsystem tests: loader fallback ladder, schedule-parameter
output-inertness through the tuned dispatch, and the measured search.

The loader contract is "a bad table can only cost performance, never
correctness": every failure mode — missing file, corrupt JSON, schema
drift, wrong backend, invalid entries, unknown buckets — must resolve to
the kernels' module defaults without raising.  The dispatch contract for
the circle family is that (block_l, shift_chunk) are *bit-inert*: any
schedule the table could ever pin must reproduce the untuned shifts and
scores exactly (seeded sweeps always run; hypothesis deepens them when
the dev extra is installed).
"""

import json
import warnings

import numpy as np
import pytest

from repro.core.circle import CommPattern, Phase
from repro.core.compat import find_rotations_batched
from repro.kernels import tune
from repro.kernels.tune.search import make_workload
from repro.kernels.tune.table import TABLE_ENV

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extra absent
    HAVE_HYPOTHESIS = False

BACKEND = tune.current_backend()


@pytest.fixture(autouse=True)
def _fresh_cache():
    """Each test sees (and leaves behind) an unprimed process cache."""
    tune.reset_cache()
    yield
    tune.reset_cache()


def _write(path, doc):
    path.write_text(json.dumps(doc))
    return path


def _table_doc(entries, *, backend=BACKEND, schema=tune.SCHEMA_VERSION):
    return {"schema_version": schema, "backend": backend, "entries": entries}


# ---------------------------------------------------------------------- #
# bucketing + search space
# ---------------------------------------------------------------------- #
def test_bucket_for_is_pow2_lane_multiple():
    assert tune.bucket_for(1) == 128
    assert tune.bucket_for(128) == 128
    assert tune.bucket_for(129) == 256
    assert tune.bucket_for(720) == 1024
    assert tune.bucket_for(2048) == 2048


def test_candidates_respect_divisibility():
    # circle family: bucket-independent full grid
    assert len(tune.candidates("circle_score_argmin", 128)) == 5 * 4
    # flash/ssd: blocks must divide the bucket and not exceed it
    for c in tune.candidates("flash_attention", 128):
        assert c["block_q"] <= 128 and c["block_k"] <= 128
    assert {c["chunk"] for c in tune.candidates("ssd_scan", 128)} == {64, 128}
    assert {c["chunk"] for c in tune.candidates("ssd_scan", 512)} == {
        64, 128, 256, 512,
    }


def test_clamp_to_width_keeps_pow2_divisors():
    assert tune.clamp_to_width("ssd_scan", 128, {"chunk": 256}) == {
        "chunk": 128,
    }
    assert tune.clamp_to_width("ssd_scan", 192, {"chunk": 256}) == {
        "chunk": 64,
    }
    assert tune.clamp_to_width(
        "flash_attention", 384, {"block_q": 256, "block_k": 128}
    ) == {"block_q": 128, "block_k": 128}
    # no divisibility constraint -> untouched
    assert tune.clamp_to_width(
        "circle_score_argmin", 7, {"block_l": 64, "shift_chunk": 32}
    ) == {"block_l": 64, "shift_chunk": 32}


# ---------------------------------------------------------------------- #
# loader fallback ladder
# ---------------------------------------------------------------------- #
def test_missing_file_is_silent_defaults(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # missing is normal, not a warning
        t = tune.load_table(tmp_path / "absent.json")
    assert t.entries == {} and t.source == "<defaults>"
    assert t.lookup("circle_score_argmin", 720) == dict(
        tune.DEFAULTS["circle_score_argmin"]
    )


def test_corrupt_json_warns_and_falls_back(tmp_path):
    p = tmp_path / "t.json"
    p.write_text("{not json")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        t = tune.load_table(p)
    assert t.entries == {}


def test_schema_version_mismatch_falls_back(tmp_path):
    p = _write(tmp_path / "t.json", _table_doc(
        {"circle_score/512": {"block_l": 128}}, schema=tune.SCHEMA_VERSION + 1,
    ))
    with pytest.warns(RuntimeWarning, match="unsupported schema"):
        t = tune.load_table(p)
    assert t.entries == {}


def test_non_object_top_level_falls_back(tmp_path):
    p = _write(tmp_path / "t.json", ["not", "a", "table"])
    with pytest.warns(RuntimeWarning, match="unsupported schema"):
        assert tune.load_table(p).entries == {}


def test_backend_mismatch_falls_back(tmp_path):
    p = _write(tmp_path / "t.json", _table_doc(
        {"circle_score/512": {"block_l": 128}}, backend="tpu-mosaic",
    ))
    with pytest.warns(RuntimeWarning, match="backend"):
        t = tune.load_table(p, backend="cpu-interpret")
    assert t.entries == {}


def test_invalid_entries_dropped_rest_kept(tmp_path):
    good = {"block_l": 128, "shift_chunk": 16}
    p = _write(tmp_path / "t.json", _table_doc({
        "circle_score_argmin/512": good,
        "no_such_variant/512": {"block_l": 128},       # unknown variant
        "circle_score_argmin/huge": {"block_l": 128},  # non-numeric bucket
        "circle_score/512": {"wrong_param": 8},        # off-space name
        "circle_score/1024": {"block_l": 77},          # off-space value
        "circle_score_segmin/512": {"shift_chunk": True},  # bool is not int
        "ssd_scan/512": "not a dict",
    }))
    with pytest.warns(RuntimeWarning, match="dropped invalid entries"):
        t = tune.load_table(p)
    assert t.entries == {"circle_score_argmin/512": good}
    # the surviving entry merges over defaults, unknown buckets stay default
    assert t.lookup("circle_score_argmin", 500) == good
    assert t.lookup("circle_score_argmin", 100) == dict(
        tune.DEFAULTS["circle_score_argmin"]
    )


def test_partial_entry_merges_over_defaults(tmp_path):
    p = _write(tmp_path / "t.json", _table_doc(
        {"circle_score_segmin/1024": {"block_l": 64}},
    ))
    got = tune.load_table(p).lookup("circle_score_segmin", 720)
    assert got == {
        "block_l": 64,
        "shift_chunk": tune.DEFAULTS["circle_score_segmin"]["shift_chunk"],
    }


def test_lookup_returns_fresh_dicts(tmp_path):
    p = _write(tmp_path / "t.json", _table_doc(
        {"circle_score/512": {"block_l": 8}},
    ))
    t = tune.load_table(p)
    t.lookup("circle_score", 512)["block_l"] = 999
    assert t.lookup("circle_score", 512)["block_l"] == 8
    t.lookup("circle_score", 128)["block_l"] = 999
    assert tune.DEFAULTS["circle_score"]["block_l"] != 999


def test_unknown_variant_raises():
    with pytest.raises(KeyError):
        tune.load_table("/nonexistent").lookup("no_such_kernel", 128)


def test_env_override_and_reset_cache(tmp_path, monkeypatch):
    p = _write(tmp_path / "override.json", _table_doc(
        {"circle_score/128": {"block_l": 8}},
    ))
    monkeypatch.setenv(TABLE_ENV, str(p))
    tune.reset_cache()
    assert tune.lookup("circle_score", 100) == {"block_l": 8}
    # the cache pins the table until reset, even if the env changes
    monkeypatch.delenv(TABLE_ENV)
    assert tune.lookup("circle_score", 100) == {"block_l": 8}
    tune.reset_cache()
    got = tune.lookup("circle_score", 100)
    assert got == dict(tune.DEFAULTS["circle_score"]) or got != {"block_l": 8}


# ---------------------------------------------------------------------- #
# tuned dispatch is output-inert for the circle family
# ---------------------------------------------------------------------- #
PERIODS = (160.0, 200.0, 240.0, 320.0, 400.0)
CAPACITIES = (25.0, 50.0, 100.0)
DEMANDS = (0.0, 4.0, 20.0, 40.0, 45.0, 60.0)


def _random_problem(rng, tag, k):
    pats = []
    for j in range(k):
        it = float(rng.choice(PERIODS))
        phases = []
        for _ in range(int(rng.integers(1, 3))):
            start = float(rng.uniform(0.0, it))
            dur = float(rng.uniform(0.0, 0.9 * it))
            phases.append(Phase(start, dur, float(rng.choice(DEMANDS))))
        pats.append(CommPattern(it, tuple(phases), name=f"{tag}j{j}"))
    return pats, float(rng.choice(CAPACITIES))


def _pin_weird_schedules(tmp_path, monkeypatch):
    """Point the process table at schedules far from the defaults for
    every circle bucket, so tuned dispatch demonstrably takes them."""
    entries = {}
    for v in ("circle_score", "circle_score_argmin", "circle_score_segmin"):
        for b in tune.BUCKETS:
            e = {"block_l": 16}
            if v != "circle_score":
                e["shift_chunk"] = 32
            entries[f"{v}/{b}"] = e
    p = _write(tmp_path / "weird.json", _table_doc(entries))
    monkeypatch.setenv(TABLE_ENV, str(p))
    tune.reset_cache()


def _assert_same_rotations(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert x.shifts_steps == y.shifts_steps
        assert x.score == y.score
        assert x.shifts_ms == y.shifts_ms


@pytest.mark.parametrize("seed", range(4))
def test_tuned_vs_untuned_rotations_bit_identical(seed, tmp_path, monkeypatch):
    """End to end through ``find_rotations_batched``: a table pinning
    non-default schedules for every bucket must not move one shift."""
    _pin_weird_schedules(tmp_path, monkeypatch)
    rng = np.random.default_rng(seed)
    problems = [
        _random_problem(rng, f"p{i}", int(rng.integers(2, 5)))
        for i in range(3)
    ]
    for deg in (5.0, 0.5):  # numpy-grid regime and kernel regime
        tuned = find_rotations_batched(problems, precision_deg=deg)
        untuned = find_rotations_batched(
            problems, precision_deg=deg, tuned=False
        )
        _assert_same_rotations(tuned, untuned)


if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(2, 4))
    def test_tuned_vs_untuned_rotations_hypothesis(seed, k):
        rng = np.random.default_rng(seed)
        problems = [_random_problem(rng, "h", k)]
        tuned = find_rotations_batched(problems, precision_deg=0.5)
        untuned = find_rotations_batched(
            problems, precision_deg=0.5, tuned=False
        )
        _assert_same_rotations(tuned, untuned)


@pytest.mark.parametrize("block_l", (8, 32, 128))
@pytest.mark.parametrize("shift_chunk", (4, 16, 32))
def test_ragged_argmin_schedule_sweep_bit_identical(block_l, shift_chunk):
    """Kernel-level sweep on the search's own ragged workload: every
    (block_l, shift_chunk) point reproduces the default schedule's
    (idx, val) exactly."""
    run = make_workload("circle_score_argmin", 256)
    want = run({})
    got = run({"block_l": block_l, "shift_chunk": shift_chunk})
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_segmin_schedule_sweep_bit_identical():
    run = make_workload("circle_score_segmin", 128)
    want = run({})
    for params in ({"block_l": 8, "shift_chunk": 32},
                   {"block_l": 128, "shift_chunk": 4}):
        got = run(params)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------- #
# measured search
# ---------------------------------------------------------------------- #
def test_search_smoke_and_table_round_trip(tmp_path):
    from repro.kernels.tune.search import tune_variant

    r = tune_variant("circle_score", 128, repeats=1)
    assert r.variant == "circle_score" and r.bucket == 128
    assert r.default_params == dict(tune.DEFAULTS["circle_score"])
    assert dict(r.params) in tune.candidates("circle_score", 128)
    assert r.tuned_us <= r.default_us  # the winner never loses to defaults
    assert not r.rejected  # schedule params are output-inert

    from repro.kernels.tune.search import results_to_table

    doc = results_to_table([r])
    assert doc["schema_version"] == tune.SCHEMA_VERSION
    assert doc["backend"] == BACKEND
    # only non-default winners are persisted; either way the doc loads
    p = _write(tmp_path / "searched.json", doc)
    t = tune.load_table(p)
    assert set(t.entries) <= {"circle_score/128"}
    if r.is_default:
        assert t.entries == {}
    else:
        assert t.lookup("circle_score", 128) == dict(r.params)


def test_committed_table_loads_if_present():
    """Whatever table ships for this backend must validate cleanly (no
    dropped entries, no fallback warnings)."""
    p = tune.default_table_path()
    if not p.is_file():
        pytest.skip(f"no committed table for {BACKEND}")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        t = tune.load_table(p)
    assert t.source == str(p)
    raw = json.loads(p.read_text())
    assert set(t.entries) == set(raw["entries"])
