"""Incremental water-filling re-solver: tolerance-band parity, dirty-
component bookkeeping, sparse-incidence helpers and allocation-cache LRU
eviction.

The ``incremental=True`` engine keeps the previous solve's full state
(per-link demand / live counts / mark ratios, per-slot rates) and only
refills the connected components of the (member × binding-link) graph a
delta actually touches.  It is tolerance-band equivalent to the
from-scratch solve — these tests pin the band at every probe point of
real simulations, the exact-aggregate contract (identical iteration
counts), and the state-invalidation rules that keep the deltas honest.
"""

import numpy as np
import pytest

from repro.cluster import FluidNetworkSim, contended_snapshot
from repro.cluster import network as network_mod
from repro.engine.scenarios import get_scenario

# the documented equivalence band: rates/marks from delta-maintained state
# may differ from the from-scratch floats by accumulation order only
BAND = dict(rtol=1e-9, atol=1e-9)


def _contended(racks: int, tenants: int = 1):
    spec = get_scenario(f"rack-scaling-{racks}")
    topo = spec.topology()
    jobs = contended_snapshot(topo, lambda: spec.trace(topo), tenants=tenants)
    return topo, jobs


def _probe_parity(racks: int, window_ms: float, every: int = 7):
    """Advance with the incremental engine, comparing every ``every``-th
    solve against the from-scratch solve on the same comm set."""
    topo, jobs = _contended(racks)
    net = FluidNetworkSim(topo, seed=racks, incremental=True)
    net.configure(jobs)
    stats = {"solves": 0, "probes": 0}
    orig = FluidNetworkSim._solve_alloc_incremental

    def probe(self, comm_mask):
        rates, marks = orig(self, comm_mask)
        stats["solves"] += 1
        if stats["solves"] % every == 0:
            r2, m2 = self._solve_alloc(comm_mask)
            np.testing.assert_allclose(rates, r2, **BAND)
            np.testing.assert_allclose(marks, m2, **BAND)
            stats["probes"] += 1
        return rates, marks

    FluidNetworkSim._solve_alloc_incremental = probe
    try:
        net.advance(window_ms)
    finally:
        FluidNetworkSim._solve_alloc_incremental = orig
    assert stats["probes"] > 10
    # the deltas actually exercised the delta path, not per-solve rebuilds
    assert net.alloc_delta_solves > 0.9 * (net.alloc_solves - 1)
    return net


def test_incremental_probe_parity_16rack():
    _probe_parity(16, 4_000.0)


def test_incremental_probe_parity_64rack():
    _probe_parity(64, 1_500.0)


@pytest.mark.slow
def test_incremental_probe_parity_256rack():
    """The acceptance probe: every sampled solve on the 256-rack fabric
    stays inside the band against the from-scratch solve (itself pinned
    bit-exact to the scalar oracle), with the delta path doing the work."""
    net = _probe_parity(256, 1_200.0, every=13)
    assert net.alloc_delta_solves > 100


def test_incremental_aggregate_consistency_16rack():
    """Same total iteration count as the from-scratch engine over the
    same window — band-level float drift must never move an event."""
    iters = {}
    for inc in (False, True):
        topo, jobs = _contended(16)
        net = FluidNetworkSim(topo, seed=7, incremental=inc)
        net.configure(jobs)
        net.advance(5_000.0)
        iters[inc] = sum(j.iters_done for j in jobs)
    assert iters[True] == iters[False] > 0


def test_incremental_state_reset_on_configure():
    """configure() swaps the incidence — stale delta state must die."""
    topo, jobs = _contended(16)
    net = FluidNetworkSim(topo, seed=1, incremental=True)
    net.configure(jobs)
    net.advance(500.0)
    assert net._wf is not None
    net.configure(jobs[: len(jobs) // 2])
    assert net._wf is None
    net.advance(1_000.0)  # and the rebuilt state solves cleanly


# ------------------------------------------------------------------ #
# sparse incidence helpers (CSR both ways)
# ------------------------------------------------------------------ #
def test_link_csr_matches_matrix():
    topo, jobs = _contended(16)
    inc = topo.incidence([j.placement for j in jobs])
    m = inc.matrix
    rows, cols = inc.flat_pairs
    assert rows.shape == cols.shape
    assert m.sum() == rows.size
    # job-major pairs reproduce the boolean incidence exactly
    re = np.zeros_like(m)
    re[rows, cols] = True
    assert (re == m).all()
    # link-major CSR is the exact transpose walk
    lstarts, lcounts, lrows = inc.link_csr
    assert (lcounts == m.sum(axis=0)).all()
    for link in np.nonzero(lcounts)[0][:20]:
        users = lrows[lstarts[link]: lstarts[link] + lcounts[link]]
        assert sorted(users.tolist()) == np.nonzero(m[:, link])[0].tolist()
    # gather helper: concatenated users per link, link-major
    some = np.nonzero(lcounts)[0][:5]
    got = inc.link_users(some)
    want = np.concatenate(
        [lrows[lstarts[l]: lstarts[l] + lcounts[l]] for l in some]
    )
    assert (got == want).all()


# ------------------------------------------------------------------ #
# allocation-cache LRU eviction
# ------------------------------------------------------------------ #
def test_alloc_cache_lru_keeps_hot_key(monkeypatch):
    """A hot comm-set key touched between insertions must survive a scan
    of ``_ALLOC_CACHE_MAX`` cold keys — the regression the wholesale
    cache clear used to cause (every scan wiped the working set)."""
    monkeypatch.setattr(network_mod, "_ALLOC_CACHE_MAX", 8)
    topo, jobs = _contended(16)
    net = FluidNetworkSim(topo, seed=3)
    net.configure(jobs)
    n = len(jobs)
    hot = np.zeros(n, dtype=bool)
    hot[:4] = True
    net._cached_solve(hot)
    for i in range(network_mod._ALLOC_CACHE_MAX + 4):
        cold = np.zeros(n, dtype=bool)
        cold[4 + (i % (n - 5)):] = True
        cold[4 + ((i * 3) % (n - 5))] = False  # distinct membership per i
        net._cached_solve(cold)
        net._cached_solve(hot)  # touch: the hot key stays most-recent
    before = net.alloc_solves
    net._cached_solve(hot)
    assert net.alloc_solves == before  # still cached — never evicted
    assert len(net._alloc_cache) <= network_mod._ALLOC_CACHE_MAX


def test_alloc_cache_evicts_only_lru(monkeypatch):
    monkeypatch.setattr(network_mod, "_ALLOC_CACHE_MAX", 4)
    topo, jobs = _contended(16)
    net = FluidNetworkSim(topo, seed=3)
    net.configure(jobs)
    n = len(jobs)

    def mask(i):
        m = np.zeros(n, dtype=bool)
        m[i: i + 3] = True
        return m

    for i in range(6):  # masks 0,1 fall off the LRU end, 2..5 remain
        net._cached_solve(mask(i))
    before = net.alloc_solves
    net._cached_solve(mask(5))          # most recent: hit
    assert net.alloc_solves == before
    net._cached_solve(mask(0))          # oldest: was evicted, re-solves
    assert net.alloc_solves == before + 1
