"""LinkLoadRecorder tests: binding contract, exact time integration, and
the invariants the heatmap artifact relies on (utilization bounded by the
water-filling solve, mark intensity matching the demand-over-capacity
model, bucket-width independence of the recorded integrals)."""

import json

import numpy as np
import pytest

from benchmarks.common import fluid_advance_case
from repro.cluster import FluidNetworkSim
from repro.cluster.linkload import LinkLoadRecorder

WINDOW_MS = 15_000.0


def _recorded_sim(bucket_ms, racks=16):
    topo, jobs = fluid_advance_case(racks)
    sim = FluidNetworkSim(topo, vectorized=True)
    rec = LinkLoadRecorder(bucket_ms=bucket_ms)
    sim.attach_link_recorder(rec)
    sim.configure(jobs)
    sim.advance(WINDOW_MS)
    return sim, rec


def test_attach_rejects_scalar_sim():
    topo, _ = fluid_advance_case(16)
    sim = FluidNetworkSim(topo, vectorized=False)
    with pytest.raises(ValueError, match="vectorized"):
        sim.attach_link_recorder(LinkLoadRecorder())


def test_attach_rejects_bad_bucket():
    topo, _ = fluid_advance_case(16)
    sim = FluidNetworkSim(topo, vectorized=True)
    with pytest.raises(ValueError, match="bucket_ms"):
        sim.attach_link_recorder(LinkLoadRecorder(bucket_ms=0.0))


def test_timeline_shapes_and_invariants():
    sim, rec = _recorded_sim(5_000.0)
    tl = rec.timeline()
    nb, nl = tl["utilization"].shape
    assert nl == len(sim.topo.link_ids) == len(tl["link_names"])
    assert tl["marks_per_ms"].shape == (nb, nl)
    assert tl["t_ms"].shape == (nb,)
    assert np.all(np.diff(tl["t_ms"]) == tl["bucket_ms"])
    assert nb == int(np.ceil(WINDOW_MS / tl["bucket_ms"]))
    # utilization can never exceed 1: the water-filling solve allocates at
    # most capacity (and at most congested_efficiency x while saturated)
    assert np.all(tl["utilization"] >= 0.0)
    assert np.all(tl["utilization"] <= 1.0 + 1e-9)
    assert np.all(tl["marks_per_ms"] >= 0.0)
    # the contended rack-scaling snapshot drives real traffic: something
    # must have been recorded or the heatmap artifact is vacuous
    assert tl["utilization"].max() > 0.0
    assert all(tl["link_names"])


def test_time_integral_independent_of_bucket_width():
    """An event overlapping several buckets contributes its exact overlap
    to each: per-link totals must agree across bucket resolutions."""
    _, coarse = _recorded_sim(15_000.0)
    _, fine = _recorded_sim(2_500.0)
    tc, tf = coarse.timeline(), fine.timeline()
    total_c = tc["utilization"].sum(axis=0) * tc["bucket_ms"]
    total_f = tf["utilization"].sum(axis=0) * tf["bucket_ms"]
    np.testing.assert_allclose(total_c, total_f, rtol=1e-9, atol=1e-9)
    marks_c = tc["marks_per_ms"].sum(axis=0) * tc["bucket_ms"]
    marks_f = tf["marks_per_ms"].sum(axis=0) * tf["bucket_ms"]
    np.testing.assert_allclose(marks_c, marks_f, rtol=1e-9, atol=1e-9)


def test_mark_totals_match_job_metrics():
    """Per-link mark intensity is the exact per-link total of the sim's
    demand-over-capacity marking model: integrating it over time must
    reproduce the marks the jobs accumulated (per-iteration flushes into
    ``job.ecn_marks`` plus the in-flight residue still in the sim)."""
    topo, jobs = fluid_advance_case(16)
    sim = FluidNetworkSim(topo, vectorized=True)
    rec = LinkLoadRecorder(bucket_ms=5_000.0)
    sim.attach_link_recorder(rec)
    sim.configure(jobs)
    sim.advance(WINDOW_MS)
    tl = rec.timeline()
    recorded = float(tl["marks_per_ms"].sum() * tl["bucket_ms"])
    accumulated = (
        float(sum(sum(j.ecn_marks) for j in jobs)) + float(sim._mk.sum())
    )
    assert recorded > 0.0
    np.testing.assert_allclose(recorded, accumulated, rtol=1e-9, atol=1e-6)


def test_empty_timeline_before_any_advance():
    topo, jobs = fluid_advance_case(16)
    sim = FluidNetworkSim(topo, vectorized=True)
    rec = LinkLoadRecorder()
    sim.attach_link_recorder(rec)
    sim.configure(jobs)
    tl = rec.timeline()
    assert tl["utilization"].shape == (0, len(topo.link_ids))
    assert tl["t_ms"].size == 0


def test_to_json_round_trips():
    _, rec = _recorded_sim(5_000.0)
    doc = json.loads(json.dumps(rec.to_json()))
    tl = rec.timeline()
    assert np.asarray(doc["utilization"]).shape == tl["utilization"].shape
    assert doc["link_names"] == tl["link_names"]
    np.testing.assert_allclose(
        np.asarray(doc["utilization"]), tl["utilization"], atol=1e-6
    )
