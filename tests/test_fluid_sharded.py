"""Device-sharded component fills: parity, fallback and invariance.

The ``sharded=True`` engine re-partitions every dirty-component union
into its independent water-filling components and solves them as rows of
bucketed vmap batches split across ``jax.devices()`` with shard_map
(repro.cluster.shard).  These tests pin:

- tolerance-band parity of every probed solve against the from-scratch
  ``_solve_alloc`` (itself bit-exact against the scalar oracle) at
  16/64/256 racks, with real dispatches happening;
- aggregate equivalence (identical iteration counts) across the
  sharded, incremental and scalar-oracle engines;
- the transparent single-device fallback (no mesh, same results);
- that the visible device count never changes decisions; and
- the empty-dirty-set no-op (a solve with no member diff refills
  nothing and leaves the shard telemetry untouched).

All of it runs unchanged under the forced-host-device CI leg
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), which is what
exercises the devices>1 shard_map path on every PR.
"""

import numpy as np
import pytest

from repro.cluster import FluidNetworkSim, contended_snapshot
from repro.cluster import network as network_mod
from repro.cluster import shard as shard_mod
from repro.engine.scenarios import get_scenario

# the documented equivalence band (same band as the incremental engine)
BAND = dict(rtol=1e-9, atol=1e-9)


def _contended(racks: int, tenants: int = 1):
    spec = get_scenario(f"rack-scaling-{racks}")
    topo = spec.topology()
    jobs = contended_snapshot(topo, lambda: spec.trace(topo), tenants=tenants)
    return topo, jobs


def _sharded_net(topo, *, ndev=None, seed=0):
    net = FluidNetworkSim(
        topo, seed=seed, incremental=True, sharded=True
    )
    net._shard_devices = ndev
    return net


def _probe_parity(racks, window_ms, *, ndev=None, every=5, monkeypatch=None):
    """Advance the sharded engine, comparing every ``every``-th solve
    against the from-scratch solve on the same comm set."""
    topo, jobs = _contended(racks)
    net = _sharded_net(topo, ndev=ndev, seed=racks)
    net.configure(jobs)
    if monkeypatch is not None:
        # shard even single-component unions so the device path sees
        # every solve, not just the large rebuild-shaped ones
        monkeypatch.setattr(network_mod, "_SHARD_MIN_COMPONENTS", 1)
    stats = {"solves": 0, "probes": 0}
    orig = FluidNetworkSim._solve_alloc_incremental

    def probe(self, comm_mask):
        rates, marks = orig(self, comm_mask)
        stats["solves"] += 1
        if stats["solves"] % every == 0:
            r2, m2 = self._solve_alloc(comm_mask)
            np.testing.assert_allclose(rates, r2, **BAND)
            np.testing.assert_allclose(marks, m2, **BAND)
            stats["probes"] += 1
        return rates, marks

    FluidNetworkSim._solve_alloc_incremental = probe
    try:
        net.advance(window_ms)
    finally:
        FluidNetworkSim._solve_alloc_incremental = orig
    assert stats["probes"] > 5
    return net


def test_sharded_probe_parity_16rack(monkeypatch):
    net = _probe_parity(16, 2_000.0, monkeypatch=monkeypatch)
    assert net.shard_stats.dispatches > 0
    assert net.shard_stats.components >= net.shard_stats.dispatches


def test_sharded_probe_parity_64rack(monkeypatch):
    net = _probe_parity(64, 800.0, monkeypatch=monkeypatch)
    assert net.shard_stats.dispatches > 0


@pytest.mark.slow
def test_sharded_probe_parity_256rack(monkeypatch):
    """The acceptance probe at scale: every sampled sharded solve on the
    256-rack fabric stays inside the band against the from-scratch solve
    (itself pinned bit-exact to the scalar oracle)."""
    net = _probe_parity(256, 600.0, every=13, monkeypatch=monkeypatch)
    assert net.shard_stats.dispatches > 0
    assert net.shard_stats.devices >= 1


def test_sharded_aggregate_vs_incremental_and_oracle():
    """Identical total iteration counts across the sharded engine, the
    unsharded incremental engine and the scalar oracle on the same
    contended 16-rack window — band-level drift must never move an
    event, whatever engine or device count solves the fills."""
    iters = {}
    for key, kw in (
        ("sharded", dict(incremental=True, sharded=True)),
        ("incremental", dict(incremental=True)),
        ("scalar", dict(vectorized=False)),
    ):
        topo, jobs = _contended(16)
        net = FluidNetworkSim(topo, seed=7, **kw)
        net.configure(jobs)
        net.advance(3_000.0)
        iters[key] = sum(j.iters_done for j in jobs)
    assert iters["sharded"] == iters["incremental"] == iters["scalar"] > 0


def test_single_device_fallback(monkeypatch):
    """``ndev=1`` must skip shard_map entirely (plain jit(vmap)) and
    still produce in-band results with real dispatches."""
    monkeypatch.setattr(network_mod, "_SHARD_MIN_COMPONENTS", 1)
    topo, jobs = _contended(16)
    net = _sharded_net(topo, ndev=1, seed=3)
    net.configure(jobs)
    net.advance(1_000.0)
    assert net.shard_stats.dispatches > 0
    assert net.shard_stats.devices == 1
    # no row padding is ever needed on one device
    assert net.shard_stats.padded_rows == 0


def test_device_count_invariance(monkeypatch):
    """Decisions must not depend on how many devices solve the fills:
    the same window advanced under ndev=1 and ndev=<all visible> must
    produce identical iteration counts and in-band iteration traces."""
    import jax

    monkeypatch.setattr(network_mod, "_SHARD_MIN_COMPONENTS", 1)
    runs = {}
    for ndev in (1, len(jax.devices())):
        topo, jobs = _contended(16)
        net = _sharded_net(topo, ndev=ndev, seed=11)
        net.configure(jobs)
        net.advance(1_500.0)
        runs[ndev] = (
            [j.iters_done for j in jobs],
            [j.iter_times_ms for j in jobs],
            net.shard_stats,
        )
    (it1, tr1, st1), (itN, trN, stN) = runs[1], runs[len(jax.devices())]
    assert it1 == itN
    for a, b in zip(tr1, trN):
        np.testing.assert_allclose(a, b, **BAND)
    assert st1.dispatches > 0 and stN.dispatches > 0
    assert stN.devices == len(jax.devices())


def test_batched_fill_matches_fused_fill():
    """Direct parity of the production dispatch against the fused host
    fill on a real rebuild-shaped union, at every device count."""
    import jax

    topo, jobs = _contended(64)
    net = FluidNetworkSim(topo, seed=5, incremental=True)
    net.configure(jobs)
    net.advance(300.0)
    comm = net._is_comm & net._alive & (net._dly <= 1e-9)
    caps_now = np.where(comm, net._cap_now, 0.0)
    st = net._wf_rebuild(comm, caps_now)
    binding, demand, live = st["binding"], st["demand"], st["live"]
    rows_all, cols_all = net._inc.flat_pairs
    bpair = binding[cols_all] & comm[rows_all]
    JR = np.unique(rows_all[bpair])
    if JR.size == 0:
        pytest.skip("no contention at this probe point")
    fused = net._wf_fill_core(JR, binding, demand, live)
    comps = net._wf_components(JR, binding)
    # the component partition covers the union exactly, no overlaps
    all_members = np.concatenate([m for m, _ in comps])
    assert sorted(all_members.tolist()) == JR.tolist()
    cap_l = net._inc.capacities
    rows = []
    for mem, lnks in comps:
        eff = np.where(
            demand[lnks] > cap_l[lnks] + 1e-9, net.congested_efficiency, 1.0
        )
        rows.append((
            net._cap_now[mem],
            net._inc.sub_incidence(mem, lnks),
            cap_l[lnks] * eff,
        ))
    ref = np.zeros(len(net._slots))
    ref[JR] = fused
    prev = None
    for ndev in (1, len(jax.devices())):
        out, stats = shard_mod.batched_fill(rows, ndev=ndev)
        got = np.zeros(len(net._slots))
        for (mem, _), vec in zip(comps, out):
            got[mem] = vec
        np.testing.assert_allclose(got[JR], ref[JR], **BAND)
        assert stats.components == len(comps)
        if prev is not None:
            # device count must not change the floats at all
            np.testing.assert_array_equal(got[JR], prev)
        prev = got[JR]


def test_empty_dirty_set_is_noop():
    """A repeat solve with no member diff must take the delta path,
    refill nothing and leave the shard telemetry untouched."""
    topo, jobs = _contended(16)
    net = _sharded_net(topo, seed=2)
    net.configure(jobs)
    net.advance(500.0)
    comm = net._is_comm & net._alive & (net._dly <= 1e-9)
    r1, m1 = net._solve_alloc_incremental(comm.copy())
    before_delta = net.alloc_delta_solves
    disp = net.shard_stats.dispatches
    fused = net.shard_stats.fused_fills
    r2, m2 = net._solve_alloc_incremental(comm.copy())
    assert net.alloc_delta_solves == before_delta + 1
    assert net.shard_stats.dispatches == disp
    assert net.shard_stats.fused_fills == fused
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(m1, m2)


def test_sub_incidence_matches_matrix():
    """The CSR slicing helper equals the dense incidence restricted to
    the requested rows and links."""
    topo, jobs = _contended(16)
    inc = topo.incidence([j.placement for j in jobs])
    m = inc.matrix
    rng = np.random.default_rng(0)
    rows = rng.choice(inc.num_rows, size=min(6, inc.num_rows), replace=False)
    links = rng.choice(inc.num_links, size=min(9, inc.num_links), replace=False)
    got = inc.sub_incidence(rows, links)
    want = m[np.ix_(rows, links)]
    assert (got == want).all()
    # degenerate slices
    assert inc.sub_incidence(rows[:0], links).shape == (0, links.size)
    assert inc.sub_incidence(rows, links[:0]).shape == (rows.size, 0)


def test_sharded_off_without_incremental():
    """``sharded`` rides on the incremental decomposition — without it
    the knob must quietly stay off (and never dispatch)."""
    topo, jobs = _contended(16)
    net = FluidNetworkSim(topo, seed=0, incremental=False, sharded=True)
    assert net.sharded is False
    net.configure(jobs)
    net.advance(500.0)
    assert net.shard_stats.dispatches == 0
