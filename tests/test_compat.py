"""Tests for the compatibility optimization (paper Table 1)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.circle import CommPattern, Phase
from repro.core.compat import find_rotations, score_all_shifts


def _job(iter_ms, start, dur, gbps=45.0):
    return CommPattern(iter_ms, (Phase(start, dur, gbps),))


def test_two_identical_jobs_interleave():
    # two 50 %-duty jobs on one link: perfect antiphase exists
    j = _job(240.0, 120.0, 110.0, 45.0)
    res = find_rotations([j, j], 50.0)
    assert res.score == pytest.approx(1.0)
    # the relative shift is ~half the iteration
    assert abs(res.shifts_ms[1] - 120.0) < 20.0


def test_incompatible_jobs_low_score():
    j = _job(200.0, 20.0, 160.0, 45.0)  # 80 % duty
    res = find_rotations([j, j], 50.0)
    assert res.score < 0.8


def test_score_upper_bound_and_single_job():
    j = _job(100.0, 10.0, 50.0)
    res = find_rotations([j], 50.0)
    assert res.score == pytest.approx(1.0)
    assert res.shifts_ms == (0.0,)


def test_low_demand_job_coexists():
    # paper Fig. 12(b): a light job can overlap without hurting the score
    heavy = _job(320.0, 160.0, 150.0, 45.0)
    light = _job(160.0, 50.0, 100.0, 4.0)
    res = find_rotations([heavy, heavy, light], 50.0)
    assert res.score > 0.95


def test_reference_job_shift_is_zero():
    j1 = _job(320.0, 160.0, 140.0)
    j2 = _job(320.0, 180.0, 120.0)
    res = find_rotations([j1, j2], 50.0)
    assert res.shifts_steps[0] == 0


def test_paced_periods_cover_iteration():
    j1 = _job(332.0, 100.0, 100.0)
    j2 = _job(342.0, 120.0, 100.0)
    res = find_rotations([j1, j2], 50.0)
    # pacing periods must be at least the true iteration times (ceil quantization)
    assert res.paced_periods_ms[0] >= 332.0 - 1e-6
    assert res.paced_periods_ms[1] >= 342.0 - 1e-6


def test_score_all_shifts_matches_bruteforce():
    rng = np.random.default_rng(0)
    base = rng.random(72) * 60
    cand = rng.random(72) * 60
    out = score_all_shifts(base, cand, 50.0)
    for s in [0, 1, 17, 40, 71]:
        expect = np.maximum(base + np.roll(cand, s) - 50.0, 0).sum()
        assert out[s] == pytest.approx(expect, rel=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    dur1=st.floats(10, 300), dur2=st.floats(10, 300),
    g1=st.floats(1, 50), g2=st.floats(1, 50),
)
def test_score_never_above_one_and_rotation_sane(dur1, dur2, g1, g2):
    j1 = CommPattern(320.0, (Phase(0.0, min(dur1, 320), g1),))
    j2 = CommPattern(320.0, (Phase(0.0, min(dur2, 320), g2),))
    res = find_rotations([j1, j2], 50.0)
    assert res.score <= 1.0 + 1e-9
    for j, s in enumerate(res.shifts_steps):
        assert 0 <= s < res.circle.num_angles
    # fully-overlapping low-demand jobs must be fully compatible
    if g1 + g2 <= 50.0:
        assert res.score == pytest.approx(1.0)
