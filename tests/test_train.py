"""Training substrate tests: checkpoint/restore, failure injection + resume,
loss goes down, elastic re-mesh planning, deterministic data."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.data import SyntheticLM
from repro.train.elastic import plan_remesh
from repro.train.trainer import Trainer, TrainerConfig


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    restored, step = restore_checkpoint(tmp_path, tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.zeros(3)}
    d = save_checkpoint(tmp_path, 5, tree)
    (d / "COMMIT").unlink()
    assert latest_step(tmp_path) is None


def test_data_deterministic_and_seekable():
    d = SyntheticLM(vocab=64, seq_len=16, global_batch=4, seed=3)
    b1 = d.batch_at(10)
    b2 = d.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch_at(11)["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_training_loss_decreases(tmp_path):
    from repro.train.optimizer import AdamWConfig

    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                             total_steps=40))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8)
    tr = Trainer(model, data, TrainerConfig(
        steps=40, ckpt_every=0, ckpt_dir=str(tmp_path), log_every=5))
    res = tr.run()
    assert res.steps_run == 40
    assert res.losses[-1] < res.losses[0] - 0.1


def test_failure_injection_and_resume(tmp_path):
    """Crash mid-run, restart, verify resume from the checkpoint and that
    the final state matches an uninterrupted run (determinism)."""
    cfg = get_config("smollm-135m").reduced(num_layers=1, d_model=64, d_ff=128)
    model = build_model(cfg)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4)

    mk = lambda fail, d: Trainer(model, data, TrainerConfig(
        steps=30, ckpt_every=10, ckpt_dir=str(d), log_every=30,
        fail_at_step=fail))

    with pytest.raises(RuntimeError, match="injected failure"):
        mk(25, tmp_path / "a").run()
    assert latest_step(tmp_path / "a") == 20
    res = mk(None, tmp_path / "a").run()   # restart: resumes at 20
    assert res.restored_from == 20
    assert res.steps_run == 10

    mk(None, tmp_path / "b").run()         # uninterrupted reference
    # compare final checkpoints
    a, sa = restore_checkpoint(tmp_path / "a", _tree_like(model))
    b, sb = restore_checkpoint(tmp_path / "b", _tree_like(model))
    assert sa == sb == 30
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), rtol=1e-5, atol=1e-6)


def _tree_like(model):
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt = jax.eval_shape(model.init_opt, params)
    return (params, opt)


def test_elastic_remesh_plan():
    plan = plan_remesh((2, 16, 16), ("pod", "data", "model"), failed=16)
    assert plan.viable
    assert plan.new_shape[2] == 16           # model axis preserved
    assert plan.new_shape[0] * plan.new_shape[1] * 16 <= 512 - 16
    assert plan.data_scale < 1.0

    plan2 = plan_remesh((16, 16), ("data", "model"), failed=0)
    assert plan2.new_shape == (16, 16)
    assert plan2.data_scale == 1.0

    with pytest.raises(ValueError):
        plan_remesh((16, 16), ("data", "model"), failed=250)


def test_gradient_compression_error_feedback():
    from repro.parallel.compression import (
        init_ef, int8_compress, int8_decompress, topk_compress, topk_decompress,
    )

    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                          jnp.float32)}
    ef = init_ef(g)
    comp, ef2 = int8_compress(g, ef)
    g_hat = int8_decompress(comp)
    err1 = float(jnp.abs(g_hat["w"] - g["w"]).max())
    assert err1 < 0.05  # int8 quantization error is bounded by the scale
    # error feedback: the residual carries exactly the quantization error
    np.testing.assert_allclose(
        np.asarray(ef2.residual["w"]), np.asarray(g["w"] - g_hat["w"]),
        rtol=1e-5, atol=1e-6,
    )

    comp, ef3 = topk_compress(g, ef, frac=0.25)
    g_top = topk_decompress(comp)
    nz = float((g_top["w"] != 0).mean())
    assert 0.2 < nz <= 0.3
