"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
sibling config and runs one forward/train step + one decode step on CPU,
asserting output shapes and no NaNs (full configs are exercised only via
the dry-run)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import encdec
from repro.models.api import build_model

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    if cfg.family == "audio":
        s_enc, s_dec = encdec.enc_seq_split(cfg, s)
        return {
            "frames": jnp.ones((b, s_enc, cfg.d_model), jnp.float32),
            "tokens": jnp.ones((b, s_dec), jnp.int32),
            "labels": jnp.ones((b, s_dec), jnp.int32),
        }
    if cfg.num_patches:
        return {
            "tokens": jnp.ones((b, s - cfg.num_patches), jnp.int32),
            "patches": jnp.ones((b, cfg.num_patches, cfg.d_model), jnp.float32),
            "labels": jnp.ones((b, s - cfg.num_patches), jnp.int32),
        }
    return {
        "tokens": jnp.ones((b, s), jnp.int32),
        "labels": jnp.ones((b, s), jnp.int32),
    }


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_and_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)

    logits = model.forward(params, batch)
    b = batch["tokens"].shape[0]
    assert logits.shape[0] == b
    assert logits.shape[-1] == cfg.padded_vocab
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    p2, o2, metrics = jax.jit(model.train_step)(params, model.init_opt(params), batch)
    assert float(metrics["loss"]) > 0
    assert not bool(jnp.isnan(metrics["loss"]))
    assert not any(bool(jnp.isnan(x.astype(jnp.float32)).any())
                   for x in jax.tree.leaves(p2))

    if cfg.family == "audio":
        state = model.init_decode_state(b, 128, params=params,
                                        frames=batch["frames"])
    else:
        state = model.init_decode_state(b, 128)
    logits2, state2 = jax.jit(model.serve_step)(
        params, jnp.ones((b, 1), jnp.int32), state
    )
    assert logits2.shape == (b, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any())
    assert int(state2.pos) == 1


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-1.3b", "zamba2-7b"])
def test_decode_matches_forward_teacher_forcing(arch):
    """Greedy decode logits must match the training forward at the same
    positions (KV-cache / SSM-state correctness).  Run in fp32 so the check
    is tight — in bf16 the two algebraically-identical paths accumulate
    ~0.1 of rounding noise over deep stacks."""
    cfg = get_config(arch).reduced(dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks})

    state = model.init_decode_state(b, 32)
    outs = []
    step = jax.jit(model.serve_step)
    for t in range(s):
        logits, state = step(params, toks[:, t:t+1], state)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    import numpy as np

    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=1e-4, atol=1e-4,
    )


def test_full_configs_match_assignment():
    spec = {
        "mamba2-1.3b": dict(num_layers=48, d_model=2048, vocab=50280, ssm_state=128),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, d_ff=2048, vocab=163840,
                                num_experts=384, top_k=8),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=14336, vocab=32000,
                             num_experts=8, top_k=2),
        "granite-3-8b": dict(num_layers=40, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=12800, vocab=49155),
        "qwen1.5-0.5b": dict(num_layers=24, d_model=1024, num_heads=16,
                             num_kv_heads=16, d_ff=2816, vocab=151936,
                             qkv_bias=True),
        "smollm-135m": dict(num_layers=30, d_model=576, num_heads=9,
                            num_kv_heads=3, d_ff=1536, vocab=49152),
        "llama3.2-1b": dict(num_layers=16, d_model=2048, num_heads=32,
                            num_kv_heads=8, d_ff=8192, vocab=128256),
        "internvl2-26b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab=92553),
        "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                          num_kv_heads=32, d_ff=14336, vocab=32000,
                          ssm_state=64),
        "whisper-large-v3": dict(num_layers=32, d_model=1280, num_heads=20,
                                 num_kv_heads=20, d_ff=5120, vocab=51866),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_param_count_smollm_full():
    """smollm-135m's real config should have ≈135M parameters (+pad)."""
    cfg = get_config("smollm-135m")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    n = sum(int(jnp.prod(jnp.array(x.shape))) for x in jax.tree.leaves(shapes))
    assert 130e6 < n < 200e6
