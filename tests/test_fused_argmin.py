"""Parity harness for the fused device-resident rotation search.

``circle_score_argmin`` must match host ``np.argmin`` over the full
excess matrix *bit for bit* — same excess values, first-index (lowest
shift) tie-breaking — for every row shape the batched search can
produce: equal excess at multiple shifts, zero-capacity rows (every
shift ties), all-infeasible rows (no shift reaches zero excess) and
per-row admissible-shift bounds.  ``circle_score_segmin`` must replay
the product-grid acceptance scan (strict 1e-12 improvement, rows in
order, incumbent carried across chunks) exactly.  Lane padding — the
default that makes any angle count Mosaic-alignable — must not change
one output bit.

The hypothesis properties need the dev extra; seeded numpy sweeps cover
the same distributions where it is unavailable.
"""

import numpy as np
import pytest

from repro.core.compat import BatchStats, find_rotations, find_rotations_batched
from repro.core.circle import CommPattern, Phase
from repro.kernels.circle_score.kernel import (
    LANE_MULTIPLE,
    circle_score_argmin_pallas,
    circle_score_pallas,
)
from repro.kernels.circle_score.ops import (
    ACCEPT_SLACK,
    circle_score,
    circle_score_argmin,
    circle_score_argmin_ref,
    circle_score_segmin,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extra absent
    HAVE_HYPOTHESIS = False


def _random_rows(rng, l, a, *, zero_cap_frac=0.25, infeasible_frac=0.25):
    base = (rng.random((l, a)) * 60).astype(np.float32)
    cand = (rng.random((l, a)) * 60).astype(np.float32)
    caps = rng.choice([25.0, 50.0, 100.0], l).astype(np.float32)
    k = int(l * zero_cap_frac)
    caps[:k] = 0.0                       # zero capacity: every shift ties
    m = int(l * infeasible_frac)
    base[k:k + m] += 200.0               # all-infeasible: excess everywhere
    valid = rng.integers(1, a + 1, l).astype(np.int32)
    return base, cand, caps, valid


def _assert_parity(base, cand, caps, valid):
    idx, val = circle_score_argmin(base, cand, caps, valid)
    idx, val = np.asarray(idx), np.asarray(val)
    ref_idx, ref_val = circle_score_argmin_ref(base, cand, caps, valid)
    np.testing.assert_array_equal(idx, ref_idx)
    np.testing.assert_array_equal(val, ref_val)
    # and against the kernel's own full matrix (the exact values the host
    # reduction would have seen)
    mat = np.asarray(circle_score(base, cand, caps))
    for i in range(len(idx)):
        assert idx[i] == np.argmin(mat[i, : valid[i]])
        assert val[i] == mat[i, idx[i]]


# ---------------------------------------------------------------------- #
# per-row argmin parity
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("seed,l,a", [(0, 6, 72), (1, 4, 257), (2, 9, 144),
                                      (3, 3, 720), (4, 33, 96)])
def test_argmin_parity_seeded(seed, l, a):
    rng = np.random.default_rng(seed)
    _assert_parity(*_random_rows(rng, l, a))


def test_argmin_ties_pick_lowest_shift():
    """Exactly periodic candidate: shifts s and s + A/2 produce identical
    excess — the fused reduction must return the lower one, like argmin."""
    a = 144
    base = np.zeros((2, a), np.float32)
    base[:, :12] = 80.0
    cand = np.zeros((2, a), np.float32)
    cand[:, 20:32] = 60.0
    cand[:, 20 + a // 2: 32 + a // 2] = 60.0   # period A/2 ⇒ full-circle ties
    idx, val = circle_score_argmin(base, cand, 50.0)
    mat = np.asarray(circle_score(base, cand, 50.0))
    for i in range(2):
        winners = np.flatnonzero(mat[i] == mat[i].min())
        assert len(winners) >= 2               # the tie actually happened
        assert int(np.asarray(idx)[i]) == winners[0]


def test_argmin_zero_capacity_rows():
    """C = 0 makes every rotation's excess the same total demand.  With
    integer demands the float32 sums are exact, so all A shifts tie
    *exactly* and the reduction must settle on shift 0 (lowest wins)."""
    rng = np.random.default_rng(5)
    base = rng.integers(0, 40, (3, 72)).astype(np.float32)
    cand = rng.integers(0, 40, (3, 72)).astype(np.float32)
    idx, val = circle_score_argmin(base, cand, 0.0)
    assert np.all(np.asarray(idx) == 0)
    np.testing.assert_array_equal(
        np.asarray(val), (base + cand).sum(axis=1, dtype=np.float32)
    )


def test_argmin_all_infeasible_rows():
    """No rotation reaches zero excess: the early-exit must not fire and the
    scan must still return the true minimum."""
    rng = np.random.default_rng(6)
    base = (rng.random((4, 96)) * 30 + 100).astype(np.float32)
    cand = (rng.random((4, 96)) * 30).astype(np.float32)
    idx, val = circle_score_argmin(base, cand, 50.0)
    assert np.all(np.asarray(val) > 0.0)
    _assert_parity(base, cand, np.full(4, 50.0, np.float32),
                   np.full(4, 96, np.int32))


# ---------------------------------------------------------------------- #
# segmented acceptance scan
# ---------------------------------------------------------------------- #
def _host_fold(mat, valid, seg_ids, init_best):
    """Reference: the scalar product-grid acceptance loop."""
    num_segs = len(init_best)
    best = [float(b) for b in init_best]
    row = [0] * num_segs
    shift = [0] * num_segs
    acc = [False] * num_segs
    for r in range(mat.shape[0]):
        sid = int(seg_ids[r])
        s = int(np.argmin(mat[r, : valid[r]]))
        if float(mat[r, s]) < best[sid] - ACCEPT_SLACK:
            best[sid] = float(mat[r, s])
            row[sid] = r
            shift[sid] = s
            acc[sid] = True
    return acc, row, shift, best


@pytest.mark.parametrize("seed", range(4))
def test_segmin_matches_host_acceptance_scan(seed):
    rng = np.random.default_rng(100 + seed)
    l, a = 24, 144
    base, cand, caps, valid = _random_rows(rng, l, a)
    seg_sizes = [5, 1, 8, 10]
    seg_ids = np.repeat(np.arange(4), seg_sizes).astype(np.int32)
    # mixed incumbents: fresh (inf), already-zero (0 — nothing can beat it),
    # and a finite best carried from a "previous chunk"
    init = np.array([np.inf, 0.0, np.inf, 300.0], np.float64)
    acc, row, shift, best = map(
        np.asarray, circle_score_segmin(base, cand, caps, valid, seg_ids, init)
    )
    mat = np.asarray(circle_score(base, cand, caps))
    h_acc, h_row, h_shift, h_best = _host_fold(mat, valid, seg_ids, init)
    np.testing.assert_array_equal(acc, h_acc)
    np.testing.assert_array_equal(best, h_best)
    for s in range(4):
        if acc[s]:
            assert row[s] == h_row[s] and shift[s] == h_shift[s]
    assert not acc[1]  # zero incumbent is unbeatable


def test_segmin_equal_row_does_not_displace_earlier():
    """Two identical rows in one segment: the strict-slack rule keeps the
    first accepted row (np.argmin-style earliest-wins across rows)."""
    rng = np.random.default_rng(9)
    one = (rng.random((1, 72)) * 80).astype(np.float32)
    base = np.repeat(one, 2, axis=0)
    cand = np.repeat((rng.random((1, 72)) * 80).astype(np.float32), 2, axis=0)
    caps = np.full(2, 50.0, np.float32)
    valid = np.full(2, 72, np.int32)
    seg = np.zeros(2, np.int32)
    acc, row, shift, best = map(
        np.asarray,
        circle_score_segmin(base, cand, caps, valid, seg, np.array([np.inf])),
    )
    assert acc[0] and row[0] == 0


# ---------------------------------------------------------------------- #
# lane padding (Mosaic alignment satellite)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("l,a", [(3, 72), (5, 257), (2, 100), (4, 720)])
def test_lane_padding_changes_no_output_bit(l, a):
    """Padding the angle axis to a multiple of LANE_MULTIPLE (the default,
    satisfying the kernel's Mosaic lane requirement for any circle) must
    leave every score bit-identical — the kernels statically re-slice to
    the real width before each reduction."""
    rng = np.random.default_rng(a)
    base, cand, caps, valid = _random_rows(rng, l, a)
    on = np.asarray(circle_score_pallas(base, cand, caps, lane_pad=True))
    off = np.asarray(circle_score_pallas(base, cand, caps, lane_pad=False))
    np.testing.assert_array_equal(on, off)
    assert on.shape == (l, a)  # padding never leaks into the result

    assert a % LANE_MULTIPLE != 0  # every case exercises a padded width

    i_on, v_on = circle_score_argmin_pallas(base, cand, caps, valid, lane_pad=True)
    i_off, v_off = circle_score_argmin_pallas(base, cand, caps, valid, lane_pad=False)
    np.testing.assert_array_equal(np.asarray(i_on), np.asarray(i_off))
    np.testing.assert_array_equal(np.asarray(v_on), np.asarray(v_off))


# ---------------------------------------------------------------------- #
# end-to-end: device-reduced search == scalar search
# ---------------------------------------------------------------------- #
def _link_problems(rng, n, k):
    periods = (160.0, 200.0, 240.0, 320.0, 400.0, 480.0)
    demands = (0.0, 4.0, 20.0, 40.0, 45.0, 60.0)
    out = []
    for i in range(n):
        pats = []
        for j in range(k):
            it = float(rng.choice(periods))
            phases = tuple(
                Phase(float(rng.uniform(0, it)), float(rng.uniform(0, 0.9 * it)),
                      float(rng.choice(demands)))
                for _ in range(int(rng.integers(1, 3)))
            )
            pats.append(CommPattern(it, phases, name=f"f{i}j{j}"))
        out.append((pats, float(rng.choice((25.0, 50.0, 100.0)))))
    return out


@pytest.mark.parametrize("seed,k", [(0, 2), (1, 3), (2, 3)])
def test_grid_device_reduce_bit_identical_forced_pallas(seed, k):
    """backend='pallas' makes even small circles kernel-eligible, so the
    fused grid path runs; results must equal the scalar search and the
    full-matrix batched path bit for bit, with every call device-reduced."""
    rng = np.random.default_rng(seed)
    problems = _link_problems(rng, 3, k)
    scalar = [find_rotations(p, c, backend="pallas") for p, c in problems]
    stats_on = BatchStats()
    on = find_rotations_batched(
        problems, backend="pallas", stats=stats_on, device_reduce=True
    )
    stats_off = BatchStats()
    off = find_rotations_batched(
        problems, backend="pallas", stats=stats_off, device_reduce=False
    )
    for s, b_on, b_off in zip(scalar, on, off):
        assert b_on.score == s.score == b_off.score
        assert b_on.shifts_steps == s.shifts_steps == b_off.shifts_steps
        assert b_on.shifts_ms == s.shifts_ms == b_off.shifts_ms
    assert stats_on.device_reduced == stats_on.batched_calls > 0
    assert stats_off.device_reduced == 0
    assert stats_on.bytes_returned < stats_off.bytes_returned
    assert stats_on.bytes_matrix == stats_off.bytes_matrix


def test_grid_device_reduce_across_chunks(monkeypatch):
    """A tiny GRID_CHUNK_ROWS splits problems mid-grid; the incumbent best
    must carry into the next chunk's device scan (init_best) so the result
    still equals the unchunked scalar search."""
    from repro.core import compat

    rng = np.random.default_rng(42)
    problems = _link_problems(rng, 3, 3)
    scalar = [find_rotations(p, c, backend="pallas") for p, c in problems]
    monkeypatch.setattr(compat, "GRID_CHUNK_ROWS", 5)
    stats = BatchStats()
    batched = find_rotations_batched(
        problems, backend="pallas", stats=stats, device_reduce=True
    )
    for s, b in zip(scalar, batched):
        assert b.score == s.score and b.shifts_steps == s.shifts_steps
    assert stats.device_reduced == stats.batched_calls > 1


# ---------------------------------------------------------------------- #
# hypothesis properties (dev extra)
# ---------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_argmin_parity_property(data):
        seed = data.draw(st.integers(0, 2**31 - 1))
        l = data.draw(st.integers(1, 12))
        a = data.draw(st.sampled_from((72, 96, 144, 257)))
        rng = np.random.default_rng(seed)
        zero_frac = data.draw(st.sampled_from((0.0, 0.5, 1.0)))
        inf_frac = data.draw(st.sampled_from((0.0, 0.5)))
        _assert_parity(*_random_rows(
            rng, l, a, zero_cap_frac=zero_frac, infeasible_frac=inf_frac
        ))

    @settings(max_examples=20, deadline=None)
    @given(st.data())
    def test_segmin_matches_host_scan_property(data):
        seed = data.draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        num_segs = data.draw(st.integers(1, 5))
        sizes = [data.draw(st.integers(1, 6)) for _ in range(num_segs)]
        a = data.draw(st.sampled_from((72, 144)))
        l = sum(sizes)
        base, cand, caps, valid = _random_rows(rng, l, a)
        seg_ids = np.repeat(np.arange(num_segs), sizes).astype(np.int32)
        init = np.array(
            [data.draw(st.sampled_from((np.inf, 0.0, 500.0)))
             for _ in range(num_segs)], np.float64,
        )
        acc, row, shift, best = map(
            np.asarray,
            circle_score_segmin(base, cand, caps, valid, seg_ids, init),
        )
        mat = np.asarray(circle_score(base, cand, caps))
        h_acc, h_row, h_shift, h_best = _host_fold(mat, valid, seg_ids, init)
        np.testing.assert_array_equal(acc, h_acc)
        np.testing.assert_array_equal(best, h_best)
        for s in range(num_segs):
            if acc[s]:
                assert row[s] == h_row[s] and shift[s] == h_shift[s]
