"""Parity harness for the fused device-resident rotation search.

``circle_score_argmin`` must match host ``np.argmin`` over the full
excess matrix *bit for bit* — same excess values, first-index (lowest
shift) tie-breaking — for every row shape the batched search can
produce: equal excess at multiple shifts, zero-capacity rows (every
shift ties), all-infeasible rows (no shift reaches zero excess) and
per-row admissible-shift bounds.  ``circle_score_segmin`` must replay
the product-grid acceptance scan (strict 1e-12 improvement, rows in
order, incumbent carried across chunks) exactly.  Lane padding — the
default that makes any angle count Mosaic-alignable — must not change
one output bit.

The hypothesis properties need the dev extra; seeded numpy sweeps cover
the same distributions where it is unavailable.
"""

import numpy as np
import pytest

from repro.core.compat import BatchStats, find_rotations, find_rotations_batched
from repro.core.circle import CommPattern, Phase
from repro.kernels.circle_score.kernel import (
    LANE_MULTIPLE,
    circle_score_argmin_pallas,
    circle_score_pallas,
)
from repro.kernels.circle_score.ops import (
    ACCEPT_SLACK,
    circle_score,
    circle_score_argmin,
    circle_score_argmin_ref,
    circle_score_ragged_argmin,
    circle_score_ragged_segmin,
    circle_score_segmin,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extra absent
    HAVE_HYPOTHESIS = False


def _random_rows(rng, l, a, *, zero_cap_frac=0.25, infeasible_frac=0.25):
    base = (rng.random((l, a)) * 60).astype(np.float32)
    cand = (rng.random((l, a)) * 60).astype(np.float32)
    caps = rng.choice([25.0, 50.0, 100.0], l).astype(np.float32)
    k = int(l * zero_cap_frac)
    caps[:k] = 0.0                       # zero capacity: every shift ties
    m = int(l * infeasible_frac)
    base[k:k + m] += 200.0               # all-infeasible: excess everywhere
    valid = rng.integers(1, a + 1, l).astype(np.int32)
    return base, cand, caps, valid


def _assert_parity(base, cand, caps, valid):
    idx, val = circle_score_argmin(base, cand, caps, valid)
    idx, val = np.asarray(idx), np.asarray(val)
    ref_idx, ref_val = circle_score_argmin_ref(base, cand, caps, valid)
    np.testing.assert_array_equal(idx, ref_idx)
    np.testing.assert_array_equal(val, ref_val)
    # and against the kernel's own full matrix (the exact values the host
    # reduction would have seen)
    mat = np.asarray(circle_score(base, cand, caps))
    for i in range(len(idx)):
        assert idx[i] == np.argmin(mat[i, : valid[i]])
        assert val[i] == mat[i, idx[i]]


# ---------------------------------------------------------------------- #
# per-row argmin parity
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("seed,l,a", [(0, 6, 72), (1, 4, 257), (2, 9, 144),
                                      (3, 3, 720), (4, 33, 96)])
def test_argmin_parity_seeded(seed, l, a):
    rng = np.random.default_rng(seed)
    _assert_parity(*_random_rows(rng, l, a))


def test_argmin_ties_pick_lowest_shift():
    """Exactly periodic candidate: shifts s and s + A/2 produce identical
    excess — the fused reduction must return the lower one, like argmin."""
    a = 144
    base = np.zeros((2, a), np.float32)
    base[:, :12] = 80.0
    cand = np.zeros((2, a), np.float32)
    cand[:, 20:32] = 60.0
    cand[:, 20 + a // 2: 32 + a // 2] = 60.0   # period A/2 ⇒ full-circle ties
    idx, val = circle_score_argmin(base, cand, 50.0)
    mat = np.asarray(circle_score(base, cand, 50.0))
    for i in range(2):
        winners = np.flatnonzero(mat[i] == mat[i].min())
        assert len(winners) >= 2               # the tie actually happened
        assert int(np.asarray(idx)[i]) == winners[0]


def test_argmin_zero_capacity_rows():
    """C = 0 makes every rotation's excess the same total demand.  With
    integer demands the float32 sums are exact, so all A shifts tie
    *exactly* and the reduction must settle on shift 0 (lowest wins)."""
    rng = np.random.default_rng(5)
    base = rng.integers(0, 40, (3, 72)).astype(np.float32)
    cand = rng.integers(0, 40, (3, 72)).astype(np.float32)
    idx, val = circle_score_argmin(base, cand, 0.0)
    assert np.all(np.asarray(idx) == 0)
    np.testing.assert_array_equal(
        np.asarray(val), (base + cand).sum(axis=1, dtype=np.float32)
    )


def test_argmin_all_infeasible_rows():
    """No rotation reaches zero excess: the early-exit must not fire and the
    scan must still return the true minimum."""
    rng = np.random.default_rng(6)
    base = (rng.random((4, 96)) * 30 + 100).astype(np.float32)
    cand = (rng.random((4, 96)) * 30).astype(np.float32)
    idx, val = circle_score_argmin(base, cand, 50.0)
    assert np.all(np.asarray(val) > 0.0)
    _assert_parity(base, cand, np.full(4, 50.0, np.float32),
                   np.full(4, 96, np.int32))


# ---------------------------------------------------------------------- #
# segmented acceptance scan
# ---------------------------------------------------------------------- #
def _host_fold(mat, valid, seg_ids, init_best):
    """Reference: the scalar product-grid acceptance loop."""
    num_segs = len(init_best)
    best = [float(b) for b in init_best]
    row = [0] * num_segs
    shift = [0] * num_segs
    acc = [False] * num_segs
    for r in range(mat.shape[0]):
        sid = int(seg_ids[r])
        s = int(np.argmin(mat[r, : valid[r]]))
        if float(mat[r, s]) < best[sid] - ACCEPT_SLACK:
            best[sid] = float(mat[r, s])
            row[sid] = r
            shift[sid] = s
            acc[sid] = True
    return acc, row, shift, best


@pytest.mark.parametrize("seed", range(4))
def test_segmin_matches_host_acceptance_scan(seed):
    rng = np.random.default_rng(100 + seed)
    l, a = 24, 144
    base, cand, caps, valid = _random_rows(rng, l, a)
    seg_sizes = [5, 1, 8, 10]
    seg_ids = np.repeat(np.arange(4), seg_sizes).astype(np.int32)
    # mixed incumbents: fresh (inf), already-zero (0 — nothing can beat it),
    # and a finite best carried from a "previous chunk"
    init = np.array([np.inf, 0.0, np.inf, 300.0], np.float64)
    acc, row, shift, best = map(
        np.asarray, circle_score_segmin(base, cand, caps, valid, seg_ids, init)
    )
    mat = np.asarray(circle_score(base, cand, caps))
    h_acc, h_row, h_shift, h_best = _host_fold(mat, valid, seg_ids, init)
    np.testing.assert_array_equal(acc, h_acc)
    np.testing.assert_array_equal(best, h_best)
    for s in range(4):
        if acc[s]:
            assert row[s] == h_row[s] and shift[s] == h_shift[s]
    assert not acc[1]  # zero incumbent is unbeatable


def test_segmin_equal_row_does_not_displace_earlier():
    """Two identical rows in one segment: the strict-slack rule keeps the
    first accepted row (np.argmin-style earliest-wins across rows)."""
    rng = np.random.default_rng(9)
    one = (rng.random((1, 72)) * 80).astype(np.float32)
    base = np.repeat(one, 2, axis=0)
    cand = np.repeat((rng.random((1, 72)) * 80).astype(np.float32), 2, axis=0)
    caps = np.full(2, 50.0, np.float32)
    valid = np.full(2, 72, np.int32)
    seg = np.zeros(2, np.int32)
    acc, row, shift, best = map(
        np.asarray,
        circle_score_segmin(base, cand, caps, valid, seg, np.array([np.inf])),
    )
    assert acc[0] and row[0] == 0


# ---------------------------------------------------------------------- #
# lane padding (Mosaic alignment satellite)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("l,a", [(3, 72), (5, 257), (2, 100), (4, 720)])
def test_lane_padding_changes_no_output_bit(l, a):
    """Padding the angle axis to a multiple of LANE_MULTIPLE (the default,
    satisfying the kernel's Mosaic lane requirement for any circle) must
    leave every score bit-identical — the kernels statically re-slice to
    the real width before each reduction."""
    rng = np.random.default_rng(a)
    base, cand, caps, valid = _random_rows(rng, l, a)
    on = np.asarray(circle_score_pallas(base, cand, caps, lane_pad=True))
    off = np.asarray(circle_score_pallas(base, cand, caps, lane_pad=False))
    np.testing.assert_array_equal(on, off)
    assert on.shape == (l, a)  # padding never leaks into the result

    assert a % LANE_MULTIPLE != 0  # every case exercises a padded width

    i_on, v_on = circle_score_argmin_pallas(base, cand, caps, valid, lane_pad=True)
    i_off, v_off = circle_score_argmin_pallas(base, cand, caps, valid, lane_pad=False)
    np.testing.assert_array_equal(np.asarray(i_on), np.asarray(i_off))
    np.testing.assert_array_equal(np.asarray(v_on), np.asarray(v_off))


# ---------------------------------------------------------------------- #
# ragged single-launch batches (mixed angle counts in ONE kernel launch)
# ---------------------------------------------------------------------- #
RAGGED_ANGLE_COUNTS = (512, 640, 1024)


def _ragged_rows(rng, nas, *, zero_cap_frac=0.25, infeasible_frac=0.25):
    """Pack rows with per-row angle counts ``nas`` into one (L, max) batch
    (row l real in [:nas[l]], zero-padded above), with the same zero-cap /
    infeasible row mix as the uniform harness."""
    nas = np.asarray(nas, np.int32)
    l, w = len(nas), int(nas.max())
    base = np.zeros((l, w), np.float32)
    cand = np.zeros((l, w), np.float32)
    for i, a in enumerate(nas):
        base[i, :a] = rng.random(a) * 60
        cand[i, :a] = rng.random(a) * 60
    caps = rng.choice([25.0, 50.0, 100.0], l).astype(np.float32)
    k = int(l * zero_cap_frac)
    caps[:k] = 0.0
    m = int(l * infeasible_frac)
    base[k:k + m] += np.where(
        np.arange(w)[None, :] < nas[k:k + m, None], 200.0, 0.0
    ).astype(np.float32)
    valid = np.array([rng.integers(1, a + 1) for a in nas], np.int32)
    return base, cand, caps, valid, nas


def _assert_ragged_parity(base, cand, caps, valid, nas, **kw):
    """Ragged single launch == per-group uniform launches == scalar oracle,
    bit for bit (shifts AND excess values)."""
    idx, val = map(
        np.asarray,
        circle_score_ragged_argmin(base, cand, caps, valid, nas, **kw),
    )
    # per-group launches: one uniform kernel call per distinct angle count,
    # rows tightly sliced to their own width
    for a in np.unique(nas):
        sel = nas == a
        g_idx, g_val = map(
            np.asarray,
            circle_score_argmin(
                base[sel][:, :a], cand[sel][:, :a], caps[sel], valid[sel]
            ),
        )
        np.testing.assert_array_equal(idx[sel], g_idx)
        np.testing.assert_array_equal(val[sel], g_val)
    # scalar oracle: per-row full matrix + np.argmin over admissible shifts
    r_idx, r_val = circle_score_argmin_ref(base, cand, caps, valid, nas)
    np.testing.assert_array_equal(idx, r_idx)
    np.testing.assert_array_equal(val, r_val)


@pytest.mark.parametrize("seed", range(3))
def test_ragged_mixed_angle_parity_seeded(seed):
    rng = np.random.default_rng(500 + seed)
    nas = rng.choice(RAGGED_ANGLE_COUNTS, 9)
    _assert_ragged_parity(*_ragged_rows(rng, nas))


def test_ragged_single_row_batch():
    """L = 1 (one link problem in the whole launch) for each angle count."""
    for a in RAGGED_ANGLE_COUNTS:
        rng = np.random.default_rng(a)
        _assert_ragged_parity(
            *_ragged_rows(rng, [a], zero_cap_frac=0.0, infeasible_frac=0.0)
        )


def test_ragged_all_rows_padded():
    """Every row narrower than the launch width (``pad_to`` forces the
    width no row reaches): the masking invariants alone must keep the
    results bit-identical to the tightly-padded launches."""
    rng = np.random.default_rng(7)
    nas = np.array([512, 512, 640, 640, 512], np.int32)
    base, cand, caps, valid, nas = _ragged_rows(rng, nas)
    _assert_ragged_parity(base, cand, caps, valid, nas, pad_to=1024)
    # and wider than any lane requirement, mid-block
    _assert_ragged_parity(base, cand, caps, valid, nas, pad_to=1920)


def test_ragged_ties_and_zero_capacity():
    """Zero capacity + integer demands: the float32 sums are exact, so all
    admissible shifts of a row tie *exactly* — the tournament must resolve
    every row of the mixed batch to shift 0 (np.argmin first-index)."""
    rng = np.random.default_rng(11)
    nas = np.array([512, 640, 1024, 640], np.int32)
    l, w = len(nas), int(nas.max())
    base = np.zeros((l, w), np.float32)
    cand = np.zeros((l, w), np.float32)
    for i, a in enumerate(nas):
        base[i, :a] = rng.integers(0, 40, a)
        cand[i, :a] = rng.integers(0, 40, a)
    caps = np.zeros(l, np.float32)
    valid = nas.copy()  # all shifts admissible
    idx, val = map(
        np.asarray, circle_score_ragged_argmin(base, cand, caps, valid, nas)
    )
    assert np.all(idx == 0)
    np.testing.assert_array_equal(
        val,
        np.array([
            (base[i, :a] + cand[i, :a]).sum(dtype=np.float64)
            for i, a in enumerate(nas)
        ]).astype(np.float32),
    )
    _assert_ragged_parity(base, cand, caps, valid, nas)


def test_ragged_segmin_matches_host_scan():
    """Segments spanning rows of different angle counts: the device accept
    scan must replay the host fold over each row's own-width matrix."""
    rng = np.random.default_rng(21)
    nas = np.array([512, 640, 1024, 512, 640, 1024, 512, 640], np.int32)
    base, cand, caps, valid, nas = _ragged_rows(rng, nas)
    seg_sizes = [3, 1, 4]
    seg_ids = np.repeat(np.arange(3), seg_sizes).astype(np.int32)
    init = np.array([np.inf, 0.0, 90000.0], np.float64)
    acc, row, shift, best = map(
        np.asarray,
        circle_score_ragged_segmin(base, cand, caps, valid, nas, seg_ids, init),
    )
    # host fold over per-row own-width matrices
    h_best = [float(b) for b in init]
    h_row, h_shift, h_acc = [0] * 3, [0] * 3, [False] * 3
    for r in range(len(nas)):
        a = int(nas[r])
        mat = np.asarray(
            circle_score(base[r : r + 1, :a], cand[r : r + 1, :a], caps[r])
        )[0]
        s = int(np.argmin(mat[: valid[r]]))
        sid = int(seg_ids[r])
        if float(mat[s]) < h_best[sid] - ACCEPT_SLACK:
            h_best[sid] = float(mat[s])
            h_row[sid], h_shift[sid], h_acc[sid] = r, s, True
    np.testing.assert_array_equal(acc, h_acc)
    np.testing.assert_array_equal(best, h_best)
    for s in range(3):
        if acc[s]:
            assert row[s] == h_row[s] and shift[s] == h_shift[s]
    assert not acc[1]  # zero incumbent is unbeatable


# ---------------------------------------------------------------------- #
# end-to-end ragged: one launch per step through find_rotations_batched
# ---------------------------------------------------------------------- #
def _mixed_angle_link_problems(rng, wraps=(7, 11, 13), per=2, k=2):
    """Link problems whose unified circles land on different angle counts:
    a slow job of period 100·w forces ``num_angles`` to the next multiple
    of w above the base grid, so each w yields its own angle count."""
    out = []
    for wi, w in enumerate(wraps):
        for i in range(per):
            pats = [
                CommPattern(
                    100.0 * w,
                    (Phase(float(rng.uniform(0, 50.0 * w)), 30.0 * w, 40.0),),
                    name=f"w{w}s{i}",
                )
            ]
            for j in range(k - 1):
                pats.append(
                    CommPattern(
                        100.0,
                        (Phase(float(rng.uniform(0, 60.0)), 35.0, 30.0),),
                        name=f"w{w}f{i}{j}",
                    )
                )
            out.append((pats, float(rng.choice((25.0, 50.0)))))
    return out


def test_grid_ragged_one_launch_bit_identical():
    """Mixed-angle grid problems: ragged=True must solve the whole epoch in
    ONE launch (launches == batched_calls == 1) with results bit-identical
    to the per-group launches (ragged=False) and the scalar search."""
    rng = np.random.default_rng(60)
    problems = _mixed_angle_link_problems(rng)
    deg = 0.5
    scalar = [find_rotations(p, c, precision_deg=deg) for p, c in problems]
    angle_counts = {s.circle.num_angles for s in scalar}
    assert len(angle_counts) >= 2  # the mix actually happened

    st_r, st_g = BatchStats(), BatchStats()
    ragged = find_rotations_batched(
        problems, precision_deg=deg, stats=st_r, ragged=True
    )
    grouped = find_rotations_batched(
        problems, precision_deg=deg, stats=st_g, ragged=False
    )
    for s, r, g in zip(scalar, ragged, grouped):
        assert r.shifts_steps == s.shifts_steps == g.shifts_steps
        assert r.score == s.score == g.score
        assert r.shifts_ms == s.shifts_ms == g.shifts_ms
    assert st_r.launches == st_r.batched_calls == 1
    assert st_r.ragged_rows == st_r.grid_rows > 0
    assert 0.0 <= st_r.pad_fraction < 1.0
    assert st_g.launches == len(angle_counts) > st_r.launches
    assert st_g.ragged_rows == 0
    # bytes_matrix accounts real row widths on both paths
    assert st_r.bytes_matrix == st_g.bytes_matrix


def test_descent_ragged_accepted_sequences_match_grouped():
    """Mixed-angle k=4 descents: the ragged per-step launch must walk the
    exact accepted-shift sequence of the per-group launches, with one
    launch per (trial, sweep, job) step."""
    from repro.core.compat import _DescentState

    rng = np.random.default_rng(61)
    problems = _mixed_angle_link_problems(rng, wraps=(7, 11), per=1, k=4)

    def record(ragged):
        accepted = []
        orig = _DescentState.apply_shift

        def recording(self, j, base, s_new):
            accepted.append((self.index, j, int(s_new)))
            return orig(self, j, base, s_new)

        stats = BatchStats()
        try:
            _DescentState.apply_shift = recording
            res = find_rotations_batched(
                problems, precision_deg=0.5, stats=stats, ragged=ragged
            )
        finally:
            _DescentState.apply_shift = orig
        return accepted, res, stats

    acc_r, res_r, st_r = record(True)
    acc_g, res_g, st_g = record(False)
    assert acc_r == acc_g and len(acc_r) > 0
    for r, g in zip(res_r, res_g):
        assert r.shifts_steps == g.shifts_steps and r.score == g.score
    assert st_r.descent_problems == 2
    assert st_r.launches == st_r.batched_calls  # one launch per step
    assert st_r.ragged_rows == st_r.descent_rows
    assert st_g.launches > st_r.launches  # grouped pays per angle count


def test_ragged_chunk_boundaries(monkeypatch):
    """A tiny GRID_CHUNK_ROWS splits the mixed-angle batch mid-problem: one
    launch per chunk, incumbents carried across, results unchanged."""
    from repro.core import compat

    rng = np.random.default_rng(62)
    problems = _mixed_angle_link_problems(rng, wraps=(7, 13), per=2, k=3)
    deg = 5.0  # k=3 grids at 5°: multi-row product grids, still mixed A
    scalar = [
        find_rotations(p, c, precision_deg=deg, backend="pallas")
        for p, c in problems
    ]
    monkeypatch.setattr(compat, "GRID_CHUNK_ROWS", 3)
    stats = BatchStats()
    batched = find_rotations_batched(
        problems, precision_deg=deg, backend="pallas", stats=stats, ragged=True
    )
    for s, b in zip(scalar, batched):
        assert b.shifts_steps == s.shifts_steps and b.score == s.score
    assert stats.launches == stats.batched_calls > 1
    assert stats.ragged_rows == stats.grid_rows


# ---------------------------------------------------------------------- #
# end-to-end: device-reduced search == scalar search
# ---------------------------------------------------------------------- #
def _link_problems(rng, n, k):
    periods = (160.0, 200.0, 240.0, 320.0, 400.0, 480.0)
    demands = (0.0, 4.0, 20.0, 40.0, 45.0, 60.0)
    out = []
    for i in range(n):
        pats = []
        for j in range(k):
            it = float(rng.choice(periods))
            phases = tuple(
                Phase(float(rng.uniform(0, it)), float(rng.uniform(0, 0.9 * it)),
                      float(rng.choice(demands)))
                for _ in range(int(rng.integers(1, 3)))
            )
            pats.append(CommPattern(it, phases, name=f"f{i}j{j}"))
        out.append((pats, float(rng.choice((25.0, 50.0, 100.0)))))
    return out


@pytest.mark.parametrize("seed,k", [(0, 2), (1, 3), (2, 3)])
def test_grid_device_reduce_bit_identical_forced_pallas(seed, k):
    """backend='pallas' makes even small circles kernel-eligible, so the
    fused grid path runs; results must equal the scalar search and the
    full-matrix batched path bit for bit, with every call device-reduced."""
    rng = np.random.default_rng(seed)
    problems = _link_problems(rng, 3, k)
    scalar = [find_rotations(p, c, backend="pallas") for p, c in problems]
    stats_on = BatchStats()
    on = find_rotations_batched(
        problems, backend="pallas", stats=stats_on, device_reduce=True
    )
    stats_off = BatchStats()
    off = find_rotations_batched(
        problems, backend="pallas", stats=stats_off, device_reduce=False
    )
    for s, b_on, b_off in zip(scalar, on, off):
        assert b_on.score == s.score == b_off.score
        assert b_on.shifts_steps == s.shifts_steps == b_off.shifts_steps
        assert b_on.shifts_ms == s.shifts_ms == b_off.shifts_ms
    assert stats_on.device_reduced == stats_on.batched_calls > 0
    assert stats_off.device_reduced == 0
    assert stats_on.bytes_returned < stats_off.bytes_returned
    assert stats_on.bytes_matrix == stats_off.bytes_matrix


def test_grid_device_reduce_across_chunks(monkeypatch):
    """A tiny GRID_CHUNK_ROWS splits problems mid-grid; the incumbent best
    must carry into the next chunk's device scan (init_best) so the result
    still equals the unchunked scalar search."""
    from repro.core import compat

    rng = np.random.default_rng(42)
    problems = _link_problems(rng, 3, 3)
    scalar = [find_rotations(p, c, backend="pallas") for p, c in problems]
    monkeypatch.setattr(compat, "GRID_CHUNK_ROWS", 5)
    stats = BatchStats()
    batched = find_rotations_batched(
        problems, backend="pallas", stats=stats, device_reduce=True
    )
    for s, b in zip(scalar, batched):
        assert b.score == s.score and b.shifts_steps == s.shifts_steps
    assert stats.device_reduced == stats.batched_calls > 1


# ---------------------------------------------------------------------- #
# hypothesis properties (dev extra)
# ---------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_argmin_parity_property(data):
        seed = data.draw(st.integers(0, 2**31 - 1))
        l = data.draw(st.integers(1, 12))
        a = data.draw(st.sampled_from((72, 96, 144, 257)))
        rng = np.random.default_rng(seed)
        zero_frac = data.draw(st.sampled_from((0.0, 0.5, 1.0)))
        inf_frac = data.draw(st.sampled_from((0.0, 0.5)))
        _assert_parity(*_random_rows(
            rng, l, a, zero_cap_frac=zero_frac, infeasible_frac=inf_frac
        ))

    @settings(max_examples=12, deadline=None)
    @given(st.data())
    def test_ragged_mixed_angle_parity_property(data):
        """One ragged launch over rows mixing angle counts {512, 640, 1024}
        — any mix, any admissible-shift bounds, zero-capacity and
        infeasible rows included — must match the per-group launches and
        the scalar oracle bit for bit (all-same-width and single-row
        batches are drawn too)."""
        seed = data.draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        l = data.draw(st.sampled_from((1, 3, 6, 9)))
        nas = np.array(
            [data.draw(st.sampled_from(RAGGED_ANGLE_COUNTS)) for _ in range(l)],
            np.int32,
        )
        zero_frac = data.draw(st.sampled_from((0.0, 0.5)))
        inf_frac = data.draw(st.sampled_from((0.0, 0.5)))
        pad_to = data.draw(st.sampled_from((None, 1024, 1664)))
        base, cand, caps, valid, nas = _ragged_rows(
            rng, nas, zero_cap_frac=zero_frac, infeasible_frac=inf_frac
        )
        _assert_ragged_parity(base, cand, caps, valid, nas, pad_to=pad_to)

    @settings(max_examples=20, deadline=None)
    @given(st.data())
    def test_segmin_matches_host_scan_property(data):
        seed = data.draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        num_segs = data.draw(st.integers(1, 5))
        sizes = [data.draw(st.integers(1, 6)) for _ in range(num_segs)]
        a = data.draw(st.sampled_from((72, 144)))
        l = sum(sizes)
        base, cand, caps, valid = _random_rows(rng, l, a)
        seg_ids = np.repeat(np.arange(num_segs), sizes).astype(np.int32)
        init = np.array(
            [data.draw(st.sampled_from((np.inf, 0.0, 500.0)))
             for _ in range(num_segs)], np.float64,
        )
        acc, row, shift, best = map(
            np.asarray,
            circle_score_segmin(base, cand, caps, valid, seg_ids, init),
        )
        mat = np.asarray(circle_score(base, cand, caps))
        h_acc, h_row, h_shift, h_best = _host_fold(mat, valid, seg_ids, init)
        np.testing.assert_array_equal(acc, h_acc)
        np.testing.assert_array_equal(best, h_best)
        for s in range(num_segs):
            if acc[s]:
                assert row[s] == h_row[s] and shift[s] == h_shift[s]


# ---------------------------------------------------------------------- #
# ragged launch-width bucketing (jit recompile bound)
# ---------------------------------------------------------------------- #
def test_bucket_width_values():
    from repro.kernels.circle_score.ops import bucket_width

    assert bucket_width(1) == LANE_MULTIPLE
    assert bucket_width(128) == 128
    assert bucket_width(129) == 256
    assert bucket_width(512) == 512
    assert bucket_width(513) == 1024
    assert bucket_width(721) == 1024
    assert bucket_width(1024) == 1024
    assert bucket_width(1025) == 2048
    with pytest.raises(ValueError, match="positive"):
        bucket_width(0)


def test_ragged_width_bucketing_bounds_recompiles():
    """A long-tailed mix of packed widths inside one bucket must compile
    the fused kernel at most once: the ragged wrapper rounds the launch
    width up to a power-of-two multiple of 128 before the jit boundary,
    so the cache key sees the bucket, not the raw chunk width."""
    rng = np.random.default_rng(23)
    widths = (513, 600, 648, 700, 777, 900, 1000, 1024)  # all bucket to 1024
    l = 4
    baseline = circle_score_argmin_pallas._cache_size()
    results = []
    for w in widths:
        nas = np.full(l, w, np.int32)
        base, cand, caps, valid, nas = _ragged_rows(
            rng, nas, zero_cap_frac=0.0, infeasible_frac=0.0
        )
        results.append(
            tuple(
                map(np.ndarray.tolist, map(np.asarray, circle_score_ragged_argmin(
                    base, cand, caps, valid, nas
                )))
            )
        )
    grown = circle_score_argmin_pallas._cache_size() - baseline
    assert grown <= 1, (
        f"8 distinct packed widths in one bucket grew the jit cache by "
        f"{grown} entries (expected at most 1 — one compile per bucket)"
    )
    # and the bucketed launches stay correct: parity for the last width
    nas = np.full(l, widths[-1], np.int32)
    _assert_ragged_parity(
        *_ragged_rows(rng, nas, zero_cap_frac=0.0, infeasible_frac=0.0)
    )


def test_ragged_width_bucketing_distinct_buckets_compile_separately():
    """Widths in different buckets still get their own (correct) compile —
    bucketing caps recompiles, it does not merge genuinely different
    shapes."""
    from repro.kernels.circle_score.ops import bucket_width

    rng = np.random.default_rng(29)
    for w in (200, 520, 1100):
        nas = np.full(3, w, np.int32)
        base, cand, caps, valid, nas = _ragged_rows(
            rng, nas, zero_cap_frac=0.0, infeasible_frac=0.0
        )
        _assert_ragged_parity(base, cand, caps, valid, nas)
        assert bucket_width(w) in (256, 1024, 2048)
