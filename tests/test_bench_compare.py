"""Unit tests for the cross-PR bench regression gate + trend history
(`benchmarks/compare.py`): gate negative paths and the `--history` JSONL
round-trip with its per-row trend rendering."""

import json

from benchmarks.compare import (
    append_history,
    compare,
    fmt_compact,
    load_history,
    render_markdown,
    render_trends,
)


def _row(us, speedup=None):
    r = {"us_per_call": us}
    if speedup is not None:
        r["speedup"] = speedup
    return r


def test_compare_gate_negative_paths():
    baseline = {
        "a": _row(10_000.0, 2.0),
        "b": _row(10_000.0),
        "c": _row(10_000.0, 1.5),
    }
    current = {
        "a": _row(10_000.0, 2.1),  # ok
        "b": _row(20_000.0),       # +100% wall above the floor: SLOWER
        # "c" missing entirely
        "d": _row(50.0),           # new row: reported, never fails
    }
    table, failures = compare(current, baseline, threshold=0.20)
    statuses = {name: status for name, *_, status in table}
    assert statuses == {"a": "ok", "b": "SLOWER", "c": "MISSING", "d": "new"}
    assert len(failures) == 2


def test_compare_lost_speedup():
    baseline = {"a": _row(100.0, 1.5)}
    _, failures = compare({"a": _row(100.0, 0.9)}, baseline, 0.20)
    assert any("lost its speedup" in f for f in failures)
    _, failures = compare({"a": _row(100.0)}, baseline, 0.20)
    assert any("lost its speedup" in f for f in failures)


def test_compare_floor_exempts_subfloor_drift():
    """A 100→200µs 'regression' is 100µs of timer jitter: reported as
    ``noise``, marked ✅, and never fails the gate."""
    baseline = {"a": _row(100.0)}
    table, failures = compare({"a": _row(200.0)}, baseline, threshold=0.20)
    assert failures == []
    assert [s for _, *_, s in table] == ["noise"]
    md = render_markdown(table, failures, 0.20, "wall.")
    assert "✅ noise" in md
    assert "GATE FAILED" not in md


def test_compare_floor_boundary_and_override():
    # either side crossing the floor re-arms the relative gate: a genuine
    # 4ms → 6ms regression must not hide behind the baseline being small
    baseline = {"a": _row(4_000.0)}
    _, failures = compare({"a": _row(6_000.0)}, baseline, threshold=0.20)
    assert len(failures) == 1 and "drifted" in failures[0]
    # --floor-us 0 disables the exemption entirely
    baseline = {"a": _row(100.0)}
    _, failures = compare(
        {"a": _row(200.0)}, baseline, threshold=0.20, floor_us=0.0
    )
    assert len(failures) == 1


def test_compare_floor_never_shields_speedup_gate():
    """The floor exempts *wall drift* only — a sub-floor row that lost its
    claimed speedup still fails."""
    baseline = {"a": _row(100.0, 3.0)}
    table, failures = compare({"a": _row(200.0, 0.8)}, baseline, 0.20)
    assert len(failures) == 1 and "lost its speedup" in failures[0]
    assert [s for _, *_, s in table] == ["LOST-SPEEDUP"]


def test_history_roundtrip_and_trends(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    for i, us in enumerate((100.0, 110.0, 90.0)):
        append_history(
            path,
            {"a": _row(us, 2.0), "b": _row(10.0 * (i + 1))},
            {"wall_s": 1.0 + i},
        )
    runs = load_history(path)
    assert len(runs) == 3
    trends = render_trends(runs)
    assert trends["a"] == "100→110→90"
    assert trends["b"] == "10→20→30"
    # only the last TREND_RUNS entries survive
    for us in (1.0, 2.0, 3.0, 4.0):
        append_history(path, {"a": _row(us)}, {"wall_s": 0.0})
    assert len(load_history(path)) == 5


def test_history_skips_torn_lines(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    append_history(path, {"a": _row(1.0)}, {"wall_s": 0.0})
    with open(path, "a") as f:
        f.write('{"rows": {"a"\n')           # torn write
        f.write("not json at all\n")
        f.write(json.dumps({"no_rows": 1}) + "\n")
    append_history(path, {"a": _row(2.0)}, {"wall_s": 0.0})
    runs = load_history(path)
    assert [r["rows"]["a"]["us"] for r in runs] == [1.0, 2.0]


def test_render_markdown_trend_column_is_optional():
    table = [("a", 100.0, 100.0, "+0.0%", 2.0, 2.0, "ok")]
    md_plain = render_markdown(table, [], 0.2, "wall.")
    assert "trend" not in md_plain
    md_trend = render_markdown(table, [], 0.2, "wall.", {"a": "100→100"})
    assert "trend (last 5)" in md_trend
    assert "100→100" in md_trend
    # a row the history has never seen renders a placeholder, not a crash
    md_missing = render_markdown(table, [], 0.2, "wall.", {})
    assert "—" in md_missing


def test_fmt_compact():
    assert fmt_compact(950) == "950"
    assert fmt_compact(12_340) == "12.3k"
    assert fmt_compact(3_500_000) == "3.5M"
    assert fmt_compact(None) == "?"
