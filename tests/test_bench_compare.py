"""Unit tests for the cross-PR bench regression gate + trend history
(`benchmarks/compare.py`): gate negative paths and the `--history` JSONL
round-trip with its per-row trend rendering."""

import json

from benchmarks.compare import (
    append_history,
    compare,
    fmt_compact,
    load_history,
    render_markdown,
    render_trends,
)


def _row(us, speedup=None):
    r = {"us_per_call": us}
    if speedup is not None:
        r["speedup"] = speedup
    return r


def test_compare_gate_negative_paths():
    baseline = {
        "a": _row(100.0, 2.0),
        "b": _row(100.0),
        "c": _row(100.0, 1.5),
    }
    current = {
        "a": _row(100.0, 2.1),     # ok
        "b": _row(200.0),          # +100% wall: SLOWER
        # "c" missing entirely
        "d": _row(50.0),           # new row: reported, never fails
    }
    table, failures = compare(current, baseline, threshold=0.20)
    statuses = {name: status for name, *_, status in table}
    assert statuses == {"a": "ok", "b": "SLOWER", "c": "MISSING", "d": "new"}
    assert len(failures) == 2


def test_compare_lost_speedup():
    baseline = {"a": _row(100.0, 1.5)}
    _, failures = compare({"a": _row(100.0, 0.9)}, baseline, 0.20)
    assert any("lost its speedup" in f for f in failures)
    _, failures = compare({"a": _row(100.0)}, baseline, 0.20)
    assert any("lost its speedup" in f for f in failures)


def test_history_roundtrip_and_trends(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    for i, us in enumerate((100.0, 110.0, 90.0)):
        append_history(
            path,
            {"a": _row(us, 2.0), "b": _row(10.0 * (i + 1))},
            {"wall_s": 1.0 + i},
        )
    runs = load_history(path)
    assert len(runs) == 3
    trends = render_trends(runs)
    assert trends["a"] == "100→110→90"
    assert trends["b"] == "10→20→30"
    # only the last TREND_RUNS entries survive
    for us in (1.0, 2.0, 3.0, 4.0):
        append_history(path, {"a": _row(us)}, {"wall_s": 0.0})
    assert len(load_history(path)) == 5


def test_history_skips_torn_lines(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    append_history(path, {"a": _row(1.0)}, {"wall_s": 0.0})
    with open(path, "a") as f:
        f.write('{"rows": {"a"\n')           # torn write
        f.write("not json at all\n")
        f.write(json.dumps({"no_rows": 1}) + "\n")
    append_history(path, {"a": _row(2.0)}, {"wall_s": 0.0})
    runs = load_history(path)
    assert [r["rows"]["a"]["us"] for r in runs] == [1.0, 2.0]


def test_render_markdown_trend_column_is_optional():
    table = [("a", 100.0, 100.0, "+0.0%", 2.0, 2.0, "ok")]
    md_plain = render_markdown(table, [], 0.2, "wall.")
    assert "trend" not in md_plain
    md_trend = render_markdown(table, [], 0.2, "wall.", {"a": "100→100"})
    assert "trend (last 5)" in md_trend
    assert "100→100" in md_trend
    # a row the history has never seen renders a placeholder, not a crash
    md_missing = render_markdown(table, [], 0.2, "wall.", {})
    assert "—" in md_missing


def test_fmt_compact():
    assert fmt_compact(950) == "950"
    assert fmt_compact(12_340) == "12.3k"
    assert fmt_compact(3_500_000) == "3.5M"
    assert fmt_compact(None) == "?"
