"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.circle_score.ops import circle_score
from repro.kernels.circle_score.ref import circle_score_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.default_rng(42)


# --------------------------- circle_score ------------------------------ #
@pytest.mark.parametrize("l,a", [(1, 72), (3, 144), (8, 360), (5, 257)])
def test_circle_score_shapes(l, a):
    base = jnp.asarray(RNG.random((l, a)) * 60, jnp.float32)
    cand = jnp.asarray(RNG.random((l, a)) * 60, jnp.float32)
    out = circle_score(base, cand, 50.0)
    ref = circle_score_ref(base, cand, 50.0)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


def test_circle_score_zero_when_under_capacity():
    base = jnp.full((2, 72), 10.0, jnp.float32)
    cand = jnp.full((2, 72), 10.0, jnp.float32)
    out = circle_score(base, cand, 50.0)
    assert float(jnp.max(out)) == 0.0


# --------------------------- flash attention --------------------------- #
@pytest.mark.parametrize(
    "b,s,h,hkv,d,dtype",
    [
        (1, 128, 2, 2, 64, jnp.float32),
        (2, 256, 4, 2, 64, jnp.float32),
        (1, 256, 4, 1, 32, jnp.float32),
        (2, 128, 2, 2, 64, jnp.bfloat16),
    ],
)
def test_flash_attention_vs_ref(b, s, h, hkv, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    groups = h // hkv
    kr = jnp.repeat(k, groups, 2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, groups, 2).transpose(0, 2, 1, 3)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kr, vr).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=False, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


# --------------------------- ssd scan ---------------------------------- #
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [(1, 64, 2, 8, 4, 16), (2, 128, 3, 16, 8, 32), (1, 96, 1, 32, 16, 32)],
)
def test_ssd_scan_vs_recurrence(b, s, h, p, n, chunk):
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, s, h)) * 0.5 + 0.05, jnp.float32)
    a_log = jnp.asarray(RNG.standard_normal(h) * 0.3, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    out = ssd_scan(x, dt, a_log, Bm, Cm, chunk=chunk)
    ref = ssd_ref(x, dt, a_log, Bm, Cm)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_model_chunked_path_matches_kernel_oracle():
    from repro.models.mamba import ssd_chunked

    b, s, h, p, n = 2, 64, 2, 8, 4
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, s, h)) * 0.4 + 0.05, jnp.float32)
    a_log = jnp.asarray(RNG.standard_normal(h) * 0.3, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    out = ssd_chunked(x, dt, a_log, Bm, Cm, chunk=16)
    ref = ssd_ref(x, dt, a_log, Bm, Cm)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_ssd_decode_step_matches_recurrence_tail():
    """Running the chunked path for S tokens then one decode step equals
    the sequential recurrence for S+1 tokens."""
    from repro.models.mamba import ssd_decode_step

    b, s, h, p, n = 1, 32, 2, 8, 4
    x = jnp.asarray(RNG.standard_normal((b, s + 1, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, s + 1, h)) * 0.4 + 0.05, jnp.float32)
    a_log = jnp.asarray(RNG.standard_normal(h) * 0.3, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((b, s + 1, n)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((b, s + 1, n)), jnp.float32)

    full = ssd_ref(x, dt, a_log, Bm, Cm)
    # replay the first s tokens through decode steps to build the state
    state = jnp.zeros((b, h, n, p), jnp.float32)
    for t in range(s + 1):
        state, y = ssd_decode_step(
            state, x[:, t:t+1], dt[:, t:t+1], a_log, Bm[:, t:t+1], Cm[:, t:t+1]
        )
    np.testing.assert_allclose(y[:, 0], full[:, -1], rtol=2e-3, atol=2e-3)
