"""Sharding-rule tests on a small in-process mesh (1 CPU device → the
divisibility fallback paths get exercised; full 512-device behaviour is
covered by the dry-run cells)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import make_spec
from repro.parallel.sharding import param_shardings


def _mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_make_spec_divisibility_fallback():
    mesh = _mesh()
    # everything divides a size-1 axis → sharded as requested
    spec = make_spec(mesh, (8, 16), ("data", "model"))
    assert spec == P("data", "model")


def test_param_rules_by_name():
    mesh = _mesh()
    params = {
        "embed": jnp.zeros((512, 64)),
        "unembed": jnp.zeros((64, 512)),
        "layers": {
            "attn": {
                "wq": jnp.zeros((2, 64, 4, 16)),
                "wk": jnp.zeros((2, 64, 2, 16)),
                "wo": jnp.zeros((2, 4, 16, 64)),
            },
            "mlp": {
                "w_gate": jnp.zeros((2, 64, 256)),
                "w_down": jnp.zeros((2, 256, 64)),
            },
            "ln1": jnp.zeros((2, 64)),
        },
    }
    sh = param_shardings(params, mesh)
    assert sh["embed"].spec == P("model", ("data",))
    assert sh["unembed"].spec == P(("data",), "model")
    # stacked leading layer dim never sharded
    assert sh["layers"]["attn"]["wq"].spec[0] is None
    assert sh["layers"]["mlp"]["w_gate"].spec == P(None, ("data",), "model")
    assert sh["layers"]["mlp"]["w_down"].spec == P(None, "model", ("data",))
    # 1-d params replicated
    assert sh["layers"]["ln1"].spec == P(None, None)


def test_moe_expert_sharding_fallbacks():
    mesh = _mesh()
    params = {
        "moe": {
            "w_gate": jnp.zeros((2, 384, 64, 32)),   # divisible expert count
            "w_down": jnp.zeros((2, 384, 32, 64)),
        }
    }
    sh = param_shardings(params, mesh, num_experts=384)
    assert sh["moe"]["w_gate"].spec[1] == "model"
    params8 = {
        "moe": {
            "w_gate": jnp.zeros((2, 8, 64, 32)),
            "w_down": jnp.zeros((2, 8, 32, 64)),
        }
    }
    sh8 = param_shardings(params8, mesh, num_experts=8)
    # 8 experts on a 16-way model axis → shard the FFN dim instead
    # (on this 1-sized test mesh everything divides; rule choice is what we
    #  check: expert dim for divisible counts, ff dim otherwise is covered
    #  by the 512-device dry-run where model=16)
    assert sh8["moe"]["w_gate"].spec[-1] in ("model", None)


def test_smoke_mesh_training_step_runs_sharded():
    """Jit a reduced train step under an explicit 1×1 mesh with shardings —
    exercises the in_shardings plumbing end to end."""
    from repro.configs import get_config
    from repro.models.api import build_model

    mesh = _mesh()
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    p_sh = param_shardings(params, mesh)
    params = jax.device_put(params, p_sh)
    opt = model.init_opt(params)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    with mesh:
        p2, o2, m = jax.jit(model.train_step)(params, opt, batch)
    assert not bool(jnp.isnan(m["loss"]))
