"""Cluster substrate tests: topology, fluid network model, end-to-end
interleaving gains (the paper's Fig. 2 scenario as an executable test)."""

import pytest

from repro.cluster import (
    ClusterSimulator,
    FluidNetworkSim,
    Topology,
    arrival_trace,
    ideal_metrics,
    nearest_rank,
    snapshot_trace,
)
from repro.cluster.network import segments_from_pattern
from repro.core.circle import CommPattern, Phase
from repro.sched import CassiniAugmented
from repro.sched.fixed import FixedPlacementScheduler


def test_topology_paths():
    t = Topology.paper_testbed()
    assert t.num_servers == 24
    assert t.path(0, 1)  # same rack: host links only
    assert all(l.name.startswith("host") for l in t.path(0, 1))
    cross = t.path(0, 6)
    assert any(l.name.startswith("up") for l in cross)
    # deterministic routing
    assert [l.name for l in t.path(0, 6)] == [l.name for l in t.path(0, 6)]


def test_job_links_ring():
    t = Topology.paper_testbed()
    links = t.job_links((0, 1, 6))
    names = {l.name for l in links}
    assert "host:r0s0" in names and "host:r1s0" in names
    assert any(n.startswith("up:r0") for n in names)


def test_segments_from_pattern_roundtrip():
    p = CommPattern(100.0, (Phase(40.0, 30.0, 45.0),))
    segs = segments_from_pattern(p)
    assert [s.kind for s in segs] == ["compute", "comm", "compute"]
    assert sum(s.duration_ms for s in segs) == pytest.approx(100.0)
    assert segs[1].gbits == pytest.approx(45.0 * 0.03)


def test_solo_job_runs_at_solo_speed():
    t = Topology.paper_testbed()
    jobs = snapshot_trace([("vgg19", 4, 1400)], iters=20)
    jobs[0].placement = (0, 1, 6, 7)
    jobs[0].state = jobs[0].state.RUNNING
    sim = FluidNetworkSim(t)
    sim.configure(jobs)
    sim.advance(60_000)
    assert jobs[0].iters_done == 20
    for it in jobs[0].iter_times_ms:
        assert it == pytest.approx(jobs[0].solo_iter_ms, rel=0.01)


def test_contention_stretches_iterations_and_marks_ecn():
    t = Topology.paper_testbed()
    jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=30)
    jobs[0].placement = (0, 6)
    jobs[1].placement = (1, 7)  # same rack pair → same uplink
    for j in jobs:
        j.state = j.state.RUNNING
    sim = FluidNetworkSim(t)
    sim.configure(jobs)
    sim.advance(120_000)
    mean = sum(jobs[0].iter_times_ms) / len(jobs[0].iter_times_ms)
    assert mean > jobs[0].solo_iter_ms * 1.15  # congestion hurts
    assert sum(jobs[0].ecn_marks) > 0


def test_cassini_timeshift_removes_contention():
    """Fig. 2: the same placement with CASSINI shifts runs ~solo speed."""
    t = Topology.paper_testbed()
    pl = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}

    def run(with_cassini):
        jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=100)
        sched = FixedPlacementScheduler(pl)
        if with_cassini:
            sched = CassiniAugmented(sched, num_candidates=1)
        sim = ClusterSimulator(t, sched)
        return sim.run(jobs, horizon_ms=3_600_000)

    base = run(False)
    cass = run(True)
    assert cass.avg_iter_ms < base.avg_iter_ms * 0.85
    assert cass.ecn_per_iter() < base.ecn_per_iter() * 0.2


# ------------------------------------------------------------------ #
# metrics helpers
# ------------------------------------------------------------------ #
def test_nearest_rank_percentile():
    """The ONE shared percentile helper: nearest-rank (ceil) semantics."""
    import math

    assert math.isnan(nearest_rank([], 99))
    assert nearest_rank([7.0], 50) == 7.0
    assert nearest_rank([7.0], 99) == 7.0
    xs = [10.0, 20.0, 30.0, 40.0]
    assert nearest_rank(xs, 25) == 10.0    # ceil(0.25·4) = 1st
    assert nearest_rank(xs, 26) == 20.0    # ceil(1.04) = 2nd
    assert nearest_rank(xs, 50) == 20.0
    assert nearest_rank(xs, 75) == 30.0
    assert nearest_rank(xs, 100) == 40.0
    assert nearest_rank(xs, 0) == 10.0     # clamped to the sample range
    # order-free: input need not be sorted
    assert nearest_rank([40.0, 10.0, 30.0, 20.0], 50) == 20.0
    # Metrics and the benchmark drivers share this exact function
    from benchmarks.common import pct
    from repro.cluster.simulator import Metrics

    assert pct is nearest_rank
    assert Metrics._pct is nearest_rank


def test_arrival_trace_rejects_unknown_pattern():
    with pytest.raises(ValueError, match="unknown arrival pattern"):
        arrival_trace(Topology.paper_testbed(), pattern="tidal")


# ------------------------------------------------------------------ #
# fluid-model invariants
# ------------------------------------------------------------------ #
def _contending_jobs(n, iters=30):
    """n vgg19 pairs whose ring edges all cross the same rack0↔rack1 uplink."""
    t = Topology.paper_testbed()
    jobs = snapshot_trace([("vgg19", 2, 1400)] * n, iters=iters)
    for i, j in enumerate(jobs):
        j.placement = (i, 6 + i)  # server i in rack 0, server 6+i in rack 1
        j.state = j.state.RUNNING
    return t, jobs


@pytest.mark.parametrize("vectorized", [False, True])
def test_fluid_allocation_never_exceeds_capacity(vectorized):
    """Invariant: summed allocated rates on any link stay within capacity
    (the congested-efficiency factor only ever lowers the budget)."""
    t, jobs = _contending_jobs(3, iters=200)
    sim = FluidNetworkSim(t, vectorized=vectorized)
    sim.configure(jobs)
    probes = 0
    while sim.now_ms < 30_000 and sim._execs:
        rates = sim._allocate()
        per_link: dict[str, float] = {}
        for jid, ex in sim._execs.items():
            for l in ex.links:
                per_link[l.name] = per_link.get(l.name, 0.0) + rates.get(jid, 0.0)
        for lname, total in per_link.items():
            assert total <= t.links[lname].capacity_gbps + 1e-6, lname
        probes += sum(1 for r in rates.values() if r > 0)
        sim.advance(sim.now_ms + 40.0)
    assert probes > 0  # the probe actually saw contended comm segments


@pytest.mark.parametrize("vectorized", [False, True])
def test_ecn_marks_monotone_in_added_contention(vectorized):
    """Invariant: adding a job to a contended link never reduces the marks
    the existing jobs accumulate."""
    def total_marks_job0(n):
        t, jobs = _contending_jobs(n)
        sim = FluidNetworkSim(t, vectorized=vectorized)
        sim.configure(jobs)
        sim.advance(150_000)
        assert jobs[0].iters_done == 30
        return sum(jobs[0].ecn_marks)

    two, three = total_marks_job0(2), total_marks_job0(3)
    assert two > 0
    assert three >= two


@pytest.mark.parametrize("vectorized", [False, True])
def test_cutoff_job_stops_consuming_link_share(vectorized):
    """Invariant: a horizon-expired (CUTOFF) job releases its link share —
    the surviving job returns to solo-speed iterations and the cutoff job
    no longer appears in the allocation."""
    from repro.cluster.job import JobState

    t, jobs = _contending_jobs(2, iters=400)
    sim = FluidNetworkSim(t, vectorized=vectorized)
    sim.configure(jobs)
    sim.advance(60_000)
    assert sum(jobs[1].iter_times_ms) / len(jobs[1].iter_times_ms) > (
        jobs[1].solo_iter_ms * 1.15
    )  # contended before the cutoff

    jobs[0].state = JobState.CUTOFF
    recorded = len(jobs[1].iter_times_ms)
    cutoff_iters = jobs[0].iters_done
    sim.advance(150_000)
    assert jobs[0].job_id not in sim._allocate()
    # the cutoff job is frozen: no more iterations, never flips to DONE
    assert jobs[0].iters_done == cutoff_iters
    assert jobs[0].state is JobState.CUTOFF and jobs[0].finish_ms is None
    post = jobs[1].iter_times_ms[recorded + 2:]  # skip the boundary iters
    assert post, "survivor must keep iterating after the cutoff"
    mean_post = sum(post) / len(post)
    assert mean_post == pytest.approx(jobs[1].solo_iter_ms, rel=0.02)


def test_ideal_metrics_no_contention():
    t = Topology.paper_testbed()
    jobs = snapshot_trace([("bert", 4, 8), ("vgg19", 4, 1400)], iters=10)
    m = ideal_metrics(t, jobs)
    for j in m.jobs:
        assert j.iters_done == 10
        assert j.mean_iter_ms() == pytest.approx(j.solo_iter_ms, rel=0.02)
