"""End-to-end system behaviour: the paper's headline claims as tests."""

import pytest

from repro.cluster import ClusterSimulator, Topology, dynamic_trace, snapshot_trace
from repro.core import find_rotations
from repro.profiles import PROFILES, get_profile
from repro.sched import CassiniAugmented, ThemisScheduler
from repro.sched.fixed import FixedPlacementScheduler


def test_all_13_paper_models_have_profiles():
    expected = {
        "vgg11", "vgg16", "vgg19", "resnet50", "wideresnet101",
        "bert", "roberta", "camembert", "xlm",
        "gpt1", "gpt2", "gpt3", "dlrm",
    }
    assert set(PROFILES) == expected


def test_paper_compatibility_structure():
    """§2.2/§5 pairings: compatible pairs score higher than incompatible."""
    def score(a, b):
        return find_rotations(
            [get_profile(a).pattern(4), get_profile(b).pattern(4)], 50.0
        ).score

    assert score("wideresnet101", "vgg16") == pytest.approx(1.0, abs=0.01)
    assert score("vgg19", "vgg16") == pytest.approx(1.0, abs=0.01)
    assert score("bert", "vgg19") < 0.85          # "no suitable time-shift"
    # GPT/DLRM pairing preference (§5.4)
    good = score("gpt1", "gpt2") + score("gpt3", "dlrm")
    bad = score("gpt3", "gpt2") + score("gpt1", "dlrm")
    assert good > bad + 0.1


def test_snapshot5_partial_compatibility():
    pats = [get_profile(m).pattern(4) for m in ("bert", "vgg19", "wideresnet101")]
    res = find_rotations(pats, 50.0)
    assert 0.45 < res.score < 0.75  # paper: 0.6


def test_fig2_interleaving_end_to_end():
    """Two VGG19 jobs forced onto one uplink: CASSINI's time-shift recovers
    near-solo iteration time and slashes ECN marks (paper Fig. 2: 1.26×)."""
    topo = Topology.paper_testbed()
    pl = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}

    def run(with_cassini):
        jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=150)
        sched = FixedPlacementScheduler(pl)
        if with_cassini:
            sched = CassiniAugmented(sched, num_candidates=1)
        sim = ClusterSimulator(topo, sched)
        return sim.run(jobs, horizon_ms=3_600_000)

    themis = run(False)
    cassini = run(True)
    speedup = themis.avg_iter_ms / cassini.avg_iter_ms
    assert speedup > 1.2, f"expected ≥1.2× (paper 1.26×), got {speedup:.2f}"
    assert cassini.ecn_per_iter() < themis.ecn_per_iter() * 0.1


def test_dynamic_trace_cassini_reduces_ecn():
    """Fig. 10/11 scenario: ECN marks drop by an order of magnitude."""
    topo = Topology.paper_testbed()

    def run(mk):
        jobs = dynamic_trace(
            topo, base_models=("vgg19", "wideresnet101", "gpt1"),
            burst_models=("dlrm", "resnet50"), workers=7, iters=250,
        )
        for j in jobs:
            if j.job_id.startswith("burst"):
                j.num_workers = 5
        sim = ClusterSimulator(topo, mk(), epoch_ms=300_000, compute_jitter=0.005)
        return sim.run(jobs, horizon_ms=3_600_000)

    themis = run(ThemisScheduler)
    cassini = run(lambda: CassiniAugmented(ThemisScheduler()))
    assert cassini.ecn_per_iter() < themis.ecn_per_iter() * 0.25


def test_drift_adjustments_are_rare_for_compatible_jobs():
    """§5.7 / Fig. 14: with realistic jitter, aligned compatible jobs adjust
    less than ~2×/min (we allow < 4 for CI noise)."""
    topo = Topology.paper_testbed()
    pl = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}
    jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=300)
    sched = CassiniAugmented(FixedPlacementScheduler(pl), num_candidates=1)
    sim = ClusterSimulator(topo, sched, compute_jitter=0.003)
    m = sim.run(jobs, horizon_ms=3_600_000)
    total_min = max(j.finish_ms or 0 for j in m.jobs) / 60_000.0
    adj_per_min = sum(j.drift_adjustments for j in m.jobs) / max(total_min, 1e-9)
    assert adj_per_min < 4.0


def test_dryrun_profiles_schedule_assigned_archs():
    """Bridge test: CASSINI schedules the assigned JAX architectures using
    profiles derived from their own dry-run artifacts."""
    pytest.importorskip("repro.profiles.from_dryrun")
    from repro.profiles.from_dryrun import available_archs, dryrun_pattern

    archs = available_archs()
    if len(archs) < 2:
        pytest.skip("dry-run cache not populated")
    pats = [dryrun_pattern(a) for a in archs[:2]]
    res = find_rotations(pats, 50.0)
    assert -1.0 <= res.score <= 1.0
    assert all(t >= 0 for t in res.shifts_ms)
