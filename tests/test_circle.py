"""Unit + property tests for the geometric abstraction (paper §3)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.circle import CommPattern, Phase, UnifiedCircle, unified_perimeter


def test_pattern_demand_basic():
    p = CommPattern(100.0, (Phase(40.0, 30.0, 45.0),))
    assert p.demand_at(10.0) == 0.0
    assert p.demand_at(45.0) == 45.0
    assert p.demand_at(69.9) == 45.0
    assert p.demand_at(70.0) == 0.0
    # periodic
    assert p.demand_at(145.0) == 45.0
    assert p.mean_gbps == pytest.approx(45.0 * 0.3)


def test_pattern_overlapping_phases_add():
    p = CommPattern(100.0, (Phase(0.0, 50.0, 20.0), Phase(25.0, 50.0, 25.0)))
    assert p.demand_at(10.0) == 20.0
    assert p.demand_at(30.0) == 45.0
    assert p.demand_at(60.0) == 25.0


def test_pattern_wrapping_phase():
    p = CommPattern(100.0, (Phase(80.0, 40.0, 10.0),))  # wraps to [0, 20)
    assert p.demand_at(90.0) == 10.0
    assert p.demand_at(10.0) == 10.0
    assert p.demand_at(30.0) == 0.0


def test_unified_perimeter_lcm():
    # paper Fig. 3: 40 ms and 60 ms → 120 ms
    assert unified_perimeter([40.0, 60.0], 10.0) == pytest.approx(120.0)


def test_unified_circle_wraps():
    j1 = CommPattern(40.0, (Phase(20.0, 20.0, 40.0),))
    j2 = CommPattern(60.0, (Phase(30.0, 30.0, 40.0),))
    c = UnifiedCircle.build([j1, j2])
    assert c.perimeter_ms == pytest.approx(120.0)
    assert c.wraps == (3, 2)
    # demand integral is conserved on the circle
    mean1 = c.bw[0].mean()
    assert mean1 == pytest.approx(j1.mean_gbps, rel=0.1)


def test_rotation_identity_after_full_private_iteration():
    j1 = CommPattern(40.0, (Phase(20.0, 20.0, 40.0),))
    j2 = CommPattern(60.0, (Phase(30.0, 30.0, 40.0),))
    c = UnifiedCircle.build([j1, j2])
    g0 = c.shift_grid(0)
    np.testing.assert_allclose(c.rotated(0, g0 * c.wraps[0] // c.wraps[0]),
                               np.roll(c.bw[0], g0))
    # rotating by one private iteration is the identity
    np.testing.assert_allclose(c.rotated(0, g0), np.roll(c.bw[0], g0))
    np.testing.assert_allclose(np.roll(c.bw[0], g0), c.bw[0])


@settings(max_examples=50, deadline=None)
@given(
    iters=st.lists(
        st.integers(min_value=2, max_value=30).map(lambda k: k * 20.0),
        min_size=1, max_size=4,
    )
)
def test_perimeter_is_multiple_of_each_iteration(iters):
    p = unified_perimeter(iters, 10.0)
    for t in iters:
        ratio = p / (round(t / 10.0) * 10.0)
        assert abs(ratio - round(ratio)) < 1e-9


@settings(max_examples=30, deadline=None)
@given(
    start=st.floats(0, 300), dur=st.floats(1, 200), gbps=st.floats(0.5, 50),
    iter_ms=st.floats(50, 400),
)
def test_demand_series_integral_conserved(start, dur, gbps, iter_ms):
    dur = min(dur, iter_ms)  # a phase can cover at most the iteration
    p = CommPattern(iter_ms, (Phase(start, dur, gbps),))
    series = p.demand_series(4096)
    assert series.mean() == pytest.approx(gbps * dur / iter_ms, rel=0.05, abs=0.05)
