"""Property-based equivalence harness for the batched k-job grids.

``find_rotations_batched`` must be *bit-identical* to per-problem
``find_rotations`` calls — same scores, same normalized shifts — for every
link shape the scheduler can produce: k ∈ {2, 3, 4} jobs with mixed
periods, phases that wrap the iteration boundary, and degenerate
zero-demand jobs.  k ≤ 3 exercises the batched exact product grid, k = 4
the lockstep-batched coordinate descent.  A second property checks the
module layer: the link cache after ``score_candidates_batched`` holds the
same keys and results as after the scalar ``score_candidates``.

The hypothesis properties need the dev extra; a seeded numpy generator
drives the same problem distribution so the equivalence harness still runs
(deterministically) where hypothesis is unavailable.
"""

import numpy as np
import pytest

from repro.core import compat
from repro.core.circle import CommPattern, Phase
from repro.core.compat import (
    BatchStats,
    find_rotations,
    find_rotations_batched,
)
from repro.core.plugin import CassiniModule, PlacementCandidate

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extra absent
    HAVE_HYPOTHESIS = False

# Periods from a fixed menu keep the unified-circle LCM (and hence test
# runtime) bounded while still mixing wrap counts r_j > 1.
PERIODS = (160.0, 200.0, 240.0, 320.0, 400.0, 480.0)
CAPACITIES = (25.0, 50.0, 100.0)
# 0.0 gbps produces the degenerate all-zero-demand job the harness must
# round-trip; the rest straddle the capacity menu above and below.
DEMANDS = (0.0, 4.0, 20.0, 40.0, 45.0, 60.0)


def _assert_bit_identical(scalar, batched):
    assert len(scalar) == len(batched)
    for s, b in zip(scalar, batched):
        assert b.score == s.score
        assert b.shifts_steps == s.shifts_steps
        assert b.shifts_ms == s.shifts_ms
        assert b.deltas_rad == s.deltas_rad
        assert b.paced_periods_ms == s.paced_periods_ms
        assert b.capacity_gbps == s.capacity_gbps


def _random_problem(rng: np.random.Generator, tag: str, k: int):
    """One k-job link problem from the shared distribution (numpy mirror of
    the hypothesis strategy below)."""
    pats = []
    for j in range(k):
        it = float(rng.choice(PERIODS))
        phases = []
        for _ in range(int(rng.integers(1, 3))):
            start = float(rng.uniform(0.0, it))     # may wrap the boundary
            dur = float(rng.uniform(0.0, 0.9 * it))
            gbps = float(rng.choice(DEMANDS))
            phases.append(Phase(start, dur, gbps))
        pats.append(CommPattern(it, tuple(phases), name=f"{tag}j{j}"))
    return pats, float(rng.choice(CAPACITIES))


# ---------------------------------------------------------------------- #
# seeded-random equivalence (runs with or without hypothesis)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(8))
def test_batched_bit_identical_to_scalar_seeded(seed):
    rng = np.random.default_rng(seed)
    problems = [
        _random_problem(rng, f"p{i}", int(rng.integers(1, 5)))
        for i in range(int(rng.integers(1, 5)))
    ]
    scalar = [find_rotations(pats, cap) for pats, cap in problems]
    stats = BatchStats()
    batched = find_rotations_batched(problems, stats=stats)
    assert stats.scalar_fallbacks == 0
    assert stats.problems == len(problems)
    _assert_bit_identical(scalar, batched)


@pytest.mark.parametrize("seed", range(4))
def test_batched_descent_bit_identical_for_4job_links_seeded(seed):
    """k = 4 exceeds MAX_EXACT_JOBS: both paths run coordinate descent, the
    batched one in lockstep — results must still match bit for bit."""
    rng = np.random.default_rng(100 + seed)
    problems = [_random_problem(rng, f"p{i}", 4) for i in range(2)]
    scalar = [find_rotations(pats, cap) for pats, cap in problems]
    stats = BatchStats()
    batched = find_rotations_batched(problems, stats=stats)
    assert stats.descent_problems == len(problems)
    assert stats.scalar_fallbacks == 0
    _assert_bit_identical(scalar, batched)


def test_grid_chunking_does_not_change_results(monkeypatch):
    """Chunk boundaries are invisible: a tiny GRID_CHUNK_ROWS forces many
    flushes mid-problem and must produce the same accepted rows."""
    rng = np.random.default_rng(7)
    problems = [_random_problem(rng, f"p{i}", 3) for i in range(3)]
    scalar = [find_rotations(pats, cap) for pats, cap in problems]
    monkeypatch.setattr(compat, "GRID_CHUNK_ROWS", 7)
    batched = find_rotations_batched(problems)
    _assert_bit_identical(scalar, batched)


def test_per_row_capacity_matches_per_problem_scalar():
    """One batched call over rows with *different* capacities equals the
    row-at-a-time evaluation with each row's own scalar capacity."""
    rng = np.random.default_rng(0)
    base = rng.random((6, 72)).astype(np.float32) * 60
    cand = rng.random((6, 72)).astype(np.float32) * 60
    caps = np.array([20.0, 30.0, 40.0, 50.0, 60.0, 70.0], dtype=np.float32)
    out = compat._batched_excess(base, cand, caps, backend="numpy")
    for i, c in enumerate(caps):
        row = compat._batched_excess(
            base[i:i + 1], cand[i:i + 1], float(c), backend="numpy"
        )[0]
        np.testing.assert_array_equal(out[i], row)


def test_cache_contents_match_scalar_path_seeded():
    """After scoring the same candidates, the batched module's link cache
    holds exactly the scalar module's keys with bit-identical results."""
    rng = np.random.default_rng(21)
    patterns: dict[str, CommPattern] = {}
    capacities: dict[str, float] = {}
    job_links: dict[str, list[str]] = {}
    for l, k in enumerate((2, 3, 4)):
        pats, cap = _random_problem(rng, f"l{l}", k)
        capacities[f"link{l}"] = cap
        for p in pats:
            patterns[p.name] = p
            job_links[p.name] = [f"link{l}"]

    def cands():
        return [PlacementCandidate(
            job_links={j: list(ls) for j, ls in job_links.items()}
        )]

    m_scalar, m_batched = CassiniModule(), CassiniModule()
    ev_s = m_scalar.score_candidates(cands(), patterns, capacities)
    ev_b = m_batched.score_candidates_batched(cands(), patterns, capacities)

    assert set(m_batched._link_cache) == set(m_scalar._link_cache)
    for key, rs in m_scalar._link_cache.items():
        rb = m_batched._link_cache[key]
        assert rb.score == rs.score
        assert rb.shifts_steps == rs.shifts_steps
        assert rb.shifts_ms == rs.shifts_ms
        assert rb.paced_periods_ms == rs.paced_periods_ms
    assert [c.score for c, _, _ in ev_b] == [c.score for c, _, _ in ev_s]
    assert m_batched.last_batch_stats is not None
    assert m_batched.last_batch_stats.scalar_fallbacks == 0


@pytest.mark.parametrize("seed", range(3))
def test_descent_accepted_shift_sequences_device_on_off(seed):
    """The lockstep descent must walk the *same* accepted-shift sequence
    whether each step's argmin runs on device (fused kernel) or on the host
    (full matrix + np.argmin) — not just end in the same optimum.  Forcing
    backend='pallas' makes small circles kernel-eligible so the device path
    actually runs."""
    from repro.core.compat import _DescentState

    rng = np.random.default_rng(300 + seed)
    problems = [_random_problem(rng, f"p{i}", 4) for i in range(2)]

    def record_run(device_reduce):
        accepted: list[tuple[int, int, int]] = []
        orig = _DescentState.apply_shift

        def recording(self, j, base, s_new):
            accepted.append((self.index, j, int(s_new)))
            return orig(self, j, base, s_new)

        stats = BatchStats()
        try:
            _DescentState.apply_shift = recording
            results = find_rotations_batched(
                problems, backend="pallas", stats=stats,
                device_reduce=device_reduce,
            )
        finally:
            _DescentState.apply_shift = orig
        return accepted, results, stats

    acc_on, res_on, stats_on = record_run(True)
    acc_off, res_off, stats_off = record_run(False)
    assert acc_on == acc_off          # identical step-by-step acceptance
    assert len(acc_on) > 0
    _assert_bit_identical(res_off, res_on)
    assert stats_on.descent_problems == stats_off.descent_problems == 2
    assert stats_on.device_reduced == stats_on.batched_calls > 0
    assert stats_off.device_reduced == 0
    assert stats_on.bytes_returned < stats_off.bytes_returned


def test_batch_stats_routes_every_problem():
    """Stats partition the problem set: trivial + grid + descent covers all
    shapes with no scalar fallback."""
    def pat(it, s, d, g, name):
        return CommPattern(it, (Phase(s * it, d * it, g),), name)

    problems = [
        ([pat(250.0, 0.2, 0.5, 45.0, "solo")], 50.0),
        ([pat(320.0, 0.3, 0.4, 45.0, "a"), pat(320.0, 0.6, 0.3, 40.0, "b")], 50.0),
        ([pat(300.0, 0.1, 0.3, 40.0, "x"), pat(300.0, 0.4, 0.3, 40.0, "y"),
          pat(300.0, 0.7, 0.2, 40.0, "z")], 50.0),
        ([pat(240.0, 0.05, 0.3, 30.0, "k1"), pat(240.0, 0.3, 0.3, 30.0, "k2"),
          pat(240.0, 0.55, 0.25, 25.0, "k3"), pat(480.0, 0.8, 0.15, 20.0, "k4")],
         50.0),
    ]
    stats = BatchStats()
    find_rotations_batched(problems, stats=stats)
    assert stats.problems == 4
    assert stats.trivial == 1
    assert stats.grid_problems == 2
    assert stats.descent_problems == 1
    assert stats.scalar_fallbacks == 0
    assert stats.grid_rows > 0 and stats.descent_rows > 0


# ---------------------------------------------------------------------- #
# hypothesis properties (dev extra)
# ---------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @st.composite
    def comm_pattern(draw, name: str) -> CommPattern:
        it = draw(st.sampled_from(PERIODS))
        phases = []
        for _ in range(draw(st.integers(1, 2))):
            start = draw(st.floats(0.0, it, allow_nan=False))
            # start anywhere + durations up to 0.9·it ⇒ phases may wrap the
            # iteration boundary (demand_at handles the wrap)
            dur = draw(st.floats(0.0, 0.9 * it, allow_nan=False))
            phases.append(Phase(start, dur, draw(st.sampled_from(DEMANDS))))
        return CommPattern(it, tuple(phases), name=name)

    @st.composite
    def link_problem(draw, tag: str = "p", min_jobs: int = 2, max_jobs: int = 4):
        k = draw(st.integers(min_jobs, max_jobs))
        pats = [draw(comm_pattern(name=f"{tag}j{j}")) for j in range(k)]
        return pats, draw(st.sampled_from(CAPACITIES))

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_batched_bit_identical_to_scalar(data):
        n = data.draw(st.integers(1, 4))
        problems = [data.draw(link_problem(tag=f"p{i}")) for i in range(n)]
        scalar = [find_rotations(pats, cap) for pats, cap in problems]
        stats = BatchStats()
        batched = find_rotations_batched(problems, stats=stats)
        assert stats.scalar_fallbacks == 0
        _assert_bit_identical(scalar, batched)

    @settings(max_examples=15, deadline=None)
    @given(st.data())
    def test_batched_descent_bit_identical_for_4job_links(data):
        problems = [
            data.draw(link_problem(tag=f"p{i}", min_jobs=4, max_jobs=4))
            for i in range(data.draw(st.integers(1, 3)))
        ]
        scalar = [find_rotations(pats, cap) for pats, cap in problems]
        stats = BatchStats()
        batched = find_rotations_batched(problems, stats=stats)
        assert stats.descent_problems == len(problems)
        assert stats.scalar_fallbacks == 0
        _assert_bit_identical(scalar, batched)

    @settings(max_examples=15, deadline=None)
    @given(st.data())
    def test_cache_contents_match_scalar_path(data):
        patterns: dict[str, CommPattern] = {}
        capacities: dict[str, float] = {}
        job_links: dict[str, list[str]] = {}
        for l in range(data.draw(st.integers(1, 3))):
            pats, cap = data.draw(link_problem(tag=f"l{l}"))
            capacities[f"link{l}"] = cap
            for p in pats:
                patterns[p.name] = p
                job_links[p.name] = [f"link{l}"]

        def cands():
            return [PlacementCandidate(
                job_links={j: list(ls) for j, ls in job_links.items()}
            )]

        m_scalar, m_batched = CassiniModule(), CassiniModule()
        m_scalar.score_candidates(cands(), patterns, capacities)
        m_batched.score_candidates_batched(cands(), patterns, capacities)
        assert set(m_batched._link_cache) == set(m_scalar._link_cache)
        for key, rs in m_scalar._link_cache.items():
            rb = m_batched._link_cache[key]
            assert rb.score == rs.score
            assert rb.shifts_steps == rs.shifts_steps
            assert rb.shifts_ms == rs.shifts_ms
            assert rb.paced_periods_ms == rs.paced_periods_ms
