"""Shared model-zoo foundations: architecture config, parameter init,
norms, rotary embeddings and divisibility-aware sharding helpers.

Design rules (they matter at 512 devices):

- per-layer parameters are **stacked along a leading layer axis** and the
  forward pass is a ``jax.lax.scan`` over layers — the HLO stays O(1) in
  depth, which keeps 61-layer × 512-device dry-run compiles tractable;
- every weight/activation gets a :func:`shard` constraint derived from
  logical rules, with graceful fallback to replication when a dimension is
  not divisible by the mesh axis (e.g. 9 attention heads on a 16-way model
  axis) — ``.compile()`` must succeed for every assigned architecture;
- vocabularies are padded to a multiple of 256 so embedding/unembedding
  shard cleanly on the model axis; logits at padded positions are masked.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any  # pytree of arrays

VOCAB_PAD = 256


# ---------------------------------------------------------------------- #
# configuration
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (src/repro/configs/<id>.py instantiates)."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    # attention
    head_dim: int = 0            # 0 → d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 → full causal (mixtral: 4096)
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block applied every N slots
    attn_every: int = 0
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # vlm (internvl2)
    num_patches: int = 0
    # parallelism
    seq_shard: bool = True       # sequence-parallel residual stream (Megatron-SP)
    streaming_attn: bool = False # online-softmax attention (flash-in-XLA)
    attn_kv_chunk: int = 512
    # training
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    opt_moments_dtype: Any = jnp.float32
    remat: str = "full"          # none | full | dots
    use_scan: bool = True
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return int(math.ceil(self.vocab / VOCAB_PAD)) * VOCAB_PAD

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (ssm/hybrid only)"""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized sibling of this config (same family/topology,
        tiny dims) for CPU tests."""
        small = dict(
            # hybrids need at least one full (mamba…+attn) group + a tail
            num_layers=7 if self.attn_every else min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=(
                min(4, max(1, self.num_kv_heads * 4 // self.num_heads))
                if self.num_heads > 0
                else 0
            ),
            d_ff=256,
            vocab=512,
            head_dim=32,
            num_experts=min(self.num_experts, 4) if self.is_moe else 0,
            top_k=min(self.top_k, 2) if self.is_moe else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=32,
            attn_every=3 if self.attn_every else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_seq=32,
            num_patches=16 if self.num_patches else 0,
            remat="none",
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return replace(self, **small)


# ---------------------------------------------------------------------- #
# sharding helpers
# ---------------------------------------------------------------------- #
def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def make_spec(mesh: Mesh | None, shape: Sequence[int], axes: Sequence) -> P:
    """PartitionSpec over ``axes`` with replication fallback: a dim keeps its
    mesh axis only when its size is divisible by the axis size."""
    if mesh is None:
        return P()
    spec = []
    for dim, ax in zip(shape, axes):
        if ax is not None and dim % _axis_size(mesh, ax) == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return P(*spec)


def shard(x: jax.Array, mesh: Mesh | None, *axes) -> jax.Array:
    """``with_sharding_constraint`` via logical axes (None = replicated)."""
    if mesh is None:
        return x
    spec = make_spec(mesh, x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


BATCH_AXES = ("pod", "data")   # flattened where the mesh lacks "pod"


def batch_axes(mesh: Mesh | None):
    if mesh is None:
        return None
    present = tuple(a for a in BATCH_AXES if a in mesh.shape)
    return present if present else None


# ---------------------------------------------------------------------- #
# numerics
# ---------------------------------------------------------------------- #
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------- #
# parameter init
# ---------------------------------------------------------------------- #
def cast_block_params(params, dtype):
    """Cast matmul weights (ndim ≥ 2) to the compute dtype; 1-d params
    (norm scales, biases, dt/a_log) stay in their storage dtype — the
    numerically-sensitive ops handle their own fp32 upcasts."""
    import jax as _jax

    return _jax.tree.map(
        lambda a: a.astype(dtype) if hasattr(a, "ndim") and a.ndim >= 2 else a,
        params,
    )


def dense_init(key, shape, dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(1, fan))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
