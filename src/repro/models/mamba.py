"""Mamba-2 (state-space duality) block — Dao & Gu 2024 (arXiv:2405.21060).

SSD computes, per head, ``y_t = Σ_{s≤t} C_t · (Π_{r=s+1..t} a_r) · B_s x_s``
plus a skip ``D·x_t``.  Three execution paths:

- **chunked prefill** (training / long prefill): split the sequence into
  chunks of ``cfg.ssm_chunk``; the intra-chunk term is a masked quadratic
  attention-like product, inter-chunk states are carried by a
  ``jax.lax.scan`` (the TPU-friendly formulation — chunk matmuls feed the
  MXU; a Pallas kernel with the same math lives in
  ``repro.kernels.ssd_scan``);
- **single-step decode**: O(1) recurrent state update — this is why the
  ssm/hybrid architectures run the 500k-context decode shape;
- pure recurrence (``ref``-grade) lives in the kernel's ``ref.py``.

Layout notes: x is expanded to ``d_inner = expand·d_model`` and split into
``ssm_heads`` heads of ``ssm_head_dim``; B/C are shared across heads
(n_groups = 1), ``dt`` and the decay ``A`` are per-head scalars.  A short
depthwise causal conv precedes the SSM, as in the reference model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, dense_init, rms_norm, split_keys


def _segsum(log_a: jax.Array) -> jax.Array:
    """(…, L) per-step log-decay → (…, L, L) cumulative decay matrix:
    ``out[t, s] = Σ_{r=s+1..t} log_a_r`` for s ≤ t, −inf above diagonal."""
    L = log_a.shape[-1]
    cum = jnp.cumsum(log_a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)   values
    dt: jax.Array,     # (B, S, H)      per-head step (softplus'd)
    a_log: jax.Array,  # (H,)           log of -A (decay strength)
    Bm: jax.Array,     # (B, S, N)      input matrix (shared across heads)
    Cm: jax.Array,     # (B, S, N)      output matrix
    chunk: int,
) -> jax.Array:
    """Chunked SSD scan.  Returns (B, S, H, P)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} % chunk {chunk} != 0"

    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,) negative
    log_decay = dt.astype(jnp.float32) * a                  # (B, S, H)
    xdt = x * dt[..., None].astype(x.dtype)                 # fold dt into x

    # chunked views: (B, NC, L, ...)
    xc = xdt.reshape(b, nc, chunk, h, p)
    dc = log_decay.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    # intra-chunk (quadratic, matmul-friendly)
    L = jnp.exp(_segsum(dc.transpose(0, 1, 3, 2)))          # (B,NC,H,L,L)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)          # (B,NC,L,L)
    intra = jnp.einsum(
        "bchlm,bclm,bcmhp->bclhp",
        L.transpose(0, 1, 2, 3, 4),
        scores.astype(jnp.float32),
        xc.astype(jnp.float32),
    )

    # chunk-final states: (B, NC, H, N, P)
    dc_sum = dc.sum(axis=2)                                  # (B,NC,H)
    # decay from position l to end of chunk: exp(Σ_{r>l} logdecay)
    decay_end = jnp.exp(dc_sum[:, :, None, :] - jnp.cumsum(dc, axis=2))  # (B,NC,L,H)
    states = jnp.einsum(
        "bcln,bclh,bclhp->bchnp",
        Bc.astype(jnp.float32),
        decay_end.astype(jnp.float32),
        xc.astype(jnp.float32),
    )

    # inter-chunk recurrence over chunk states
    def scan_fn(carry, inp):
        st, chunk_decay = inp                                # (B,H,N,P), (B,H)
        new = carry * jnp.exp(chunk_decay)[..., None, None] + st
        # emit state BEFORE this chunk
        return new, carry

    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), dc_sum.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B,NC,H,N,P)

    # contribution of carried-in state to each position
    decay_in = jnp.exp(jnp.cumsum(dc, axis=2))               # (B,NC,L,H)
    inter = jnp.einsum(
        "bcln,bclh,bchnp->bclhp",
        Cc.astype(jnp.float32),
        decay_in.astype(jnp.float32),
        prev_states,
    )
    y = (intra + inter).reshape(b, s, h, p)
    return y.astype(x.dtype)


def ssd_decode_step(
    state: jax.Array,  # (B, H, N, P) carried SSM state
    x: jax.Array,      # (B, 1, H, P)
    dt: jax.Array,     # (B, 1, H)
    a_log: jax.Array,  # (H,)
    Bm: jax.Array,     # (B, 1, N)
    Cm: jax.Array,     # (B, 1, N)
) -> tuple[jax.Array, jax.Array]:
    """O(1) recurrent update: state' = decay·state + B x dt; y = C·state'."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt[:, 0].astype(jnp.float32) * a)        # (B, H)
    upd = jnp.einsum(
        "bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
        (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
    )
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), new_state)
    return new_state, y[:, None].astype(x.dtype)


# ---------------------------------------------------------------------- #
# full block: in_proj → conv → SSD → gated norm → out_proj
# ---------------------------------------------------------------------- #
def _causal_conv(x: jax.Array, w: jax.Array, cache: jax.Array | None = None):
    """Depthwise causal conv along seq. x: (B, S, C), w: (K, C).
    With a cache (decode): cache holds the last K−1 inputs."""
    k = w.shape[0]
    if cache is not None:
        window = jnp.concatenate([cache, x], axis=1)         # (B, K, C)
        y = jnp.einsum("bkc,kc->bc", window[:, -k:], w)[:, None]
        return y, window[:, -(k - 1):]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return y, None


def mamba_block(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ArchConfig,
    *,
    ssm_state: jax.Array | None = None,   # (B, H, N, P) decode carry
    conv_cache: jax.Array | None = None,  # (B, K-1, conv_ch)
):
    """Returns (y, new_ssm_state, new_conv_cache)."""
    b, s, _ = x.shape
    d_in, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.ssm_heads

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z = zxbcdt[..., :d_in]                       # gate
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]   # conv channels (x, B, C)
    dt = zxbcdt[..., 2 * d_in + 2 * n :]         # (B, S, H) step sizes
    xbc, conv_cache = _causal_conv(xbc, params["conv_w"], conv_cache)
    xbc = jax.nn.silu(xbc + params["conv_b"].astype(xbc.dtype))
    xs = xbc[..., :d_in].reshape(b, s, h, hd)
    Bm = xbc[..., d_in : d_in + n]
    Cm = xbc[..., d_in + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)

    if ssm_state is not None:
        new_state, y = ssd_decode_step(ssm_state, xs, dt, params["a_log"], Bm, Cm)
    else:
        y = ssd_chunked(xs, dt, params["a_log"], Bm, Cm, cfg.ssm_chunk)
        new_state = None
    y = y + xs * params["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, new_state, conv_cache


def init_mamba(key, cfg: ArchConfig, dtype):
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = d_in + 2 * n
    proj_out = d_in + conv_ch + h
    k1, k2, k3 = split_keys(key, 3)
    return {
        "in_proj": dense_init(k1, (cfg.d_model, proj_out), dtype, cfg.d_model),
        "conv_w": dense_init(k2, (cfg.conv_width, conv_ch), dtype, cfg.conv_width),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),               # A = -1 initially
        "d_skip": jnp.ones((h,), dtype),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(k3, (d_in, cfg.d_model), dtype, d_in),
    }
