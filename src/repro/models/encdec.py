"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` supplies precomputed frame embeddings
``(B, S_enc, d_model)``.  The backbone is real: a bidirectional encoder
stack and a causal decoder stack with cross-attention, both scanned.

Shape convention: the assigned ``seq_len`` S splits as
``S_enc = min(cfg.enc_seq, S // 2)`` encoder frames and
``S_dec = S − S_enc`` decoder tokens (documented in DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import attention_block, decode_attention, init_attention
from .common import (ArchConfig, batch_axes, cast_block_params, dense_init,
                     rms_norm, shard, split_keys)
from .mlp import init_mlp, mlp_block


def enc_seq_split(cfg: ArchConfig, seq_len: int) -> tuple[int, int]:
    """Split the assigned seq_len into (encoder frames, decoder tokens).

    For long sequences the decoder side must stay divisible by the
    q-chunked attention block (1024), so the encoder share rounds down to
    a multiple of 1024 (32k -> 1024 frames + 31744 decoder tokens)."""
    from .attention import CHUNK_THRESHOLD, Q_CHUNK

    cap = min(cfg.enc_seq, seq_len // 2)
    if seq_len > CHUNK_THRESHOLD:
        cap = max(Q_CHUNK, (cap // Q_CHUNK) * Q_CHUNK)
    else:
        # 16-align both sides so sequence-parallel sharding applies (a
        # 1500-frame encoder silently fell back to replicated activations
        # and full-size TP all-reduces — §Perf whisper iteration 2)
        cap = max(16, (cap // 16) * 16)
    return cap, seq_len - cap


# ---------------------------------------------------------------------- #
def _cross_attention(params, x, enc_kv, cfg):
    """Decoder cross-attention over precomputed encoder K/V."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    out = decode_attention(q, k, v, k.shape[1]) if x.shape[1] == 1 else None
    if out is None:
        d = q.shape[-1]
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def _enc_block(params, x, cfg, mesh):
    params = cast_block_params(params, cfg.dtype)
    ba = batch_axes(mesh)
    seq_ax = "model" if cfg.seq_shard else None
    h, _ = attention_block(
        params["attn"], rms_norm(x, params["ln1"]), cfg, causal=False, use_rope=False
    )
    x = shard(x + h, mesh, ba, seq_ax, None)
    x = x + mlp_block(params["mlp"], rms_norm(x, params["ln2"]), mesh)
    return shard(x, mesh, ba, seq_ax, None)


def _dec_block(params, x, enc_kv, cfg, mesh, *, positions=None, kv_cache=None,
               cache_len=None):
    params = cast_block_params(params, cfg.dtype)
    ba = batch_axes(mesh)
    seq_ax = "model" if cfg.seq_shard else None
    h, new_kv = attention_block(
        params["attn"], rms_norm(x, params["ln1"]), cfg,
        positions=positions, kv_cache=kv_cache, cache_len=cache_len,
    )
    x = shard(x + h, mesh, ba, seq_ax, None)
    x = x + _cross_attention(params["xattn"], rms_norm(x, params["lnx"]), enc_kv, cfg)
    x = shard(x, mesh, ba, seq_ax, None)
    x = x + mlp_block(params["mlp"], rms_norm(x, params["ln2"]), mesh)
    return shard(x, mesh, ba, seq_ax, None), new_kv


# ---------------------------------------------------------------------- #
def init_encdec(key, cfg: ArchConfig):
    dtype = cfg.param_dtype
    ne, nd = cfg.enc_layers, cfg.num_layers
    keys = split_keys(key, ne + nd + 4)

    def enc_layer(k):
        k1, k2 = split_keys(k, 2)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(k1, cfg, dtype),
            "mlp": init_mlp(k2, cfg, dtype),
        }

    def dec_layer(k):
        k1, k2, k3, k4 = split_keys(k, 4)
        hd = cfg.hd
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "lnx": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(k1, cfg, dtype),
            "xattn": {
                "wq": dense_init(
                    k2, (cfg.d_model, cfg.num_heads, hd), dtype, cfg.d_model
                ),
                "wk": dense_init(
                    k3, (cfg.d_model, cfg.num_kv_heads, hd), dtype, cfg.d_model
                ),
                "wv": dense_init(
                    k3, (cfg.d_model, cfg.num_kv_heads, hd), dtype, cfg.d_model
                ),
                "wo": dense_init(k4, (cfg.num_heads, hd, cfg.d_model), dtype,
                                 cfg.num_heads * hd),
            },
            "mlp": init_mlp(k2, cfg, dtype),
        }

    stack = lambda layers: jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "frame_proj": dense_init(keys[-1], (cfg.d_model, cfg.d_model), dtype),
        "enc_pos": dense_init(keys[-2], (cfg.enc_seq, cfg.d_model), dtype) * 0.02,
        "encoder": stack([enc_layer(keys[i]) for i in range(ne)]),
        "ln_enc": jnp.ones((cfg.d_model,), dtype),
        "embed": dense_init(keys[-3], (cfg.padded_vocab, cfg.d_model), dtype),
        "decoder": stack([dec_layer(keys[ne + i]) for i in range(nd)]),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "unembed": dense_init(keys[-4], (cfg.d_model, cfg.padded_vocab), dtype,
                              cfg.d_model),
    }


def encode(params, cfg: ArchConfig, mesh, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings → encoder output (B, S_enc, D)."""
    ba = batch_axes(mesh)
    x = jnp.einsum("bsd,de->bse", frames.astype(cfg.dtype),
                   params["frame_proj"].astype(cfg.dtype))
    x = x + params["enc_pos"][: x.shape[1]].astype(cfg.dtype)
    x = shard(x, mesh, ba, None, None)
    remat = cfg.remat != "none"
    body = lambda xx, lp: (_enc_block(lp, xx, cfg, mesh), None)
    if remat:
        fn = jax.checkpoint(lambda xx, lp: body(xx, lp)[0])
        x = jax.lax.scan(lambda xx, lp: (fn(xx, lp), None), x, params["encoder"])[0]
    else:
        x = jax.lax.scan(body, x, params["encoder"])[0]
    return rms_norm(x, params["ln_enc"])


def _enc_kv(params_dec_stack, enc_out, cfg, mesh=None):
    """Precompute per-decoder-layer cross K/V (stacked): (L, B, S_enc, H, hd).

    §Perf (whisper): without an explicit constraint this (L,B,S,H,hd) stack
    was replicated by the partitioner and re-gathered inside every decoder
    layer; shard batch over the data axes and head_dim over model (20 heads
    do not divide a 16-way axis, hd=64 does)."""
    def mk(lp):
        k = jnp.einsum(
            "bsd,dhk->bshk", enc_out, lp["xattn"]["wk"].astype(enc_out.dtype)
        )
        v = jnp.einsum(
            "bsd,dhk->bshk", enc_out, lp["xattn"]["wv"].astype(enc_out.dtype)
        )
        return k, v

    kx, vx = jax.vmap(mk, in_axes=(0,))(params_dec_stack)
    ba = batch_axes(mesh)
    model = mesh.shape.get("model", 1) if mesh is not None else 1
    h_axes = ("model", None) if cfg.num_heads % model == 0 else (None, "model")
    kx = shard(kx, mesh, None, ba, None, *h_axes)
    vx = shard(vx, mesh, None, ba, None, *h_axes)
    return kx, vx


def encdec_forward(params, cfg: ArchConfig, mesh, frames, tokens) -> jax.Array:
    """Training forward → decoder logits (B, S_dec, V)."""
    ba = batch_axes(mesh)
    enc_out = encode(params, cfg, mesh, frames)
    kx, vx = _enc_kv(params["decoder"], enc_out, cfg, mesh)

    x = params["embed"][tokens].astype(cfg.dtype) * jnp.sqrt(
        cfg.d_model
    ).astype(cfg.dtype)
    x = shard(x, mesh, ba, None, None)
    remat = cfg.remat != "none"

    def body(xx, inp):
        lp, k_l, v_l = inp
        out, _ = _dec_block(lp, xx, (k_l, v_l), cfg, mesh)
        return out, None

    if remat:
        fn = jax.checkpoint(lambda xx, inp: body(xx, inp)[0])
        x = jax.lax.scan(lambda xx, inp: (fn(xx, inp), None), x,
                         (params["decoder"], kx, vx))[0]
    else:
        x = jax.lax.scan(body, x, (params["decoder"], kx, vx))[0]
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))
    return shard(logits, mesh, ba, None, "model")


class EncDecDecodeState(NamedTuple):
    kv: Any          # decoder self-attn cache (L, B, S, Hkv, hd) ×2
    enc_kv: Any      # cross K/V (L, B, S_enc, H, hd) ×2
    pos: jax.Array


def init_encdec_decode_state(
    params, cfg: ArchConfig, batch, max_seq, frames, mesh=None
):
    enc_out = encode(params, cfg, mesh, frames)
    kx, vx = _enc_kv(params["decoder"], enc_out, cfg, mesh)
    L = cfg.num_layers
    ba = batch_axes(mesh)
    k = jnp.zeros((L, batch, max_seq, cfg.num_kv_heads, cfg.hd), cfg.dtype)
    v = jnp.zeros_like(k)
    if mesh is not None:
        seq_ax = "data" if batch == 1 else None
        model_size = mesh.shape.get("model", 1)
        axes = (
            (None, ba, seq_ax, "model", None)
            if cfg.num_kv_heads % model_size == 0
            else (None, ba, seq_ax, None, "model")
        )
        k, v = shard(k, mesh, *axes), shard(v, mesh, *axes)
    return EncDecDecodeState(kv=(k, v), enc_kv=(kx, vx), pos=jnp.zeros((), jnp.int32))


def encdec_decode_step(params, cfg: ArchConfig, mesh, tokens, state):
    x = params["embed"][tokens].astype(cfg.dtype) * jnp.sqrt(
        cfg.d_model
    ).astype(cfg.dtype)
    positions = jnp.broadcast_to(state.pos, (tokens.shape[0], 1))

    def body(xx, inp):
        lp, kc, vc, kx_l, vx_l = inp
        out, new_kv = _dec_block(
            lp, xx, (kx_l, vx_l), cfg, mesh,
            positions=positions, kv_cache=(kc, vc), cache_len=state.pos,
        )
        return out, new_kv

    x, (kc, vc) = jax.lax.scan(
        body, x,
        (params["decoder"], state.kv[0], state.kv[1],
         state.enc_kv[0], state.enc_kv[1]),
    )
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))
    return logits, EncDecDecodeState(kv=(kc, vc), enc_kv=state.enc_kv,
                                     pos=state.pos + 1)
