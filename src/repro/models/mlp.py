"""Feed-forward sublayers: SwiGLU dense MLP and token-choice top-k MoE.

The MoE uses the GShard/Switch grouped-dispatch formulation adapted for the
(pod, data, model) mesh:

- tokens are processed in groups of ``MOE_GROUP`` so the one-hot dispatch
  mask is O(group · E · C) instead of O(N · E · C);
- dispatched activations carry explicit sharding constraints — expert dim
  on the model axis when divisible (kimi-k2: 384 experts), otherwise the
  expert FFN's hidden dim shards on the model axis (mixtral: 8 experts);
- capacity ``C = group · top_k / E · capacity_factor`` with residual
  passthrough for dropped tokens.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ArchConfig, batch_axes, dense_init, shard, split_keys

MOE_GROUP = 512


# ---------------------------------------------------------------------- #
# dense SwiGLU
# ---------------------------------------------------------------------- #
def mlp_block(params: dict, x: jax.Array, mesh=None) -> jax.Array:
    gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = jax.nn.silu(gate) * up
    h = shard(h, mesh, batch_axes(mesh), None, "model")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


def init_mlp(key, cfg: ArchConfig, dtype, d_ff: int | None = None):
    f = d_ff or cfg.d_ff
    k1, k2, k3 = split_keys(key, 3)
    return {
        "w_gate": dense_init(k1, (cfg.d_model, f), dtype, cfg.d_model),
        "w_up": dense_init(k2, (cfg.d_model, f), dtype, cfg.d_model),
        "w_down": dense_init(k3, (f, cfg.d_model), dtype, f),
    }


# ---------------------------------------------------------------------- #
# mixture of experts
# ---------------------------------------------------------------------- #
def moe_capacity(cfg: ArchConfig, group: int) -> int:
    cap = int(math.ceil(group * cfg.top_k / cfg.num_experts * cfg.capacity_factor))
    return max(4, cap)


def moe_block(params: dict, x: jax.Array, cfg: ArchConfig, mesh=None) -> jax.Array:
    """Token-choice top-k MoE with grouped capacity dispatch.

    x: (B, S, D) → (B, S, D); aux losses returned via params-free closure
    would complicate the scan carry, so the load-balancing loss is folded
    into the output as a stop-gradient-free scalar stored by the caller.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    n = b * s
    g = math.gcd(n, MOE_GROUP)
    group = MOE_GROUP if n % MOE_GROUP == 0 else g
    ngroups = n // group
    cap = moe_capacity(cfg, group)

    xt = x.reshape(ngroups, group, d)
    ba = batch_axes(mesh)
    xt = shard(xt, mesh, ba, None, None)

    logits = jnp.einsum("gnd,de->gne", xt, params["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                     # (G, n, E)
    topv, topi = jax.lax.top_k(gates, k)                        # (G, n, k)
    topv = topv / (topv.sum(-1, keepdims=True) + 1e-9)

    # position of each (token, choice) within its expert's capacity
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)         # (G, n, k, E)
    pos = jnp.cumsum(onehot.sum(2), axis=1) - onehot.sum(2)     # (G, n, E)
    pos_per_choice = jnp.einsum("gnke,gne->gnk", onehot, pos)   # (G, n, k)
    keep = pos_per_choice < cap
    gate_kept = topv * keep

    # dispatch: (G, n, k) choices → (G, E, C) slots
    cap_oh = jax.nn.one_hot(pos_per_choice.astype(jnp.int32), cap, dtype=x.dtype)
    disp = jnp.einsum("gnke,gnkc->gnec", onehot.astype(x.dtype), cap_oh)
    # expert-parallel layout: experts over the data axes when divisible
    # (weights resident; token dispatch = all-to-all over data), else
    # experts over model with FSDP-D weights (small expert counts).
    ba_n = 1
    if mesh is not None:
        for a in (ba or ()):
            ba_n *= mesh.shape[a]
    from repro.parallel.sharding import EXPERT_RESIDENT

    expert_par = EXPERT_RESIDENT and mesh is not None and ba and e % ba_n == 0
    if expert_par:
        # dispatch stays token(g)-major; the E-major constraint on xe makes
        # GSPMD insert the all-to-all (tokens travel to resident experts)
        disp = shard(disp, mesh, ba, None, None, None)
        xe = jnp.einsum("gnec,gnd->gecd", disp, xt)             # (G, E, C, D)
        xe = shard(xe, mesh, None, ba, None, None)
        gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
        h = jax.nn.silu(gate) * up
        h = shard(h, mesh, None, ba, None, "model")
        ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])  # (G, E, C, D)
        ye = shard(ye, mesh, None, ba, None, None)
    else:
        disp = shard(disp, mesh, ba, None, "model" if e % 16 == 0 else None, None)
        xe = jnp.einsum("gnec,gnd->gecd", disp, xt)             # (G, E, C, D)
        xe = shard(xe, mesh, ba, "model", None, None)
        gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
        h = jax.nn.silu(gate) * up
        h = shard(h, mesh, ba, "model", None, None if e % 16 == 0 else "model")
        ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])  # (G, E, C, D)
        ye = shard(ye, mesh, ba, "model", None, None)

    # combine: tokens gather their (gated) expert outputs back from slots.
    # cap_oh is all-zero for overflow positions, so dropped tokens simply
    # pass through as zeros (residual connection preserves them upstream).
    comb_w = jnp.einsum(
        "gnk,gnke,gnkc->gnec",
        gate_kept.astype(x.dtype),
        onehot.astype(x.dtype),
        cap_oh,
    )
    y = jnp.einsum("gnec,gecd->gnd", comb_w, ye)
    return y.reshape(b, s, d)


def init_moe(key, cfg: ArchConfig, dtype):
    e, f = cfg.num_experts, cfg.d_ff
    k0, k1, k2, k3 = split_keys(key, 4)
    return {
        "router": dense_init(k0, (cfg.d_model, e), dtype, cfg.d_model),
        "w_gate": dense_init(k1, (e, cfg.d_model, f), dtype, cfg.d_model),
        "w_up": dense_init(k2, (e, cfg.d_model, f), dtype, cfg.d_model),
        "w_down": dense_init(k3, (e, f, cfg.d_model), dtype, f),
    }
