"""Decoder-only LM assembly (dense / MoE / Mamba-2), scan-over-layers.

One homogeneous block stack: per-layer params are stacked on a leading
axis and the stack runs under ``jax.lax.scan`` (+ optional remat), so HLO
size is independent of depth.  The block kind is fixed per config
(dense-attn+MLP, attn+MoE, or mamba), which covers mamba2-1.3b, the MoE
and dense LMs, and internvl2's language backbone (patch embeddings are
concatenated in front of the token embeddings).  Heterogeneous stacks
(zamba2) live in :mod:`repro.models.hybrid`; enc-dec (whisper) in
:mod:`repro.models.encdec`.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import attention_block, init_attention
from .common import (
    ArchConfig,
    batch_axes,
    dense_init,
    rms_norm,
    shard,
    split_keys,
)
from .mamba import init_mamba, mamba_block
from .mlp import init_mlp, init_moe, mlp_block, moe_block


# ---------------------------------------------------------------------- #
# one decoder block (params are per-layer slices)
# ---------------------------------------------------------------------- #
def decoder_block(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    mesh,
    *,
    positions=None,
    kv_cache=None,
    cache_len=None,
    ssm_state=None,
    conv_cache=None,
):
    """Pre-norm block. Returns (x, new_kv_cache, new_ssm_state, new_conv)."""
    from .common import cast_block_params

    params = cast_block_params(params, cfg.dtype)
    ba = batch_axes(mesh)
    seq_ax = "model" if cfg.seq_shard else None
    if cfg.is_ssm or (cfg.is_hybrid and "in_proj" in params):
        h, new_ssm, new_conv = mamba_block(
            params["mix"] if "mix" in params else params,
            rms_norm(x, params["ln1"]),
            cfg,
            ssm_state=ssm_state,
            conv_cache=conv_cache,
        )
        x = x + h
        x = shard(x, mesh, ba, seq_ax, None)
        return x, None, new_ssm, new_conv

    h, new_cache = attention_block(
        params["attn"],
        rms_norm(x, params["ln1"]),
        cfg,
        positions=positions,
        kv_cache=kv_cache,
        cache_len=cache_len,
    )
    x = x + h
    x = shard(x, mesh, ba, seq_ax, None)
    h2 = rms_norm(x, params["ln2"])
    if cfg.is_moe:
        h2 = moe_block(params["moe"], h2, cfg, mesh)
    else:
        h2 = mlp_block(params["mlp"], h2, mesh)
    x = x + h2
    x = shard(x, mesh, ba, seq_ax, None)
    return x, new_cache, None, None


def init_decoder_block(key, cfg: ArchConfig, dtype):
    if cfg.is_ssm:
        p = dict(init_mamba(key, cfg, dtype))
        p["ln1"] = jnp.ones((cfg.d_model,), dtype)
        return p
    k1, k2 = split_keys(key, 2)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(k1, cfg, dtype),
    }
    if cfg.is_moe:
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg, dtype)
    return p


# ---------------------------------------------------------------------- #
# whole model
# ---------------------------------------------------------------------- #
def init_lm(key, cfg: ArchConfig):
    dtype = cfg.param_dtype
    keys = split_keys(key, cfg.num_layers + 3)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_decoder_block(keys[i], cfg, dtype) for i in range(cfg.num_layers)],
    )
    params = {
        "embed": dense_init(keys[-3], (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": stacked,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            keys[-2], (cfg.d_model, cfg.padded_vocab), dtype, cfg.d_model
        )
    if cfg.num_patches:
        params["patch_proj"] = dense_init(
            keys[-1], (cfg.d_model, cfg.d_model), dtype, cfg.d_model
        )
    return params


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def lm_forward(
    params: dict,
    cfg: ArchConfig,
    mesh,
    tokens: jax.Array,                      # (B, S) int32
    *,
    patch_embeds: jax.Array | None = None,  # (B, Np, D) vlm stub frontend
) -> jax.Array:
    """Training/prefill forward → logits (B, S_total, padded_vocab)."""
    ba = batch_axes(mesh)
    x = params["embed"][tokens] * jnp.sqrt(cfg.d_model).astype(cfg.dtype)
    x = x.astype(cfg.dtype)
    if patch_embeds is not None:
        pe = jnp.einsum("bnd,de->bne", patch_embeds.astype(cfg.dtype),
                        params["patch_proj"].astype(cfg.dtype))
        x = jnp.concatenate([pe, x], axis=1)
    x = shard(x, mesh, ba, "model" if cfg.seq_shard else None, None)

    if cfg.use_scan:
        block = _remat(
            lambda xx, layer_params: decoder_block(layer_params, xx, cfg, mesh)[0],
            cfg,
        )
        x = jax.lax.scan(
            lambda xx, lp: (block(xx, lp), None), x, params["layers"]
        )[0]
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x = decoder_block(lp, x, cfg, mesh)[0]

    x = rms_norm(x, params["ln_f"])
    w_out = params.get("unembed")
    if w_out is None:
        w_out = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w_out.astype(cfg.dtype))
    logits = shard(logits, mesh, ba, None, "model")
    return logits


class DecodeState(NamedTuple):
    """Carried state for autoregressive decoding."""

    kv: Any            # (L, B, S, Hkv, hd) ×2 for attn archs, else None
    ssm: Any           # (L, B, H, N, P) for ssm archs, else None
    conv: Any          # (L, B, K-1, C) for ssm archs, else None
    pos: jax.Array     # scalar int32: current cache length


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int, mesh=None):
    L = cfg.num_layers
    ba = batch_axes(mesh)
    kv = ssm = conv = None
    if cfg.is_ssm:
        ssm = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
        )
        conv = jnp.zeros(
            (L, batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state), cfg.dtype
        )
        if mesh is not None:
            ssm = shard(ssm, mesh, None, ba, "model", None, None)
            conv = shard(conv, mesh, None, ba, None, None)
    else:
        mk = lambda: jnp.zeros((L, batch, max_seq, cfg.num_kv_heads, cfg.hd), cfg.dtype)
        k, v = mk(), mk()
        if mesh is not None:
            # batch=1 long-context: shard the cache sequence over data.
            # GQA KV heads often don't divide the model axis (kv=8 on a
            # 16-way axis); shard head_dim instead so the cache still
            # distributes (hd is 64/112/128 across the zoo — all divisible).
            seq_ax = "data" if batch == 1 else None
            model_size = mesh.shape.get("model", 1)
            if cfg.num_kv_heads % model_size == 0:
                axes = (None, ba, seq_ax, "model", None)
            else:
                axes = (None, ba, seq_ax, None, "model")
            k = shard(k, mesh, *axes)
            v = shard(v, mesh, *axes)
        kv = (k, v)
    return DecodeState(kv=kv, ssm=ssm, conv=conv, pos=jnp.zeros((), jnp.int32))


def lm_decode_step(
    params: dict,
    cfg: ArchConfig,
    mesh,
    tokens: jax.Array,          # (B, 1) next token ids
    state: DecodeState,
) -> tuple[jax.Array, DecodeState]:
    """One decode step → (logits (B, 1, V), new state)."""
    ba = batch_axes(mesh)
    x = params["embed"][tokens] * jnp.sqrt(cfg.d_model).astype(cfg.dtype)
    x = x.astype(cfg.dtype)
    positions = jnp.broadcast_to(state.pos, (tokens.shape[0], 1))

    def step(carry, inp):
        xx = carry
        lp, kv_l, ssm_l, conv_l = inp
        out, new_kv, new_ssm, new_conv = decoder_block(
            lp, xx, cfg, mesh,
            positions=positions,
            kv_cache=kv_l,
            cache_len=state.pos,
            ssm_state=ssm_l,
            conv_cache=conv_l,
        )
        return out, (new_kv, new_ssm, new_conv)

    if cfg.is_ssm:
        x, (new_kv, new_ssm, new_conv) = jax.lax.scan(
            lambda xx, inp: step(xx, (inp[0], None, inp[1], inp[2])),
            x,
            (params["layers"], state.ssm, state.conv),
        )
        new_state = DecodeState(kv=None, ssm=new_ssm, conv=new_conv,
                                pos=state.pos + 1)
    else:
        x, (new_kv, _, _) = jax.lax.scan(
            lambda xx, inp: step(xx, (inp[0], (inp[1], inp[2]), None, None)),
            x,
            (params["layers"], state.kv[0], state.kv[1]),
        )
        new_state = DecodeState(kv=new_kv, ssm=None, conv=None, pos=state.pos + 1)

    x = rms_norm(x, params["ln_f"])
    w_out = params.get("unembed")
    if w_out is None:
        w_out = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w_out.astype(cfg.dtype))
    return logits, new_state
