"""Unified model API: build a :class:`Model` from an :class:`ArchConfig`
and get ``init`` / ``train_step`` / ``serve_step`` / ``input_specs``.

``input_specs(shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for every
input of the step function — weak-type-correct and shardable, with **no
device allocation** — which is what the multi-pod dry-run lowers against.

Shape registry (assignment):
    train_4k     seq 4,096   global_batch 256   → train_step
    prefill_32k  seq 32,768  global_batch 32    → prefill (forward)
    decode_32k   seq 32,768  global_batch 128   → serve_step (1 new token)
    long_500k    seq 524,288 global_batch 1     → serve_step, ssm/hybrid only
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw

from . import encdec, hybrid, transformer
from .common import ArchConfig, batch_axes

__all__ = ["SHAPES", "ShapeSpec", "Model", "build_model"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Causal-LM loss; positions with label < 0 are masked (vlm patches,
    padding).  Padded-vocab logits are masked to −inf.

    Written gather-free: ``take_along_axis`` over a vocab-sharded logits
    tensor makes GSPMD all-gather the full (tokens × vocab) array; the
    max/logsumexp reductions and the one-hot contraction all partition
    cleanly over both the batch and vocab axes instead.
    """
    v_pad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if v_pad > vocab:
        vmask = jnp.arange(v_pad) < vocab
        logits = jnp.where(vmask, logits, -1e30)
    mx = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=-1)) + mx[..., 0]
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    onehot = jax.nn.one_hot(safe, v_pad, dtype=logits.dtype)
    label_logit = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - label_logit
    return (nll * valid).sum() / jnp.maximum(valid.sum(), 1)


# ---------------------------------------------------------------------- #
class Model:
    """Family-dispatching wrapper produced by :func:`build_model`."""

    def __init__(self, cfg: ArchConfig, mesh=None, opt: AdamWConfig | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.opt = opt or AdamWConfig()

    # ---------------- parameters ---------------------------------- #
    def init(self, rng: jax.Array):
        cfg = self.cfg
        if cfg.family == "hybrid":
            return hybrid.init_hybrid(rng, cfg)
        if cfg.family == "audio":
            return encdec.init_encdec(rng, cfg)
        return transformer.init_lm(rng, cfg)

    def init_opt(self, params) -> AdamWState:
        return init_adamw(params, moments_dtype=self.cfg.opt_moments_dtype)

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))

    # ---------------- forward / loss ------------------------------- #
    def forward(self, params, batch: dict) -> jax.Array:
        cfg, mesh = self.cfg, self.mesh
        if cfg.family == "hybrid":
            return hybrid.hybrid_forward(params, cfg, mesh, batch["tokens"])
        if cfg.family == "audio":
            return encdec.encdec_forward(
                params, cfg, mesh, batch["frames"], batch["tokens"]
            )
        return transformer.lm_forward(
            params, cfg, mesh, batch["tokens"],
            patch_embeds=batch.get("patches"),
        )

    def loss_fn(self, params, batch: dict) -> jax.Array:
        logits = self.forward(params, batch)
        labels = batch["labels"]
        if self.cfg.num_patches:  # vlm: logits cover patches + tokens
            pad = -jnp.ones(
                (labels.shape[0], self.cfg.num_patches), labels.dtype
            )
            labels = jnp.concatenate([pad, labels], axis=1)
        return cross_entropy(logits, labels, self.cfg.vocab)

    # ---------------- steps --------------------------------------- #
    def train_step(self, params, opt_state: AdamWState, batch: dict):
        loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
        new_params, new_opt, metrics = adamw_update(
            self.opt, grads, params, opt_state
        )
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    def prefill_step(self, params, batch: dict) -> jax.Array:
        return self.forward(params, batch)

    def serve_step(self, params, tokens, state):
        cfg, mesh = self.cfg, self.mesh
        if cfg.family == "hybrid":
            return hybrid.hybrid_decode_step(params, cfg, mesh, tokens, state)
        if cfg.family == "audio":
            return encdec.encdec_decode_step(params, cfg, mesh, tokens, state)
        return transformer.lm_decode_step(params, cfg, mesh, tokens, state)

    def init_decode_state(self, batch: int, max_seq: int, params=None, frames=None):
        cfg, mesh = self.cfg, self.mesh
        if cfg.family == "hybrid":
            return hybrid.init_hybrid_decode_state(cfg, batch, max_seq, mesh)
        if cfg.family == "audio":
            return encdec.init_encdec_decode_state(
                params, cfg, batch, max_seq, frames, mesh
            )
        return transformer.init_decode_state(cfg, batch, max_seq, mesh)

    def decode_state_shardings(self, state_shapes, batch: int):
        """NamedSharding pytree for a decode state (mirrors the sharding
        logic of the init_*_decode_state functions — needed as jit
        in_shardings so dry-run memory analysis sees distributed caches)."""
        from jax.sharding import NamedSharding

        from .common import make_spec

        cfg, mesh = self.cfg, self.mesh
        ba = batch_axes(mesh)
        model_size = mesh.shape.get("model", 1) if mesh else 1
        seq_ax = "data" if batch == 1 else None

        def kv_axes(rank):  # (L, B, S, H|hd sharded)
            head_ok = cfg.num_kv_heads % model_size == 0
            axes = (None, ba, seq_ax, "model", None) if head_ok else (
                None, ba, seq_ax, None, "model")
            return axes[-rank:] if rank <= 5 else (None,) * (rank - 5) + axes

        def assign(path, leaf):
            name = ""
            for p in path:
                if hasattr(p, "name"):
                    name = p.name
                    break
                if hasattr(p, "idx"):
                    name = type(state_shapes)._fields[p.idx]
                    break
            rank = len(leaf.shape)
            if name == "kv" or name == "enc_kv":
                axes = kv_axes(rank)
            elif name.startswith("ssm"):
                axes = (None,) * (rank - 4) + (ba, "model", None, None)
            elif name.startswith("conv"):
                axes = (None,) * (rank - 3) + (ba, None, None)
            else:
                axes = (None,) * rank
            return NamedSharding(mesh, make_spec(mesh, leaf.shape, axes))

        return jax.tree_util.tree_map_with_path(assign, state_shapes)

    # ---------------- dry-run input specs -------------------------- #
    def input_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda s: jax.ShapeDtypeStruct((B, s), jnp.int32)
        d = cfg.d_model
        if shape.kind in ("train", "prefill"):
            if cfg.family == "audio":
                s_enc, s_dec = encdec.enc_seq_split(cfg, S)
                out = {
                    "frames": jax.ShapeDtypeStruct((B, s_enc, d), jnp.float32),
                    "tokens": tok(s_dec),
                }
                if shape.kind == "train":
                    out["labels"] = tok(s_dec)
                return out
            s_text = S - cfg.num_patches if cfg.num_patches else S
            out = {"tokens": tok(s_text)}
            if cfg.num_patches:
                out["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_patches, d), jnp.float32
                )
            if shape.kind == "train":
                out["labels"] = tok(s_text)
            return out
        # decode: one new token against a cache of S
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def build_model(cfg: ArchConfig, mesh=None, opt: AdamWConfig | None = None) -> Model:
    return Model(cfg, mesh, opt)
