"""Zamba-2-style hybrid stack (arXiv:2411.15242): a Mamba-2 backbone with a
single *shared* attention block applied periodically.

Layer slots 0..L−1: every ``cfg.attn_every``-th slot runs the shared
attention block (one set of weights reused at each application — Zamba's
parameter-efficiency trick), all other slots are Mamba-2 blocks.  The
mamba layers are organized as ``(groups, per_group)`` stacks so the
forward is an outer scan over groups with an inner scan over the group's
mamba layers — HLO stays compact at 81 slots.

Decode: each shared-attention *application* keeps its own KV cache
(weights shared, state not); mamba layers carry (ssm, conv) state.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import attention_block, init_attention
from .common import (ArchConfig, batch_axes, cast_block_params, dense_init,
                     rms_norm, shard, split_keys)
from .mamba import init_mamba, mamba_block


def hybrid_layout(cfg: ArchConfig) -> tuple[int, int, int]:
    """(num_groups, mamba_per_group, trailing_mamba): slots =
    groups × (per_group mamba + 1 shared attn) + trailing mamba."""
    k = cfg.attn_every
    groups = cfg.num_layers // k
    trailing = cfg.num_layers - groups * k
    return groups, k - 1, trailing


def init_hybrid(key, cfg: ArchConfig):
    dtype = cfg.param_dtype
    groups, per_group, trailing = hybrid_layout(cfg)
    n_mamba = groups * per_group + trailing
    keys = split_keys(key, n_mamba + 4)

    def mk_mamba(i):
        p = dict(init_mamba(keys[i], cfg, dtype))
        p["ln1"] = jnp.ones((cfg.d_model,), dtype)
        return p

    grouped = jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape(groups, per_group, *xs[0].shape),
        *[mk_mamba(i) for i in range(groups * per_group)],
    )
    params = {
        "mamba_groups": grouped,
        "shared_attn": {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(keys[-4], cfg, dtype),
        },
        "embed": dense_init(keys[-3], (cfg.padded_vocab, cfg.d_model), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "unembed": dense_init(keys[-2], (cfg.d_model, cfg.padded_vocab), dtype,
                              cfg.d_model),
    }
    if trailing:
        params["mamba_tail"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[mk_mamba(groups * per_group + i) for i in range(trailing)],
        )
    return params


def _mamba_scan(stack_params, x, cfg, mesh, remat: bool):
    def body(xx, lp):
        lp = cast_block_params(lp, cfg.dtype)
        h, _, _ = mamba_block(lp, rms_norm(xx, lp["ln1"]), cfg)
        seq_ax = "model" if cfg.seq_shard else None
        out = shard(xx + h, mesh, batch_axes(mesh), seq_ax, None)
        return out, None

    fn = jax.checkpoint(lambda xx, lp: body(xx, lp)[0]) if remat else None
    if remat:
        return jax.lax.scan(lambda xx, lp: (fn(xx, lp), None), x, stack_params)[0]
    return jax.lax.scan(body, x, stack_params)[0]


def hybrid_forward(params, cfg: ArchConfig, mesh, tokens: jax.Array) -> jax.Array:
    ba = batch_axes(mesh)
    groups, per_group, trailing = hybrid_layout(cfg)
    remat = cfg.remat != "none"
    x = params["embed"][tokens].astype(cfg.dtype) * jnp.sqrt(
        cfg.d_model
    ).astype(cfg.dtype)
    x = shard(x, mesh, ba, None, None)

    sa = cast_block_params(params["shared_attn"], cfg.dtype)

    def group_body(xx, gp):
        xx = _mamba_scan(gp, xx, cfg, mesh, remat)
        h, _ = attention_block(sa["attn"], rms_norm(xx, sa["ln1"]), cfg)
        xx = shard(xx + h, mesh, ba, "model" if cfg.seq_shard else None, None)
        return xx, None

    gb = jax.checkpoint(lambda xx, gp: group_body(xx, gp)[0]) if remat else None
    if remat:
        x = jax.lax.scan(lambda xx, gp: (gb(xx, gp), None), x,
                         params["mamba_groups"])[0]
    else:
        x = jax.lax.scan(group_body, x, params["mamba_groups"])[0]
    if trailing:
        x = _mamba_scan(params["mamba_tail"], x, cfg, mesh, remat)

    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))
    return shard(logits, mesh, ba, None, "model")


class HybridDecodeState(NamedTuple):
    ssm_groups: Any    # (G, per_group, B, H, N, P)
    conv_groups: Any   # (G, per_group, B, K-1, C)
    ssm_tail: Any
    conv_tail: Any
    kv: Any            # (G, B, S, Hkv, hd) ×2 — per shared-attn application
    pos: jax.Array


def init_hybrid_decode_state(cfg: ArchConfig, batch: int, max_seq: int, mesh=None):
    groups, per_group, trailing = hybrid_layout(cfg)
    ba = batch_axes(mesh)
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state

    def mk_ssm(n):
        s = jnp.zeros((*n, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                      jnp.float32)
        c = jnp.zeros((*n, batch, cfg.conv_width - 1, conv_ch), cfg.dtype)
        return s, c

    ssm_g, conv_g = mk_ssm((groups, per_group))
    ssm_t, conv_t = mk_ssm((trailing,)) if trailing else (None, None)
    k = jnp.zeros((groups, batch, max_seq, cfg.num_kv_heads, cfg.hd), cfg.dtype)
    v = jnp.zeros_like(k)
    if mesh is not None:
        seq_ax = "data" if batch == 1 else None
        model_size = mesh.shape.get("model", 1)
        kv_axes = (
            (None, ba, seq_ax, "model", None)
            if cfg.num_kv_heads % model_size == 0
            else (None, ba, seq_ax, None, "model")
        )
        k, v = shard(k, mesh, *kv_axes), shard(v, mesh, *kv_axes)
        ssm_g = shard(ssm_g, mesh, None, None, ba, "model", None, None)
    return HybridDecodeState(
        ssm_groups=ssm_g, conv_groups=conv_g, ssm_tail=ssm_t, conv_tail=conv_t,
        kv=(k, v), pos=jnp.zeros((), jnp.int32),
    )


def hybrid_decode_step(params, cfg: ArchConfig, mesh, tokens, state):
    groups, per_group, trailing = hybrid_layout(cfg)
    x = params["embed"][tokens].astype(cfg.dtype) * jnp.sqrt(
        cfg.d_model
    ).astype(cfg.dtype)
    positions = jnp.broadcast_to(state.pos, (tokens.shape[0], 1))
    sa = cast_block_params(params["shared_attn"], cfg.dtype)

    def mamba_step(xx, lp, ssm, conv):
        lp = cast_block_params(lp, cfg.dtype)
        h, new_ssm, new_conv = mamba_block(
            lp, rms_norm(xx, lp["ln1"]), cfg, ssm_state=ssm, conv_cache=conv
        )
        return xx + h, new_ssm, new_conv

    def group_step(xx, inp):
        gp, ssm_g, conv_g, kv_k, kv_v = inp

        # inner scan over the group's mamba layers
        def inner_body(c, inp2):
            lp, ssm_l, conv_l = inp2
            c2, ns, nc = mamba_step(c, lp, ssm_l, conv_l)
            return c2, (ns, nc)

        xx, (new_ssm, new_conv) = jax.lax.scan(inner_body, xx, (gp, ssm_g, conv_g))
        h, new_kv = attention_block(
            sa["attn"], rms_norm(xx, sa["ln1"]), cfg,
            positions=positions, kv_cache=(kv_k, kv_v), cache_len=state.pos,
        )
        return xx + h, (new_ssm, new_conv, new_kv[0], new_kv[1])

    x, (ssm_g, conv_g, kc, vc) = jax.lax.scan(
        group_step, x,
        (params["mamba_groups"], state.ssm_groups, state.conv_groups,
         state.kv[0], state.kv[1]),
    )
    ssm_t = conv_t = None
    if trailing:
        def tail_body(c, inp2):
            lp, ssm_l, conv_l = inp2
            c2, ns, nc = mamba_step(c, lp, ssm_l, conv_l)
            return c2, (ns, nc)

        x, (ssm_t, conv_t) = jax.lax.scan(
            tail_body, x, (params["mamba_tail"], state.ssm_tail, state.conv_tail)
        )
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))
    new_state = HybridDecodeState(
        ssm_groups=ssm_g, conv_groups=conv_g, ssm_tail=ssm_t, conv_tail=conv_t,
        kv=(kc, vc), pos=state.pos + 1,
    )
    return logits, new_state
