"""JAX model zoo for the assigned architectures."""

from .api import SHAPES, Model, ShapeSpec, build_model
from .common import ArchConfig

__all__ = ["SHAPES", "Model", "ShapeSpec", "build_model", "ArchConfig"]
