"""Grouped-query attention: full, q-chunked (long prefill), and cached
single-token decode.  Pure JAX (XLA attention); the Pallas flash kernel in
``repro.kernels.flash_attention`` is a drop-in for the TPU target and is
validated against the same math in interpret mode.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .common import ArchConfig, rope

NEG_INF = -1e30
CHUNK_THRESHOLD = 8192   # above this seq length, scan over query chunks
Q_CHUNK = 1024


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, D) → (B, S, Hkv*groups, D) by head-group broadcast."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)).reshape(
        b, s, h * groups, d
    )


def _mask_bias(q_pos, k_pos, window: int = 0):
    """(…, Q, K) additive causal (+ optional sliding-window) bias."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        causal &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(causal, 0.0, NEG_INF)


def full_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    window: int = 0,
    causal: bool = True,
) -> jax.Array:
    """Dense softmax attention; fine up to ~8k sequence.

    §Perf iteration 3: the (B,H,S,S) score/prob buffers stay in the compute
    dtype (bf16) — reductions (max, normalizer) use fp32 *accumulators*
    without materializing an fp32 copy of the score tensor, which halves
    the dominant memory-roofline buffers of every 4k-train cell.  Safe:
    probs ∈ [0,1] after max-subtraction; only the normalizer needs range.
    """
    b, s, h, d = q.shape
    groups = h // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    if causal:
        pos = jnp.arange(s)
        scores = scores + _mask_bias(pos, pos, window).astype(scores.dtype)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m)                                   # compute dtype
    l = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32) # f32 accumulate
    probs = (p / l.astype(p.dtype))
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def streaming_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, window: int = 0,
    kv_chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention scanned over KV blocks — the flash-attention
    algorithm expressed in XLA: scores exist only per (S, kv_chunk) tile and
    never hit HBM at (S, S) size.  §Perf iteration 1: this removes the
    fp32 (B,H,S,S) buffers that dominate the memory roofline term of every
    full-attention training cell (the Pallas kernel is the TPU-native form;
    this is its scan lowering for targets where Mosaic is unavailable)."""
    b, s, h, d = q.shape
    groups = h // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    nk = s // kv_chunk
    assert nk * kv_chunk == s, (s, kv_chunk)
    qt = (q / jnp.sqrt(d).astype(q.dtype)).transpose(0, 2, 1, 3)   # (B,H,S,D)
    kc = k.transpose(0, 2, 1, 3).reshape(b, h, nk, kv_chunk, d)
    vc = v.transpose(0, 2, 1, 3).reshape(b, h, nk, kv_chunk, d)
    q_pos = jnp.arange(s)

    # jax.checkpoint on the step: the backward pass recomputes each tile's
    # scores instead of saving (B,H,S,kv_chunk) residuals per step — this
    # is exactly the flash-attention VJP strategy, expressed in XLA.
    @jax.checkpoint
    def step(carry, inp):
        m, l, acc = carry
        kb, vb, idx = inp                                   # (B,H,C,D), idx
        srs = jnp.einsum(
            "bhqd,bhkd->bhqk", qt, kb, preferred_element_type=jnp.float32
        )
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        srs = jnp.where(mask, srs, NEG_INF)
        m_new = jnp.maximum(m, srs.max(-1, keepdims=True))
        p = jnp.exp(srs - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, h, s, 1), NEG_INF, jnp.float32),
        jnp.zeros((b, h, s, 1), jnp.float32),
        jnp.zeros((b, h, s, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init,
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4), jnp.arange(nk)),
    )
    out = acc / jnp.maximum(l, 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, window: int = 0
) -> jax.Array:
    """Causal attention scanned over query chunks — the XLA analogue of
    flash attention: per-step score tensors are (B, H, Q_CHUNK, S), so the
    32k-prefill working set stays bounded regardless of sequence length."""
    b, s, h, d = q.shape
    groups = h // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    nchunk = s // Q_CHUNK
    assert nchunk * Q_CHUNK == s, f"seq {s} not divisible by {Q_CHUNK}"
    qc = q.reshape(b, nchunk, Q_CHUNK, h, d).transpose(1, 0, 2, 3, 4)
    k_pos = jnp.arange(s)

    def step(_, inp):
        qi, idx = inp
        q_pos = idx * Q_CHUNK + jnp.arange(Q_CHUNK)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qi, k) / jnp.sqrt(d).astype(q.dtype)
        bias = jnp.where(
            (k_pos[None, :] <= q_pos[:, None])
            & ((window <= 0) | (k_pos[None, :] > q_pos[:, None] - window)),
            0.0,
            NEG_INF,
        )
        scores = scores + bias.astype(scores.dtype)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
        return None, jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    _, out = jax.lax.scan(step, None, (qc, jnp.arange(nchunk)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def decode_attention(
    q: jax.Array,        # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,
    cache_len: jax.Array | int,  # number of valid positions
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention over the KV cache."""
    b, s, hkv, d = k_cache.shape
    h = q.shape[2]
    groups = h // hkv
    k = _repeat_kv(k_cache, groups)
    v = _repeat_kv(v_cache, groups)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    pos = jnp.arange(s)
    valid = pos[None, None, None, :] < cache_len
    if window > 0:
        valid &= pos[None, None, None, :] >= (cache_len - window)
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------- #
def attention_block(
    params: dict,
    x: jax.Array,            # (B, S, D)
    cfg: ArchConfig,
    *,
    positions: jax.Array | None = None,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,
    cache_len: jax.Array | int | None = None,
    causal: bool = True,
    use_rope: bool = True,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """One attention sublayer: qkv proj → rope → attention → out proj.

    In decode mode (``kv_cache`` given, S == 1) the new K/V are written at
    ``cache_len`` and attention runs over the cache; returns updated cache.
    """
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        kc, vc = kv_cache
        idx = cache_len if cache_len is not None else 0
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, idx, 0, 0))
        new_cache = (kc, vc)
        out = decode_attention(q, kc, vc, idx + s, window=cfg.sliding_window)
    elif causal and cfg.streaming_attn and s >= 2 * cfg.attn_kv_chunk:
        out = streaming_attention(
            q, k, v, window=cfg.sliding_window, kv_chunk=cfg.attn_kv_chunk
        )
    elif causal and s > CHUNK_THRESHOLD:
        out = chunked_attention(q, k, v, window=cfg.sliding_window)
    else:
        out = full_attention(q, k, v, window=cfg.sliding_window, causal=causal)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_attention(key, cfg: ArchConfig, dtype):
    from .common import dense_init, split_keys

    hd = cfg.hd
    k1, k2, k3, k4 = split_keys(key, 4)
    p = {
        "wq": dense_init(k1, (cfg.d_model, cfg.num_heads, hd), dtype, cfg.d_model),
        "wk": dense_init(k2, (cfg.d_model, cfg.num_kv_heads, hd), dtype, cfg.d_model),
        "wv": dense_init(k3, (cfg.d_model, cfg.num_kv_heads, hd), dtype, cfg.d_model),
        "wo": dense_init(
            k4, (cfg.num_heads, hd, cfg.d_model), dtype, cfg.num_heads * hd
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
    return p
