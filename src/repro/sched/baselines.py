"""Random and Ideal baseline schedulers (paper §5.1)."""

from __future__ import annotations

import random

from repro.sched.base import ClusterState, PlacementMap, Scheduler

__all__ = ["RandomScheduler", "IdealScheduler"]


class RandomScheduler(Scheduler):
    """Places workers uniformly at random — highest network overhead,
    no locality, no compatibility (paper's worst baseline)."""

    name = "random"

    def __init__(self, *, seed: int = 0) -> None:
        self.seed = seed

    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        jobs = [j for j in state.running if j.remaining_iters() > 0]
        alloc: dict[str, int] = {}
        budget = state.topology.num_gpus
        for j in jobs:
            take = min(j.num_workers, budget)
            if take > 0:
                alloc[j.job_id] = take
                budget -= take
        return alloc

    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        rng = random.Random(self.seed + int(state.now_ms) % 100_000)
        out: list[PlacementMap] = []
        for _ in range(k):
            servers = list(range(state.topology.num_gpus))
            rng.shuffle(servers)
            pl: PlacementMap = {}
            pos = 0
            ok = True
            for j in state.running:
                w = workers.get(j.job_id, 0)
                if w == 0:
                    continue
                if pos + w > len(servers):
                    ok = False
                    break
                pl[j.job_id] = tuple(sorted(servers[pos : pos + w]))
                pos += w
            if ok and pl:
                out.append(pl)
        return out


class IdealScheduler(Scheduler):
    """Dedicated-cluster reference: every job is placed as if alone (the
    simulator is run with one job at a time, so there is never contention).

    Used through :func:`repro.cluster.ideal.ideal_metrics` which runs each
    job in isolation; as a Scheduler it simply packs with maximum locality.
    """

    name = "ideal"

    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        jobs = [j for j in state.running if j.remaining_iters() > 0]
        alloc: dict[str, int] = {}
        budget = state.topology.num_gpus
        for j in jobs:
            take = min(j.num_workers, budget)
            if take > 0:
                alloc[j.job_id] = take
                budget -= take
        return alloc

    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        from repro.sched.base import pack_placement

        jobs = [j for j in state.running if workers.get(j.job_id, 0) > 0]
        jw = [(j, workers[j.job_id]) for j in jobs]
        pl = pack_placement(state.topology, jw)
        return [pl] if pl else []
