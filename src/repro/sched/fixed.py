"""Fixed-placement scheduler for snapshot experiments (paper Fig. 2,
Table 2, Fig. 12): the placement is pinned (typically *forcing* jobs to
share ToR uplinks, as fragmentation does in a busy cluster) and only the
time-shifts differ between the baseline and the CASSINI-augmented run."""

from __future__ import annotations

from repro.sched.base import ClusterState, PlacementMap, Scheduler

__all__ = ["FixedPlacementScheduler"]


class FixedPlacementScheduler(Scheduler):
    name = "fixed"

    def __init__(self, placements: PlacementMap) -> None:
        self.placements = dict(placements)

    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        return {
            j.job_id: len(self.placements.get(j.job_id, ()))
            for j in state.running
            if j.job_id in self.placements
        }

    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        pl = {
            j.job_id: tuple(self.placements[j.job_id])
            for j in state.running
            if j.job_id in self.placements
        }
        return [pl] if pl else []
