"""Pollux-style goodput scheduler (Qiao et al., OSDI'21), reimplemented at
the granularity CASSINI needs.

Pollux reassigns GPUs periodically to maximize cluster-wide *goodput* =
throughput × statistical efficiency, and models migration costs to avoid
thrashing.  We reproduce that outcome structure: a concave per-job speedup
curve ``s(n) = n / (1 + α·(n−1))`` (diminishing returns) scaled by the
job's remaining work; GPUs go one at a time to the job with the largest
marginal goodput gain.  Placement candidates come from the same packing
permutations as Themis — Po+CASSINI and Th+CASSINI share all CASSINI
parameters (§5.1).
"""

from __future__ import annotations

import random

from repro.cluster.job import Job
from repro.sched.base import (ClusterState, PlacementMap, Scheduler,
                              propose_candidates)

__all__ = ["PolluxScheduler"]


class PolluxScheduler(Scheduler):
    name = "pollux"

    def __init__(
        self,
        *,
        num_candidates: int = 10,
        alpha: float = 0.08,       # diminishing-returns strength
        max_scale: float = 1.5,    # Pollux may scale jobs past their request
        seed: int = 0,
    ) -> None:
        self.num_candidates = num_candidates
        self.alpha = alpha
        self.max_scale = max_scale
        self.seed = seed

    # -------------------------------------------------------------- #
    def _goodput(self, job: Job, n: int) -> float:
        if n <= 0:
            return 0.0
        speedup = n / (1.0 + self.alpha * (n - 1))
        # statistical efficiency decays when scaled past the request
        eff = 1.0 if n <= job.num_workers else (job.num_workers / n) ** 0.5
        return speedup * eff / job.profile.iter_time_ms(n)

    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        jobs = [j for j in state.running if j.remaining_iters() > 0]
        if not jobs:
            return {}
        by_id = {j.job_id: j for j in jobs}
        cap = {
            j.job_id: max(1, int(round(j.num_workers * self.max_scale)))
            for j in jobs
        }
        alloc = {j.job_id: 0 for j in jobs}
        budget = state.topology.num_gpus
        while budget > 0:
            best, best_gain = None, 0.0
            for jid, a in alloc.items():
                if a >= cap[jid]:
                    continue
                gain = self._goodput(by_id[jid], a + 1) - self._goodput(by_id[jid], a)
                if gain > best_gain:
                    best, best_gain = jid, gain
            if best is None:
                break
            alloc[best] += 1
            budget -= 1
        return {jid: a for jid, a in alloc.items() if a > 0}

    # -------------------------------------------------------------- #
    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        jobs = [j for j in state.running if workers.get(j.job_id, 0) > 0]
        jw = [(j, workers[j.job_id]) for j in jobs]
        rng = random.Random(self.seed + int(state.now_ms) % 100_000)
        out = propose_candidates(state.topology, jw, k, rng)
        if not out:
            shrunk = {jid: max(1, w - 1) for jid, w in workers.items()}
            if shrunk != workers:
                return self.propose(state, shrunk, k)
        return out
