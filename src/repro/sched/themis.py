"""Themis-style finish-time-fairness scheduler (Mahajan et al., NSDI'20),
reimplemented at the granularity CASSINI needs (paper §4.2).

Themis's arbiter runs periodic auctions in which jobs bid for GPU leases;
winners are chosen to maximize aggregate improvement of the finish-time
fairness metric ρ = T_shared / T_ideal (estimated finish time under the
current allocation vs. under a dedicated 1/N share).  We reproduce the
auction's *outcome structure*: GPUs are handed out one at a time to the job
whose ρ is currently worst, bounded by each job's requested worker count —
long-term fair, locality-preferring, and network-oblivious (that is
CASSINI's opening).

``propose`` emits up to N placement candidates that all realize the same
worker allocation (hence the same fairness) but permute rack preference and
job packing order — paper §4.2 step 1 ("return up to N candidate
placements", ≈300 LoC change to Themis).
"""

from __future__ import annotations

import random

from repro.cluster.job import Job
from repro.sched.base import (ClusterState, PlacementMap, Scheduler,
                              propose_candidates)

__all__ = ["ThemisScheduler"]


class ThemisScheduler(Scheduler):
    name = "themis"

    def __init__(self, *, num_candidates: int = 10, seed: int = 0) -> None:
        self.num_candidates = num_candidates
        self.seed = seed

    # -------------------------------------------------------------- #
    def _rho(self, job: Job, workers: int, fair: float) -> float:
        """Finish-time fairness ρ = T_shared(workers)/T_ideal(fair share)."""
        if workers <= 0:
            return float("inf")
        t_shared = job.remaining_iters() * job.profile.iter_time_ms(workers) * (
            job.num_workers / workers
        )
        t_ideal = job.remaining_iters() * job.profile.iter_time_ms(
            max(1, int(fair))
        ) * (job.num_workers / max(fair, 1e-9))
        return t_shared / max(t_ideal, 1e-9)

    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        jobs = [j for j in state.running if j.remaining_iters() > 0]
        if not jobs:
            return {}
        total = state.topology.num_gpus
        fair = total / len(jobs)
        alloc = {j.job_id: 0 for j in jobs}
        budget = total
        # hand out GPUs one at a time to the worst-ρ job (auction outcome)
        by_id = {j.job_id: j for j in jobs}
        while budget > 0:
            candidates = [
                jid for jid, a in alloc.items() if a < by_id[jid].num_workers
            ]
            if not candidates:
                break
            worst = max(
                candidates,
                key=lambda jid: self._rho(by_id[jid], alloc[jid], fair),
            )
            alloc[worst] += 1
            budget -= 1
        return {jid: a for jid, a in alloc.items() if a > 0}

    # -------------------------------------------------------------- #
    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        jobs = [j for j in state.running if workers.get(j.job_id, 0) > 0]
        jw = [(j, workers[j.job_id]) for j in jobs]
        rng = random.Random(self.seed + int(state.now_ms) % 100_000)
        out = propose_candidates(state.topology, jw, k, rng)
        if not out:
            shrunk = {jid: max(1, w - 1) for jid, w in workers.items()}
            if shrunk != workers:
                return self.propose(state, shrunk, k)
        return out
