"""Scheduler interfaces shared by Themis / Pollux / Random / Ideal and the
CASSINI augmentation layer.

A host scheduler produces *placements* (job → servers).  To be CASSINI-
augmentable (paper §4.2 step 1) it must also be able to propose up to ``N``
*candidate* placements that are equivalent under its own objective
(finish-time fairness for Themis, goodput for Pollux) but differ in which
servers — and therefore which links — each job uses.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Mapping, Sequence

if TYPE_CHECKING:  # avoid a runtime cycle with repro.cluster.__init__
    from repro.cluster.job import Job
    from repro.cluster.topology import Topology
    from repro.engine.plan import AlignmentPlan

__all__ = [
    "ClusterState",
    "Decision",
    "Scheduler",
    "pack_placement",
    "sticky_placement",
]

PlacementMap = dict[str, tuple[int, ...]]  # job_id -> server ids


@dataclass
class ClusterState:
    """Scheduler-visible snapshot of the cluster."""

    topology: Topology
    now_ms: float
    running: list[Job]
    pending: list[Job]

    @property
    def jobs(self) -> list[Job]:
        return self.running + self.pending

    def gpus_free(self, placements: Mapping[str, Sequence[int]] | None = None) -> int:
        used = 0
        if placements:
            used = sum(len(v) for v in placements.values())
        return self.topology.num_gpus - used


@dataclass
class Decision:
    """Scheduling decision for one epoch.

    ``plan`` is the typed alignment payload (time-shifts, pacing periods,
    per-job min scores) produced by the pipeline's Align stage; plain host
    schedulers leave it None.  ``meta`` is a free-form debug scratchpad —
    nothing downstream reads it.
    """

    placements: PlacementMap
    time_shifts_ms: dict[str, float] = field(default_factory=dict)
    compat_score: float = float("nan")
    plan: AlignmentPlan | None = None
    meta: dict = field(default_factory=dict)


class Scheduler(abc.ABC):
    """Host scheduler interface."""

    name: str = "base"

    @abc.abstractmethod
    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        """Decide how many workers each job gets this epoch (its own
        objective: fairness, goodput, …)."""

    @abc.abstractmethod
    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        """Up to ``k`` candidate placements realizing ``workers``."""

    # -------------------------------------------------------------- #
    def schedule(self, state: ClusterState) -> Decision:
        """Default: first (locality-preferred) candidate, no time-shifts."""
        workers = self.allocate_workers(state)
        cands = self.propose(state, workers, k=1)
        return Decision(placements=cands[0] if cands else {})


# ---------------------------------------------------------------------- #
# shared placement helper
# ---------------------------------------------------------------------- #
def sticky_placement(
    topo: Topology,
    jobs_workers: Sequence[tuple[Job, int]],
    *,
    rack_order: Sequence[int] | None = None,
    job_order: Sequence[int] | None = None,
) -> PlacementMap | None:
    """Lease-respecting placement: running jobs keep their current servers
    (shrinking from the least-populated rack first when their allocation
    shrank); new jobs / grown jobs take servers from whatever is *free* —
    which, after a history of arrivals and departures, is fragmented across
    racks.  This models Themis/Pollux lease semantics: neither scheduler
    migrates every job every epoch, and fragmented placements are exactly
    where CASSINI's compatibility-aware candidate choice matters (§4.1).

    Candidate diversity comes from permuting ``rack_order`` (which racks new
    workers prefer) and ``job_order`` (who picks first).
    """
    rack_pref = (
        list(rack_order) if rack_order is not None else list(range(topo.num_racks))
    )
    order = list(job_order) if job_order is not None else list(range(len(jobs_workers)))

    taken: set[int] = set()
    kept: dict[str, list[int]] = {}
    for job, w in jobs_workers:
        cur = [s for s in job.placement]
        if not cur or w <= 0:
            continue
        if len(cur) > w:
            # shed from racks where the job has the fewest servers
            by_rack: dict[int, list[int]] = {}
            for s in cur:
                by_rack.setdefault(topo.rack_of(s), []).append(s)
            racks_sorted = sorted(by_rack, key=lambda r: len(by_rack[r]))
            while len(cur) > w and racks_sorted:
                r = racks_sorted[0]
                cur.remove(by_rack[r].pop())
                if not by_rack[r]:
                    racks_sorted.pop(0)
        kept[job.job_id] = cur[:w] if len(cur) > w else cur
        taken.update(kept[job.job_id])

    free_by_rack: dict[int, list[int]] = {r: [] for r in range(topo.num_racks)}
    for g in range(topo.num_gpus):
        if g not in taken:
            free_by_rack[topo.rack_of(g)].append(g)

    placements: PlacementMap = {}
    for idx in order:
        job, w = jobs_workers[idx]
        if w <= 0:
            continue
        got = list(kept.get(job.job_id, []))
        if len(got) < w:
            # prefer racks where the job already sits, then preference order
            own_racks = {topo.rack_of(s) for s in got}
            racks = sorted(
                rack_pref,
                key=lambda r: (r not in own_racks, -len(free_by_rack[r])),
            )
            for r in racks:
                while free_by_rack[r] and len(got) < w:
                    got.append(free_by_rack[r].pop(0))
                if len(got) == w:
                    break
        if len(got) < w:
            return None
        placements[job.job_id] = tuple(sorted(got))
    return placements


def propose_candidates(
    topo: Topology,
    jobs_workers: Sequence[tuple[Job, int]],
    k: int,
    rng,
) -> list[PlacementMap]:
    """Shared candidate generator: the lease-respecting placement under
    permuted rack preferences and job orders (paper §4.2 step 1)."""
    import itertools as _it

    seen: set[tuple] = set()
    out: list[PlacementMap] = []
    if topo.num_racks <= 4:
        rack_orders = list(_it.permutations(range(topo.num_racks)))
    else:
        # num_racks! explodes factorially (16 racks → 2·10¹³ permutations):
        # sample distinct random rack orders instead of materializing them.
        base = list(range(topo.num_racks))
        sampled: set[tuple[int, ...]] = set()
        while len(sampled) < 24:
            sampled.add(tuple(rng.sample(base, len(base))))
        rack_orders = sorted(sampled)  # deterministic order for a given rng
    job_orders = [sorted(range(len(jobs_workers)), key=lambda i: -jobs_workers[i][1])]
    for _ in range(k):
        alt = list(range(len(jobs_workers)))
        rng.shuffle(alt)
        job_orders.append(alt)
    for ro, jo in _it.product(rack_orders, job_orders):
        pl = sticky_placement(topo, jobs_workers, rack_order=list(ro), job_order=jo)
        if pl is None:
            continue
        key = tuple(sorted((jid, srv) for jid, srv in pl.items()))
        if key in seen:
            continue
        seen.add(key)
        out.append(pl)
        if len(out) >= k:
            break
    return out


def pack_placement(
    topo: Topology,
    jobs_workers: Sequence[tuple[Job, int]],
    *,
    rack_order: Sequence[int] | None = None,
    job_order: Sequence[int] | None = None,
) -> PlacementMap | None:
    """Locality-first packing: place each job on the fewest racks possible,
    preferring racks with the most free servers.  ``rack_order`` /
    ``job_order`` permute tie-breaking — that is how distinct candidate
    placements with identical worker counts are generated.

    Returns None if the jobs cannot fit.
    """
    free: dict[int, list[int]] = {r: [] for r in range(topo.num_racks)}
    for g in range(topo.num_gpus):
        free[topo.rack_of(g)].append(g)
    rack_pref = (
        list(rack_order) if rack_order is not None else list(range(topo.num_racks))
    )
    order = list(job_order) if job_order is not None else list(range(len(jobs_workers)))
    placements: PlacementMap = {}
    for idx in order:
        job, w = jobs_workers[idx]
        if w <= 0:
            continue
        got: list[int] = []
        # racks sorted: preference order, then most-free-first (best fit for
        # locality), single rack if it fits entirely
        racks = sorted(
            rack_pref, key=lambda r: (-(len(free[r]) >= w - len(got)), -len(free[r]))
        )
        for r in racks:
            while free[r] and len(got) < w:
                got.append(free[r].pop(0))
            if len(got) == w:
                break
        if len(got) < w:
            return None
        placements[job.job_id] = tuple(sorted(got))
    return placements
