"""Cluster schedulers: Themis, Pollux, Random, Ideal + CASSINI wrapper."""

from .base import ClusterState, Decision, Scheduler, pack_placement
from .baselines import IdealScheduler, RandomScheduler
from .cassini_augmented import CassiniAugmented
from .pollux import PolluxScheduler
from .themis import ThemisScheduler

__all__ = [
    "ClusterState",
    "Decision",
    "Scheduler",
    "pack_placement",
    "ThemisScheduler",
    "PolluxScheduler",
    "RandomScheduler",
    "IdealScheduler",
    "CassiniAugmented",
]
