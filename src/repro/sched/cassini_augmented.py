"""CASSINI augmentation of a host scheduler (paper §4.2, Fig. 7).

``CassiniAugmented(host)`` keeps the host's worker allocation untouched
(CASSINI "respects the hyper-parameters decided by Themis"), asks the host
for up to N candidate placements, scores them with the CASSINI module
(Algorithm 2) and returns the top placement together with unique per-job
time-shifts (Algorithm 1).

Since the engine redesign this class is a thin wrapper over
:class:`repro.engine.SchedulingPipeline`: Allocate and Propose delegate to
the host, Score runs the batched candidate scoring, Align emits a typed
:class:`repro.engine.plan.AlignmentPlan` on the returned Decision."""

from __future__ import annotations

from repro.core.plugin import CassiniModule
from repro.sched.base import ClusterState, Decision, PlacementMap, Scheduler

__all__ = ["CassiniAugmented"]


class CassiniAugmented(Scheduler):
    def __init__(
        self,
        host: Scheduler,
        *,
        num_candidates: int = 10,
        precision_deg: float = 5.0,
        quantum_ms: float = 10.0,
        pace_threshold: float = 0.9,
        batched: bool = True,
        seed: int = 0,
        device_reduce: bool = True,
        ragged: bool = True,
        tuned: bool = True,
    ) -> None:
        # pacing (isochronous grid) is only armed for jobs whose every
        # contended link scored >= pace_threshold: holding the grid on a
        # sub-interleavable link burns time on re-alignment (§5.7: "CASSINI
        # avoids placing jobs with low compatibility score on the same
        # link"; when it cannot, the shift is applied once, un-paced).
        self.pace_threshold = pace_threshold
        self.host = host
        self.num_candidates = num_candidates
        # deferred: repro.engine.pipeline imports repro.sched.base, whose
        # package init imports this module — a module-level import here
        # would break `import repro.engine.pipeline` as the first import.
        from repro.engine.pipeline import SchedulingPipeline

        self.module = CassiniModule(
            precision_deg=precision_deg, quantum_ms=quantum_ms, seed=seed,
            device_reduce=device_reduce, ragged=ragged, tuned=tuned,
        )
        self.pipeline = SchedulingPipeline.cassini(
            host,
            num_candidates=num_candidates,
            module=self.module,
            pace_threshold=pace_threshold,
            batched=batched,
        )
        self.name = f"{host.name}+cassini"

    # delegate the host scheduler's own objective ------------------- #
    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        return self.host.allocate_workers(state)

    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        return self.host.propose(state, workers, k)

    # -------------------------------------------------------------- #
    def schedule(self, state: ClusterState) -> Decision:
        return self.pipeline.schedule(state)
