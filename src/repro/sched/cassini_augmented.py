"""CASSINI augmentation of a host scheduler (paper §4.2, Fig. 7).

``CassiniAugmented(host)`` keeps the host's worker allocation untouched
(CASSINI "respects the hyper-parameters decided by Themis"), asks the host
for up to N candidate placements, scores them with the CASSINI module
(Algorithm 2) and returns the top placement together with unique per-job
time-shifts (Algorithm 1)."""

from __future__ import annotations

from repro.core.circle import CommPattern
from repro.core.plugin import CassiniModule, PlacementCandidate
from repro.sched.base import ClusterState, Decision, PlacementMap, Scheduler

__all__ = ["CassiniAugmented"]


class CassiniAugmented(Scheduler):
    def __init__(
        self,
        host: Scheduler,
        *,
        num_candidates: int = 10,
        precision_deg: float = 5.0,
        quantum_ms: float = 10.0,
        pace_threshold: float = 0.9,
        seed: int = 0,
    ) -> None:
        # pacing (isochronous grid) is only armed for jobs whose every
        # contended link scored >= pace_threshold: holding the grid on a
        # sub-interleavable link burns time on re-alignment (§5.7: "CASSINI
        # avoids placing jobs with low compatibility score on the same
        # link"; when it cannot, the shift is applied once, un-paced).
        self.pace_threshold = pace_threshold
        self.host = host
        self.num_candidates = num_candidates
        self.module = CassiniModule(
            precision_deg=precision_deg, quantum_ms=quantum_ms, seed=seed
        )
        self.name = f"{host.name}+cassini"

    # delegate the host scheduler's own objective ------------------- #
    def allocate_workers(self, state: ClusterState) -> dict[str, int]:
        return self.host.allocate_workers(state)

    def propose(
        self, state: ClusterState, workers: dict[str, int], k: int
    ) -> list[PlacementMap]:
        return self.host.propose(state, workers, k)

    # -------------------------------------------------------------- #
    def schedule(self, state: ClusterState) -> Decision:
        workers = self.allocate_workers(state)
        placements = self.propose(state, workers, self.num_candidates)
        if not placements:
            return Decision(placements={})

        topo = state.topology
        by_id = {j.job_id: j for j in state.running}
        patterns: dict[str, CommPattern] = {}
        capacities: dict[str, float] = {}
        candidates: list[PlacementCandidate] = []
        for pl in placements:
            job_links: dict[str, list[str]] = {}
            for jid, servers in pl.items():
                links = topo.job_links(servers)
                job_links[jid] = [l.name for l in links]
                for l in links:
                    capacities[l.name] = l.capacity_gbps
                if jid not in patterns:
                    patterns[jid] = by_id[jid].pattern(num_workers=len(servers))
            candidates.append(PlacementCandidate(job_links=job_links, meta=pl))

        decision = self.module.decide(candidates, patterns, capacities)
        chosen: PlacementMap = decision.top_placement.meta  # the host's map
        return Decision(
            placements=chosen,
            time_shifts_ms=dict(decision.time_shifts_ms),
            compat_score=decision.top_placement.score,
            meta={
                "link_scores": dict(decision.top_placement.link_scores),
                "num_candidates": len(placements),
                "paced_ms": dict(decision.paced_periods_ms),
                "align_ok": {
                    j: s >= self.pace_threshold
                    for j, s in decision.job_min_score.items()
                },
            },
        )
