"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2; unverified].
61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840, 384e top-8."""
import jax.numpy as jnp

from repro.models.common import ArchConfig

# 1.05T parameters: bf16 weights + bf16 Adam moments (≈6.3 TB of state)
# fully sharded over 512 devices ≈ 12.3 GB/device — fits a 16 GB v5e chip;
# fp32 everything would need ≥1024 chips (documented in DESIGN.md).
CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, d_ff=2048,
    vocab=163840, num_experts=384, top_k=8,
    param_dtype=jnp.bfloat16, opt_moments_dtype=jnp.bfloat16,
)
