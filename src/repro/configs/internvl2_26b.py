"""internvl2-26b — InternViT + InternLM2 [arXiv:2404.16821; hf].
The vision frontend is a STUB: input_specs() supplies 1024 precomputed
patch embeddings; the 48L GQA decoder backbone is real."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, d_ff=16384,
    vocab=92553, num_patches=1024,
)
