"""mamba2-1.3b — SSD state-space model [arXiv:2405.21060; unverified].
48L d_model=2048 (attn-free) vocab=50280, ssm_state=128."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)
