"""zamba2-7b — Mamba2 backbone + shared attention [arXiv:2411.15242]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, d_ff=14336,
    vocab=32000, ssm_state=64, ssm_head_dim=64, attn_every=6,
)
