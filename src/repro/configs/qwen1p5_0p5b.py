"""qwen1.5-0.5b — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, d_ff=2816,
    vocab=151936, qkv_bias=True,
)
