"""Assigned-architecture registry: ``get_config(arch_id)`` / ``ARCHS``."""

from importlib import import_module

from repro.models.common import ArchConfig

# arch id (as assigned) -> module name
ARCHS: dict[str, str] = {
    "mamba2-1.3b": "mamba2_1p3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mixtral-8x7b": "mixtral_8x7b",
    "granite-3-8b": "granite_3_8b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "smollm-135m": "smollm_135m",
    "llama3.2-1b": "llama3p2_1b",
    "internvl2-26b": "internvl2_26b",
    "zamba2-7b": "zamba2_7b",
    "whisper-large-v3": "whisper_large_v3",
}


def get_config(arch: str) -> ArchConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    return import_module(f"repro.configs.{ARCHS[arch]}").CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)
