"""whisper-large-v3 — enc-dec, conv frontend stubbed [arXiv:2212.04356].
32 encoder + 32 decoder layers; input_specs() supplies precomputed frame
embeddings (the log-mel+conv frontend is the assignment's STUB)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20, d_ff=5120,
    vocab=51866, enc_layers=32, enc_seq=1500,
)
