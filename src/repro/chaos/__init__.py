"""Deterministic fault injection for the simulator and serve paths.

``repro.chaos`` turns "what survives churn?" into a replayable input: a
:class:`FaultSchedule` is a plain, sorted list of typed fault events —
link capacity cuts, NIC flaps, elastic job resizes, per-phase timing
jitter — either written out explicitly (trace form) or drawn from a
seeded generator.  A :class:`FaultInjector` applies the schedule to a
live :class:`~repro.cluster.network.FluidNetworkSim`; both
:class:`~repro.cluster.simulator.ClusterSimulator` and
:class:`~repro.serve.service.SchedulerService` thread the injector's
next-event time into their event loops at the same point, so a schedule
replays **bit-identically** through either path (pinned by
tests/test_chaos.py on every ``churn-*`` scenario).
"""

from repro.chaos.events import (
    FaultEvent,
    JobResize,
    LinkDegrade,
    LinkDown,
    LinkRecover,
    NicFlap,
    PhaseJitter,
)
from repro.chaos.inject import FaultInjector
from repro.chaos.schedule import FaultSchedule

__all__ = [
    "FaultEvent",
    "FaultInjector",
    "FaultSchedule",
    "JobResize",
    "LinkDegrade",
    "LinkDown",
    "LinkRecover",
    "NicFlap",
    "PhaseJitter",
]
