"""Typed fault events (the chaos vocabulary).

Each event is a frozen value with an ``at_ms`` fluid-clock timestamp;
a :class:`~repro.chaos.schedule.FaultSchedule` is just a sorted tuple of
them.  Two tiers:

- **primitive** events apply directly to the network model
  (``LinkDown``/``LinkDegrade``/``LinkRecover``, ``JobResize``,
  ``PhaseJitter``);
- ``NicFlap`` is a *compound* convenience: schedule resolution expands it
  into a ``LinkDown``+``LinkRecover`` pair on the server's host link.

``realigns`` says whether applying the event should pull the affected
jobs back through Propose→Score→Align immediately (capacity and shape
changes do; a phase-jitter perturbation is exactly the drift the §5.7
agent — and the next epoch — are supposed to absorb, so it does not).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Union

__all__ = [
    "LinkDown",
    "LinkRecover",
    "LinkDegrade",
    "NicFlap",
    "JobResize",
    "PhaseJitter",
    "FaultEvent",
]


@dataclass(frozen=True)
class LinkDown:
    """Link loses all capacity (cable pull / switch port death)."""

    at_ms: float
    link: str
    realigns = True


@dataclass(frozen=True)
class LinkRecover:
    """Link returns to its pristine (pre-fault) capacity."""

    at_ms: float
    link: str
    realigns = True


@dataclass(frozen=True)
class LinkDegrade:
    """Link drops to ``factor`` × pristine capacity (flaky optics /
    autoneg downshift), ``0 < factor < 1``."""

    at_ms: float
    link: str
    factor: float
    realigns = True


@dataclass(frozen=True)
class NicFlap:
    """A server's NIC goes down for ``down_ms`` then recovers — sugar for
    ``LinkDown(host link)`` + ``LinkRecover`` at ``at_ms + down_ms``."""

    at_ms: float
    server: int
    down_ms: float
    realigns = True


@dataclass(frozen=True)
class JobResize:
    """Elastic resize: the job's worker count changes by
    ``delta_workers`` (negative = shrink, e.g. worker preemption or a
    failed host; positive = regrow).  Routed through
    :func:`repro.train.elastic.plan_remesh` so shrinks follow the same
    data-axis remesh the training stack performs."""

    at_ms: float
    job_id: str
    delta_workers: int
    realigns = True


@dataclass(frozen=True)
class PhaseJitter:
    """Per-iteration timing perturbation (psim-style measured ``deltas``):
    the job's next phase slips by ``delta_ms`` (may be negative)."""

    at_ms: float
    job_id: str
    delta_ms: float
    realigns = False


FaultEvent = Union[
    LinkDown, LinkRecover, LinkDegrade, NicFlap, JobResize, PhaseJitter
]
