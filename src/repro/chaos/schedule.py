"""Fault schedules: the replayable trace of what goes wrong, and when.

A :class:`FaultSchedule` is a value — an ``at_ms``-sorted tuple of typed
events from :mod:`repro.chaos.events`.  Build one explicitly (trace form:
``FaultSchedule.of(LinkDown(60_000, "up:r0-sp0"), …)``) or from one of
the seeded generators (``linkfail`` / ``elastic`` / ``jitter``), which
draw every fault from a private ``random.Random(seed)`` so the same
arguments always produce the same schedule.

**Determinism contract.**  The schedule is generated entirely *up front*
— no randomness is consumed during simulation — and events fire at
fluid-clock times that both the batch simulator and the serve loop step
to exactly (their event loops take ``min(next arrival, next epoch, next
fault, bound)``).  Replaying one schedule through
``ClusterSimulator.run`` and through ``SchedulerService`` therefore
applies the identical float mutations in the identical order, which is
what makes the two paths' decisions and metrics bit-identical
(tests/test_chaos.py pins this on every ``churn-*`` scenario).
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import TYPE_CHECKING, Sequence

from repro.chaos.events import (
    FaultEvent,
    JobResize,
    LinkDegrade,
    LinkDown,
    LinkRecover,
    NicFlap,
    PhaseJitter,
)

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.job import Job
    from repro.cluster.topology import Topology

__all__ = ["FaultSchedule"]


@dataclass(frozen=True)
class FaultSchedule:
    """An ``at_ms``-sorted, validated tuple of fault events."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self) -> None:
        for ev in self.events:
            if ev.at_ms < 0:
                raise ValueError(f"fault before t=0: {ev!r}")
            if isinstance(ev, LinkDegrade) and not 0.0 < ev.factor < 1.0:
                raise ValueError(
                    f"LinkDegrade factor must be in (0, 1): {ev!r}"
                )
            if isinstance(ev, NicFlap) and ev.down_ms <= 0:
                raise ValueError(f"NicFlap needs down_ms > 0: {ev!r}")
        # stable sort: same-timestamp events keep their authored order
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda ev: ev.at_ms)),
        )

    # ------------------------------------------------------------- #
    @classmethod
    def of(cls, *events: FaultEvent) -> "FaultSchedule":
        """Explicit trace form."""
        return cls(tuple(events))

    @property
    def empty(self) -> bool:
        return not self.events

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def resolve(self, topo: "Topology") -> tuple[FaultEvent, ...]:
        """Expand compound events into primitives, re-sorted by time.

        ``NicFlap`` becomes a ``LinkDown``/``LinkRecover`` pair on the
        server's host link; everything else passes through.  The sort is
        stable on (time, authored position), so resolution is itself
        deterministic.
        """
        prim: list[tuple[float, int, int, FaultEvent]] = []
        for seq, ev in enumerate(self.events):
            if isinstance(ev, NicFlap):
                link = topo.host_link(ev.server).name
                prim.append((ev.at_ms, seq, 0, LinkDown(ev.at_ms, link)))
                up = ev.at_ms + ev.down_ms
                prim.append((up, seq, 1, LinkRecover(up, link)))
            else:
                prim.append((ev.at_ms, seq, 0, ev))
        prim.sort(key=lambda t: t[:3])
        return tuple(p[3] for p in prim)

    # ---------------------- seeded generators --------------------- #
    @classmethod
    def linkfail(
        cls,
        topo: "Topology",
        *,
        seed: int,
        horizon_ms: float,
        events: int = 6,
        outage_frac: tuple[float, float] = (0.04, 0.12),
        degrade_prob: float = 0.4,
    ) -> "FaultSchedule":
        """Seeded link-failure churn: ``events`` independent incidents on
        distinct links, each a full outage (down → recover) or, with
        ``degrade_prob``, a degrade to 30–70 % capacity (→ recover).
        Incidents land in the middle 10–80 % of the horizon so the first
        placements and the tail drain stay fault-free."""
        rng = random.Random(seed)
        names = list(topo.links)
        rng.shuffle(names)
        out: list[FaultEvent] = []
        for name in names[: max(0, events)]:
            at = rng.uniform(0.10, 0.80) * horizon_ms
            outage = rng.uniform(*outage_frac) * horizon_ms
            if rng.random() < degrade_prob:
                out.append(LinkDegrade(at, name, rng.uniform(0.3, 0.7)))
            else:
                out.append(LinkDown(at, name))
            out.append(LinkRecover(at + outage, name))
        return cls(tuple(out))

    @classmethod
    def elastic(
        cls,
        jobs: Sequence["Job"],
        *,
        seed: int,
        horizon_ms: float,
        resizes: int = 6,
        dwell_frac: tuple[float, float] = (0.08, 0.20),
    ) -> "FaultSchedule":
        """Seeded elastic churn: ``resizes`` distinct multi-worker jobs
        each shrink by 1..(workers−1) mid-run and regrow to their
        original size after a dwell — the shrink/regrow pair the
        ``train/elastic.py`` remesh models."""
        rng = random.Random(seed)
        pool = [j for j in jobs if j.num_workers >= 2]
        rng.shuffle(pool)
        out: list[FaultEvent] = []
        for job in pool[: max(0, resizes)]:
            drop = rng.randint(1, job.num_workers - 1)
            at = max(
                job.arrival_ms + 1.0, rng.uniform(0.15, 0.65) * horizon_ms
            )
            out.append(JobResize(at, job.job_id, -drop))
            back = at + rng.uniform(*dwell_frac) * horizon_ms
            out.append(JobResize(back, job.job_id, drop))
        return cls(tuple(out))

    @classmethod
    def jitter(
        cls,
        jobs: Sequence["Job"],
        *,
        seed: int,
        horizon_ms: float,
        magnitude_ms: float,
        events: int = 48,
    ) -> "FaultSchedule":
        """Seeded timing-perturbation replay: ``events`` phase slips drawn
        uniformly over the middle of the horizon, each targeting a random
        job with a ``gauss(0, magnitude_ms)`` delta — psim's measured
        per-iteration ``deltas`` as a replayable trace.  A zero magnitude
        yields the empty schedule (the robustness curves' baseline
        point)."""
        if magnitude_ms <= 0 or not jobs:
            return cls(())
        rng = random.Random(seed)
        ids = [j.job_id for j in jobs]
        out: list[FaultEvent] = []
        for _ in range(max(0, events)):
            at = rng.uniform(0.05, 0.95) * horizon_ms
            jid = rng.choice(ids)
            out.append(PhaseJitter(at, jid, rng.gauss(0.0, magnitude_ms)))
        return cls(tuple(out))
