"""Apply a :class:`FaultSchedule` to a live fluid-network simulation.

One injector per run: it resolves the schedule against the run's topology
(expanding ``NicFlap`` into its down/recover pair), snapshots the
pristine link capacities so ``LinkRecover``/``LinkDegrade`` are defined
relative to the *pre-fault* fabric (stacked faults on one link cannot
compound), and then hands the event loop two things:

- :attr:`next_ms` — the fluid-clock time of the next unapplied event,
  which the loop folds into its ``min(arrival, epoch, fault, bound)``
  step target;
- :meth:`apply_due` — apply everything due at ``now``; returns whether
  any applied event wants an immediate re-alignment pass (capacity and
  shape changes do, phase jitter is left for the §5.7 agent / the next
  epoch to absorb).

Events that target state that no longer exists — a resize for a job that
already finished, jitter for a job not currently placed — are *skipped
and counted*, never raised: a fault schedule is environment, not input
validation.  Both event loops call this at the same point with the same
clock, so a schedule replays bit-identically through the batch simulator
and the serve service.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Iterable

from repro.chaos.events import (
    FaultEvent,
    JobResize,
    LinkDegrade,
    LinkDown,
    LinkRecover,
    PhaseJitter,
)
from repro.chaos.schedule import FaultSchedule
from repro.cluster.errors import UnknownJobError
from repro.cluster.job import Job, JobState
from repro.train.elastic import plan_remesh

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.network import FluidNetworkSim

__all__ = ["FaultInjector", "DOWN_GBPS"]

_EPS = 1e-9

# A "down" link keeps a 1 Mbps trickle instead of a hard zero: jobs
# crossing it are effectively stalled (a 1-Gbit phase would take ~17 min),
# but every rate/score stays finite — the geometric scorer (Eq. 2 divides
# by capacity) prices candidates over the dead link as enormously negative
# and routes around it, which is the network-aware behaviour the churn
# scenarios exist to measure, rather than crashing on a 0-capacity link.
DOWN_GBPS = 1e-3


class FaultInjector:
    """Stateful cursor over one schedule's resolved events."""

    def __init__(self, net: "FluidNetworkSim", schedule: FaultSchedule) -> None:
        self.net = net
        self._events = schedule.resolve(net.topo)
        self._i = 0
        # pristine capacities: recover/degrade targets, immune to stacking
        self._orig = net.topo.link_capacities.copy()
        self.applied: list[FaultEvent] = []
        self.skipped: int = 0
        self.remesh_plans: list = []  # RemeshPlan per applied shrink

    # ------------------------------------------------------------- #
    @property
    def next_ms(self) -> float:
        """Fluid-clock time of the next unapplied event (inf when done)."""
        if self._i < len(self._events):
            return self._events[self._i].at_ms
        return math.inf

    @property
    def applied_count(self) -> int:
        return len(self.applied)

    def apply_due(self, now_ms: float, jobs: Iterable[Job]) -> bool:
        """Apply every event with ``at_ms <= now``; True if any applied
        event requests an immediate re-alignment pass."""
        realign = False
        by_id: dict[str, Job] | None = None
        while (
            self._i < len(self._events)
            and self._events[self._i].at_ms <= now_ms + _EPS
        ):
            ev = self._events[self._i]
            self._i += 1
            if by_id is None:
                by_id = {j.job_id: j for j in jobs}
            if self._apply(ev, by_id):
                self.applied.append(ev)
                realign = realign or ev.realigns
            else:
                self.skipped += 1
        return realign

    # ------------------------------------------------------------- #
    def _apply(self, ev: FaultEvent, by_id: dict[str, Job]) -> bool:
        net = self.net
        if isinstance(ev, LinkDown):
            net.set_link_capacity(ev.link, DOWN_GBPS)
            return True
        if isinstance(ev, LinkDegrade):
            pristine = self._orig[net.topo.link_ids[ev.link]]
            net.set_link_capacity(ev.link, pristine * ev.factor)
            return True
        if isinstance(ev, LinkRecover):
            pristine = self._orig[net.topo.link_ids[ev.link]]
            net.set_link_capacity(ev.link, pristine)
            return True
        if isinstance(ev, JobResize):
            job = by_id.get(ev.job_id)
            if job is None or job.state in (JobState.DONE, JobState.CUTOFF):
                return False
            old = job.num_workers
            if ev.delta_workers < 0:
                # shrink = device failure: route through the training
                # stack's remesh planner (data axis shrinks first)
                failed = min(-ev.delta_workers, old - 1)
                if failed <= 0:
                    return False
                plan = plan_remesh((old,), ("data",), failed)
                new = 1
                for s in plan.new_shape:
                    new *= s
                self.remesh_plans.append(plan)
            else:
                new = old + ev.delta_workers
            if new == old:
                return False
            job.num_workers = new
            return True
        if isinstance(ev, PhaseJitter):
            try:
                net.perturb_job(ev.job_id, ev.delta_ms)
            except UnknownJobError:
                return False  # not currently placed (pending/finished)
            return True
        raise TypeError(f"unknown fault event {ev!r}")  # pragma: no cover
