"""Device-sharded batched water-filling for independent components.

The incremental re-solver (:mod:`repro.cluster.network`,
``_solve_alloc_incremental``) decomposes every dirty re-fill into
connected components of the (member job x binding link) graph —
components share no links and no jobs, so their progressive-filling
cascades are mutually independent.  The fused path solves their union in
one ``_wf_fill_core`` call on the host; this module instead solves the
components as *rows of a batch*:

- each component becomes one (caps, binding-matrix, link-limit) row,
- rows are grouped into fixed power-of-two **buckets** by padded
  (members, links) shape so the jit cache stays small and stable,
- every bucket dispatches as ONE ``vmap``-batched fill, and
- with more than one device the bucket's row axis is split across
  ``jax.devices()`` with ``shard_map`` (transparent single-device
  fallback: the same jitted fill without the mesh).

Padding invariants (see docs/architecture.md "Device sharding"):

- padded members carry ``cap = +inf`` and ``valid = False`` — they start
  frozen, bind no links, and their output rate is discarded;
- padded links have an all-False binding column, so their live count is
  0 and their water level pins at ``+inf`` (never the round minimum);
- padded rows are entirely invalid and exit the fill loop immediately.

The per-row fill mirrors ``_wf_fill_core``'s absolute-water-level
recurrence (cap-batch freezes vs link-saturation freezes against the
same ``1e-300``-floored remaining/live ratio), recomputing per-link
used/live from the frozen mask each round instead of maintaining
decrements — algebraically the same quantities, so results agree with
the fused path inside the documented 1e-9 tolerance band.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache, partial

import numpy as np

_EPS = 1e-9

# Below this many dirty components a batch dispatch cannot amortise its
# device round-trip — callers should keep the fused host fill instead.
MIN_COMPONENTS = 4

# Floor bucket dims: merging tiny components into one shape avoids a
# recompile per distinct 2-member/3-link shape.
_MIN_MEMBERS = 8
_MIN_LINKS = 8


def device_count() -> int:
    """Host-visible device count (1 when jax is unavailable)."""
    try:
        import jax

        return len(jax.devices())
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return 1


def _pow2ceil(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


@dataclass
class ShardStats:
    """Telemetry for one or more sharded fill dispatches."""

    dispatches: int = 0  # batched bucket launches
    components: int = 0  # component rows solved on device
    padded_rows: int = 0  # all-invalid rows added for the device split
    fused_fills: int = 0  # fills kept on the host (below MIN_COMPONENTS)
    devices: int = 1  # device count used by the last dispatch
    bucket_shapes: set = field(default_factory=set)  # distinct (M, L)

    def merge(self, other: "ShardStats") -> None:
        self.dispatches += other.dispatches
        self.components += other.components
        self.padded_rows += other.padded_rows
        self.fused_fills += other.fused_fills
        self.devices = other.devices
        self.bucket_shapes |= other.bucket_shapes


def _fill_row(caps, bmat, limit, valid, jnp, lax):
    """One component's progressive filling at fixed (M, L) shape.

    ``caps``    (M,)  member demand caps (+inf on padding)
    ``bmat``    (M,L) member-uses-link incidence as float64 0/1
                      (all-zero on padding rows/columns)
    ``limit``   (L,)  per-link capacity x congestion efficiency
    ``valid``   (M,)  real-member mask

    Returns (M,) rates; padding positions hold 0.

    Link remaining-capacity / live-count state is carried through the
    loop and decremented by one ``newly-frozen @ bmat`` matvec per round
    — the same ±decrement recurrence as the fused host fill, so float
    behaviour tracks it closely (both start from ``limit`` and subtract
    the identical per-member rates).
    """
    m = caps.shape[0]
    inf = jnp.inf

    def cond(state):
        rates, frozen, rem, lv, r_cur, done, rounds = state
        return (~done) & jnp.any(valid & ~frozen) & (rounds <= m + 1)

    def body(state):
        rates, frozen, rem, lv, r_cur, done, rounds = state
        # drained links (lv 0) pin at +inf; the 1e-300 floor keeps float
        # drift in rem from producing -inf/NaN levels
        level = jnp.where(lv > 0.5, jnp.maximum(rem, 1e-300) / lv, inf)
        s = jnp.min(level)
        cap_unf = jnp.where(valid & ~frozen, caps, inf)
        cap_first = jnp.min(cap_unf) <= s + _EPS
        # cap-batch freeze: every unfrozen cap <= S takes its final rate
        # now (freezing a user below a link's level only raises it)
        newly_cap = valid & ~frozen & (caps <= s + _EPS)
        # link-saturation freeze: unfrozen users of every argmin link
        sat = (level == s).astype(caps.dtype)
        newly_sat = valid & ~frozen & (bmat @ sat > 0.5)
        # stuck: no finite level and no cap to take (defensive — a finite
        # S always has a live user while rem/lv track the fused fill)
        stuck = (~cap_first) & (jnp.isinf(s) | ~jnp.any(newly_sat))
        newly = jnp.where(
            stuck, False, jnp.where(cap_first, newly_cap, newly_sat)
        )
        vals = jnp.where(cap_first, caps, s)
        r_new = jnp.where(
            cap_first,
            jnp.maximum(r_cur, jnp.max(jnp.where(newly_cap, caps, -inf))),
            s,
        )
        r_cur = jnp.where(stuck, r_cur, r_new)
        rates = jnp.where(newly, vals, rates)
        frozen = frozen | newly
        newf = newly.astype(caps.dtype)
        rem = rem - (newf * vals) @ bmat
        lv = lv - newf @ bmat
        return rates, frozen, rem, lv, r_cur, stuck, rounds + 1

    rates0 = jnp.zeros_like(caps)
    frozen0 = ~valid
    rem0 = limit
    lv0 = valid.astype(caps.dtype) @ bmat
    state = (
        rates0, frozen0, rem0, lv0,
        jnp.float64(0.0), jnp.bool_(False), jnp.int32(0),
    )
    rates, frozen, _, _, r_cur, _, _ = lax.while_loop(cond, body, state)
    # residual unfrozen members ride at the last water level
    rates = jnp.where(valid & ~frozen, r_cur, rates)
    return jnp.where(valid, rates, 0.0)


@lru_cache(maxsize=None)
def _bucket_fill(ndev: int):
    """Compiled batched fill for ``ndev`` devices (jit caches per shape).

    ``ndev == 1`` is a plain ``jit(vmap(fill))``; ``ndev > 1`` wraps the
    vmapped fill in ``shard_map`` over a 1-d device mesh, splitting the
    row axis.  Row counts must be a multiple of ``ndev`` (callers pad
    with all-invalid rows).
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    fill = partial(_fill_row, jnp=jnp, lax=lax)
    batched = jax.vmap(fill)
    if ndev <= 1:
        return jax.jit(batched)

    from jax.sharding import Mesh, PartitionSpec as P

    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:  # pragma: no cover - newer jax moved it
        from jax.shard_map import shard_map  # type: ignore[no-redef]

    mesh = Mesh(np.array(jax.devices()[:ndev]), axis_names=("rows",))
    spec = P("rows")
    sharded = shard_map(
        batched,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=spec,
        check_rep=False,
    )
    return jax.jit(sharded)


def batched_fill(rows, ndev: int | None = None):
    """Solve independent component rows as bucketed batched fills.

    ``rows`` is a sequence of ``(caps, bmat, limit)`` numpy triples, one
    per component: member demand caps ``(m,)``, boolean member x link
    incidence ``(m, l)``, and per-link fill limits ``(l,)``.  Returns
    ``(rates, stats)`` where ``rates[i]`` is the ``(m_i,)`` float64 rate
    vector for row ``i`` and ``stats`` is a :class:`ShardStats`.

    ``ndev`` overrides the device count (tests use 1 to pin the
    single-device fallback and assert device-count invariance).
    """
    from jax.experimental import enable_x64

    if ndev is None:
        ndev = device_count()
    ndev = max(1, int(ndev))

    stats = ShardStats(devices=ndev)
    buckets: dict[tuple[int, int], list[int]] = {}
    for i, (caps, bmat, limit) in enumerate(rows):
        key = (
            max(_MIN_MEMBERS, _pow2ceil(caps.shape[0])),
            max(_MIN_LINKS, _pow2ceil(limit.shape[0])),
        )
        buckets.setdefault(key, []).append(i)

    out: list[np.ndarray | None] = [None] * len(rows)
    with enable_x64():
        for (mpad, lpad), members in sorted(buckets.items()):
            r = len(members)
            rpad = -(-r // ndev) * ndev if ndev > 1 else r
            caps_b = np.full((rpad, mpad), np.inf, dtype=np.float64)
            bmat_b = np.zeros((rpad, mpad, lpad), dtype=np.float64)
            lim_b = np.full((rpad, lpad), np.inf, dtype=np.float64)
            val_b = np.zeros((rpad, mpad), dtype=bool)
            for j, i in enumerate(members):
                caps, bmat, limit = rows[i]
                m, l = bmat.shape
                caps_b[j, :m] = caps
                bmat_b[j, :m, :l] = bmat
                lim_b[j, :l] = limit
                val_b[j, :m] = True
            filled = np.asarray(_bucket_fill(ndev)(caps_b, bmat_b, lim_b, val_b))
            for j, i in enumerate(members):
                m = rows[i][0].shape[0]
                out[i] = filled[j, :m]
            stats.dispatches += 1
            stats.components += r
            stats.padded_rows += rpad - r
            stats.bucket_shapes.add((mpad, lpad))
    return out, stats
