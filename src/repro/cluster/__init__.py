"""Cluster substrate: topology, jobs, fluid network model, simulator, traces."""

from .ideal import ideal_metrics
from .job import Job, JobState
from .network import FluidNetworkSim, Segment, segments_from_pattern
from .simulator import ClusterSimulator, Metrics
from .topology import Link, Topology
from .traces import dynamic_trace, poisson_trace, snapshot_trace

__all__ = [
    "Job",
    "JobState",
    "FluidNetworkSim",
    "Segment",
    "segments_from_pattern",
    "ClusterSimulator",
    "Metrics",
    "Link",
    "Topology",
    "poisson_trace",
    "dynamic_trace",
    "snapshot_trace",
    "ideal_metrics",
]
