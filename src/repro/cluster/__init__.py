"""Cluster substrate: topology, jobs, fluid network model, simulator, traces."""

from .ideal import ideal_metrics
from .job import Job, JobState
from .network import FluidNetworkSim, Segment, segments_from_pattern
from .shard import ShardStats, batched_fill
from .simulator import ClusterSimulator, Metrics, nearest_rank
from .topology import Link, LinkIncidence, Topology
from .traces import (
    ARRIVAL_PATTERNS,
    arrival_trace,
    contended_snapshot,
    dynamic_trace,
    iter_arrival_trace,
    iter_poisson_trace,
    poisson_trace,
    snapshot_trace,
)

__all__ = [
    "Job",
    "JobState",
    "FluidNetworkSim",
    "Segment",
    "segments_from_pattern",
    "ClusterSimulator",
    "Metrics",
    "nearest_rank",
    "Link",
    "LinkIncidence",
    "Topology",
    "ShardStats",
    "batched_fill",
    "poisson_trace",
    "iter_poisson_trace",
    "dynamic_trace",
    "snapshot_trace",
    "contended_snapshot",
    "arrival_trace",
    "iter_arrival_trace",
    "ARRIVAL_PATTERNS",
    "ideal_metrics",
]
