"""Workload traces (paper §5.1): Poisson, dynamic, snapshot, arrival sweeps.

- *Poisson trace*: job arrivals with exponential inter-arrival times, rate
  calibrated so the average fraction of busy GPUs equals ``load``.
- *Dynamic trace*: a base set of jobs present in the cluster plus a burst
  of new arrivals (the paper triggers DLRM + ResNet50 arrivals).
- *Snapshot trace*: all jobs present at t = 0 (Table 2 experiments).
- *Arrival trace family*: the same job population under parameterized
  arrival processes — homogeneous Poisson, clustered bursts, and a
  diurnally-modulated (non-homogeneous) Poisson — the "varied online
  arrival patterns" axis the online-scheduling literature evaluates
  against (Bao et al.).

All models have equal occurrence probability, training duration is sampled
uniformly in [200, 1000] iterations and the initial worker request in
[1, 12] GPUs — matching §5.1.
"""

from __future__ import annotations

import itertools
import math
import random
from typing import Iterator, Sequence

from repro.cluster.job import Job
from repro.cluster.topology import Topology
from repro.profiles.models import PROFILES, get_profile

__all__ = [
    "poisson_trace",
    "iter_poisson_trace",
    "dynamic_trace",
    "snapshot_trace",
    "contended_snapshot",
    "arrival_trace",
    "iter_arrival_trace",
    "ARRIVAL_PATTERNS",
]


def _mk_job(
    rng: random.Random,
    idx: int,
    arrival_ms: float,
    models: Sequence[str],
    *,
    min_workers: int = 1,
    max_workers: int = 12,
    min_iters: int = 200,
    max_iters: int = 1000,
) -> Job:
    model = rng.choice(list(models))
    return Job(
        job_id=f"j{idx:03d}-{model}",
        model=model,
        num_workers=rng.randint(min_workers, max_workers),
        duration_iters=rng.randint(min_iters, max_iters),
        arrival_ms=arrival_ms,
    )


def iter_poisson_trace(
    topo: Topology,
    *,
    load: float = 0.9,
    num_jobs: int | None = 20,
    models: Sequence[str] | None = None,
    seed: int = 0,
    min_iters: int = 200,
    max_iters: int = 1000,
) -> Iterator[Job]:
    """Generator form of :func:`poisson_trace`: yields jobs one by one in
    arrival order, ``num_jobs=None`` streaming forever.  The RNG stream is
    consumed in exactly the list form's order, so the first ``n`` yielded
    jobs are bit-identical to ``poisson_trace(..., num_jobs=n)`` — serve
    mode can consume an unbounded arrival stream in O(1) memory.
    """
    rng = random.Random(seed)
    models = models or list(PROFILES)
    t = 0.0
    counter = range(num_jobs) if num_jobs is not None else itertools.count()
    for i in counter:
        j = _mk_job(rng, i, t, models, min_iters=min_iters, max_iters=max_iters)
        yield j
        # expected service time of this job (solo): iters × iter_time
        service_ms = j.duration_iters * j.profile.iter_time_ms(j.num_workers)
        # arrival rate so that E[busy gpus] = load × num_gpus:
        #   λ · E[workers·service] = load · G  →  inter-arrival = w·s/(load·G)
        inter = j.num_workers * service_ms / (load * topo.num_gpus)
        t += rng.expovariate(1.0) * inter


def poisson_trace(
    topo: Topology,
    *,
    load: float = 0.9,
    num_jobs: int = 20,
    models: Sequence[str] | None = None,
    seed: int = 0,
    min_iters: int = 200,
    max_iters: int = 1000,
) -> list[Job]:
    """Poisson arrivals targeting ``load`` average GPU occupancy."""
    return list(iter_poisson_trace(
        topo, load=load, num_jobs=num_jobs, models=models, seed=seed,
        min_iters=min_iters, max_iters=max_iters,
    ))


def dynamic_trace(
    topo: Topology,
    *,
    base_models: Sequence[str] = ("vgg19", "wideresnet101", "bert", "gpt1"),
    burst_models: Sequence[str] = ("dlrm", "resnet50"),
    burst_at_ms: float = 120_000.0,
    workers: int = 4,
    iters: int = 400,
    seed: int = 0,
) -> list[Job]:
    """Base jobs at t=0; a burst of new arrivals at ``burst_at_ms`` (§5.3)."""
    rng = random.Random(seed)
    jobs: list[Job] = []
    for i, m in enumerate(base_models):
        jobs.append(
            Job(
                job_id=f"base{i}-{m}",
                model=m,
                num_workers=workers,
                duration_iters=iters + rng.randint(0, 100),
                arrival_ms=0.0,
            )
        )
    for i, m in enumerate(burst_models):
        jobs.append(
            Job(
                job_id=f"burst{i}-{m}",
                model=m,
                num_workers=workers,
                duration_iters=iters,
                arrival_ms=burst_at_ms,
            )
        )
    return jobs


ARRIVAL_PATTERNS = ("poisson", "burst", "diurnal")


def iter_arrival_trace(
    topo: Topology,
    *,
    pattern: str = "poisson",
    load: float = 0.9,
    num_jobs: int | None = 20,
    models: Sequence[str] | None = None,
    seed: int = 0,
    min_iters: int = 200,
    max_iters: int = 1000,
    burst_size: int = 4,
    diurnal_period_ms: float = 1_800_000.0,
    diurnal_depth: float = 0.8,
) -> Iterator[Job]:
    """One job population, three arrival processes (same mean load).

    The job *population* (models, worker counts, durations) is drawn
    exactly like :func:`poisson_trace`; only the arrival-time process
    differs by ``pattern``:

      - ``"poisson"``: homogeneous Poisson — exponential inter-arrival
        gaps sized so E[busy GPUs] = ``load`` × cluster GPUs;
      - ``"burst"``: clustered arrivals — jobs land in bursts of
        ``burst_size`` (everyone in a burst arrives together, the gap
        *between* bursts carries the whole burst's expected inter-arrival
        mass), the worst case for placement fragmentation;
      - ``"diurnal"``: non-homogeneous Poisson with intensity
        ``λ(t) ∝ 1 + depth·sin(2πt/period)`` — each exponential gap is
        stretched by the inverse instantaneous intensity, producing the
        day/night load swing of production clusters.

    All three draw the same RNG stream for the population, so a sweep
    isolates the arrival process itself.

    This is the generator core (``num_jobs=None`` streams forever, in O(1)
    memory); :func:`arrival_trace` materializes it.  The first ``n`` yields
    are bit-identical to the list form with ``num_jobs=n``.
    """
    if pattern not in ARRIVAL_PATTERNS:
        raise ValueError(
            f"unknown arrival pattern {pattern!r}; one of {ARRIVAL_PATTERNS}"
        )
    rng = random.Random(seed)
    models = models or list(PROFILES)
    t = 0.0
    pending_gap = 0.0
    counter = range(num_jobs) if num_jobs is not None else itertools.count()
    for i in counter:
        j = _mk_job(rng, i, t, models, min_iters=min_iters, max_iters=max_iters)
        yield j
        service_ms = j.duration_iters * j.profile.iter_time_ms(j.num_workers)
        inter = j.num_workers * service_ms / (load * topo.num_gpus)
        gap = rng.expovariate(1.0) * inter
        if pattern == "poisson":
            t += gap
        elif pattern == "burst":
            # accumulate each member's gap; release it between bursts so
            # the long-run arrival rate (and thus load) is unchanged
            pending_gap += gap
            if (i + 1) % burst_size == 0:
                t += pending_gap
                pending_gap = 0.0
        else:  # diurnal
            intensity = 1.0 + diurnal_depth * math.sin(
                2.0 * math.pi * t / diurnal_period_ms
            )
            t += gap / max(intensity, 1e-3)


def arrival_trace(
    topo: Topology,
    *,
    pattern: str = "poisson",
    load: float = 0.9,
    num_jobs: int = 20,
    models: Sequence[str] | None = None,
    seed: int = 0,
    min_iters: int = 200,
    max_iters: int = 1000,
    burst_size: int = 4,
    diurnal_period_ms: float = 1_800_000.0,
    diurnal_depth: float = 0.8,
) -> list[Job]:
    """Materialized form of :func:`iter_arrival_trace` (same RNG stream)."""
    return list(iter_arrival_trace(
        topo, pattern=pattern, load=load, num_jobs=num_jobs, models=models,
        seed=seed, min_iters=min_iters, max_iters=max_iters,
        burst_size=burst_size, diurnal_period_ms=diurnal_period_ms,
        diurnal_depth=diurnal_depth,
    ))


def snapshot_trace(
    specs: Sequence[tuple[str, int, int]],
    *,
    iters: int = 300,
) -> list[Job]:
    """All jobs at t=0. ``specs`` = (model, num_workers, batch_per_gpu)."""
    jobs = []
    for i, (model, workers, batch) in enumerate(specs):
        get_profile(model)  # validate name
        jobs.append(
            Job(
                job_id=f"snap{i}-{model}",
                model=model,
                num_workers=workers,
                duration_iters=iters,
                arrival_ms=0.0,
                batch_per_gpu=batch,
            )
        )
    return jobs


def contended_snapshot(
    topology: Topology,
    make_jobs,
    *,
    tenants: int = 2,
    duration_iters: int = 10**9,
) -> list[Job]:
    """A maximally-contended steady state: ``tenants`` copies of a job
    population, all present at t = 0 with effectively infinite durations,
    placed on wrap-around consecutive GPU ranges so ring edges pile onto
    shared host links and rack uplinks.

    The allocator-bound multi-tenant regime the ``fluid_advance``
    benchmarks and the incremental re-solver's rack-scaling parity tests
    share — ``make_jobs`` is called once per tenant and must return a
    fresh population each time (job objects are mutated in place).
    """
    from repro.cluster.job import JobState

    jobs: list[Job] = []
    for t in range(tenants):
        pop = list(make_jobs())
        for j in pop:
            j.job_id = f"t{t}-{j.job_id}"
        jobs.extend(pop)
    cursor, total = 0, topology.num_gpus
    for j in jobs:
        j.arrival_ms = 0.0
        j.duration_iters = duration_iters
        j.placement = tuple(
            (cursor + k) % total for k in range(j.num_workers)
        )
        cursor = (cursor + j.num_workers) % total
        j.state = JobState.RUNNING
    return jobs
