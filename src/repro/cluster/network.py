"""Event-driven fluid model of the cluster fabric.

Each running job executes a cyclic sequence of *segments* derived from its
:class:`~repro.core.circle.CommPattern`:

  - **compute** segments advance in wall-clock time unconditionally,
  - **comm** segments carry a fixed number of Gbits at a demand cap
    (the phase's Gbps); their *achieved* rate is the job's max-min-fair
    share across every link it traverses.

Between events (segment completions / scheduler epochs) all rates are
constant, so the simulator jumps directly to the next completion — an exact
fluid solution, not a time-stepped approximation.  Congestion therefore
manifests exactly as in the paper: jobs whose Up phases collide on a link
get a fraction of the link and their iterations stretch; CASSINI's
time-shifts (applied as one-shot delays before the next iteration) move the
phases apart and restore full-rate communication.

ECN marking model: whenever aggregate *demand* on a link exceeds capacity,
marks accrue at ``ecn_marks_per_gbit`` × excess-bits, attributed to the
jobs on the link in proportion to their demand — the macroscopic behaviour
of DCQCN/WRED marking in the paper's testbed (§5.1).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

from repro.cluster.job import Job, JobState
from repro.cluster.topology import Link, Topology
from repro.core.circle import CommPattern

__all__ = ["Segment", "segments_from_pattern", "FluidNetworkSim"]

_EPS = 1e-9


@dataclass
class Segment:
    """One piecewise-constant piece of a job's iteration cycle."""

    kind: str          # "compute" | "comm"
    duration_ms: float # compute: wall time; comm: duration at full demand
    gbps: float = 0.0  # comm demand cap

    @property
    def gbits(self) -> float:
        return self.gbps * self.duration_ms * 1e-3


def segments_from_pattern(pattern: CommPattern) -> list[Segment]:
    """Convert a (possibly overlapping-phase) pattern into alternating
    compute/comm segments with piecewise-constant demand."""
    t = pattern.iter_time_ms
    points = {0.0, t}
    for ph in pattern.phases:
        points.add(ph.start_ms % t)
        points.add(min((ph.start_ms % t) + ph.duration_ms, t))
        if (ph.start_ms % t) + ph.duration_ms > t:  # wrapped phase
            points.add(((ph.start_ms % t) + ph.duration_ms) % t)
    cuts = sorted(points)
    segs: list[Segment] = []
    for a, b in zip(cuts, cuts[1:]):
        if b - a < _EPS:
            continue
        mid = 0.5 * (a + b)
        level = float(pattern.demand_at(mid))
        if segs and (segs[-1].gbps - level) == 0.0 and (level > 0) == (segs[-1].kind == "comm"):
            segs[-1].duration_ms += b - a
        elif level > _EPS:
            segs.append(Segment("comm", b - a, level))
        else:
            segs.append(Segment("compute", b - a))
    if not segs:
        segs.append(Segment("compute", t))
    return segs


# ---------------------------------------------------------------------- #
@dataclass
class _JobExec:
    """Mutable execution state of one running job."""

    job: Job
    segments: list[Segment]
    links: list[Link]
    seg_idx: int = 0
    remaining: float = 0.0        # compute: ms left; comm: Gbit left
    delay_ms: float = 0.0         # one-shot delay before next segment runs
    iter_start_ms: float = 0.0
    marks: float = 0.0            # ECN marks accumulated this iteration
    # CASSINI drift-adjustment agent (paper §4.2 step 3, §5.7):
    solo_iter_ms: float = 0.0
    paced_iter_ms: float = 0.0          # isochronous grid period (≥ solo)
    ideal_next_ms: float | None = None  # armed only for aligned jobs
    applied_shift_ms: float = 0.0       # shift already realized by delays
    consec_adjust: int = 0              # disarm guard
    skip_record: bool = False           # one-shot setup delay in this iter

    def reset_segment(self) -> None:
        seg = self.segments[self.seg_idx]
        self.remaining = seg.duration_ms if self.kind == "compute" or not self.links else seg.gbits

    @property
    def kind(self) -> str:
        return self.segments[self.seg_idx].kind

    @property
    def cap_gbps(self) -> float:
        return self.segments[self.seg_idx].gbps


class FluidNetworkSim:
    """Exact event-driven fluid simulation of jobs sharing the fabric."""

    def __init__(
        self,
        topology: Topology,
        *,
        ecn_marks_per_gbit: float = 1000.0,
        compute_jitter: float = 0.0,
        migration_pause_ms: float = 1000.0,
        drift_tolerance: float = 0.05,
        congested_efficiency: float = 0.88,
        seed: int = 0,
    ) -> None:
        # DCQCN under congestion does not achieve the full link rate: the
        # paper's own Fig. 2(b) measures two competing jobs at ~22 Gbps each
        # on a 50 Gbps link (~88 %).  When aggregate demand exceeds capacity
        # the contended link delivers capacity × this factor.
        self.congested_efficiency = congested_efficiency
        self.topo = topology
        self.drift_tolerance = drift_tolerance
        self.ecn_marks_per_gbit = ecn_marks_per_gbit
        self.compute_jitter = compute_jitter
        self.migration_pause_ms = migration_pause_ms
        self._rng = random.Random(seed)
        self.now_ms: float = 0.0
        self._execs: dict[str, _JobExec] = {}

    # -------------------------------------------------------------- #
    def configure(self, jobs: list[Job]) -> None:
        """(Re)configure the running set after a scheduling decision.

        Jobs keep their identity across epochs; a job whose placement
        changed pays ``migration_pause_ms`` (checkpoint-restore) and every
        job (re)starts its cycle at its (new) time-shift delay.  All CASSINI
        inputs come off the job's typed ``alignment`` directive
        (:class:`repro.engine.plan.JobAlignment`): the cumulative shift
        target, whether the pacing agent holds the isochronous grid, and
        the grid period.
        """
        new: dict[str, _JobExec] = {}
        for job in jobs:
            pattern = job.pattern()
            segs = segments_from_pattern(pattern)
            links = self.topo.job_links(job.placement)
            prev = self._execs.get(job.job_id)
            align = job.alignment
            ex = _JobExec(
                job=job, segments=segs, links=links,
                solo_iter_ms=pattern.iter_time_ms,
                paced_iter_ms=align.paced_period_ms or pattern.iter_time_ms,
            )
            migrated = prev is not None and prev.links != links
            if prev is None or migrated:
                ex.delay_ms = (self.migration_pause_ms if migrated else 0.0)
                ex.delay_ms += align.shift_ms
                ex.applied_shift_ms = align.shift_ms
                ex.iter_start_ms = self.now_ms
                ex.seg_idx = 0
                ex.reset_segment()
                # the migration pause / initial shift is a one-shot setup
                # cost, not an iteration time: exclude it from the CDF
                ex.skip_record = ex.delay_ms > _EPS
                if align.hold:
                    ex.ideal_next_ms = self.now_ms + ex.delay_ms + ex.paced_iter_ms
            else:
                # same placement: keep mid-iteration progress.  A shift from
                # this epoch's decision is applied as the *delta* against the
                # shift this worker has already realized (re-sending the same
                # shift must be a no-op).
                ex.seg_idx = prev.seg_idx
                ex.remaining = prev.remaining
                ex.iter_start_ms = prev.iter_start_ms
                ex.marks = prev.marks
                ex.delay_ms = prev.delay_ms
                ex.applied_shift_ms = prev.applied_shift_ms
                ex.ideal_next_ms = prev.ideal_next_ms
                ex.consec_adjust = prev.consec_adjust
                ex.skip_record = prev.skip_record
                if job.shift_pending:
                    delta = (align.shift_ms - prev.applied_shift_ms) % ex.solo_iter_ms
                    if delta > _EPS and (ex.solo_iter_ms - delta) > _EPS:
                        ex.delay_ms += delta
                        ex.skip_record = True
                        if ex.ideal_next_ms is not None:
                            ex.ideal_next_ms += delta
                    ex.applied_shift_ms = align.shift_ms
                # (re)arm / disarm the alignment agent (§5.7)
                if align.hold and ex.ideal_next_ms is None:
                    ex.ideal_next_ms = ex.iter_start_ms + ex.delay_ms + ex.paced_iter_ms
                    ex.consec_adjust = 0
                elif not align.hold:
                    ex.ideal_next_ms = None
            job.shift_pending = False
            if job.start_ms is None:
                job.start_ms = self.now_ms
            new[job.job_id] = ex
        self._execs = new

    # -------------------------------------------------------------- #
    def _comm_jobs(self) -> dict[str, _JobExec]:
        """Jobs currently competing for link bandwidth: in a comm segment,
        not delayed, and not horizon-expired — a ``JobState.CUTOFF`` job has
        stopped training and must not consume link share or attract marks."""
        return {
            jid: ex
            for jid, ex in self._execs.items()
            if ex.kind == "comm" and ex.delay_ms <= _EPS and ex.links
            and ex.job.state is not JobState.CUTOFF
        }

    def _allocate(self) -> dict[str, float]:
        """Max-min-fair rates (Gbps) for jobs currently in a comm segment,
        respecting per-segment demand caps (progressive filling)."""
        comm = self._comm_jobs()
        rates = {jid: 0.0 for jid in comm}
        if not comm:
            return rates
        remaining = {}
        users: dict[str, list[str]] = {}
        demand: dict[str, float] = {}
        caps: dict[str, float] = {}
        for jid, ex in comm.items():
            for l in ex.links:
                users.setdefault(l.name, []).append(jid)
                demand[l.name] = demand.get(l.name, 0.0) + ex.cap_gbps
                caps[l.name] = l.capacity_gbps
        for lname, cap in caps.items():
            eff = self.congested_efficiency if demand[lname] > cap + _EPS else 1.0
            remaining[lname] = cap * eff
        unfrozen = set(comm)
        while unfrozen:
            # next increment: smallest of (per-link equal share, cap slack)
            inc = math.inf
            for lname, js in users.items():
                live = [j for j in js if j in unfrozen]
                if live:
                    inc = min(inc, remaining[lname] / len(live))
            for j in unfrozen:
                inc = min(inc, comm[j].cap_gbps - rates[j])
            if inc is math.inf or inc < 0:
                break
            for j in unfrozen:
                rates[j] += inc
            for lname, js in users.items():
                live = sum(1 for j in js if j in unfrozen)
                remaining[lname] -= inc * live
            newly_frozen = {
                j for j in unfrozen if comm[j].cap_gbps - rates[j] <= _EPS
            }
            for lname, js in users.items():
                if remaining[lname] <= _EPS:
                    newly_frozen |= {j for j in js if j in unfrozen}
            if not newly_frozen:
                break
            unfrozen -= newly_frozen
        return rates

    def _mark_rates(self) -> dict[str, float]:
        """ECN marks per ms for each job (demand-over-capacity model)."""
        comm = self._comm_jobs()
        demand: dict[str, float] = {}
        users: dict[str, list[str]] = {}
        caps: dict[str, float] = {}
        for jid, ex in comm.items():
            for l in ex.links:
                demand[l.name] = demand.get(l.name, 0.0) + ex.cap_gbps
                users.setdefault(l.name, []).append(jid)
                caps[l.name] = l.capacity_gbps
        marks = {jid: 0.0 for jid in comm}
        for lname, d in demand.items():
            excess = d - caps[lname]
            if excess <= 0:
                continue
            for jid in users[lname]:
                share = comm[jid].cap_gbps / d
                # Gbit/ms of excess attributed to this job × marks/Gbit
                marks[jid] += excess * share * 1e-3 * self.ecn_marks_per_gbit
        return marks

    # -------------------------------------------------------------- #
    def advance(self, until_ms: float, *, max_events: int = 2_000_000) -> list[Job]:
        """Advance the fluid simulation to ``until_ms`` (exact events).

        Returns as soon as one or more jobs finish their last iteration (so
        the cluster simulator can react to the departure immediately); the
        finished jobs are returned with ``finish_ms`` / ``state`` set.
        """
        finished: list[Job] = []
        events = 0
        while self.now_ms < until_ms - _EPS and self._execs:
            events += 1
            if events > max_events:
                raise RuntimeError("fluid sim exceeded max_events")
            rates = self._allocate()
            marks = self._mark_rates()
            # time to next event for every job; CUTOFF jobs are frozen —
            # they neither bound dt nor make progress (a cutoff job must
            # not finish iterations, flip to DONE, or consume link share)
            dt = until_ms - self.now_ms
            for jid, ex in self._execs.items():
                if ex.job.state is JobState.CUTOFF:
                    continue
                if ex.delay_ms > _EPS:
                    dt = min(dt, ex.delay_ms)
                elif ex.kind == "compute" or not ex.links:
                    dt = min(dt, ex.remaining)
                else:
                    r = rates.get(jid, 0.0)
                    if r > _EPS:
                        dt = min(dt, ex.remaining / r * 1e3)
            dt = max(dt, 1e-6)
            self.now_ms += dt
            # progress everyone by dt (rates constant over the interval)
            for jid, ex in list(self._execs.items()):
                if ex.job.state is JobState.CUTOFF:
                    continue
                if ex.delay_ms > _EPS:
                    ex.delay_ms = max(0.0, ex.delay_ms - dt)
                    continue
                if ex.kind == "compute" or not ex.links:
                    ex.remaining -= dt
                else:
                    ex.remaining -= rates.get(jid, 0.0) * dt * 1e-3
                    ex.marks += marks.get(jid, 0.0) * dt
                if ex.remaining <= _EPS:
                    self._complete_segment(ex)
                    if ex.job.remaining_iters() == 0:
                        ex.job.finish_ms = self.now_ms
                        ex.job.state = JobState.DONE
                        del self._execs[jid]
                        finished.append(ex.job)
            if finished:
                break
        return finished

    # -------------------------------------------------------------- #
    def _complete_segment(self, ex: _JobExec) -> None:
        ex.seg_idx += 1
        if ex.seg_idx >= len(ex.segments):
            # iteration boundary
            job = ex.job
            end = self.now_ms  # dt already chosen to land on the boundary
            if ex.skip_record:
                ex.skip_record = False
            else:
                job.iter_times_ms.append(end - ex.iter_start_ms)
                job.ecn_marks.append(ex.marks)
            job.iters_done += 1
            ex.marks = 0.0
            ex.iter_start_ms = end
            ex.seg_idx = 0
            # CASSINI alignment agent (§4.2 step 3, §5.7).  Aligned jobs run
            # *isochronously* on a grid with the optimizer's (quantized)
            # period: finishing early waits for the next slot (pacing — this
            # is what makes interleaving stable when real iteration times
            # differ slightly from the quantized ones the optimizer saw);
            # drifting late by more than 5 % triggers a re-alignment delay
            # onto the next slot.  Systematically-late jobs (3 consecutive
            # adjustments) disarm — their placement is not interleavable and
            # holding the grid would only burn time.
            if ex.ideal_next_ms is not None:
                drift = end - ex.ideal_next_ms
                if drift <= 0.0:
                    ex.delay_ms += -drift          # pace to the slot
                    ex.consec_adjust = 0
                    ex.ideal_next_ms += ex.paced_iter_ms
                elif drift > self.drift_tolerance * ex.paced_iter_ms:
                    extra = (-drift) % ex.paced_iter_ms
                    ex.delay_ms += extra
                    job.drift_adjustments += 1
                    ex.consec_adjust += 1
                    ex.ideal_next_ms = end + extra + ex.paced_iter_ms
                    if ex.consec_adjust >= 3:
                        ex.ideal_next_ms = None    # disarm
                else:
                    ex.consec_adjust = 0
                    ex.ideal_next_ms += ex.paced_iter_ms
        seg = ex.segments[ex.seg_idx]
        if seg.kind == "compute" or not ex.links:
            jitter = (
                1.0 + self._rng.gauss(0.0, self.compute_jitter)
                if self.compute_jitter > 0
                else 1.0
            )
            ex.remaining = seg.duration_ms * max(0.1, jitter)
        else:
            ex.remaining = seg.gbits

    # -------------------------------------------------------------- #
    def finished_jobs(self) -> list[Job]:
        return [ex.job for ex in self._execs.values() if ex.job.remaining_iters() == 0]
