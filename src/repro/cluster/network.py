"""Event-driven fluid model of the cluster fabric.

Each running job executes a cyclic sequence of *segments* derived from its
:class:`~repro.core.circle.CommPattern`:

  - **compute** segments advance in wall-clock time unconditionally,
  - **comm** segments carry a fixed number of Gbits at a demand cap
    (the phase's Gbps); their *achieved* rate is the job's max-min-fair
    share across every link it traverses.

Between events (segment completions / scheduler epochs) all rates are
constant, so the simulator jumps directly to the next completion — an exact
fluid solution, not a time-stepped approximation.  Congestion therefore
manifests exactly as in the paper: jobs whose Up phases collide on a link
get a fraction of the link and their iterations stretch; CASSINI's
time-shifts (applied as one-shot delays before the next iteration) move the
phases apart and restore full-rate communication.

ECN marking model: whenever aggregate *demand* on a link exceeds capacity,
marks accrue at ``ecn_marks_per_gbit`` × excess-bits, attributed to the
jobs on the link in proportion to their demand — the macroscopic behaviour
of DCQCN/WRED marking in the paper's testbed (§5.1).

Two engines share these semantics bit for bit:

  - the **scalar oracle** (``vectorized=False``): the original pure-Python
    dict-of-dicts progressive-filling loop, re-run at every event — kept
    as the reference the vectorized engine is equivalence-tested against;
  - the **vectorized engine** (``vectorized=True``, the default): job and
    link state lives in numpy arrays keyed by the job×link incidence the
    topology precomputes at ``configure`` (never per event); the max-min
    allocation + ECN marking are solved with vectorized water-filling once
    per *distinct comm-competing set* and cached (segment transitions of
    compute-only jobs hit the cache), and ``advance`` steps every job's
    delay/remaining/marks with batched array updates.  Every float is
    produced by the same IEEE operation in the same order as the scalar
    loop, so rates, event sequences and ``Metrics.summary()`` are
    *identical* — not merely close (tests/test_fluid_vectorized.py).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass

import numpy as np

from repro.cluster.errors import UnknownJobError
from repro.cluster.job import Job, JobState
from repro.cluster.shard import MIN_COMPONENTS as _SHARD_MIN_COMPONENTS
from repro.cluster.shard import ShardStats, batched_fill
from repro.cluster.topology import Link, LinkIncidence, Topology
from repro.core.circle import CommPattern

__all__ = ["Segment", "segments_from_pattern", "FluidNetworkSim"]

# Distinct comm-competing sets cached between two ``configure`` calls are
# bounded in practice (jobs cycle through few segments); this cap only
# guards pathological drift from unbounded memory growth.
_ALLOC_CACHE_MAX = 4096

# Delta solves between from-scratch rebuilds of the incremental solver's
# per-link demand accumulators: bounds float drift from repeated ± deltas
# (each rebuild resets demand to one exact left-to-right bincount sum).
_WF_REFRESH = 64

_EPS = 1e-9


@dataclass
class Segment:
    """One piecewise-constant piece of a job's iteration cycle."""

    kind: str          # "compute" | "comm"
    duration_ms: float # compute: wall time; comm: duration at full demand
    gbps: float = 0.0  # comm demand cap

    @property
    def gbits(self) -> float:
        return self.gbps * self.duration_ms * 1e-3


def segments_from_pattern(pattern: CommPattern) -> list[Segment]:
    """Convert a (possibly overlapping-phase) pattern into alternating
    compute/comm segments with piecewise-constant demand.

    The segments **exactly tile** ``[0, iter_time_ms)``: every cut interval
    contributes its full width to some segment.  Sub-``_EPS`` sliver
    intervals (nearly-coincident cut points from wrapped/overlapping
    phases) are folded into the neighbouring segment's duration instead of
    being dropped — the conservation error of billing a sliver at its
    neighbour's demand level is at most ``gbps × _EPS`` Gbit, while
    dropping it used to leave a tiling gap that desynchronized iteration
    boundaries from ``iter_time_ms`` (tests/test_segments.py pins both the
    tiling and the Gbit-conservation invariants).
    """
    t = pattern.iter_time_ms
    points = {0.0, t}
    for ph in pattern.phases:
        points.add(ph.start_ms % t)
        points.add(min((ph.start_ms % t) + ph.duration_ms, t))
        if (ph.start_ms % t) + ph.duration_ms > t:  # wrapped phase
            points.add(((ph.start_ms % t) + ph.duration_ms) % t)
    cuts = sorted(points)
    segs: list[Segment] = []
    carry = 0.0  # sliver width owed to the next emitted segment
    for a, b in zip(cuts, cuts[1:]):
        if b - a < _EPS:
            # sliver: fold its width into a neighbour, never drop it
            if segs:
                segs[-1].duration_ms += b - a
            else:
                carry += b - a
            continue
        mid = 0.5 * (a + b)
        level = float(pattern.demand_at(mid))
        kind = "comm" if level > _EPS else "compute"
        gbps = level if kind == "comm" else 0.0
        width = (b - a) + carry
        carry = 0.0
        if segs and segs[-1].kind == kind and (segs[-1].gbps - gbps) == 0.0:
            segs[-1].duration_ms += width
        elif kind == "comm":
            segs.append(Segment("comm", width, gbps))
        else:
            segs.append(Segment("compute", width))
    if carry:
        if segs:
            segs[-1].duration_ms += carry
        else:
            segs.append(Segment("compute", carry))
    if not segs:
        segs.append(Segment("compute", t))
    return segs


# ---------------------------------------------------------------------- #
@dataclass
class _JobExec:
    """Mutable execution state of one running job."""

    job: Job
    segments: list[Segment]
    links: list[Link]
    seg_idx: int = 0
    remaining: float = 0.0        # compute: ms left; comm: Gbit left
    delay_ms: float = 0.0         # one-shot delay before next segment runs
    iter_start_ms: float = 0.0
    marks: float = 0.0            # ECN marks accumulated this iteration
    # CASSINI drift-adjustment agent (paper §4.2 step 3, §5.7):
    solo_iter_ms: float = 0.0
    paced_iter_ms: float = 0.0          # isochronous grid period (≥ solo)
    ideal_next_ms: float | None = None  # armed only for aligned jobs
    applied_shift_ms: float = 0.0       # shift already realized by delays
    consec_adjust: int = 0              # disarm guard
    skip_record: bool = False           # one-shot setup delay in this iter

    def reset_segment(self) -> None:
        seg = self.segments[self.seg_idx]
        self.remaining = (
            seg.duration_ms if self.kind == "compute" or not self.links
            else seg.gbits
        )

    @property
    def kind(self) -> str:
        return self.segments[self.seg_idx].kind

    @property
    def cap_gbps(self) -> float:
        return self.segments[self.seg_idx].gbps


class FluidNetworkSim:
    """Exact event-driven fluid simulation of jobs sharing the fabric."""

    def __init__(
        self,
        topology: Topology,
        *,
        ecn_marks_per_gbit: float = 1000.0,
        compute_jitter: float = 0.0,
        migration_pause_ms: float = 1000.0,
        drift_tolerance: float = 0.05,
        congested_efficiency: float = 0.88,
        vectorized: bool = True,
        incremental: bool = False,
        sharded: bool = False,
        seed: int = 0,
    ) -> None:
        # DCQCN under congestion does not achieve the full link rate: the
        # paper's own Fig. 2(b) measures two competing jobs at ~22 Gbps each
        # on a 50 Gbps link (~88 %).  When aggregate demand exceeds capacity
        # the contended link delivers capacity × this factor.
        self.congested_efficiency = congested_efficiency
        self.topo = topology
        self.drift_tolerance = drift_tolerance
        self.ecn_marks_per_gbit = ecn_marks_per_gbit
        self.compute_jitter = compute_jitter
        self.migration_pause_ms = migration_pause_ms
        self._rng = random.Random(seed)
        self.now_ms: float = 0.0
        self._execs: dict[str, _JobExec] = {}
        self.vectorized = vectorized
        # incremental water-filling re-solve (256+-rack fabrics): cache
        # misses delta-update per-link demand/live state from the previous
        # solve and fill only the links that can actually saturate.  Rates
        # then match the scalar oracle within documented tolerance bands
        # rather than bit-exactly; the default (False) keeps the bit-exact
        # from-scratch solve.  Meaningful only on the vectorized engine.
        self.incremental = bool(incremental and vectorized)
        # device-sharded component fills (repro.cluster.shard): dirty
        # components batch into bucketed vmap fills split across
        # jax.devices() with shard_map instead of one fused host fill.
        # Rides on the incremental path's component decomposition, so it
        # is meaningful only with incremental=True; results stay inside
        # the same documented tolerance band.
        self.sharded = bool(sharded and self.incremental)
        # test hook: force the device count seen by the sharded fill
        # (None → len(jax.devices())); the device-count-invariance tests
        # pin that decisions do not depend on this value
        self._shard_devices: int | None = None
        self.shard_stats = ShardStats()
        # telemetry: how many allocations were actually *solved* (cache
        # misses) on the vectorized path — the invalidation tests pin that
        # compute-only segment churn does not grow this — and how many
        # were answered from the cache (serve-mode telemetry)
        self.alloc_solves: int = 0
        self.alloc_hits: int = 0
        # optional psim-style per-link load telemetry (repro.cluster
        # .linkload): None costs nothing; attach_link_recorder wires one
        # into the vectorized event loop
        self.link_recorder = None
        # telemetry: solves answered by the delta path (vs from-scratch
        # state rebuilds within the incremental solver)
        self.alloc_delta_solves: int = 0
        # incremental link-state (see _solve_alloc_incremental)
        self._wf: dict | None = None
        # link ids whose capacity changed since the last incremental solve
        # (fault injection): fed into _wf_delta as extra dirty links so the
        # affected components re-fill against the new capacities
        self._wf_cap_dirty: set[int] = set()
        # array-resident engine state, rebuilt by _build_arrays on configure
        self._slots: list[_JobExec] = []
        self._slot_of: dict[str, int] = {}
        self._inc: LinkIncidence | None = None
        self._alloc_cache: dict[bytes, tuple[np.ndarray, np.ndarray]] = {}
        self._rem = np.zeros(0)
        self._dly = np.zeros(0)
        self._mk = np.zeros(0)
        self._cap_now = np.zeros(0)
        self._segi = np.zeros(0, dtype=np.int32)
        self._is_comm = np.zeros(0, dtype=bool)
        self._alive = np.zeros(0, dtype=bool)

    # -------------------------------------------------------------- #
    def _exec_for(self, job: Job) -> _JobExec:
        """Build job's execution state for this epoch (reading the *current*
        ``_execs`` for its previous state).  Shared verbatim by the
        rebuild (:meth:`configure`) and delta (:meth:`add_job` /
        :meth:`update_job`) paths, so both produce identical execs."""
        pattern = job.pattern()
        segs = segments_from_pattern(pattern)
        links = self.topo.job_links(job.placement)
        prev = self._execs.get(job.job_id)
        align = job.alignment
        ex = _JobExec(
            job=job, segments=segs, links=links,
            solo_iter_ms=pattern.iter_time_ms,
            paced_iter_ms=align.paced_period_ms or pattern.iter_time_ms,
        )
        # a changed segment structure (elastic resize: same placement, new
        # worker count → new pattern) is a remesh: checkpoint-restore like
        # a migration, restarting the cycle at segment 0 — stale seg_idx /
        # remaining from the old segment list would be meaningless
        migrated = prev is not None and (
            prev.links != links or prev.segments != segs
        )
        if prev is None or migrated:
            ex.delay_ms = (self.migration_pause_ms if migrated else 0.0)
            ex.delay_ms += align.shift_ms
            ex.applied_shift_ms = align.shift_ms
            ex.iter_start_ms = self.now_ms
            ex.seg_idx = 0
            ex.reset_segment()
            # the migration pause / initial shift is a one-shot setup
            # cost, not an iteration time: exclude it from the CDF
            ex.skip_record = ex.delay_ms > _EPS
            if align.hold:
                ex.ideal_next_ms = self.now_ms + ex.delay_ms + ex.paced_iter_ms
        else:
            # same placement: keep mid-iteration progress.  A shift from
            # this epoch's decision is applied as the *delta* against the
            # shift this worker has already realized (re-sending the same
            # shift must be a no-op).
            ex.seg_idx = prev.seg_idx
            ex.remaining = prev.remaining
            ex.iter_start_ms = prev.iter_start_ms
            ex.marks = prev.marks
            ex.delay_ms = prev.delay_ms
            ex.applied_shift_ms = prev.applied_shift_ms
            ex.ideal_next_ms = prev.ideal_next_ms
            ex.consec_adjust = prev.consec_adjust
            ex.skip_record = prev.skip_record
            if job.shift_pending:
                delta = (align.shift_ms - prev.applied_shift_ms) % ex.solo_iter_ms
                if delta > _EPS and (ex.solo_iter_ms - delta) > _EPS:
                    ex.delay_ms += delta
                    ex.skip_record = True
                    if ex.ideal_next_ms is not None:
                        ex.ideal_next_ms += delta
                ex.applied_shift_ms = align.shift_ms
            # (re)arm / disarm the alignment agent (§5.7)
            if align.hold and ex.ideal_next_ms is None:
                ex.ideal_next_ms = ex.iter_start_ms + ex.delay_ms + ex.paced_iter_ms
                ex.consec_adjust = 0
            elif not align.hold:
                ex.ideal_next_ms = None
        return ex

    @staticmethod
    def _admit(job: Job, now_ms: float) -> None:
        """Per-job bookkeeping every (re)configuration path performs."""
        job.shift_pending = False
        if job.start_ms is None:
            job.start_ms = now_ms

    def configure(self, jobs: list[Job]) -> None:
        """(Re)configure the running set after a scheduling decision.

        Jobs keep their identity across epochs; a job whose placement
        changed pays ``migration_pause_ms`` (checkpoint-restore) and every
        job (re)starts its cycle at its (new) time-shift delay.  All CASSINI
        inputs come off the job's typed ``alignment`` directive
        (:class:`repro.engine.plan.JobAlignment`): the cumulative shift
        target, whether the pacing agent holds the isochronous grid, and
        the grid period.

        This is the *rebuild* path: array state and the water-filling
        cache are reconstructed from scratch.  Serve mode goes through
        :meth:`configure_incremental`, which applies the same per-job
        logic as slot-level deltas whenever the membership diff allows.
        """
        new: dict[str, _JobExec] = {}
        for job in jobs:
            ex = self._exec_for(job)
            self._admit(job, self.now_ms)
            new[job.job_id] = ex
        self._execs = new
        if self.vectorized:
            self._build_arrays()

    # ---------------------- delta configuration ------------------- #
    # Serve-mode arrivals/departures touch one job while the other
    # n-1 keep running; rebuilding every array (and discarding the
    # water-filling cache) per event is what makes batch reconfiguration
    # O(cluster) per arrival.  The delta ops below touch only the affected
    # slot and *keep* the allocation cache, which stays sound because a
    # cache key is (comm-membership bytes, per-member segment bytes) over
    # the current slot axis:
    #
    #   * ``remove_job`` only clears the slot's alive bit — keys where the
    #     slot was a comm member can never be produced again, keys where
    #     it was not remain exactly as valid;
    #   * ``add_job`` appends a slot, so every new key's membership mask is
    #     one byte longer — old entries become unreachable (never wrong),
    #     since a (mask, int32-segments) encoding can never collide with
    #     one whose mask length differs by 1 (4·k' − 4·k = 1 is unsolvable);
    #   * ``update_job`` with an unchanged placement alters only
    #     delay/alignment state, which enters the solve through the
    #     membership mask itself; a changed placement (in-place migration)
    #     rewrites the slot's link columns, which ARE invisible to the key —
    #     that one case clears the cache.
    #
    # Dead slots accumulated by departures are compacted (full rebuild)
    # once they outnumber the live ones, bounding memory.
    def add_job(self, job: Job) -> None:
        """Admit one arriving job without rebuilding the running set.

        Bit-exact against ``configure(previous jobs + [job])``
        (tests/test_serve_incremental.py pins state and trace parity).
        """
        if job.job_id in self._execs:
            raise ValueError(f"job {job.job_id!r} already configured")
        ex = self._exec_for(job)
        self._admit(job, self.now_ms)
        self._execs[job.job_id] = ex
        if not self.vectorized:
            return
        live = int(np.count_nonzero(self._alive))
        if self._inc is None or len(self._slots) - live >= max(8, live):
            self._build_arrays()  # first build / compact dead slots
            return
        i = len(self._slots)
        self._slots.append(ex)
        self._slot_of[job.job_id] = i
        cols = self.topo.job_link_ids(job.placement)
        self._inc = self._inc.with_row(cols)
        self._rem = np.append(self._rem, ex.remaining)
        self._dly = np.append(self._dly, ex.delay_ms)
        self._mk = np.append(self._mk, ex.marks)
        self._cap_now = np.append(self._cap_now, 0.0)
        self._segi = np.append(self._segi, np.int32(0))
        self._is_comm = np.append(self._is_comm, False)
        self._alive = np.append(self._alive, True)
        # the incremental solver's link-state is per-slot: the new slot
        # axis invalidates it (rebuilt from scratch at the next solve)
        self._wf = None
        self._sync_seg(i, ex)

    def remove_job(self, job_id: str) -> Job:
        """Retire one departing job without rebuilding the running set."""
        try:
            ex = self._execs.pop(job_id)
        except KeyError:
            raise UnknownJobError(job_id, self._execs) from None
        if self.vectorized:
            self._alive[self._slot_of.pop(job_id)] = False
        return ex.job

    def update_job(self, job: Job) -> None:
        """Re-apply one running job's epoch decision (directive / placement)
        in place — the per-job logic of :meth:`configure` on a single slot."""
        old = self._execs.get(job.job_id)
        if old is None:
            raise UnknownJobError(job.job_id, self._execs)
        ex = self._exec_for(job)
        migrated = ex.links != old.links
        # elastic resize with an unchanged placement: the link columns keep
        # the cache keys valid, but the new segment list changes the demand
        # the same (mask, segment-index) key now encodes
        resized = ex.segments != old.segments
        self._admit(job, self.now_ms)
        self._execs[job.job_id] = ex  # overwrite keeps dict position
        if not self.vectorized:
            return
        i = self._slot_of[job.job_id]
        self._slots[i] = ex
        self._rem[i] = ex.remaining
        self._dly[i] = ex.delay_ms
        self._mk[i] = ex.marks
        self._sync_seg(i, ex)
        if migrated:
            # the slot's link columns change under the cache keys' feet
            cols = self.topo.job_link_ids(job.placement)
            self._inc = self._inc.replace_row(i, cols)
        if migrated or resized:
            # either way the cached rates no longer describe this slot:
            # drop the cache (and the incremental solver's per-link
            # demand/live state with it)
            self._alloc_cache.clear()
            self._wf = None

    def configure_incremental(self, jobs: list[Job]) -> str:
        """Apply an epoch decision as slot deltas when the membership diff
        allows, falling back to the full rebuild otherwise.

        The delta form requires the new running order to be reachable by
        departures + in-place updates + appended arrivals (surviving jobs
        in their current relative order, new jobs at the end) — exactly
        what arrival/departure-triggered decisions produce.  A decision
        that *reorders* survivors (e.g. re-admitting a previously starved
        job mid-list) rebuilds, because slot order defines the float
        accumulation order the scalar oracle is matched against.

        Returns ``"delta"`` or ``"rebuild"`` (serve-mode telemetry).
        """
        new_ids = [j.job_id for j in jobs]
        live = list(self._execs)
        new_set = set(new_ids)
        if len(new_set) != len(new_ids):
            raise ValueError("duplicate job ids in decision")
        survivors = [jid for jid in live if jid in new_set]
        expected = survivors + [jid for jid in new_ids if jid not in set(live)]
        if new_ids != expected:
            self.configure(jobs)
            return "rebuild"
        for jid in live:
            if jid not in new_set:
                self.remove_job(jid)
        for job in jobs:
            if job.job_id in self._execs:
                self.update_job(job)
            else:
                self.add_job(job)
        return "delta"

    # ---------------------- fault injection ----------------------- #
    def set_link_capacity(self, name: str, gbps: float) -> float:
        """Mutate one link's capacity mid-simulation; returns the old value.

        The primitive behind ``LinkDown`` (0.0) / ``LinkDegrade`` /
        ``LinkRecover``.  Capacities are deliberately not part of the
        allocation-cache key (they never changed mid-run before faults
        existed), so the cache is dropped; the solvers read capacities
        live, so the next solve — scalar, vectorized, or incremental —
        sees the new value.  The incremental water-filling state is kept:
        the link id is marked dirty and the next delta solve re-fills
        exactly the components the change touches.
        """
        old = self.topo.set_link_capacity(name, gbps)
        self._alloc_cache.clear()
        if self.incremental:
            self._wf_cap_dirty.add(self.topo.link_ids[name])
        return old

    def perturb_job(self, job_id: str, delta_ms: float) -> float:
        """Shift one job's pending segment delay by ``delta_ms``
        (``PhaseJitter``): per-iteration timing perturbation à la psim's
        measured ``deltas``, pushing the job's phase off its aligned slot
        without touching alignment state — the drift-adjustment agent
        (§5.7) sees it exactly like real compute jitter.  Negative deltas
        pull the phase earlier, floored at zero delay.  Returns the new
        delay.  Both engines apply the identical float operation (the
        vectorized mirror and the exec field agree between advances), so
        replays stay bit-identical.
        """
        ex = self._execs.get(job_id)
        if ex is None:
            raise UnknownJobError(job_id, self._execs)
        new = max(0.0, ex.delay_ms + delta_ms)
        ex.delay_ms = new
        if self.vectorized and self._inc is not None:
            self._dly[self._slot_of[job_id]] = new
        return new

    # -------------------------------------------------------------- #
    def _comm_jobs(self) -> dict[str, _JobExec]:
        """Jobs currently competing for link bandwidth: in a comm segment,
        not delayed, and not horizon-expired — a ``JobState.CUTOFF`` job has
        stopped training and must not consume link share or attract marks."""
        return {
            jid: ex
            for jid, ex in self._execs.items()
            if ex.kind == "comm" and ex.delay_ms <= _EPS and ex.links
            and ex.job.state is not JobState.CUTOFF
        }

    def _allocate(self) -> dict[str, float]:
        """Max-min-fair rates (Gbps) for jobs currently in a comm segment,
        respecting per-segment demand caps (progressive filling).

        Dispatches to the cached vectorized solve or the scalar oracle;
        both return the same dict, bit for bit."""
        if self.vectorized:
            comm_mask = self._comm_mask(self._cutoff_mask())
            rates, _, _ = self._cached_solve(comm_mask)
            return {
                self._slots[i].job.job_id: float(rates[i])
                for i in np.nonzero(comm_mask)[0]
            }
        return self._allocate_scalar()

    def _mark_rates(self) -> dict[str, float]:
        """ECN marks per ms for each job (demand-over-capacity model)."""
        if self.vectorized:
            comm_mask = self._comm_mask(self._cutoff_mask())
            _, marks, _ = self._cached_solve(comm_mask)
            return {
                self._slots[i].job.job_id: float(marks[i])
                for i in np.nonzero(comm_mask)[0]
            }
        return self._mark_rates_scalar()

    # ---------------------- scalar oracle ------------------------- #
    def _allocate_scalar(self) -> dict[str, float]:
        """The original per-event progressive-filling loop (the oracle the
        vectorized water-filling is equivalence-tested against)."""
        comm = self._comm_jobs()
        rates = {jid: 0.0 for jid in comm}
        if not comm:
            return rates
        remaining = {}
        users: dict[str, list[str]] = {}
        demand: dict[str, float] = {}
        caps: dict[str, float] = {}
        for jid, ex in comm.items():
            for l in ex.links:
                users.setdefault(l.name, []).append(jid)
                demand[l.name] = demand.get(l.name, 0.0) + ex.cap_gbps
                caps[l.name] = l.capacity_gbps
        for lname, cap in caps.items():
            eff = self.congested_efficiency if demand[lname] > cap + _EPS else 1.0
            remaining[lname] = cap * eff
        unfrozen = set(comm)
        while unfrozen:
            # next increment: smallest of (per-link equal share, cap slack)
            inc = math.inf
            for lname, js in users.items():
                live = [j for j in js if j in unfrozen]
                if live:
                    inc = min(inc, remaining[lname] / len(live))
            for j in unfrozen:
                inc = min(inc, comm[j].cap_gbps - rates[j])
            if inc is math.inf or inc < 0:
                break
            for j in unfrozen:
                rates[j] += inc
            for lname, js in users.items():
                live = sum(1 for j in js if j in unfrozen)
                remaining[lname] -= inc * live
            newly_frozen = {
                j for j in unfrozen if comm[j].cap_gbps - rates[j] <= _EPS
            }
            for lname, js in users.items():
                if remaining[lname] <= _EPS:
                    newly_frozen |= {j for j in js if j in unfrozen}
            if not newly_frozen:
                break
            unfrozen -= newly_frozen
        return rates

    def _mark_rates_scalar(self) -> dict[str, float]:
        """ECN marks per ms for each job (demand-over-capacity model)."""
        comm = self._comm_jobs()
        demand: dict[str, float] = {}
        users: dict[str, list[str]] = {}
        caps: dict[str, float] = {}
        for jid, ex in comm.items():
            for l in ex.links:
                demand[l.name] = demand.get(l.name, 0.0) + ex.cap_gbps
                users.setdefault(l.name, []).append(jid)
                caps[l.name] = l.capacity_gbps
        marks = {jid: 0.0 for jid in comm}
        for lname, d in demand.items():
            excess = d - caps[lname]
            if excess <= 0:
                continue
            for jid in users[lname]:
                share = comm[jid].cap_gbps / d
                # Gbit/ms of excess attributed to this job × marks/Gbit
                marks[jid] += excess * share * 1e-3 * self.ecn_marks_per_gbit
        return marks

    # ---------------------- vectorized engine --------------------- #
    def _build_arrays(self) -> None:
        """Rebuild the array-resident execution state after ``configure``.

        The job×link incidence comes precomputed from the topology (global
        link ids, cached ring walks); everything else is a dense per-slot
        vector.  Slots follow ``_execs`` insertion order — the same order
        every scalar dict iterates — which is what lets the vectorized
        reductions reproduce the oracle's float accumulation exactly.
        """
        self._slots = list(self._execs.values())
        self._slot_of = {
            ex.job.job_id: i for i, ex in enumerate(self._slots)
        }
        n = len(self._slots)
        self._inc = self.topo.incidence(
            [ex.job.placement for ex in self._slots]
        )
        self._rem = np.array([ex.remaining for ex in self._slots], dtype=np.float64)
        self._dly = np.array([ex.delay_ms for ex in self._slots], dtype=np.float64)
        self._mk = np.array([ex.marks for ex in self._slots], dtype=np.float64)
        self._cap_now = np.zeros(n, dtype=np.float64)
        self._segi = np.zeros(n, dtype=np.int32)
        self._is_comm = np.zeros(n, dtype=bool)
        self._alive = np.ones(n, dtype=bool)
        for i, ex in enumerate(self._slots):
            self._sync_seg(i, ex)
        self._alloc_cache.clear()
        self._wf = None

    def _sync_seg(self, i: int, ex: _JobExec) -> None:
        """Refresh slot ``i``'s segment-derived columns (on transition)."""
        seg = ex.segments[ex.seg_idx]
        self._segi[i] = ex.seg_idx
        self._is_comm[i] = seg.kind == "comm" and bool(ex.links)
        self._cap_now[i] = seg.gbps

    def _sync_execs(self) -> None:
        """Write the array state back into the exec objects so callers
        between ``advance`` calls (configure, tests, probes) see current
        values."""
        for i in np.nonzero(self._alive)[0]:
            ex = self._slots[i]
            ex.remaining = float(self._rem[i])
            ex.delay_ms = float(self._dly[i])
            ex.marks = float(self._mk[i])

    def _cutoff_mask(self) -> np.ndarray:
        return np.fromiter(
            (ex.job.state is JobState.CUTOFF for ex in self._slots),
            dtype=bool, count=len(self._slots),
        )

    def _comm_mask(self, cutoff: np.ndarray) -> np.ndarray:
        """Array form of :meth:`_comm_jobs`'s membership rule."""
        return self._alive & self._is_comm & (self._dly <= _EPS) & ~cutoff

    def _cached_solve(
        self, comm_mask: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rates, mark rates, rate>0 mask) for the comm-competing set.

        Keyed on (membership, per-member segment): the allocation is a
        pure function of *which jobs communicate with which demand cap*,
        so anything else — compute-only jobs advancing through their own
        segments, delays draining, time passing — hits the cache and the
        per-event cost collapses to one dict lookup.
        """
        key = comm_mask.tobytes() + self._segi[comm_mask].tobytes()
        hit = self._alloc_cache.get(key)
        if hit is not None:
            self.alloc_hits += 1
            # LRU touch: re-insertion moves the key to the dict's tail, so
            # eviction below always removes the least-recently-used entry
            self._alloc_cache[key] = self._alloc_cache.pop(key)
        else:
            while len(self._alloc_cache) >= _ALLOC_CACHE_MAX:
                # evict only the LRU entry — a cold scan of fresh comm-sets
                # (256+-rack churn) must not wipe the hot working set
                del self._alloc_cache[next(iter(self._alloc_cache))]
            if self.incremental:
                rates, marks = self._solve_alloc_incremental(comm_mask)
            else:
                rates, marks = self._solve_alloc(comm_mask)
            hit = (rates, marks, rates > _EPS)
            self._alloc_cache[key] = hit
            self.alloc_solves += 1
        return hit

    def _solve_alloc(self, comm_mask: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized water-filling + ECN marking over (jobs, links) arrays.

        Produces exactly the scalar oracle's floats: per-link demand
        accumulates through ``np.bincount`` over the job-major flat
        incidence (sequential in input order == the scalar dicts'
        insertion order), every filling round performs the same
        divisions/additions the scalar loop does as whole-array
        operations, and per-membership mark contributions on congested
        links are summed per job in the oracle's demand-dict order (a
        (job, first-seen-rank) lexsort when any job has ≥ 3 congested
        links; ≤ 2-term sums are commutative) — so even multi-link float
        accumulations agree bit for bit.
        """
        n = len(self._slots)
        rates = np.zeros(n, dtype=np.float64)
        marks = np.zeros(n, dtype=np.float64)
        idx = np.nonzero(comm_mask)[0]
        k = idx.size
        if k == 0:
            return rates, marks
        caps_j = self._cap_now[idx]
        # flat (job-major) view of the comm subset's incidence — the CSR
        # gather returns columns in exactly the job-major order the scalar
        # dicts iterate, so the bincount sums below stay bit-exact
        counts = self._inc.counts[idx]
        cols_sub = self._inc.flat_cols(idx)
        job_rep = np.repeat(np.arange(k), counts)
        caps_rep = np.repeat(caps_j, counts)
        nl = self._inc.num_links
        cap_l = self._inc.capacities
        # np.bincount accumulates its weights sequentially in input (job-
        # major) order — the scalar dicts' per-link insertion order — so
        # demand is the oracle's float sum bit for bit
        demand = np.bincount(cols_sub, weights=caps_rep, minlength=nl)
        # progressive filling: one vector op per filling round (links with
        # no comm users keep demand 0 < capacity, so they never bound inc,
        # never saturate and never mark — the global link axis is free).
        # Every unfrozen job has received every increment so far, so all
        # unfrozen rates equal ONE scalar accumulator ``r_cur`` (the same
        # float-add sequence the oracle applies per job), the cap-slack min
        # is (smallest unfrozen cap) − r_cur via a sorted-cap pointer, and
        # jobs freeze at caps_j − r_cur ≤ ε exactly like the oracle's
        # per-job test — the per-job array work drops out of the loop.
        eff = np.where(demand > cap_l + _EPS, self.congested_efficiency, 1.0)
        remaining = cap_l * eff
        r = np.zeros(k, dtype=np.float64)
        unfrozen = np.ones(k, dtype=bool)
        n_unfrozen = k
        r_cur = 0.0
        cap_order = np.argsort(caps_j, kind="stable").tolist()
        caps_list = caps_j.tolist()
        ptr = 0
        # live user counts per link, maintained incrementally as jobs freeze
        # (exact integers — identical to recounting every round)
        live = np.bincount(cols_sub, minlength=nl)
        has = live > 0
        linkbuf = np.empty(nl, dtype=np.float64)
        inf = math.inf
        while n_unfrozen:
            linkbuf.fill(inf)
            np.divide(remaining, live, out=linkbuf, where=has)
            inc = float(linkbuf.min()) if nl else inf
            while ptr < k and not unfrozen[cap_order[ptr]]:
                ptr += 1
            if ptr < k:
                inc = min(inc, caps_list[cap_order[ptr]] - r_cur)
            if inc == inf or inc < 0:
                break
            r_cur += inc
            remaining -= inc * live
            newly = np.zeros(k, dtype=bool)
            any_newly = False
            while ptr < k and caps_list[cap_order[ptr]] - r_cur <= _EPS:
                j = cap_order[ptr]
                if unfrozen[j]:
                    newly[j] = True
                    any_newly = True
                ptr += 1
            sat = remaining <= _EPS
            if sat.any():
                sat_jobs = np.zeros(k, dtype=bool)
                sat_jobs[job_rep[sat[cols_sub]]] = True
                newly |= unfrozen & sat_jobs
                any_newly = any_newly or bool(newly.any())
            if not any_newly:
                break
            r[newly] = r_cur
            unfrozen &= ~newly
            n_unfrozen = int(np.count_nonzero(unfrozen))
            live -= np.bincount(cols_sub[newly[job_rep]], minlength=nl)
            has = live > 0
        r[unfrozen] = r_cur
        rates[idx] = r
        # ECN marking: per-membership contributions on congested links,
        # accumulated per job in the oracle's order — jobs with ≤ 2
        # congested links sum commutatively (any order is exact), ≥ 3
        # require the subset's first-seen link order (the oracle iterates
        # its demand dict), restored by a (job, first-seen-rank) lexsort
        exc = demand - cap_l
        cong_flat = exc[cols_sub] > 0
        if cong_flat.any():
            jm = job_rep[cong_flat]
            lm = cols_sub[cong_flat]
            cm = caps_rep[cong_flat]
            if np.bincount(jm, minlength=k).max() > 2:
                uniq, first_idx = np.unique(cols_sub, return_index=True)
                rank = np.zeros(nl, dtype=np.int64)
                rank[uniq[np.argsort(first_idx, kind="stable")]] = np.arange(
                    uniq.size
                )
                order = np.lexsort((rank[lm], jm))
                jm, lm, cm = jm[order], lm[order], cm[order]
            contrib = exc[lm] * (cm / demand[lm]) * 1e-3 * self.ecn_marks_per_gbit
            marks[idx] = np.bincount(jm, weights=contrib, minlength=k)
        return rates, marks

    # ------------------ incremental water-filling ----------------- #
    def _solve_alloc_incremental(
        self, comm_mask: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Water-filling via delta-maintained state and dirty-component
        refills.

        At 256+ racks adjacent comm-competing sets differ by one or two
        jobs, yet the from-scratch solve re-accumulates demand and live
        counts over *every* member and re-runs the filling cascade over
        *every* contended link.  This path keeps the full solution between
        solves — per-link demand / live counts / mark ratios, per-slot
        rates and per-job mark totals — and applies the member diff as
        batched ``np.bincount`` deltas over only the changed slots' link
        columns, O(changed nnz) instead of O(comm nnz).

        Rates exploit that water-filling decomposes exactly across
        connected components of the (member job × binding link) graph —
        the same loosely-connected affinity-graph structure the paper's
        scheduler partitions (§4): components share no links and no jobs,
        so each one's cascade is independent of the rest.  A delta dirties
        only the components touching a changed slot or a demand-changed
        binding link; a seed-driven BFS walks exactly those components
        (output-sensitive — clean components are never visited) and ONE
        batched fill re-solves their union (independent sub-problems solve
        jointly without interacting), while every clean component keeps
        its previous rates verbatim.  Mark totals are maintained the same
        way: per-link ``max(excess,0)/demand`` ratios are patched on the
        changed links and scattered into per-job totals through the
        link-major CSR.

        Equivalence is by tolerance band, not bit-exactness (see
        docs/architecture.md "Incremental re-solve"): demand/mark sums
        float-drift under ± deltas (bounded by a from-scratch refresh
        every ``_WF_REFRESH`` delta solves) and component-local fills
        reorder float accumulation.  ``incremental=False`` (the default)
        never enters this path and stays bit-exact against the scalar
        oracle.
        """
        n = len(self._slots)
        caps_now = np.where(comm_mask, self._cap_now, 0.0)
        st = self._wf
        if st is None or st["caps"].shape[0] != n or st["age"] >= _WF_REFRESH:
            st = self._wf_rebuild(comm_mask, caps_now)
            self._wf_cap_dirty.clear()  # rebuilt from live capacities
        else:
            changed = np.nonzero(
                (st["mask"] != comm_mask) | (st["caps"] != caps_now)
            )[0]
            extra = None
            if self._wf_cap_dirty:
                # link capacities mutated by fault injection since the
                # last solve: treat them as demand-changed links so their
                # ratios/binding flips recompute and their components
                # re-fill against the new capacity
                extra = np.fromiter(
                    sorted(self._wf_cap_dirty), dtype=np.int64,
                    count=len(self._wf_cap_dirty),
                )
                self._wf_cap_dirty.clear()
            if changed.size or extra is not None:
                self._wf_delta(
                    st, comm_mask, caps_now, changed, extra_links=extra
                )
            st["age"] += 1
            self.alloc_delta_solves += 1
        # T accumulates ± ratio deltas between refreshes — clamp the tiny
        # negative float residue so mark rates stay ≥ 0 like the oracle's
        marks = caps_now * np.maximum(st["T"], 0.0)
        marks *= 1e-3 * self.ecn_marks_per_gbit
        return st["rates"].copy(), marks

    def _wf_rebuild(self, comm_mask: np.ndarray, caps_now: np.ndarray) -> dict:
        """From-scratch build of the incremental solver state."""
        inc = self._inc
        n = len(self._slots)
        nl = inc.num_links
        cap_l = inc.capacities
        idx = np.nonzero(comm_mask)[0]
        cols = inc.flat_cols(idx)
        w = np.repeat(caps_now[idx], inc.counts[idx])
        # bincount returns int64 for *empty* weights — pin float64
        demand = np.bincount(cols, weights=w, minlength=nl).astype(np.float64)
        live = np.bincount(cols, minlength=nl).astype(np.int64)
        exc = demand - cap_l
        with np.errstate(divide="ignore", invalid="ignore"):
            lratio = np.where(exc > 0, exc / demand, 0.0)
        rows_all, cols_all = inc.flat_pairs
        T = np.bincount(
            rows_all, weights=lratio[cols_all], minlength=n
        ).astype(np.float64)
        eff = np.where(demand > cap_l + _EPS, self.congested_efficiency, 1.0)
        binding = (live > 0) & (demand >= cap_l * eff - _EPS)
        rates = np.zeros(n, dtype=np.float64)
        rates[idx] = caps_now[idx]
        if binding.any():
            bpair = binding[cols_all] & comm_mask[rows_all]
            JR = np.unique(rows_all[bpair])
            if JR.size:
                self._wf_fill_dispatch(rates, JR, binding, demand, live)
        self._wf = st = {
            "mask": comm_mask.copy(),
            "caps": caps_now,
            "demand": demand,
            "live": live,
            "lratio": lratio,
            "T": T,
            "binding": binding,
            "rates": rates,
            "age": 0,
        }
        return st

    def _wf_delta(
        self,
        st: dict,
        comm_mask: np.ndarray,
        caps_now: np.ndarray,
        changed: np.ndarray,
        extra_links: np.ndarray | None = None,
    ) -> None:
        """Apply a member diff to the state and refill dirty components.

        ``extra_links`` names link ids whose *capacity* changed with no
        member diff of their own (fault injection): they join the changed-
        link set so mark ratios, binding flips and component refills all
        re-evaluate against the mutated ``inc.capacities``."""
        inc = self._inc
        nl = inc.num_links
        cap_l = inc.capacities
        ccols = inc.flat_cols(changed)
        reps = inc.counts[changed]
        dcap = np.repeat(caps_now[changed] - st["caps"][changed], reps)
        demand = st["demand"]
        demand += np.bincount(ccols, weights=dcap, minlength=nl)
        dmem = (
            comm_mask[changed].astype(np.int64)
            - st["mask"][changed].astype(np.int64)
        )
        if dmem.any():
            # sums of ±1 in float64 are exact — astype is lossless
            st["live"] += np.bincount(
                ccols, weights=np.repeat(dmem, reps), minlength=nl
            ).astype(np.int64)
        live = st["live"]
        st["mask"] = comm_mask.copy()
        st["caps"] = caps_now
        # mark ratios move only where demand (or capacity) moved; scatter
        # the per-link delta into the per-job totals through the link-major
        # CSR
        if extra_links is not None and extra_links.size:
            cl = np.unique(np.concatenate((ccols, extra_links)))
        else:
            cl = np.unique(ccols)
        exc = demand[cl] - cap_l[cl]
        with np.errstate(divide="ignore", invalid="ignore"):
            new_r = np.where(exc > 0, exc / demand[cl], 0.0)
        dr = new_r - st["lratio"][cl]
        if dr.any():
            st["T"] += np.bincount(
                inc.link_users(cl),
                weights=np.repeat(dr, inc.link_csr[1][cl]),
                minlength=st["T"].size,
            )
            st["lratio"][cl] = new_r
        # binding flips can only happen on the demand-changed links
        binding = st["binding"]
        b_old = binding[cl]
        eff = np.where(demand[cl] > cap_l[cl] + _EPS, self.congested_efficiency, 1.0)
        b_new = (live[cl] > 0) & (demand[cl] >= cap_l[cl] * eff - _EPS)
        binding[cl] = b_new
        # dirty slots: the changed members themselves, plus every user of a
        # changed link that is (or just stopped being) contended — slots in
        # clean components are untouched and keep their previous rates
        dlinks = cl[b_old | b_new]
        dirty = np.concatenate((changed, inc.link_users(dlinks)))
        rates = st["rates"]
        # members default to their demand caps (exact for every slot with
        # no binding link — sub-binding links can never saturate), then the
        # component refill overwrites the contended ones
        rates[dirty] = caps_now[dirty]
        # seed-driven BFS over the (member × binding-link) graph: visits
        # exactly the dirty components, never the clean ones
        rows_l, link_rows = inc.adjacency
        seenL: set[int] = set()
        stack: list[int] = []
        for lnk in dlinks.tolist():
            if binding[lnk] and lnk not in seenL:
                seenL.add(lnk)
                stack.append(lnk)
        for s in dirty.tolist():
            if comm_mask[s]:
                for g in rows_l[s]:
                    if g not in seenL and binding[g]:
                        seenL.add(g)
                        stack.append(g)
        if not stack:
            return  # no contended component touched
        JRs: set[int] = set()
        while stack:
            lnk = stack.pop()
            for u in link_rows[lnk]:
                if u not in JRs and comm_mask[u]:
                    JRs.add(u)
                    for g in rows_l[u]:
                        if g not in seenL and binding[g]:
                            seenL.add(g)
                            stack.append(g)
        if not JRs:
            return
        sub_binding = np.zeros(nl, dtype=bool)
        sub_binding[sorted(seenL)] = True
        JR = np.fromiter(sorted(JRs), dtype=np.int64, count=len(JRs))
        self._wf_fill_dispatch(rates, JR, sub_binding, demand, live)

    def _wf_components(
        self, JR: np.ndarray, binding: np.ndarray
    ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Partition the closed member set ``JR`` into its connected
        components of the (member x binding-link) graph.

        ``JR`` is closed under the BFS that built it: every comm user of
        every binding link reachable from a member of ``JR`` is itself in
        ``JR`` (``_wf_rebuild`` takes all bound comm users; ``_wf_delta``
        closes over the dirty seeds).  That closure is what makes each
        returned ``(members, links)`` pair a self-contained water-filling
        sub-problem: global live counts on a component's links equal its
        in-component user counts, so the batched fill can recompute them
        from the component's own sub-incidence.
        """
        rows_l, link_rows = self._inc.adjacency
        jr = set(JR.tolist())
        seen: set[int] = set()
        comps: list[tuple[np.ndarray, np.ndarray]] = []
        for j0 in JR.tolist():
            if j0 in seen:
                continue
            seen.add(j0)
            members = [j0]
            links: list[int] = []
            seenL: set[int] = set()
            stack = [j0]
            while stack:
                u = stack.pop()
                for g in rows_l[u]:
                    if binding[g] and g not in seenL:
                        seenL.add(g)
                        links.append(g)
                        for v in link_rows[g]:
                            if v in jr and v not in seen:
                                seen.add(v)
                                members.append(v)
                                stack.append(v)
            members.sort()
            links.sort()
            comps.append((
                np.array(members, dtype=np.int64),
                np.array(links, dtype=np.int64),
            ))
        return comps

    def _wf_fill_dispatch(
        self,
        rates: np.ndarray,
        JR: np.ndarray,
        binding: np.ndarray,
        demand: np.ndarray,
        live: np.ndarray,
    ) -> None:
        """Route a dirty-union refill to the fused or device-sharded fill.

        The sharded path (``sharded=True``) re-partitions the union into
        components and solves them as rows of bucketed vmap batches split
        across devices (repro.cluster.shard).  Below ``MIN_COMPONENTS``
        the batch cannot amortise a device round-trip, so small unions —
        including every typical delta, which dirties one or two
        components — keep the fused host fill.  Both paths write the same
        slots of ``rates``; equivalence is tolerance-band (component
        fills reorder float accumulation vs the union fill)."""
        if self.sharded:
            comps = self._wf_components(JR, binding)
            if len(comps) >= _SHARD_MIN_COMPONENTS:
                cap_l = self._inc.capacities
                rows = []
                for mem, lnks in comps:
                    eff = np.where(
                        demand[lnks] > cap_l[lnks] + _EPS,
                        self.congested_efficiency,
                        1.0,
                    )
                    rows.append((
                        self._cap_now[mem],
                        self._inc.sub_incidence(mem, lnks),
                        cap_l[lnks] * eff,
                    ))
                filled, stats = batched_fill(rows, ndev=self._shard_devices)
                for (mem, _), vec in zip(comps, filled):
                    rates[mem] = vec
                self.shard_stats.merge(stats)
                return
            self.shard_stats.fused_fills += 1
        rates[JR] = self._wf_fill_core(JR, binding, demand, live)

    def _wf_fill_core(
        self,
        idx: np.ndarray,
        binding: np.ndarray,
        demand: np.ndarray,
        live: np.ndarray,
    ) -> np.ndarray:
        """Progressive filling over only the links that can saturate.

        A link with ``demand < capacity·eff − ε`` can never bound a filling
        increment: its remaining/live ratio strictly exceeds the smallest
        cap slack among its users at every round (each user's rate is
        capped by its demand contribution, so the link retains headroom
        until every user freezes at cap).  Dropping those links — and every
        job incident to *no* surviving link, which simply freezes at its
        demand cap — shrinks the filling loop's axes from (comm jobs, all
        links) to (contended jobs, contended links), typically a small
        constant at 256+ racks.  Frozen-at-cap rates agree with the oracle
        to ≤ ε (the oracle freezes at cap-slack ≤ ε); everything else is
        the same progressive-filling recurrence on fewer axes.

        ``idx`` is the candidate slot set (the comm members on a rebuild, a
        dirty-component union on a delta); ``binding`` restricts the link
        axis the same way.  Returns the rates for ``idx`` in order.
        """
        n = len(self._slots)
        k = idx.size
        caps_j = self._cap_now[idx]
        r = caps_j.copy()
        nl = self._inc.num_links
        cap_l = self._inc.capacities
        counts = self._inc.counts[idx]
        cols_sub = self._inc.flat_cols(idx)
        job_rep = np.repeat(np.arange(k), counts)
        bsel = binding[cols_sub]
        jb = job_rep[bsel]
        B = np.nonzero(binding)[0]
        bound = np.zeros(k, dtype=bool)
        bound[jb] = True
        J = np.nonzero(bound)[0]
        m = J.size
        L = B.size
        if m == 0 or L == 0:
            return r
        slotJ = idx[J]
        # Freeze events are scalar-sparse (each job freezes once, touching
        # a handful of links), so the loop keeps vector state only for the
        # per-round ratio min and does freeze bookkeeping through python
        # adjacency lists.  Dead links never leave the arrays: a saturated
        # link gets remaining=inf, live=BIG so its ratio pins at inf and
        # stray decrements stay harmless — no per-round masking at all.
        rows_l, link_rows = self._inc.adjacency
        BIG = 1e300
        lpos = np.full(nl, L, dtype=np.int64)  # sentinel L → dummy tail
        lpos[B] = np.arange(L)
        # Per-link *absolute* saturation level: with Rem_l = limit_l minus
        # the rates of its frozen users, a link saturates when the shared
        # water level reaches Rem_l / lv_l.  The level is invariant under
        # rounds that do not freeze one of the link's users, so each round
        # costs one reduction over the level array plus O(affected) updates
        # — no full rem/live rewrite.  The dummy tail slot absorbs
        # decrements for links outside the binding set (lpos sentinel).
        db = demand[B]
        clb = cap_l[B]
        eff_b = np.where(db > clb + _EPS, self.congested_efficiency, 1.0)
        Rem = np.empty(L + 1, dtype=np.float64)
        Rem[:L] = clb * eff_b
        Rem[L] = math.inf
        lv = np.empty(L + 1, dtype=np.float64)
        lv[:L] = live[B]
        lv[L] = BIG
        level = np.empty(L + 1, dtype=np.float64)
        np.divide(Rem, lv, out=level)
        B_list = B.tolist()
        slotJ_list = slotJ.tolist()
        unfrozen_slot = bytearray(n)
        for s in slotJ_list:
            unfrozen_slot[s] = 1
        order = np.argsort(caps_j[J], kind="stable")
        caps_sorted = caps_j[J][order].tolist()
        slot_order = slotJ[order].tolist()
        frozen_slots: list[int] = []
        frozen_vals: list[float] = []
        dec_gids: list[int] = []
        dec_vals: list[float] = []  # per frozen job: rate, fan-out
        dec_lens: list[int] = []
        n_unfrozen = m
        r_cur = 0.0
        ptr = 0
        inf = math.inf
        fmin_reduce = np.fmin.reduce
        with np.errstate(divide="ignore", invalid="ignore"):
            while n_unfrozen:
                S = float(fmin_reduce(level))
                while ptr < m and not unfrozen_slot[slot_order[ptr]]:
                    ptr += 1
                if ptr < m and caps_sorted[ptr] <= S + _EPS:
                    # batched cap freezes: every unfrozen cap ≤ S takes its
                    # final rate now — freezing a user below a link's level
                    # only raises that level ((level−c)/(lv−1) ≥ 0), so no
                    # link can saturate before the water reaches S
                    while ptr < m and caps_sorted[ptr] <= S + _EPS:
                        s = slot_order[ptr]
                        if unfrozen_slot[s]:
                            unfrozen_slot[s] = 0
                            n_unfrozen -= 1
                            c = caps_sorted[ptr]
                            if c > r_cur:
                                r_cur = c
                            frozen_slots.append(s)
                            frozen_vals.append(c)
                            row = rows_l[s]
                            dec_gids.extend(row)
                            dec_vals.append(c)
                            dec_lens.append(len(row))
                        ptr += 1
                else:
                    if S == inf:
                        break
                    r_cur = S
                    for p in np.nonzero(level == S)[0].tolist():
                        for s in link_rows[B_list[p]]:
                            if unfrozen_slot[s]:
                                unfrozen_slot[s] = 0
                                n_unfrozen -= 1
                                frozen_slots.append(s)
                                frozen_vals.append(S)
                                row = rows_l[s]
                                dec_gids.extend(row)
                                dec_vals.append(S)
                                dec_lens.append(len(row))
                    if not dec_gids:
                        break  # defensive: argmin link had no live users
                pos = lpos[np.array(dec_gids, dtype=np.int64)]
                w = np.repeat(dec_vals, dec_lens)
                Rem -= np.bincount(pos, weights=w, minlength=L + 1)
                lv -= np.bincount(pos, minlength=L + 1)
                # drained links (lv → 0) pin at +inf; the 1e-300 floor keeps
                # float drift in Rem from producing -inf/NaN levels
                np.divide(np.maximum(Rem, 1e-300), lv, out=level)
                dec_gids.clear()
                dec_vals.clear()
                dec_lens.clear()
        if n_unfrozen:
            for s in slotJ_list:
                if unfrozen_slot[s]:
                    frozen_slots.append(s)
                    frozen_vals.append(r_cur)
        if frozen_slots:
            # frozen bookkeeping runs on global slot ids — map back to
            # positions within idx for the (len idx) result
            loc = np.zeros(n, dtype=np.int64)
            loc[idx] = np.arange(k)
            r[loc[np.array(frozen_slots, dtype=np.int64)]] = frozen_vals
        return r

    # -------------------------------------------------------------- #
    def attach_link_recorder(self, recorder) -> "FluidNetworkSim":
        """Wire a :class:`repro.cluster.linkload.LinkLoadRecorder` into
        the vectorized event loop (per-link utilization / ECN-mark
        timelines).  Raises on the scalar engine — the oracle loop has no
        recording hook, and silently recording nothing would be worse."""
        recorder._bind(self)
        self.link_recorder = recorder
        return self

    def advance(self, until_ms: float, *, max_events: int = 2_000_000) -> list[Job]:
        """Advance the fluid simulation to ``until_ms`` (exact events).

        Returns as soon as one or more jobs finish their last iteration (so
        the cluster simulator can react to the departure immediately); the
        finished jobs are returned with ``finish_ms`` / ``state`` set.
        """
        if not self._execs:
            # empty cluster (every job queued or between arrivals — elastic
            # churn can grow a lone job past the fabric): the fluid state
            # is trivially constant, so jump the clock instead of stalling
            # the caller's event loop at a fixed ``now``
            self.now_ms = max(self.now_ms, until_ms)
            return []
        if self.vectorized:
            return self._advance_vectorized(until_ms, max_events=max_events)
        return self._advance_scalar(until_ms, max_events=max_events)

    def _advance_vectorized(
        self, until_ms: float, *, max_events: int
    ) -> list[Job]:
        """Batched event stepping over the cached rates.

        Per event: one cache lookup for (rates, mark rates), one batched
        min for the next event time, and whole-array updates for
        delay/remaining/marks — no per-job Python in the hot loop.  Segment
        completions (the rare part) drop back to the shared scalar
        ``_complete_segment`` in slot order, so jitter draws and the
        alignment agent behave exactly like the oracle.
        """
        finished: list[Job] = []
        events = 0
        # job states only change outside advance (scheduler epochs, tests),
        # and a finish breaks the loop — the active view is loop-invariant
        act = self._alive & ~self._cutoff_mask()
        divbuf = np.empty(len(self._slots), dtype=np.float64)
        divbuf.fill(np.inf)
        try:
            while self.now_ms < until_ms - _EPS and self._execs:
                events += 1
                if events > max_events:
                    raise RuntimeError("fluid sim exceeded max_events")
                not_delayed = self._dly <= _EPS
                comm = act & self._is_comm & not_delayed
                rates, markr, pos = self._cached_solve(comm)
                delayed = act & ~not_delayed
                compute_like = act & not_delayed & ~self._is_comm
                dt = until_ms - self.now_ms
                dt = min(dt, float(np.where(delayed, self._dly, np.inf).min()))
                dt = min(
                    dt, float(np.where(compute_like, self._rem, np.inf).min())
                )
                # pos ⊆ comm: the cached solve's comm set IS this event's
                # (same key), so rate>_EPS slots are exactly the comm slots
                # that bound dt
                divbuf.fill(np.inf)
                np.divide(self._rem, rates, out=divbuf, where=pos)
                tmin = float(divbuf.min())
                if tmin < np.inf:
                    dt = min(dt, tmin * 1e3)
                dt = max(dt, 1e-6)
                self.now_ms += dt
                if self.link_recorder is not None:
                    # rates are constant over [now-dt, now) by construction
                    self.link_recorder.record(
                        self.now_ms - dt, self.now_ms, comm, rates
                    )
                # progress everyone by dt (rates constant over the interval)
                np.subtract(self._dly, dt, out=self._dly, where=delayed)
                np.maximum(self._dly, 0.0, out=self._dly, where=delayed)
                np.subtract(self._rem, dt, out=self._rem, where=compute_like)
                drained = rates * dt
                drained *= 1e-3
                np.subtract(self._rem, drained, out=self._rem, where=comm)
                np.add(self._mk, markr * dt, out=self._mk, where=comm)
                prog = act & not_delayed
                done = prog & (self._rem <= _EPS)
                if done.any():
                    for i in np.nonzero(done)[0]:
                        ex = self._slots[i]
                        ex.remaining = float(self._rem[i])
                        ex.delay_ms = float(self._dly[i])
                        ex.marks = float(self._mk[i])
                        self._complete_segment(ex)
                        self._rem[i] = ex.remaining
                        self._dly[i] = ex.delay_ms
                        self._mk[i] = ex.marks
                        self._sync_seg(i, ex)
                        if ex.job.remaining_iters() == 0:
                            ex.job.finish_ms = self.now_ms
                            ex.job.state = JobState.DONE
                            del self._execs[ex.job.job_id]
                            self._slot_of.pop(ex.job.job_id, None)
                            self._alive[i] = False
                            finished.append(ex.job)
                if finished:
                    break
        finally:
            self._sync_execs()
        return finished

    def _advance_scalar(
        self, until_ms: float, *, max_events: int
    ) -> list[Job]:
        """The original per-event Python loop (oracle for the vectorized
        engine's event stepping)."""
        finished: list[Job] = []
        events = 0
        while self.now_ms < until_ms - _EPS and self._execs:
            events += 1
            if events > max_events:
                raise RuntimeError("fluid sim exceeded max_events")
            rates = self._allocate()
            marks = self._mark_rates()
            # time to next event for every job; CUTOFF jobs are frozen —
            # they neither bound dt nor make progress (a cutoff job must
            # not finish iterations, flip to DONE, or consume link share)
            dt = until_ms - self.now_ms
            for jid, ex in self._execs.items():
                if ex.job.state is JobState.CUTOFF:
                    continue
                if ex.delay_ms > _EPS:
                    dt = min(dt, ex.delay_ms)
                elif ex.kind == "compute" or not ex.links:
                    dt = min(dt, ex.remaining)
                else:
                    r = rates.get(jid, 0.0)
                    if r > _EPS:
                        dt = min(dt, ex.remaining / r * 1e3)
            dt = max(dt, 1e-6)
            self.now_ms += dt
            # progress everyone by dt (rates constant over the interval)
            for jid, ex in list(self._execs.items()):
                if ex.job.state is JobState.CUTOFF:
                    continue
                if ex.delay_ms > _EPS:
                    ex.delay_ms = max(0.0, ex.delay_ms - dt)
                    continue
                if ex.kind == "compute" or not ex.links:
                    ex.remaining -= dt
                else:
                    ex.remaining -= rates.get(jid, 0.0) * dt * 1e-3
                    ex.marks += marks.get(jid, 0.0) * dt
                if ex.remaining <= _EPS:
                    self._complete_segment(ex)
                    if ex.job.remaining_iters() == 0:
                        ex.job.finish_ms = self.now_ms
                        ex.job.state = JobState.DONE
                        del self._execs[jid]
                        finished.append(ex.job)
            if finished:
                break
        return finished

    # -------------------------------------------------------------- #
    def _complete_segment(self, ex: _JobExec) -> None:
        ex.seg_idx += 1
        if ex.seg_idx >= len(ex.segments):
            # iteration boundary
            job = ex.job
            end = self.now_ms  # dt already chosen to land on the boundary
            if ex.skip_record:
                ex.skip_record = False
            else:
                job.iter_times_ms.append(end - ex.iter_start_ms)
                job.ecn_marks.append(ex.marks)
            job.iters_done += 1
            ex.marks = 0.0
            ex.iter_start_ms = end
            ex.seg_idx = 0
            # CASSINI alignment agent (§4.2 step 3, §5.7).  Aligned jobs run
            # *isochronously* on a grid with the optimizer's (quantized)
            # period: finishing early waits for the next slot (pacing — this
            # is what makes interleaving stable when real iteration times
            # differ slightly from the quantized ones the optimizer saw);
            # drifting late by more than 5 % triggers a re-alignment delay
            # onto the next slot.  Systematically-late jobs (3 consecutive
            # adjustments) disarm — their placement is not interleavable and
            # holding the grid would only burn time.
            if ex.ideal_next_ms is not None:
                drift = end - ex.ideal_next_ms
                if drift <= 0.0:
                    ex.delay_ms += -drift          # pace to the slot
                    ex.consec_adjust = 0
                    ex.ideal_next_ms += ex.paced_iter_ms
                elif drift > self.drift_tolerance * ex.paced_iter_ms:
                    extra = (-drift) % ex.paced_iter_ms
                    ex.delay_ms += extra
                    job.drift_adjustments += 1
                    ex.consec_adjust += 1
                    ex.ideal_next_ms = end + extra + ex.paced_iter_ms
                    if ex.consec_adjust >= 3:
                        ex.ideal_next_ms = None    # disarm
                else:
                    ex.consec_adjust = 0
                    ex.ideal_next_ms += ex.paced_iter_ms
        seg = ex.segments[ex.seg_idx]
        if seg.kind == "compute" or not ex.links:
            jitter = (
                1.0 + self._rng.gauss(0.0, self.compute_jitter)
                if self.compute_jitter > 0
                else 1.0
            )
            ex.remaining = seg.duration_ms * max(0.1, jitter)
        else:
            ex.remaining = seg.gbits
