"""Discrete-event cluster simulator (paper §5 methodology).

Drives job arrivals/departures and scheduling epochs over the fluid network
model.  Placement changes are triggered — exactly as in the paper — by job
arrivals, job departures, and lease (epoch) expiry; the configured
scheduler (optionally CASSINI-augmented) decides placements and time-shifts
at each trigger.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.cluster.job import Job, JobState
from repro.cluster.network import FluidNetworkSim
from repro.cluster.topology import Topology
from repro.sched.base import ClusterState, Decision, Scheduler

__all__ = ["nearest_rank", "Metrics", "ClusterSimulator"]


def nearest_rank(xs, q: float) -> float:
    """Nearest-rank percentile: smallest value with ≥ q% of samples ≤ it.

    The ONE percentile definition shared by every metric in the repo
    (``Metrics`` and the benchmark drivers) — ``ceil(q/100·n)``-th order
    statistic, clamped to the sample range; NaN on an empty sample.
    """
    xs = list(xs)
    if not xs:
        return float("nan")
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(math.ceil(q / 100.0 * len(ys))) - 1))
    return ys[i]


@dataclass
class Metrics:
    """Aggregated results of one simulation run."""

    jobs: list[Job] = field(default_factory=list)

    # ------------------------------------------------------------- #
    def _all_iters(self) -> list[float]:
        out: list[float] = []
        for j in self.jobs:
            out.extend(j.iter_times_ms)
        return out

    _pct = staticmethod(nearest_rank)  # back-compat alias

    @property
    def avg_iter_ms(self) -> float:
        xs = self._all_iters()
        return sum(xs) / len(xs) if xs else float("nan")

    def pct_iter_ms(self, q: float = 99.0) -> float:
        return self._pct(self._all_iters(), q)

    @property
    def jcts_ms(self) -> list[float]:
        return [j.jct_ms for j in self.jobs if j.jct_ms is not None]

    @property
    def avg_jct_ms(self) -> float:
        xs = self.jcts_ms
        return sum(xs) / len(xs) if xs else float("nan")

    def pct_jct_ms(self, q: float = 99.0) -> float:
        return self._pct(self.jcts_ms, q)

    def ecn_per_iter(self, model: str | None = None) -> float:
        marks: list[float] = []
        for j in self.jobs:
            if model is None or j.model == model:
                marks.extend(j.ecn_marks)
        return sum(marks) / len(marks) if marks else 0.0

    def iter_times(self, model: str | None = None) -> list[float]:
        out: list[float] = []
        for j in self.jobs:
            if model is None or j.model == model:
                out.extend(j.iter_times_ms)
        return out

    def slowdowns(self, model: str | None = None) -> list[float]:
        """Per-iteration slowdown factors iter_time / solo_iter_time — the
        scale-free view of the paper's iteration-time CDFs for traces that
        mix fast and slow models."""
        out: list[float] = []
        for j in self.jobs:
            if model is None or j.model == model:
                solo = max(j.solo_iter_ms, 1e-9)
                out.extend(it / solo for it in j.iter_times_ms)
        return out

    @property
    def avg_slowdown(self) -> float:
        xs = self.slowdowns()
        return sum(xs) / len(xs) if xs else float("nan")

    def pct_slowdown(self, q: float = 99.0) -> float:
        return self._pct(self.slowdowns(), q)

    def summary(self) -> dict[str, float]:
        return {
            "avg_iter_ms": self.avg_iter_ms,
            "p99_iter_ms": self.pct_iter_ms(99),
            "avg_slowdown": self.avg_slowdown,
            "p99_slowdown": self.pct_slowdown(99),
            "avg_jct_ms": self.avg_jct_ms,
            "p99_jct_ms": self.pct_jct_ms(99),
            "ecn_per_iter": self.ecn_per_iter(),
            "jobs_finished": float(
                sum(1 for j in self.jobs if j.state == JobState.DONE)
            ),
        }


# ---------------------------------------------------------------------- #
class ClusterSimulator:
    """Event loop: arrivals → scheduling epochs → fluid network advance."""

    def __init__(
        self,
        topology: Topology,
        scheduler: Scheduler,
        *,
        epoch_ms: float = 600_000.0,   # paper: 10-min bidding period
        compute_jitter: float = 0.0,
        migration_pause_ms: float = 1000.0,
        congested_efficiency: float = 0.88,
        vectorized: bool = True,
        incremental: bool = False,
        sharded: bool = False,
        seed: int = 0,
        fault_schedule=None,
    ) -> None:
        self.topo = topology
        self.scheduler = scheduler
        self.epoch_ms = epoch_ms
        # optional repro.chaos.FaultSchedule injected during run(); a fresh
        # FaultInjector cursor is built per run so the simulator can be
        # re-run (and the equivalence harness can replay) from scratch
        self.fault_schedule = fault_schedule
        self.chaos = None
        self.net = FluidNetworkSim(
            topology,
            compute_jitter=compute_jitter,
            migration_pause_ms=migration_pause_ms,
            congested_efficiency=congested_efficiency,
            vectorized=vectorized,
            incremental=incremental,
            sharded=sharded,
            seed=seed,
        )
        self.decisions: list[tuple[float, Decision]] = []

    # -------------------------------------------------------------- #
    def run(self, jobs: list[Job], *, horizon_ms: float = 36_000_000.0) -> Metrics:
        pending = sorted(jobs, key=lambda j: j.arrival_ms)
        running: list[Job] = []
        done: list[Job] = []
        next_epoch = 0.0
        chaos = None
        if self.fault_schedule is not None and not self.fault_schedule.empty:
            # deferred import: repro.chaos depends on repro.cluster
            from repro.chaos.inject import FaultInjector

            chaos = FaultInjector(self.net, self.fault_schedule)
        self.chaos = chaos

        def reschedule(now: float) -> None:
            state = ClusterState(
                topology=self.topo, now_ms=now, running=list(running), pending=[]
            )
            decision = self.scheduler.schedule(state)
            self.decisions.append((now, decision))
            placed: list[Job] = []
            for job in running:
                servers = decision.placements.get(job.job_id, ())
                if servers:
                    job.placement = tuple(servers)
                    job.state = JobState.RUNNING
                    directive = (
                        decision.plan.directive_for(job.job_id)
                        if decision.plan is not None
                        else None
                    )
                    if directive is not None:
                        job.apply_directive(directive)
                    else:
                        job.clear_directive()
                    placed.append(job)
                else:
                    job.placement = ()
                    job.state = JobState.PENDING  # queued: no GPUs this epoch
            self.net.configure(placed)

        while (pending or running) and self.net.now_ms < horizon_ms:
            now = self.net.now_ms
            t_arrival = pending[0].arrival_ms if pending else math.inf
            t_fault = chaos.next_ms if chaos is not None else math.inf
            t_event = min(t_arrival, next_epoch, t_fault, horizon_ms)

            if t_event > now:
                finished = self.net.advance(t_event)
                if finished:
                    for job in finished:
                        running.remove(job)
                        done.append(job)
                    reschedule(self.net.now_ms)  # departure triggers re-place
                    continue
            now = self.net.now_ms
            if chaos is not None and now >= chaos.next_ms - 1e-9:
                # faults due now mutate capacity / job shape / phase; a
                # re-aligning fault triggers an immediate pass unless an
                # arrival at the same instant is about to trigger one anyway
                if chaos.apply_due(now, running) and not (
                    pending and pending[0].arrival_ms <= now + 1e-9
                ):
                    reschedule(now)
            if pending and now >= pending[0].arrival_ms - 1e-9:
                while pending and pending[0].arrival_ms <= now + 1e-9:
                    running.append(pending.pop(0))
                reschedule(now)
            if now >= next_epoch - 1e-9:
                next_epoch = now + self.epoch_ms
                if not (pending and pending[0].arrival_ms <= now + 1e-9):
                    reschedule(now)

        for job in running:  # still running at the horizon: mark explicitly
            if job.state == JobState.RUNNING:
                job.state = JobState.CUTOFF  # finish_ms/jct_ms stay None
        return Metrics(jobs=done + running)
