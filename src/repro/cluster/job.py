"""Job model for the cluster scheduler/simulator."""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

from repro.core.circle import CommPattern
from repro.engine.plan import JobAlignment
from repro.profiles.models import ModelProfile, get_profile

__all__ = ["JobState", "Job"]


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    # still RUNNING when the simulation horizon expired: the job never
    # finished, so ``finish_ms``/``jct_ms`` stay None and it is excluded
    # from the "jobs_finished" metric.
    CUTOFF = "cutoff"


@dataclass
class Job:
    """One training job in the cluster.

    A job requests ``num_workers`` GPUs and runs ``duration_iters`` training
    iterations; the scheduler may change its placement (and CASSINI its
    alignment directive) at every scheduling epoch.
    """

    job_id: str
    model: str
    num_workers: int
    duration_iters: int
    arrival_ms: float = 0.0
    batch_per_gpu: int | None = None

    # runtime state ------------------------------------------------- #
    state: JobState = JobState.PENDING
    placement: tuple[int, ...] = ()          # server ids
    # typed CASSINI directive (shift / pacing / hold), set per epoch by the
    # simulator from the Decision's AlignmentPlan; shift_pending marks a new
    # shift target the workers have not realized yet.
    alignment: JobAlignment = field(default_factory=JobAlignment)
    shift_pending: bool = False
    drift_adjustments: int = 0
    iters_done: int = 0
    iter_times_ms: list[float] = field(default_factory=list)
    ecn_marks: list[float] = field(default_factory=list)
    start_ms: float | None = None
    finish_ms: float | None = None

    # -------------------------------------------------------------- #
    def apply_directive(self, directive: JobAlignment) -> None:
        """Adopt a fresh alignment directive from this epoch's plan."""
        self.alignment = directive
        self.shift_pending = True

    def clear_directive(self) -> None:
        """No directive this epoch: keep the realized shift target but
        disarm pacing (matches an un-augmented scheduling decision)."""
        self.alignment = JobAlignment(shift_ms=self.alignment.shift_ms)
        self.shift_pending = False

    @property
    def time_shift_ms(self) -> float:
        """Current target time-shift (back-compat convenience view)."""
        return self.alignment.shift_ms

    # -------------------------------------------------------------- #
    @property
    def profile(self) -> ModelProfile:
        return get_profile(self.model)

    def pattern(self, num_workers: int | None = None) -> CommPattern:
        return self.profile.pattern(
            num_workers=num_workers or self.num_workers,
            batch_per_gpu=self.batch_per_gpu,
        )

    @property
    def solo_iter_ms(self) -> float:
        return self.profile.iter_time_ms(self.num_workers, self.batch_per_gpu)

    @property
    def jct_ms(self) -> float | None:
        """Job completion time (arrival → finish)."""
        if self.finish_ms is None:
            return None
        return self.finish_ms - self.arrival_ms

    def remaining_iters(self) -> int:
        return max(0, self.duration_iters - self.iters_done)

    # -------------------------------------------------------------- #
    def mean_iter_ms(self) -> float | None:
        if not self.iter_times_ms:
            return None
        return sum(self.iter_times_ms) / len(self.iter_times_ms)

    def __repr__(self) -> str:
        return f"{self.job_id}({self.model}x{self.num_workers})"
