"""Cluster fabric model (paper §5.1 / Fig. 18, generalized).

The paper's testbed is 24 single-GPU servers under a Tofino switch emulating
13 logical switches, 48 bidirectional links and **2:1 oversubscription above
the ToRs**.  We model a two-tier leaf-spine fabric:

    servers ── ToR (leaf) ── spine(s)

- every server has one `host` link to its ToR (full NIC rate),
- every ToR has `uplinks` to the spine tier sized for the requested
  oversubscription ratio (capacity = servers_per_rack × nic / oversub,
  split across `num_spines` physical uplinks),
- routing is deterministic: traffic between two servers in the same rack
  stays under the ToR; cross-rack traffic uses src-ToR→spine→dst-ToR with
  the spine chosen by a stable hash of the (src_rack, dst_rack) pair
  (ECMP-like but reproducible).

Links are unidirectional in our accounting (a, b) ordered pairs; ML
collectives are symmetric so both directions carry the same demand and we
track the pair once as a *bidirectional* link, which matches how the paper
counts its 48 links.
"""

from __future__ import annotations

import functools
import hashlib
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.cluster.errors import UnknownJobError, UnknownLinkError

__all__ = ["Link", "LinkIncidence", "Topology"]


@dataclass(frozen=True)
class Link:
    """A (bidirectional) network link with fixed capacity."""

    name: str
    capacity_gbps: float

    def __repr__(self) -> str:  # keep affinity-graph vertex labels short
        return self.name


@dataclass(frozen=True, eq=False)
class LinkIncidence:
    """CSR-style job×link incidence of one running set.

    Job ``j``'s traversed links (global link-id columns, in ``job_links``
    order) occupy ``cols_flat[starts[j] : starts[j] + counts[j]]``.  Rows
    share one flat backing store but need not be stored contiguously or in
    job order: the delta helpers append new/replacement rows at the store's
    high-water mark and leave holes behind removed rows, so ``with_row`` /
    ``replace_row`` / ``without_row`` touch O(changed-row nnz) column
    memory (plus an O(jobs) ``starts``/``counts`` copy) instead of
    re-walking every unchanged job — the dense per-event rebuild the serve
    path used to pay.  A compacting copy runs only when the garbage
    outgrows the live columns or the store runs out of append room.

    Instances are immutable *values*: the backing store is shared between
    delta-derived instances, but appends only ever write at or beyond the
    shared high-water mark (the ``_used`` ownership token), which every
    existing instance's rows live strictly below — a row view can never be
    overwritten under a live reader.
    """

    starts: np.ndarray     # (jobs,) int64: row j begins at cols_flat[starts[j]]
    counts: np.ndarray     # (jobs,) int64: row j's column count
    cols_flat: np.ndarray  # int32 backing store (capacity ≥ high-water mark)
    capacities: np.ndarray  # (num_links,) float64, topology-global
    num_links: int
    _used: list            # shared single-cell [high-water mark] token
    _my_used: int          # high-water mark when this instance was created

    @classmethod
    def from_rows(
        cls,
        rows: Sequence[np.ndarray],
        capacities: np.ndarray,
        num_links: int,
    ) -> "LinkIncidence":
        rows = [np.asarray(r, dtype=np.int32) for r in rows]
        counts = np.array([r.size for r in rows], dtype=np.int64)
        bounds = np.concatenate(([0], np.cumsum(counts)))
        nnz = int(bounds[-1])
        # 25% append slack so the first few serve-mode arrivals extend in
        # place instead of triggering an immediate copy-grow
        store = np.empty(max(16, nnz + (nnz >> 2)), dtype=np.int32)
        if nnz:
            store[:nnz] = np.concatenate(rows)
        return cls(
            starts=bounds[:-1].copy(), counts=counts, cols_flat=store,
            capacities=capacities, num_links=num_links,
            _used=[nnz], _my_used=nnz,
        )

    @property
    def num_rows(self) -> int:
        return self.counts.size

    @property
    def rows(self) -> tuple[np.ndarray, ...]:
        """Per-job column arrays (views into the shared store)."""
        return tuple(
            self.cols_flat[s: s + c]
            for s, c in zip(self.starts.tolist(), self.counts.tolist())
        )

    @property
    def matrix(self) -> np.ndarray:
        """(jobs, num_links) boolean incidence matrix."""
        m = np.zeros((self.counts.size, self.num_links), dtype=bool)
        for j, cols in enumerate(self.rows):
            m[j, cols] = True
        return m

    @functools.cached_property
    def adjacency(self) -> tuple[list[list[int]], list[list[int]]]:
        """(row → link ids, link id → row ids) as plain python lists.

        The incremental water-filling fill walks these during freeze
        events (a handful of scalar hops per event); python lists beat
        numpy scalar indexing by ~3x there.  Cached per instance — delta-
        derived incidences rebuild it lazily on their first solve.
        """
        rows_l = [
            self.cols_flat[s: s + c].tolist()
            for s, c in zip(self.starts.tolist(), self.counts.tolist())
        ]
        link_rows: list[list[int]] = [[] for _ in range(self.num_links)]
        for j, cols in enumerate(rows_l):
            for g in cols:
                link_rows[g].append(j)
        return rows_l, link_rows

    @functools.cached_property
    def flat_pairs(self) -> tuple[np.ndarray, np.ndarray]:
        """(row ids, link columns) of every (job, link) pair, job-major.

        The whole-graph companion to :meth:`flat_cols`: compacted out of
        the (possibly gappy) shared store once per instance, for passes
        that scan every pair — binding-pair extraction, per-job mark
        totals.  Both arrays are int64 and nnz-long.
        """
        cols = self.flat_cols(np.arange(self.counts.size))
        rows = np.repeat(
            np.arange(self.counts.size, dtype=np.int64), self.counts
        )
        return rows, cols

    @functools.cached_property
    def link_csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Link-major CSR: (starts, counts, row ids) grouped by link.

        The transpose gather of :attr:`flat_pairs` — ``row ids`` holds the
        users of link 0, then link 1, …  The stable sort keeps each link's
        users in ascending row order (the job-major input order), matching
        :attr:`adjacency`'s ``link_rows`` lists.
        """
        rows, cols = self.flat_pairs
        order = np.argsort(cols, kind="stable")
        lcounts = np.bincount(cols, minlength=self.num_links).astype(np.int64)
        lstarts = np.zeros(self.num_links, dtype=np.int64)
        np.cumsum(lcounts[:-1], out=lstarts[1:])
        return lstarts, lcounts, rows[order]

    def link_users(self, links: np.ndarray) -> np.ndarray:
        """Rows using links ``links``, concatenated link-major (int64)."""
        lstarts, lcounts, lrows = self.link_csr
        links = np.asarray(links, dtype=np.int64)
        reps = lcounts[links]
        total = int(reps.sum())
        if total == 0:
            return np.zeros(0, dtype=np.int64)
        shift = np.zeros(links.size, dtype=np.int64)
        np.cumsum(reps[:-1], out=shift[1:])
        pos = np.repeat(lstarts[links] - shift, reps) + np.arange(total)
        return lrows[pos]

    def flat_cols(self, idx: np.ndarray) -> np.ndarray:
        """Rows ``idx``'s link columns concatenated job-major (int64).

        The allocator's gather: O(len(idx) + selected nnz) whatever the
        store's total size, and the output order is exactly the job-major
        order a contiguous CSR walk would produce — which is what keeps
        the from-scratch water-filling solve bit-exact on top of the
        non-contiguous delta store.
        """
        idx = np.asarray(idx, dtype=np.int64)
        reps = self.counts[idx]
        total = int(reps.sum())
        if total == 0:
            return np.zeros(0, dtype=np.int64)
        shift = np.zeros(idx.size, dtype=np.int64)
        np.cumsum(reps[:-1], out=shift[1:])
        pos = np.repeat(self.starts[idx] - shift, reps) + np.arange(total)
        return self.cols_flat[pos].astype(np.int64)

    def sub_incidence(self, rows: np.ndarray, links: np.ndarray) -> np.ndarray:
        """Dense (len(rows), len(links)) boolean sub-incidence.

        The device-sharded fill's slicing primitive: one component's
        member rows against its binding links, cut out of the CSR store
        in O(selected nnz) with a link-id LUT — columns of ``rows``
        outside ``links`` are dropped (a component's members may also use
        non-binding links; those never bound a filling increment).  Row
        columns are unique by construction (``Topology.job_links`` dedups
        per job), so the boolean matrix loses no multiplicity.
        """
        rows = np.asarray(rows, dtype=np.int64)
        links = np.asarray(links, dtype=np.int64)
        m = np.zeros((rows.size, links.size), dtype=bool)
        if rows.size == 0 or links.size == 0:
            return m
        lut = np.full(self.num_links, -1, dtype=np.int64)
        lut[links] = np.arange(links.size)
        cols = self.flat_cols(rows)
        rr = np.repeat(np.arange(rows.size), self.counts[rows])
        loc = lut[cols]
        keep = loc >= 0
        m[rr[keep], loc[keep]] = True
        return m

    # ------------------------- delta updates ---------------------- #
    # Serve mode reconfigures the running set one arrival/departure at a
    # time.  These return an updated incidence touching only the affected
    # row — bit-exact against a full :meth:`Topology.incidence` rebuild of
    # the same running set (tests/test_serve_incremental.py).
    def with_row(self, row: np.ndarray) -> "LinkIncidence":
        """Incidence with one job's link columns appended (job arrival)."""
        row = np.asarray(row, dtype=np.int32)
        m = int(row.size)
        live = int(self.counts.sum())
        used = self._used[0]
        if (
            used == self._my_used                  # we own the store's tail
            and used + m <= self.cols_flat.size    # room to append
            and used - live <= max(64, live)       # garbage still bounded
        ):
            self.cols_flat[used: used + m] = row
            self._used[0] = used + m
            return LinkIncidence(
                starts=np.append(self.starts, used),
                counts=np.append(self.counts, m),
                cols_flat=self.cols_flat,
                capacities=self.capacities, num_links=self.num_links,
                _used=self._used, _my_used=used + m,
            )
        # compact + grow: gather the live rows contiguously into a fresh
        # store (rare path — amortized O(1) appends in between)
        flat = self.flat_cols(np.arange(self.counts.size))
        store = np.empty(max(16, 2 * (live + m)), dtype=np.int32)
        store[:live] = flat
        store[live: live + m] = row
        counts = np.append(self.counts, m)
        bounds = np.concatenate(([0], np.cumsum(counts)))
        return LinkIncidence(
            starts=bounds[:-1].copy(), counts=counts, cols_flat=store,
            capacities=self.capacities, num_links=self.num_links,
            _used=[live + m], _my_used=live + m,
        )

    def without_row(self, index: int) -> "LinkIncidence":
        """Incidence with job ``index``'s row removed (job departure).

        The removed row's columns stay behind as garbage in the shared
        store (compacted by the next ``with_row`` that trips the bound).
        """
        if not 0 <= index < self.counts.size:
            raise UnknownJobError(
                index, range(self.counts.size)
            )
        return LinkIncidence(
            starts=np.delete(self.starts, index),
            counts=np.delete(self.counts, index),
            cols_flat=self.cols_flat,
            capacities=self.capacities, num_links=self.num_links,
            _used=self._used, _my_used=self._my_used,
        )

    def replace_row(self, index: int, row: np.ndarray) -> "LinkIncidence":
        """Incidence with job ``index``'s columns rewritten (in-place
        migration): the new columns are appended at the high-water mark and
        the row repointed — the old columns become garbage."""
        if not 0 <= index < self.counts.size:
            raise UnknownJobError(
                index, range(self.counts.size)
            )
        grown = self.with_row(row)
        starts = grown.starts[:-1].copy()
        counts = grown.counts[:-1].copy()
        starts[index] = grown.starts[-1]
        counts[index] = grown.counts[-1]
        return LinkIncidence(
            starts=starts, counts=counts, cols_flat=grown.cols_flat,
            capacities=self.capacities, num_links=self.num_links,
            _used=grown._used, _my_used=grown._my_used,
        )


def _stable_hash(*parts: object) -> int:
    h = hashlib.blake2s("/".join(map(str, parts)).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


@dataclass
class Topology:
    """Two-tier leaf-spine topology with deterministic routing."""

    num_racks: int
    servers_per_rack: int
    nic_gbps: float = 50.0
    oversubscription: float = 2.0
    num_spines: int = 0  # 0 → derived from the oversubscription ratio
    gpus_per_server: int = 1
    # Heterogeneous fabrics: per-rack NIC rate overriding ``nic_gbps``
    # (one entry per rack; a rack's host links *and* uplinks run at its
    # rate, modelling mixed 50/100 Gbps NIC generations side by side).
    rack_nic_gbps: tuple[float, ...] | None = None

    links: dict[str, Link] = field(default_factory=dict, repr=False)
    # precomputed array-side link indexing (built in __post_init__):
    # stable link-name → id table + the global capacity vector, so the
    # fluid engine's incidence representation is pure id arithmetic.
    link_ids: dict[str, int] = field(
        default_factory=dict, repr=False, compare=False
    )
    link_capacities: np.ndarray = field(
        default=None, repr=False, compare=False
    )
    _job_links_cache: dict = field(
        default_factory=dict, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        # discrete NIC-rate uplinks (as in the paper's fabric): a rack's
        # aggregate uplink capacity is servers × nic / oversub, realized as
        # individual 1×nic-rate links that flows hash onto.
        if self.num_spines <= 0:
            self.num_spines = max(
                1, round(self.servers_per_rack / self.oversubscription)
            )
        if self.rack_nic_gbps is not None:
            self.rack_nic_gbps = tuple(self.rack_nic_gbps)
            if len(self.rack_nic_gbps) != self.num_racks:
                raise ValueError(
                    f"rack_nic_gbps needs one rate per rack: got "
                    f"{len(self.rack_nic_gbps)} for {self.num_racks} racks"
                )
        for r in range(self.num_racks):
            nic = self.rack_nic(r)
            for s in range(self.servers_per_rack):
                name = f"host:r{r}s{s}"
                self.links[name] = Link(name, nic)
            for sp in range(self.num_spines):
                name = f"up:r{r}-sp{sp}"
                self.links[name] = Link(name, nic)
        self.link_ids = {name: i for i, name in enumerate(self.links)}
        self.link_capacities = np.array(
            [l.capacity_gbps for l in self.links.values()], dtype=np.float64
        )

    def set_link_capacity(self, name: str, gbps: float) -> float:
        """Mutate one link's capacity in place; returns the old value.

        The fault-injection primitive behind ``LinkDown``/``LinkDegrade``/
        ``LinkRecover``.  ``Link`` is a frozen value type, but its identity
        is shared everywhere a link appears — ``self.links``, the
        ``job_links`` cache, every ``_JobExec.links`` list — so writing the
        field through ``object.__setattr__`` updates every holder at once
        (the scalar allocator reads ``Link.capacity_gbps`` directly).  The
        ``link_capacities`` vector is shared by reference with every
        ``LinkIncidence`` built from this topology, so the vectorized and
        incremental solvers see the new capacity on their next solve too.
        """
        if gbps < 0:
            raise ValueError(f"negative capacity {gbps} for link {name!r}")
        link = self.links.get(name)
        if link is None:
            raise UnknownLinkError(name, self.links)
        old = float(link.capacity_gbps)
        object.__setattr__(link, "capacity_gbps", float(gbps))
        self.link_capacities[self.link_ids[name]] = float(gbps)
        return old

    def rack_nic(self, rack: int) -> float:
        """NIC rate of one rack (uniform unless ``rack_nic_gbps`` is set)."""
        if self.rack_nic_gbps is not None:
            return self.rack_nic_gbps[rack]
        return self.nic_gbps

    # -------------------------------------------------------------- #
    @classmethod
    def paper_testbed(cls) -> "Topology":
        """The 24-server, 2:1-oversubscribed testbed of §5.1 (4 racks × 6)."""
        return cls(num_racks=4, servers_per_rack=6, nic_gbps=50.0, oversubscription=2.0)

    # -------------------------------------------------------------- #
    @property
    def num_servers(self) -> int:
        return self.num_racks * self.servers_per_rack

    @property
    def num_gpus(self) -> int:
        return self.num_servers * self.gpus_per_server

    def server_of(self, gpu: int) -> int:
        """Placements hold GPU ids; with gpus_per_server > 1 two GPUs can
        share one server (and one NIC)."""
        return gpu // self.gpus_per_server

    def rack_of(self, gpu: int) -> int:
        return self.server_of(gpu) // self.servers_per_rack

    def host_link(self, server: int) -> Link:
        r, s = divmod(server, self.servers_per_rack)
        return self.links[f"host:r{r}s{s}"]

    def uplink(self, rack: int, src_rack: int, dst_rack: int) -> Link:
        sp = (
            _stable_hash(min(src_rack, dst_rack), max(src_rack, dst_rack))
            % self.num_spines
        )
        return self.links[f"up:r{rack}-sp{sp}"]

    # -------------------------------------------------------------- #
    def path(self, src_gpu: int, dst_gpu: int) -> list[Link]:
        """Links traversed by a flow between two GPUs (NVLink-local when
        they share a server → no network links)."""
        src, dst = self.server_of(src_gpu), self.server_of(dst_gpu)
        if src == dst:
            return []
        rs = src // self.servers_per_rack
        rd = dst // self.servers_per_rack
        p = [self.host_link(src)]
        if rs != rd:
            p.append(self.uplink(rs, rs, rd))
            p.append(self.uplink(rd, rs, rd))
        p.append(self.host_link(dst))
        return p

    def job_links(self, gpus: Sequence[int]) -> list[Link]:
        """Links a job's collective traffic traverses.

        Data/hybrid-parallel jobs synchronize with ring collectives over
        their workers ordered by GPU id (NCCL ring order); the job's
        traffic covers every link on every ring edge's path.  Results are
        cached per worker set — placements repeat across scheduling epochs
        and the ring walk re-hashes every ECMP uplink choice.
        """
        ws = tuple(sorted(set(gpus)))
        cached = self._job_links_cache.get(ws)
        if cached is None:
            out: dict[str, Link] = {}
            if len(ws) >= 2:
                for a, b in zip(ws, ws[1:] + ws[:1]):
                    for l in self.path(a, b):
                        out[l.name] = l
            cached = self._job_links_cache[ws] = list(out.values())
        return list(cached)

    def job_link_ids(self, gpus: Sequence[int]) -> np.ndarray:
        """Global link-id columns of :meth:`job_links` (same order)."""
        return np.array(
            [self.link_ids[l.name] for l in self.job_links(gpus)],
            dtype=np.int32,
        )

    def incidence(self, placements: Sequence[Sequence[int]]) -> LinkIncidence:
        """Job×link incidence of a running set, as id arrays.

        The fluid engine rebuilds this once per ``configure`` (placement
        change), never per event: between scheduling decisions the
        incidence — and therefore everything the allocator derives from it
        — is a pure function of which jobs currently communicate.
        """
        return LinkIncidence.from_rows(
            [self.job_link_ids(p) for p in placements],
            capacities=self.link_capacities,
            num_links=len(self.links),
        )

    def shared_links(
        self, placements: dict[object, Sequence[int]]
    ) -> dict[Link, list[object]]:
        """Map of contended links → jobs whose traffic traverses them."""
        by_link: dict[str, tuple[Link, list[object]]] = {}
        for job, servers in placements.items():
            for l in self.job_links(servers):
                by_link.setdefault(l.name, (l, []))[1].append(job)
        return {l: js for l, js in by_link.values() if len(js) > 1}
