"""Cluster fabric model (paper §5.1 / Fig. 18, generalized).

The paper's testbed is 24 single-GPU servers under a Tofino switch emulating
13 logical switches, 48 bidirectional links and **2:1 oversubscription above
the ToRs**.  We model a two-tier leaf-spine fabric:

    servers ── ToR (leaf) ── spine(s)

- every server has one `host` link to its ToR (full NIC rate),
- every ToR has `uplinks` to the spine tier sized for the requested
  oversubscription ratio (capacity = servers_per_rack × nic / oversub,
  split across `num_spines` physical uplinks),
- routing is deterministic: traffic between two servers in the same rack
  stays under the ToR; cross-rack traffic uses src-ToR→spine→dst-ToR with
  the spine chosen by a stable hash of the (src_rack, dst_rack) pair
  (ECMP-like but reproducible).

Links are unidirectional in our accounting (a, b) ordered pairs; ML
collectives are symmetric so both directions carry the same demand and we
track the pair once as a *bidirectional* link, which matches how the paper
counts its 48 links.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

__all__ = ["Link", "LinkIncidence", "Topology"]


@dataclass(frozen=True)
class Link:
    """A (bidirectional) network link with fixed capacity."""

    name: str
    capacity_gbps: float

    def __repr__(self) -> str:  # keep affinity-graph vertex labels short
        return self.name


@dataclass(frozen=True)
class LinkIncidence:
    """Array-resident job×link incidence of one running set.

    Built once per :meth:`Topology.incidence` call (i.e. once per
    ``FluidNetworkSim.configure``, never per event): ``rows[j]`` holds job
    ``j``'s traversed links as global link-id columns (in ``job_links``
    order), ``capacities`` is the topology's global per-link capacity
    vector, and ``matrix`` materializes the dense boolean incidence for
    whole-matrix consumers (tests, invariant probes).
    """

    rows: tuple[np.ndarray, ...]   # per job: int32 global link-id columns
    capacities: np.ndarray         # (num_links,) float64, topology-global
    num_links: int

    @property
    def matrix(self) -> np.ndarray:
        """(jobs, num_links) boolean incidence matrix."""
        m = np.zeros((len(self.rows), self.num_links), dtype=bool)
        for j, cols in enumerate(self.rows):
            m[j, cols] = True
        return m

    # ------------------------- delta updates ---------------------- #
    # Serve mode reconfigures the running set one arrival/departure at a
    # time; rebuilding the whole incidence per event re-walks every
    # unchanged job.  These return an updated incidence touching only the
    # affected row — bit-exact against a full :meth:`Topology.incidence`
    # rebuild of the same running set (tests/test_serve_incremental.py).
    def with_row(self, row: np.ndarray) -> "LinkIncidence":
        """Incidence with one job's link columns appended (job arrival)."""
        return LinkIncidence(
            rows=self.rows + (np.asarray(row, dtype=np.int32),),
            capacities=self.capacities,
            num_links=self.num_links,
        )

    def without_row(self, index: int) -> "LinkIncidence":
        """Incidence with job ``index``'s row removed (job departure)."""
        if not 0 <= index < len(self.rows):
            raise IndexError(
                f"incidence has {len(self.rows)} rows, no index {index}"
            )
        return LinkIncidence(
            rows=self.rows[:index] + self.rows[index + 1:],
            capacities=self.capacities,
            num_links=self.num_links,
        )


def _stable_hash(*parts: object) -> int:
    h = hashlib.blake2s("/".join(map(str, parts)).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


@dataclass
class Topology:
    """Two-tier leaf-spine topology with deterministic routing."""

    num_racks: int
    servers_per_rack: int
    nic_gbps: float = 50.0
    oversubscription: float = 2.0
    num_spines: int = 0  # 0 → derived from the oversubscription ratio
    gpus_per_server: int = 1
    # Heterogeneous fabrics: per-rack NIC rate overriding ``nic_gbps``
    # (one entry per rack; a rack's host links *and* uplinks run at its
    # rate, modelling mixed 50/100 Gbps NIC generations side by side).
    rack_nic_gbps: tuple[float, ...] | None = None

    links: dict[str, Link] = field(default_factory=dict, repr=False)
    # precomputed array-side link indexing (built in __post_init__):
    # stable link-name → id table + the global capacity vector, so the
    # fluid engine's incidence representation is pure id arithmetic.
    link_ids: dict[str, int] = field(
        default_factory=dict, repr=False, compare=False
    )
    link_capacities: np.ndarray = field(
        default=None, repr=False, compare=False
    )
    _job_links_cache: dict = field(
        default_factory=dict, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        # discrete NIC-rate uplinks (as in the paper's fabric): a rack's
        # aggregate uplink capacity is servers × nic / oversub, realized as
        # individual 1×nic-rate links that flows hash onto.
        if self.num_spines <= 0:
            self.num_spines = max(
                1, round(self.servers_per_rack / self.oversubscription)
            )
        if self.rack_nic_gbps is not None:
            self.rack_nic_gbps = tuple(self.rack_nic_gbps)
            if len(self.rack_nic_gbps) != self.num_racks:
                raise ValueError(
                    f"rack_nic_gbps needs one rate per rack: got "
                    f"{len(self.rack_nic_gbps)} for {self.num_racks} racks"
                )
        for r in range(self.num_racks):
            nic = self.rack_nic(r)
            for s in range(self.servers_per_rack):
                name = f"host:r{r}s{s}"
                self.links[name] = Link(name, nic)
            for sp in range(self.num_spines):
                name = f"up:r{r}-sp{sp}"
                self.links[name] = Link(name, nic)
        self.link_ids = {name: i for i, name in enumerate(self.links)}
        self.link_capacities = np.array(
            [l.capacity_gbps for l in self.links.values()], dtype=np.float64
        )

    def rack_nic(self, rack: int) -> float:
        """NIC rate of one rack (uniform unless ``rack_nic_gbps`` is set)."""
        if self.rack_nic_gbps is not None:
            return self.rack_nic_gbps[rack]
        return self.nic_gbps

    # -------------------------------------------------------------- #
    @classmethod
    def paper_testbed(cls) -> "Topology":
        """The 24-server, 2:1-oversubscribed testbed of §5.1 (4 racks × 6)."""
        return cls(num_racks=4, servers_per_rack=6, nic_gbps=50.0, oversubscription=2.0)

    # -------------------------------------------------------------- #
    @property
    def num_servers(self) -> int:
        return self.num_racks * self.servers_per_rack

    @property
    def num_gpus(self) -> int:
        return self.num_servers * self.gpus_per_server

    def server_of(self, gpu: int) -> int:
        """Placements hold GPU ids; with gpus_per_server > 1 two GPUs can
        share one server (and one NIC)."""
        return gpu // self.gpus_per_server

    def rack_of(self, gpu: int) -> int:
        return self.server_of(gpu) // self.servers_per_rack

    def host_link(self, server: int) -> Link:
        r, s = divmod(server, self.servers_per_rack)
        return self.links[f"host:r{r}s{s}"]

    def uplink(self, rack: int, src_rack: int, dst_rack: int) -> Link:
        sp = _stable_hash(min(src_rack, dst_rack), max(src_rack, dst_rack)) % self.num_spines
        return self.links[f"up:r{rack}-sp{sp}"]

    # -------------------------------------------------------------- #
    def path(self, src_gpu: int, dst_gpu: int) -> list[Link]:
        """Links traversed by a flow between two GPUs (NVLink-local when
        they share a server → no network links)."""
        src, dst = self.server_of(src_gpu), self.server_of(dst_gpu)
        if src == dst:
            return []
        rs = src // self.servers_per_rack
        rd = dst // self.servers_per_rack
        p = [self.host_link(src)]
        if rs != rd:
            p.append(self.uplink(rs, rs, rd))
            p.append(self.uplink(rd, rs, rd))
        p.append(self.host_link(dst))
        return p

    def job_links(self, gpus: Sequence[int]) -> list[Link]:
        """Links a job's collective traffic traverses.

        Data/hybrid-parallel jobs synchronize with ring collectives over
        their workers ordered by GPU id (NCCL ring order); the job's
        traffic covers every link on every ring edge's path.  Results are
        cached per worker set — placements repeat across scheduling epochs
        and the ring walk re-hashes every ECMP uplink choice.
        """
        ws = tuple(sorted(set(gpus)))
        cached = self._job_links_cache.get(ws)
        if cached is None:
            out: dict[str, Link] = {}
            if len(ws) >= 2:
                for a, b in zip(ws, ws[1:] + ws[:1]):
                    for l in self.path(a, b):
                        out[l.name] = l
            cached = self._job_links_cache[ws] = list(out.values())
        return list(cached)

    def job_link_ids(self, gpus: Sequence[int]) -> np.ndarray:
        """Global link-id columns of :meth:`job_links` (same order)."""
        return np.array(
            [self.link_ids[l.name] for l in self.job_links(gpus)],
            dtype=np.int32,
        )

    def incidence(self, placements: Sequence[Sequence[int]]) -> LinkIncidence:
        """Job×link incidence of a running set, as id arrays.

        The fluid engine rebuilds this once per ``configure`` (placement
        change), never per event: between scheduling decisions the
        incidence — and therefore everything the allocator derives from it
        — is a pure function of which jobs currently communicate.
        """
        return LinkIncidence(
            rows=tuple(self.job_link_ids(p) for p in placements),
            capacities=self.link_capacities,
            num_links=len(self.links),
        )

    def shared_links(
        self, placements: dict[object, Sequence[int]]
    ) -> dict[Link, list[object]]:
        """Map of contended links → jobs whose traffic traverses them."""
        by_link: dict[str, tuple[Link, list[object]]] = {}
        for job, servers in placements.items():
            for l in self.job_links(servers):
                by_link.setdefault(l.name, (l, []))[1].append(job)
        return {l: js for l, js in by_link.values() if len(js) > 1}
