"""Typed errors for the incremental mutation surface.

Fault injection (``repro.chaos``) and the serve-mode delta path mutate
live simulator state by id — job slots, incidence rows, link capacities.
A bare ``KeyError: 'job-7'`` from three layers down is useless mid-
incident, so the mutation surface raises these instead: each names the
offending id *and* summarizes the live set so the operator can see at a
glance whether the id is stale, misspelled, or belongs to a job that
already departed.

Both subclass :class:`KeyError` (and ``UnknownJobError`` additionally
``IndexError`` for the row-indexed incidence surface) so existing
``except KeyError`` / ``except LookupError`` call sites keep working.
"""

from __future__ import annotations

from typing import Iterable

__all__ = ["UnknownJobError", "UnknownLinkError"]

_PREVIEW = 8  # live-set ids shown before truncating


def _summarize(ids: Iterable[object]) -> str:
    ids = sorted(str(i) for i in ids)
    if not ids:
        return "live set is empty"
    shown = ", ".join(ids[:_PREVIEW])
    more = f", … +{len(ids) - _PREVIEW} more" if len(ids) > _PREVIEW else ""
    return f"{len(ids)} live: {shown}{more}"


class UnknownJobError(KeyError, IndexError):
    """A job id (or incidence row index) not in the live set.

    Subclasses both ``KeyError`` (dict-keyed surfaces: ``remove_job``,
    ``update_job``) and ``IndexError`` (row-indexed surfaces:
    ``LinkIncidence.without_row``/``replace_row``) so either historical
    exception contract still catches it.
    """

    def __init__(self, job_id: object, live: Iterable[object] = ()) -> None:
        self.job_id = job_id
        msg = f"unknown job {job_id!r}; {_summarize(live)}"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message
        return self.args[0]


class UnknownLinkError(KeyError):
    """A link name not present in the topology."""

    def __init__(self, link: object, live: Iterable[object] = ()) -> None:
        self.link = link
        msg = f"unknown link {link!r}; {_summarize(live)}"
        super().__init__(msg)

    def __str__(self) -> str:
        return self.args[0]
