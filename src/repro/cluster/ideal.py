"""Ideal (dedicated-cluster) reference metrics (paper §5.1).

Runs every job alone on a fresh copy of the fabric — no contention ever —
and stitches the resulting per-job iteration times into a Metrics object.
"""

from __future__ import annotations

import copy

from repro.cluster.job import Job
from repro.cluster.network import FluidNetworkSim
from repro.cluster.simulator import Metrics
from repro.cluster.topology import Topology

__all__ = ["ideal_metrics"]


def ideal_metrics(topo: Topology, jobs: list[Job]) -> Metrics:
    out: list[Job] = []
    for j in jobs:
        job = copy.deepcopy(j)
        job.placement = tuple(range(min(job.num_workers, topo.num_gpus)))
        sim = FluidNetworkSim(topo)
        sim.now_ms = job.arrival_ms
        job.state = job.state.RUNNING
        sim.configure([job])
        # a job alone can never be slowed down: advance until done
        horizon = job.arrival_ms + job.duration_iters * job.solo_iter_ms * 3 + 10_000
        sim.advance(horizon)
        out.append(job)
    return Metrics(jobs=out)
