"""psim-style per-link load telemetry for :class:`FluidNetworkSim`.

A :class:`LinkLoadRecorder` attached to a fluid sim observes every
vectorized event interval — the span over which allocated rates are
constant by construction — and accumulates two time-weighted per-link
channels into fixed-width time buckets:

  * **utilization**: delivered rate on the link divided by its capacity
    (Σ member allocated rates / ``capacity_gbps``; ≤ 1 by the
    water-filling invariant, ≤ ``congested_efficiency`` while the link is
    saturated);
  * **mark intensity**: ECN marks per ms generated *on the link* —
    ``max(demand − capacity, 0) × 1e-3 × ecn_marks_per_gbit``, exactly
    the per-link total of the sim's demand-over-capacity marking model
    (the per-job shares of :meth:`FluidNetworkSim._mark_rates_scalar`
    sum to this by construction).

Both channels are exact time integrals over the event intervals (an
event spanning several buckets contributes its overlap to each), so the
exported timeline is independent of event granularity.  Recording costs
one ``bincount`` over the job×link incidence pairs per event and is only
wired into the vectorized engine — attaching a recorder to a scalar sim
is rejected rather than silently recording nothing.

``benchmarks/scaling_curves.py`` renders the exported timeline as the
link-load heatmap artifact (PNG + JSON sidecar, uploaded by CI).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .network import FluidNetworkSim

__all__ = ["LinkLoadRecorder"]


@dataclass
class LinkLoadRecorder:
    """Time-bucketed per-link utilization / ECN-mark timelines.

    ``bucket_ms`` fixes the timeline resolution; buckets are anchored at
    absolute time 0 so replays of the same scenario land in the same
    bins.  Attach with :meth:`FluidNetworkSim.attach_link_recorder`
    before running the simulation.
    """

    bucket_ms: float = 10_000.0
    _sim: "FluidNetworkSim | None" = field(default=None, repr=False)
    # bucket index -> (util_ms, mark_ms) accumulators, each (num_links,)
    _acc: dict[int, tuple[np.ndarray, np.ndarray]] = field(
        default_factory=dict, repr=False
    )

    def _bind(self, sim: "FluidNetworkSim") -> None:
        if self.bucket_ms <= 0:
            raise ValueError(f"bucket_ms must be positive, got {self.bucket_ms}")
        if not sim.vectorized:
            raise ValueError(
                "LinkLoadRecorder requires the vectorized fluid engine "
                "(the scalar oracle has no recording hook)"
            )
        self._sim = sim

    def record(
        self, t0: float, t1: float, comm: np.ndarray, rates: np.ndarray
    ) -> None:
        """Accumulate one constant-rate event interval ``[t0, t1)``.

        Called by the vectorized advance loop with this event's comm mask
        and per-slot allocated rates (both over the sim's slot axis).
        """
        sim = self._sim
        if sim is None or t1 <= t0 or sim._inc is None:
            return
        inc = sim._inc
        caps = inc.capacities
        rows, cols = inc.flat_pairs
        live = comm[rows]
        if not live.any():
            return
        cols = cols[live]
        nl = inc.num_links
        load = np.bincount(cols, weights=rates[rows[live]], minlength=nl)
        demand = np.bincount(
            cols, weights=sim._cap_now[rows[live]], minlength=nl
        )
        util = load / caps
        markr = (
            np.maximum(demand - caps, 0.0) * 1e-3 * sim.ecn_marks_per_gbit
        )
        # spread the interval over the (usually one or two) time buckets
        # it overlaps: exact time integration, any event granularity
        b0 = int(t0 // self.bucket_ms)
        b1 = int(np.ceil(t1 / self.bucket_ms))
        for b in range(b0, max(b1, b0 + 1)):
            lo = max(t0, b * self.bucket_ms)
            hi = min(t1, (b + 1) * self.bucket_ms)
            w = hi - lo
            if w <= 0:
                continue
            acc = self._acc.get(b)
            if acc is None:
                acc = (np.zeros(nl), np.zeros(nl))
                self._acc[b] = acc
            u_acc, m_acc = acc
            u_acc += util * w
            m_acc += markr * w

    # ---------------------------- export --------------------------- #
    def timeline(self) -> dict:
        """Dense timeline arrays over the recorded bucket range.

        Returns ``{"bucket_ms", "t_ms" (B,), "utilization" (B, L),
        "marks_per_ms" (B, L), "link_names" (L,)}`` — utilization is the
        time-mean over each bucket (trailing partially-covered buckets
        are normalized by the covered span, i.e. by ``bucket_ms``, which
        under-reports only if the sim genuinely went idle).
        """
        if not self._acc:
            nl = self._sim._inc.num_links if (
                self._sim is not None and self._sim._inc is not None
            ) else 0
            return {
                "bucket_ms": self.bucket_ms,
                "t_ms": np.zeros(0),
                "utilization": np.zeros((0, nl)),
                "marks_per_ms": np.zeros((0, nl)),
                "link_names": self._link_names(nl),
            }
        b_lo, b_hi = min(self._acc), max(self._acc)
        nl = next(iter(self._acc.values()))[0].shape[0]
        nb = b_hi - b_lo + 1
        util = np.zeros((nb, nl))
        marks = np.zeros((nb, nl))
        for b, (u, m) in self._acc.items():
            util[b - b_lo] = u / self.bucket_ms
            marks[b - b_lo] = m / self.bucket_ms
        t = (np.arange(b_lo, b_hi + 1) + 0.5) * self.bucket_ms
        return {
            "bucket_ms": self.bucket_ms,
            "t_ms": t,
            "utilization": util,
            "marks_per_ms": marks,
            "link_names": self._link_names(nl),
        }

    def _link_names(self, num_links: int) -> list[str]:
        if self._sim is None:
            return [f"link{i}" for i in range(num_links)]
        names = [""] * num_links
        for name, i in self._sim.topo.link_ids.items():
            if i < num_links:
                names[i] = name
        return names

    def to_json(self) -> dict:
        """JSON-serializable timeline (lists instead of arrays)."""
        tl = self.timeline()
        return {
            "bucket_ms": tl["bucket_ms"],
            "t_ms": tl["t_ms"].tolist(),
            "utilization": np.round(tl["utilization"], 6).tolist(),
            "marks_per_ms": np.round(tl["marks_per_ms"], 6).tolist(),
            "link_names": tl["link_names"],
        }
