"""CASSINI core: the paper's contribution as a composable library.

- :mod:`repro.core.circle`    — geometric abstraction (§3)
- :mod:`repro.core.compat`    — compatibility optimization (Table 1)
- :mod:`repro.core.timeshift` — Eq. 5 + drift adjustment (§5.7)
- :mod:`repro.core.affinity`  — affinity graph + Algorithm 1 (§4.1)
- :mod:`repro.core.plugin`    — pluggable module, Algorithm 2 (§4.2)
"""

from .affinity import AffinityGraph, bfs_affinity_time_shifts
from .circle import CommPattern, Phase, UnifiedCircle, unified_perimeter
from .compat import (
    BatchStats,
    CompatResult,
    compatibility_score,
    find_rotations,
    find_rotations_batched,
)
from .plugin import CassiniDecision, CassiniModule, PlacementCandidate
from .timeshift import DriftAdjuster, rotation_to_time_shift

__all__ = [
    "AffinityGraph",
    "bfs_affinity_time_shifts",
    "CommPattern",
    "Phase",
    "UnifiedCircle",
    "unified_perimeter",
    "BatchStats",
    "CompatResult",
    "compatibility_score",
    "find_rotations",
    "find_rotations_batched",
    "CassiniDecision",
    "CassiniModule",
    "PlacementCandidate",
    "DriftAdjuster",
    "rotation_to_time_shift",
]
