"""CASSINI Affinity graph (paper §4.1, Algorithm 1, Theorem 1).

Bipartite graph ``G = (U, V, E)``: ``U`` = jobs that share a path with at
least one other job; ``V`` = links carrying more than one job; an edge
``(j, l)`` exists iff job ``j`` traverses contended link ``l`` and carries
weight ``w_e = t_j^l`` — the per-link time-shift produced by the link-level
optimization (:mod:`repro.core.compat`).

Algorithm 1 extends BFS two ways: (i) only job vertices enter the queue,
and (ii) traversing job→link negates the edge weight while link→job adds
it, so every job ``k`` discovered through reference job ``j`` receives

    t_k = (t_j − w(j,l) + w(l,k)) mod iter_time_k .

Theorem 1: on a loop-free affinity graph this assignment is unique and
preserves, for every pair of jobs on every link, the *relative* time-shift
chosen by the link-level optimization (mod the link's unified-circle
perimeter).  Property-tested in ``tests/test_affinity.py``.
"""

from __future__ import annotations

import math
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Hashable, Iterable, Mapping

__all__ = ["AffinityGraph", "bfs_affinity_time_shifts"]

JobId = Hashable
LinkId = Hashable


@dataclass
class AffinityGraph:
    """Mutable bipartite affinity graph.

    ``weights[(job, link)]`` is the link-level time-shift ``t_j^l`` in ms;
    ``iter_time_ms[job]`` is the job's own iteration time (for the final
    ``mod`` in Algorithm 1); ``perimeter_ms[link]`` is the unified-circle
    perimeter of that link (used by the Theorem-1 correctness check).
    """

    jobs: set[JobId] = field(default_factory=set)
    links: set[LinkId] = field(default_factory=set)
    job_links: dict[JobId, list[LinkId]] = field(default_factory=dict)
    link_jobs: dict[LinkId, list[JobId]] = field(default_factory=dict)
    weights: dict[tuple[JobId, LinkId], float] = field(default_factory=dict)
    iter_time_ms: dict[JobId, float] = field(default_factory=dict)
    perimeter_ms: dict[LinkId, float] = field(default_factory=dict)

    # -------------------------------------------------------------- #
    def add_edge(
        self, job: JobId, link: LinkId, weight_ms: float, iter_time_ms: float
    ) -> None:
        if job not in self.jobs:
            self.jobs.add(job)
            self.job_links[job] = []
        if link not in self.links:
            self.links.add(link)
            self.link_jobs[link] = []
        if link not in self.job_links[job]:
            self.job_links[job].append(link)
        if job not in self.link_jobs[link]:
            self.link_jobs[link].append(job)
        self.weights[(job, link)] = float(weight_ms)
        self.iter_time_ms[job] = float(iter_time_ms)

    @property
    def num_edges(self) -> int:
        return len(self.weights)

    # -------------------------------------------------------------- #
    def connected_components(self) -> list[tuple[set[JobId], set[LinkId]]]:
        """Connected subgraphs ``H ∈ G`` (Algorithm 1 line 3)."""
        seen_jobs: set[JobId] = set()
        comps: list[tuple[set[JobId], set[LinkId]]] = []
        for start in self.jobs:
            if start in seen_jobs:
                continue
            cj: set[JobId] = {start}
            cl: set[LinkId] = set()
            dq: deque[JobId] = deque([start])
            while dq:
                j = dq.popleft()
                for l in self.job_links.get(j, ()):
                    cl.add(l)
                    for k in self.link_jobs.get(l, ()):
                        if k not in cj:
                            cj.add(k)
                            dq.append(k)
            seen_jobs |= cj
            comps.append((cj, cl))
        return comps

    def has_loop(self) -> bool:
        """A connected component with ``|E| ≥ |U_H| + |V_H|`` contains a cycle
        (tree check); CASSINI discards such placements (Alg. 2 line 13)."""
        for cj, cl in self.connected_components():
            edges = sum(
                1 for (j, l) in self.weights if j in cj and l in cl
            )
            if edges >= len(cj) + len(cl):
                return True
        return False

    # -------------------------------------------------------------- #
    def bfs_time_shifts(self, *, seed: int | None = 0) -> dict[JobId, float]:
        """Algorithm 1: unique time-shift per job (milliseconds).

        ``seed`` picks the random reference vertex per component (line 6);
        ``None`` uses the system RNG, an int gives reproducibility, and the
        reference job always receives ``t = 0``.
        """
        rng = random.Random(seed)
        out: dict[JobId, float] = {}
        for cj, _cl in self.connected_components():
            ordered = sorted(cj, key=repr)
            u = rng.choice(ordered)
            t: dict[JobId, float] = {u: 0.0}
            visited: set[JobId] = {u}
            dq: deque[JobId] = deque([u])
            while dq:
                j = dq.popleft()
                for l in self.job_links.get(j, ()):
                    w1 = self.weights[(j, l)]
                    for k in self.link_jobs.get(l, ()):
                        if k in visited:
                            continue
                        visited.add(k)
                        w2 = self.weights[(k, l)]
                        # line 17: t_k = (t_j − w_e1 + w_e2) % iter_time_k
                        t[k] = (t[j] - w1 + w2) % self.iter_time_ms[k]
                        dq.append(k)
            out.update(t)
        return out

    # -------------------------------------------------------------- #
    def check_theorem1(
        self, shifts: Mapping[JobId, float], unit_ms: float = 1e-3
    ) -> bool:
        """Theorem 1 correctness predicate, in its physically-meaningful form.

        Delaying a job by a multiple of its own iteration time leaves its
        periodic traffic unchanged, and delaying *all* jobs on a link by a
        common δ leaves their interleaving unchanged.  So the link-level
        solution ``{t^l_j}`` is preserved on link ``l`` iff the congruence
        system

            δ ≡ t_j − t^l_j   (mod iter_time_j)   for all j on l

        is solvable for a single δ_l.  (The paper states Eq. 6 with
        differences mod ``p^l`` — the same statement before Alg. 1 line 17's
        harmless per-job ``mod iter_time`` reductions.)  Solvability is
        decided by general-modulus CRT on integers in ``unit_ms`` units.
        """

        def to_int(x: float) -> int:
            return int(round(x / unit_ms))

        for l, js in self.link_jobs.items():
            if len(js) < 2:
                continue
            # fold congruences δ ≡ r_j (mod m_j) one by one
            r0, m0 = 0, 1
            for j in js:
                m = to_int(self.iter_time_ms[j])
                r = to_int(shifts[j] - self.weights[(j, l)]) % m
                g = math.gcd(m0, m)
                if (r - r0) % g != 0:
                    return False
                # combine: δ ≡ r0 (mod m0) ∧ δ ≡ r (mod m)
                lcm = m0 // g * m
                # solve r0 + k·m0 ≡ r (mod m)  →  k ≡ (r−r0)/g · inv(m0/g) (mod m/g)
                k = (
                    ((r - r0) // g * pow(m0 // g, -1, m // g)) % (m // g)
                    if m // g > 1
                    else 0
                )
                r0, m0 = (r0 + k * m0) % lcm, lcm
        return True


def bfs_affinity_time_shifts(
    edges: Iterable[tuple[JobId, LinkId, float, float]], *, seed: int | None = 0
) -> dict[JobId, float]:
    """Functional wrapper: ``edges`` are ``(job, link, t_j^l, iter_time_j)``."""
    g = AffinityGraph()
    for job, link, w, it in edges:
        g.add_edge(job, link, w, it)
    return g.bfs_time_shifts(seed=seed)
