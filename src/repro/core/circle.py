"""CASSINI geometric abstraction (paper §3).

A distributed training job's network demand is periodic with its training
iteration.  We "roll" time around a circle whose perimeter equals the
iteration time: every Up (communication-heavy) and Down (compute-heavy)
phase then occupies a fixed arc of the circle, identical across iterations.

Jobs with different iteration times are compared on a *unified circle*
whose perimeter is the least common multiple (LCM) of the iteration times
of all jobs sharing a link; job ``j`` wraps around the unified circle
``r_j = perimeter / iter_time_j`` times (paper Fig. 3).

Everything here is pure, deterministic, and unit-tested; the optimization
over rotation angles lives in :mod:`repro.core.compat`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import reduce
from typing import Sequence

import numpy as np

__all__ = [
    "Phase",
    "CommPattern",
    "UnifiedCircle",
    "quantize_ms",
    "unified_perimeter",
]

# Default angular resolution: 5 degrees (paper Fig. 15 "sweet spot").
DEFAULT_PRECISION_DEG: float = 5.0
# Iteration times are quantized to this grid before computing LCMs so the
# unified-circle perimeter stays bounded (profiled iteration times carry
# measurement noise anyway; the paper's profiler has ~ms resolution).
DEFAULT_QUANTUM_MS: float = 10.0
# Bounds for the adaptive per-link circle (scalability guard, §4.1):
MAX_PERIMETER_FACTOR: float = 12.0   # perimeter ≤ this × longest iteration
MAX_ANGLES: int = 1440               # angle-grid cap


@dataclass(frozen=True)
class Phase:
    """One communication (Up) phase inside a training iteration.

    Attributes:
      start_ms:    offset of the phase start from the iteration start.
      duration_ms: length of the phase.
      gbps:        bandwidth demand during the phase (Gbit/s).
    """

    start_ms: float
    duration_ms: float
    gbps: float

    def __post_init__(self) -> None:
        if self.duration_ms < 0:
            raise ValueError(f"negative phase duration: {self.duration_ms}")
        if self.gbps < 0:
            raise ValueError(f"negative bandwidth demand: {self.gbps}")


@dataclass(frozen=True)
class CommPattern:
    """Periodic per-iteration communication pattern of one job.

    ``phases`` may overlap (hybrid-parallel jobs superimpose AllReduce,
    all-to-all and pipeline traffic); overlapping demands add.
    """

    iter_time_ms: float
    phases: tuple[Phase, ...] = ()
    name: str = ""

    def __post_init__(self) -> None:
        if self.iter_time_ms <= 0:
            raise ValueError(f"iteration time must be positive: {self.iter_time_ms}")
        object.__setattr__(self, "phases", tuple(self.phases))

    # ------------------------------------------------------------------ #
    def demand_at(self, t_ms: np.ndarray | float) -> np.ndarray:
        """Bandwidth demand (Gbps) at time(s) ``t_ms`` (wrapped into the
        iteration)."""
        t = np.asarray(t_ms, dtype=np.float64) % self.iter_time_ms
        total = np.zeros_like(t)
        for ph in self.phases:
            s = ph.start_ms % self.iter_time_ms
            e = s + ph.duration_ms
            inside = (t >= s) & (t < e)
            # phase may wrap around the iteration boundary
            if e > self.iter_time_ms:
                inside |= t < (e - self.iter_time_ms)
            total = total + np.where(inside, ph.gbps, 0.0)
        return total

    def demand_series(self, num_samples: int) -> np.ndarray:
        """Demand sampled at ``num_samples`` uniform points of one iteration."""
        t = np.arange(num_samples, dtype=np.float64) * (self.iter_time_ms / num_samples)
        return self.demand_at(t)

    @property
    def mean_gbps(self) -> float:
        return float(
            sum(p.duration_ms * p.gbps for p in self.phases) / self.iter_time_ms
        )

    @property
    def peak_gbps(self) -> float:
        if not self.phases:
            return 0.0
        return float(np.max(self.demand_series(720)))

    def scaled(self, time_scale: float = 1.0, bw_scale: float = 1.0) -> "CommPattern":
        """A new pattern with scaled iteration time and/or bandwidth (used by
        schedulers when the worker count / batch size of a job changes)."""
        return CommPattern(
            iter_time_ms=self.iter_time_ms * time_scale,
            phases=tuple(
                Phase(
                    p.start_ms * time_scale,
                    p.duration_ms * time_scale,
                    p.gbps * bw_scale,
                )
                for p in self.phases
            ),
            name=self.name,
        )


# ---------------------------------------------------------------------- #
# Unified circle
# ---------------------------------------------------------------------- #
def quantize_ms(t_ms: float, quantum_ms: float = DEFAULT_QUANTUM_MS) -> int:
    """Quantize an iteration time onto the grid (integer number of quanta).

    Rounds *up*: the quantized period is what aligned workers are paced at,
    and a job can always stretch to a longer period (wait at the slot
    boundary) but can never run faster than its own compute+comm allows.
    """
    return max(1, int(math.ceil(t_ms / quantum_ms - 1e-9)))


def unified_perimeter(
    iter_times_ms: Sequence[float], quantum_ms: float = DEFAULT_QUANTUM_MS
) -> float:
    """LCM of the (quantized) iteration times, in milliseconds."""
    ticks = [quantize_ms(t, quantum_ms) for t in iter_times_ms]
    lcm = reduce(math.lcm, ticks, 1)
    return lcm * quantum_ms


@dataclass
class UnifiedCircle:
    """The unified circle for a set of jobs competing on one link.

    ``bw`` is a dense ``(num_jobs, num_angles)`` array: ``bw[j, a]`` is job
    ``j``'s bandwidth demand at discrete angle ``a`` of the unified circle
    (paper Table 1's ``bw_circle_j(α)``).  ``wraps[j]`` is ``r_j``.
    """

    perimeter_ms: float
    num_angles: int
    patterns: tuple[CommPattern, ...]
    bw: np.ndarray = field(repr=False)
    wraps: tuple[int, ...] = ()

    # -------------------------------------------------------------- #
    @classmethod
    def build(
        cls,
        patterns: Sequence[CommPattern],
        *,
        precision_deg: float = DEFAULT_PRECISION_DEG,
        quantum_ms: float = DEFAULT_QUANTUM_MS,
        min_time_res_ms: float | None = None,
    ) -> "UnifiedCircle":
        """Construct the unified circle for ``patterns``.

        The number of discrete angles is ``360 / precision_deg`` but is
        raised if needed so one angle step is no coarser than
        ``min_time_res_ms`` (defaults to ``quantum_ms``) — large LCM
        perimeters would otherwise alias away whole Up phases.
        """
        if not patterns:
            raise ValueError("need at least one job pattern")
        iters = [p.iter_time_ms for p in patterns]
        # Adaptive quantization: mixed iteration times can make the LCM
        # perimeter explode (the scalability concern of paper §4.1).  We
        # coarsen the quantum until the perimeter is a small multiple of the
        # longest iteration — per-link circles stay cheap, at the price of
        # alignment precision on pathological period mixes.
        perimeter = unified_perimeter(iters, quantum_ms)
        cap = MAX_PERIMETER_FACTOR * max(iters)
        while perimeter > cap and quantum_ms < max(iters):
            quantum_ms *= 2.0
            perimeter = unified_perimeter(iters, quantum_ms)
        num_angles = int(round(360.0 / precision_deg))
        res = quantum_ms if min_time_res_ms is None else min_time_res_ms
        num_angles = max(num_angles, int(math.ceil(perimeter / res)))
        num_angles = min(num_angles, MAX_ANGLES)

        # quantized iteration time of each job, in ms, so wraps divide evenly
        q_iter = [
            quantize_ms(p.iter_time_ms, quantum_ms) * quantum_ms for p in patterns
        ]
        wraps = tuple(int(round(perimeter / q)) for q in q_iter)
        # make num_angles a multiple of lcm(wraps): rotating job j by
        # num_angles / r_j steps (one private iteration) must be *exactly*
        # the identity on the discrete circle.
        wraps_lcm = reduce(math.lcm, wraps, 1)
        num_angles = max(int(math.ceil(num_angles / wraps_lcm)), 1) * wraps_lcm

        t = np.arange(num_angles, dtype=np.float64) * (perimeter / num_angles)
        bw = np.stack(
            [
                # stretch the measured pattern onto its quantized period so it
                # tiles the unified circle exactly r_j times
                p.scaled(time_scale=q / p.iter_time_ms).demand_at(t)
                for p, q in zip(patterns, q_iter)
            ]
        )
        return cls(
            perimeter_ms=perimeter,
            num_angles=num_angles,
            patterns=tuple(patterns),
            bw=bw,
            wraps=wraps,
        )

    # -------------------------------------------------------------- #
    @property
    def angle_step_ms(self) -> float:
        return self.perimeter_ms / self.num_angles

    def shift_grid(self, j: int) -> int:
        """Number of *distinct* rotation steps for job ``j``: rotating by one
        full private iteration (``num_angles / r_j`` steps) is the identity on
        the unified circle (paper Eq. 4's bound ``Δ_j ≤ 2π / r_j``)."""
        return max(1, self.num_angles // self.wraps[j])

    def rotated(self, j: int, shift_steps: int) -> np.ndarray:
        """Job ``j``'s demand rotated counter-clockwise by ``shift_steps``
        discrete angles — i.e. the job is *delayed* by
        ``shift_steps * angle_step_ms``."""
        return np.roll(self.bw[j], shift_steps)

    def total_demand(self, shifts: Sequence[int]) -> np.ndarray:
        """Total demand at every angle given per-job shifts (in steps)."""
        if len(shifts) != len(self.patterns):
            raise ValueError("one shift per job required")
        return np.sum([self.rotated(j, s) for j, s in enumerate(shifts)], axis=0)

    def shift_steps_to_ms(self, j: int, shift_steps: int) -> float:
        """Paper Eq. 5: time-shift = (Δ/2π · p) mod iter_time_j."""
        t = (shift_steps / self.num_angles) * self.perimeter_ms
        return float(t % self.patterns[j].iter_time_ms)
