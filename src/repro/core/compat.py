"""CASSINI compatibility optimization (paper §3, Table 1).

Given the unified circle of jobs ``J^l`` sharing link ``l`` with capacity
``C^l``, find per-job rotation angles that maximize

    score = 1 − Σ_α Excess(demand_α) / (|A| · C)          (Table 1, Eq. 2)
    Excess(d) = max(0, d − C)                             (Eq. 1)

subject to Δ_j ∈ [0, 2π / r_j)                            (Eq. 4)

The paper solves this with an off-the-shelf optimizer; because the angle
grid is discrete (5° default) and each job only has ``|A| / r_j`` distinct
rotations, the search space is small and we solve it *exactly* for ≤ 3 jobs
(full product grid) and with seeded coordinate descent above that.  The
inner scoring loop — "score every rotation of one job against a base
demand" — is the compute hot-spot and is implemented three ways:

  * numpy (always available, used for tiny inputs),
  * the full-matrix Pallas TPU kernel :mod:`repro.kernels.circle_score`
    (batched tiles; also the numpy paths' reference), and
  * the *fused-reduction* kernels (``circle_score_argmin`` /
    ``circle_score_segmin``): the per-row argmin (a chunked
    tournament-tree reduction) and the product-grid acceptance scan run
    inside the kernel, so the batched search returns O(problems) scalars
    instead of round-tripping the ``(B, A)`` excess matrix through the
    host (``device_reduce=True``, the default on the kernel-eligible
    paths).  The fused paths are *ragged* by default (``ragged=True``):
    rows from link problems with **different** unified-circle angle
    counts ship as ONE kernel launch per grid chunk / descent step, each
    row masked to its own ``num_angles``/``valid`` window — a
    heterogeneous fabric no longer pays one dispatch per angle-count
    group (``BatchStats.launches``/``ragged_rows``/``pad_fraction``).
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .circle import (
    DEFAULT_PRECISION_DEG,
    DEFAULT_QUANTUM_MS,
    CommPattern,
    UnifiedCircle,
)

__all__ = [
    "CompatResult",
    "BatchStats",
    "excess",
    "score_for_shifts",
    "score_all_shifts",
    "find_rotations",
    "find_rotations_batched",
    "compatibility_score",
]

# Above this many jobs on one link, fall back from the exact product grid to
# coordinate descent (the paper's links carry 2–4 jobs in practice).
MAX_EXACT_JOBS = 3
EXACT_SEARCH_MAX_JOBS = MAX_EXACT_JOBS  # back-compat alias
# The exact product grid is only affordable while the number of admissible
# shift combinations of jobs 1..k−1 stays below this.
EXACT_GRID_LIMIT = 20_000
# Batched grid evaluation materializes base-demand rows in chunks of at most
# this many rows, so a full 20k-combination grid never holds more than
# chunk × A floats at once.
GRID_CHUNK_ROWS = 4096
# The vectorized numpy excess evaluation builds an (Lc, A, A) intermediate
# per row slice; keep it around this many elements so the temporaries stay
# cache-resident — evaluating a full 20k-row batch in one numpy expression
# is 5-6x *slower* (measured) because every pass streams from DRAM.
_NUMPY_CHUNK_ELEMS = 1_000_000
_COORD_DESCENT_SWEEPS = 4
_COORD_DESCENT_SEEDS = 3
# Strict-improvement slack of every acceptance predicate in the rotation
# search: a candidate only displaces the incumbent when its excess is lower
# by more than this.  The device-side accept scan
# (repro.kernels.circle_score.ops) imports this SAME constant and evaluates
# the predicate in float64 — host and device acceptance must never drift.
ACCEPT_SLACK = 1e-12


@dataclass
class BatchStats:
    """Telemetry of one :func:`find_rotations_batched` call.

    Every problem is counted exactly once: single-job problems are
    ``trivial``, problems solved on the batched exact product grid are
    ``grid_problems`` and problems solved by the lockstep-batched coordinate
    descent are ``descent_problems`` — so ``scalar_fallbacks`` is zero by
    construction, and benchmarks/CI assert it stays that way.

    The transfer counters prove the ``(B, A)`` round-trip is gone on the
    fused-reduction paths: ``device_reduced`` counts batched evaluations
    whose argmin/acceptance ran inside the kernel, ``bytes_returned`` the
    bytes that actually crossed the evaluator→search boundary, and
    ``bytes_matrix`` what the full excess matrices would have moved — on
    kernel-eligible shapes ``device_reduced == batched_calls`` and the
    ratio ``bytes_matrix / bytes_returned`` is ~A/2 or better (asserted
    ≥ 100x in the CI bench for large grids).

    The launch counters prove the per-angle-count dispatch fan-out is
    gone on the ragged path: ``launches`` counts kernel dispatches (the
    grouped comparison path pays one per angle-count group per step;
    ragged pays exactly one per grid chunk / descent step —
    ``launches == batched_calls``, asserted in the CI bench),
    ``ragged_rows`` the rows that shipped through ragged single-launch
    batches, and ``pad_fraction`` how much of the ragged launches' lane
    footprint was padding (``ragged_real_elems`` / ``ragged_pad_elems``
    are the raw element counts behind it).
    """

    problems: int = 0
    trivial: int = 0            # single-job links (no search needed)
    grid_problems: int = 0      # solved on the batched exact product grid
    grid_rows: int = 0          # product-grid rows evaluated batched
    descent_problems: int = 0   # solved by batched coordinate descent
    descent_rows: int = 0       # rows evaluated across all descent steps
    batched_calls: int = 0      # number of batched evaluator invocations
    device_reduced: int = 0     # calls whose argmin/accept ran on device
    bytes_returned: int = 0     # bytes returned by batched evaluations
    bytes_matrix: int = 0       # bytes the full (B, A) matrices would move
    launches: int = 0           # kernel dispatches (ragged: one per step)
    ragged_rows: int = 0        # rows shipped via ragged single launches
    ragged_real_elems: int = 0  # real (unpadded) elements in those launches
    ragged_pad_elems: int = 0   # lane-padded elements those launches shipped

    @property
    def scalar_fallbacks(self) -> int:
        """Problems that did not take a batched (or trivial) path."""
        return self.problems - self.trivial - self.grid_problems - self.descent_problems

    @property
    def reduction_ratio(self) -> float:
        """How many times smaller the returned results are than the full
        ``(B, A)`` matrices (1.0 when every call returned the matrix)."""
        if self.bytes_returned == 0:
            return float("inf") if self.bytes_matrix else 1.0
        return self.bytes_matrix / self.bytes_returned

    @property
    def pad_fraction(self) -> float:
        """Fraction of the ragged launches' lane footprint that was padding
        (0.0 when no ragged launch ran)."""
        if self.ragged_pad_elems == 0:
            return 0.0
        return 1.0 - self.ragged_real_elems / self.ragged_pad_elems


@dataclass(frozen=True)
class CompatResult:
    """Output of the link-level optimization (Table 1 output block)."""

    score: float                    # compatibility score (≤ 1, may be negative)
    shifts_steps: tuple[int, ...]   # per-job rotation, in discrete angle steps
    shifts_ms: tuple[float, ...]    # per-job time-shift (Eq. 5), milliseconds
    deltas_rad: tuple[float, ...]   # per-job rotation angle Δ_j in radians
    circle: UnifiedCircle
    capacity_gbps: float
    # The optimization treats job j as exactly periodic with period
    # perimeter / r_j (its *quantized* iteration time).  Workers must pace
    # their iterations at this period for the interleaving to hold — real
    # periods that differ from it precess and collide.
    paced_periods_ms: tuple[float, ...] = ()

    @property
    def fully_compatible(self) -> bool:
        return self.score >= 1.0 - 1e-9


# ---------------------------------------------------------------------- #
# scoring primitives
# ---------------------------------------------------------------------- #
def excess(demand: np.ndarray, capacity: float) -> np.ndarray:
    """Eq. 1."""
    return np.maximum(demand - capacity, 0.0)


def score_from_demand(total_demand: np.ndarray, capacity: float) -> float:
    """Eq. 2 given the summed demand per angle."""
    if capacity <= 0:
        raise ValueError("link capacity must be positive")
    return float(1.0 - excess(total_demand, capacity).mean() / capacity)


def score_for_shifts(
    circle: UnifiedCircle, shifts: Sequence[int], capacity: float
) -> float:
    """Compatibility score for a concrete rotation assignment."""
    return score_from_demand(circle.total_demand(shifts), capacity)


def score_all_shifts(
    base: np.ndarray, cand: np.ndarray, capacity: float, *, backend: str = "auto"
) -> np.ndarray:
    """Score every rotation of one candidate-job demand against a base demand.

    Args:
      base: (A,) summed demand of already-placed jobs at each angle.
      cand: (A,) candidate job demand at each angle.
      capacity: link capacity (Gbps).

    Returns:
      (A,) array: ``out[s] = Σ_α max(0, base[α] + cand[(α − s) mod A] − C)``
      — the *excess sum* for delaying the candidate by ``s`` steps (lower is
      better; the score follows as ``1 − out[s] / (A·C)``).
    """
    base = np.asarray(base, dtype=np.float32)
    cand = np.asarray(cand, dtype=np.float32)
    return _batched_excess(base[None, :], cand[None, :], capacity, backend=backend)[0]


# ---------------------------------------------------------------------- #
# optimization (Table 1)
# ---------------------------------------------------------------------- #
def find_rotations(
    patterns: Sequence[CommPattern],
    capacity_gbps: float,
    *,
    precision_deg: float = DEFAULT_PRECISION_DEG,
    quantum_ms: float = DEFAULT_QUANTUM_MS,
    backend: str = "auto",
    seed: int = 0,
    dilate_steps: int = 1,
) -> CompatResult:
    """Solve Table 1 for the jobs in ``patterns`` sharing one link.

    Returns the best rotation assignment found (exact for ≤ 3 jobs on the
    discrete grid; coordinate descent with multiple seeds above that) and
    the corresponding compatibility score and per-job time-shifts.

    ``dilate_steps`` widens every job's demand arcs by that many discrete
    angles (max-pool) before scoring.  The optimization is discretized, so a
    zero-excess solution *at the sample points* can still overlap by up to
    one angle step in continuous time; scoring on dilated arcs makes
    ``score == 1`` mean true zero overlap (with margin), which is what the
    per-worker alignment agents need to hold the shift without systematic
    drift.
    """
    circle = _build_circle(
        patterns, precision_deg=precision_deg, quantum_ms=quantum_ms,
        dilate_steps=dilate_steps,
    )
    shifts = _search(circle, capacity_gbps, backend=backend, seed=seed)
    return _finalize(circle, shifts, capacity_gbps)


def find_rotations_batched(
    problems: Sequence[tuple[Sequence[CommPattern], float]],
    *,
    precision_deg: float = DEFAULT_PRECISION_DEG,
    quantum_ms: float = DEFAULT_QUANTUM_MS,
    backend: str = "auto",
    seed: int = 0,
    dilate_steps: int = 1,
    stats: BatchStats | None = None,
    device_reduce: bool = True,
    ragged: bool = True,
    tuned: bool = True,
) -> list[CompatResult]:
    """Solve many independent link-level Table-1 problems in one pass.

    ``problems`` is a sequence of ``(patterns, capacity_gbps)`` pairs — one
    per contended link (across *all* placement candidates of a scheduling
    epoch).  Every problem takes a batched path:

      * ``k ≤ MAX_EXACT_JOBS`` jobs whose admissible shift combinations fit
        :data:`EXACT_GRID_LIMIT` — the scalar path's exact-search regime —
        enumerate the (k−1)-dimensional shift product grid as rows of a
        base-demand array (jobs 1..k−2 baked into each row, the last job
        scored for all its rotations at once), chunked to
        :data:`GRID_CHUNK_ROWS`.  On the default *ragged* kernel path all
        kernel-eligible rows of a chunk — **whatever mix of angle counts**
        — ship as ONE launch (:func:`_batched_segmin_ragged`: per-row
        ``num_angles`` masking, tournament-tree argmin and the
        product-grid acceptance scan all inside the kernel, O(problems)
        scalars back).  Non-eligible (small-angle) rows keep the
        vectorized-numpy full-matrix evaluation, grouped by angle count.

      * everything above the exact-grid cutoff runs the same seeded
        coordinate descent as the scalar path, but *lockstep-batched*: at
        each (trial, sweep, job) step the "score every rotation of the job
        being optimized" rows of all still-active problems are packed into
        one batched call — one ragged launch per step on the kernel path
        (:func:`_batched_argmin_ragged`), so each step returns one
        accepted shift per problem instead of the per-problem rotation
        rows.

    ``ragged=False`` restores the per-angle-count grouping (one launch per
    angle-count group per chunk/step — the pre-ragged behaviour, kept as
    the benchmark comparison path); ``device_reduce=False`` forces the
    full-matrix evaluation + host reduction everywhere (the pre-fusion
    behaviour, which is always grouped).  ``tuned=False`` pins every
    kernel launch to the untuned module-default schedule instead of the
    per-bucket tuning table (:mod:`repro.kernels.tune`) — schedule
    parameters are bit-inert for this family, so tuned on/off changes
    wall time only, never a shift (tests assert it).  Results are bit-identical on
    every path — tests assert it; the fold-sum padding invariance of the
    kernel family is what makes the ragged launch exact.  Pass a
    :class:`BatchStats` to observe which path each problem took
    (benchmarks assert ``scalar_fallbacks == 0``, ``device_reduced`` /
    ``bytes_returned`` prove the ``(B, A)`` round-trip is gone, and
    ``launches == batched_calls`` proves one kernel launch per
    grid-chunk/descent step on the ragged path).

    Returns one :class:`CompatResult` per problem, in input order,
    bit-identical to what per-problem ``find_rotations`` calls would produce
    (same circle construction, same argmin tie-breaking and improvement
    slack, same normalization).
    """
    stats = stats if stats is not None else BatchStats()
    stats.problems += len(problems)
    results: list[CompatResult | None] = [None] * len(problems)
    grid_probs: list[_GridProblem] = []
    descent_probs: list[_DescentState] = []
    for i, (patterns, capacity) in enumerate(problems):
        circle = _build_circle(
            patterns, precision_deg=precision_deg, quantum_ms=quantum_ms,
            dilate_steps=dilate_steps,
        )
        n = len(circle.patterns)
        grids = [circle.shift_grid(j) for j in range(n)]
        # Route exactly as the scalar _search does, so both paths stay
        # result-identical at any precision / job count.
        if n == 1:
            stats.trivial += 1
            results[i] = _finalize(circle, (0,), capacity)
        elif n <= MAX_EXACT_JOBS and int(np.prod(grids[1:])) <= EXACT_GRID_LIMIT:
            grid_probs.append(_GridProblem(i, circle, grids, float(capacity)))
        else:
            descent_probs.append(
                _DescentState(i, circle, grids, float(capacity), seed)
            )

    if grid_probs:
        _solve_grids_batched(
            grid_probs, backend, stats, device_reduce, ragged, tuned
        )
        stats.grid_problems += len(grid_probs)
        for gp in grid_probs:
            results[gp.index] = _finalize(gp.circle, gp.best, gp.capacity)
    if descent_probs:
        _solve_descent_batched(
            descent_probs, backend, stats, device_reduce, ragged, tuned
        )
        stats.descent_problems += len(descent_probs)
        for dp in descent_probs:
            results[dp.index] = _finalize(dp.circle, dp.best, dp.capacity)
    return [r for r in results if r is not None]


def _build_circle(
    patterns: Sequence[CommPattern],
    *,
    precision_deg: float,
    quantum_ms: float,
    dilate_steps: int,
) -> UnifiedCircle:
    """Unified circle with optional arc dilation (see find_rotations)."""
    import dataclasses

    circle = UnifiedCircle.build(
        patterns, precision_deg=precision_deg, quantum_ms=quantum_ms
    )
    if dilate_steps > 0:
        bw = circle.bw
        dilated = bw.copy()
        for s in range(1, dilate_steps + 1):
            dilated = np.maximum(dilated, np.roll(bw, s, axis=1))
            dilated = np.maximum(dilated, np.roll(bw, -s, axis=1))
        circle = dataclasses.replace(circle, bw=dilated)
    return circle


def _search(
    circle: UnifiedCircle, capacity_gbps: float, *, backend: str, seed: int
) -> tuple[int, ...]:
    """Pick the search strategy for one circle (Table 1 solve)."""
    n = len(circle.patterns)
    grids = [circle.shift_grid(j) for j in range(n)]
    if n == 1:
        return (0,)
    if n <= MAX_EXACT_JOBS and int(np.prod([g for g in grids[1:]])) <= EXACT_GRID_LIMIT:
        return _exact_search(circle, grids, capacity_gbps, backend)
    return _coordinate_descent(circle, grids, capacity_gbps, backend, seed)


def _finalize(
    circle: UnifiedCircle, shifts: Sequence[int], capacity_gbps: float
) -> CompatResult:
    """Score + normalize a rotation assignment into a CompatResult."""
    n = len(circle.patterns)
    score = score_for_shifts(circle, shifts, capacity_gbps)
    # normalize so the first job's shift is zero: only *relative* rotations
    # matter (global rotation leaves the score unchanged), and a zero shift
    # for the reference job makes time-shifts minimal / reproducible.
    shifts = _normalize_shifts(circle, shifts)
    shifts_ms = tuple(circle.shift_steps_to_ms(j, s) for j, s in enumerate(shifts))
    deltas = tuple(2.0 * np.pi * s / circle.num_angles for s in shifts)
    paced = tuple(circle.perimeter_ms / circle.wraps[j] for j in range(n))
    return CompatResult(
        score=score,
        shifts_steps=tuple(shifts),
        shifts_ms=shifts_ms,
        deltas_rad=deltas,
        circle=circle,
        capacity_gbps=capacity_gbps,
        paced_periods_ms=paced,
    )


def _kernel_eligible(backend: str, num_angles: int) -> bool:
    """Shapes the Pallas kernel family handles (mirrors ``_batched_excess``'s
    routing so the fused and full-matrix paths always agree on backends)."""
    return backend == "pallas" or (backend == "auto" and num_angles >= 512)


def _batched_excess(
    base: np.ndarray,
    cand: np.ndarray,
    capacity: float | np.ndarray,
    *,
    backend: str = "auto",
    stats: BatchStats | None = None,
    tuned: bool = True,
) -> np.ndarray:
    """Excess sums for every rotation of ``L`` independent rows at once.

    ``out[l, s] = Σ_α max(0, base[l, α] + cand[l, (α − s) mod A] − C_l)``.

    ``capacity`` is a scalar shared by every row or an ``(L,)`` array of
    per-row capacities — per-row capacities are what let rows from links
    with *different* capacities share one batched call (only the angle
    count must match).

    ``backend="auto"`` routes large angle grids to the Pallas
    ``circle_score`` kernel (one batched call over all rows — the TPU
    target's hot path) and everything else to a vectorized numpy evaluation;
    ``"pallas"`` / ``"numpy"`` force a path.  Both produce float32 sums like
    the scalar :func:`score_all_shifts`.

    This is the *full-matrix* evaluator: the whole ``(L, A)`` result crosses
    back to the caller (``stats`` records it), and the argmin/acceptance
    happens host-side.  The fused :func:`_batched_argmin` /
    :func:`_batched_segmin` replace it on the kernel-eligible hot paths.
    """
    base = np.asarray(base, dtype=np.float32)
    cand = np.asarray(cand, dtype=np.float32)
    l, a = base.shape
    cap = np.asarray(capacity, dtype=np.float32)
    if stats is not None:
        stats.bytes_returned += l * a * 4
        stats.bytes_matrix += l * a * 4
    if _kernel_eligible(backend, a):
        try:
            from repro.kernels.circle_score import ops as _cs_ops

            out = np.asarray(_cs_ops.circle_score(base, cand, cap, tuned=tuned))
        except Exception:  # pragma: no cover - fallback if pallas unavailable
            pass
        else:
            if stats is not None:
                stats.launches += 1
            return out
    idx = _roll_index(a)                                       # (S, A)
    cap_rows = np.broadcast_to(cap.reshape(-1, 1, 1), (l, 1, 1))
    out = np.empty((l, a), dtype=np.float32)
    # chunk rows so the (Lc, A, A) rolled/total temporaries stay cache-sized
    # regardless of batch size (see _NUMPY_CHUNK_ELEMS)
    step = max(1, _NUMPY_CHUNK_ELEMS // (a * a))
    for i in range(0, l, step):
        rolled = cand[i:i + step][:, idx]                      # (Lc, S, A)
        total = base[i:i + step, None, :] + rolled
        out[i:i + step] = np.maximum(total - cap_rows[i:i + step], 0.0).sum(axis=-1)
    return out


def _batched_argmin(
    base: np.ndarray,
    cand: np.ndarray,
    capacity: np.ndarray,
    valid: np.ndarray,
    *,
    backend: str,
    stats: BatchStats | None = None,
    tuned: bool = True,
) -> tuple[np.ndarray, np.ndarray] | None:
    """Fused per-row rotation search: ``(best_shift, best_excess)`` per row.

    Device path only — returns ``None`` when the shape is not
    kernel-eligible (or the kernel import fails) so the caller can fall
    back to the full-matrix evaluation + host ``np.argmin``.  On success
    only O(L) scalars left the device: ``stats.device_reduced`` counts the
    call and ``bytes_returned`` grows by the reduced result size instead
    of the ``(L, A)`` matrix.
    """
    l, a = np.asarray(base).shape
    if not _kernel_eligible(backend, a):
        return None
    try:
        from repro.kernels.circle_score import ops as _cs_ops

        idx, val = _cs_ops.circle_score_argmin(
            base, cand, capacity, valid, tuned=tuned
        )
        idx, val = np.asarray(idx), np.asarray(val)
    except Exception:  # pragma: no cover - fallback if pallas unavailable
        return None
    if stats is not None:
        stats.device_reduced += 1
        stats.launches += 1
        stats.bytes_returned += idx.nbytes + val.nbytes
        stats.bytes_matrix += l * a * 4
    return idx, val


def _batched_argmin_ragged(
    base: np.ndarray,
    cand: np.ndarray,
    capacity: np.ndarray,
    valid: np.ndarray,
    num_angles: np.ndarray,
    *,
    stats: BatchStats | None = None,
    tuned: bool = True,
) -> tuple[np.ndarray, np.ndarray] | None:
    """Ragged fused rotation search: mixed angle counts, ONE launch.

    ``base`` / ``cand`` are packed ``(L, W)`` rows (row ``l`` real in
    ``[:num_angles[l]]``, zero above).  The caller has already partitioned
    rows by kernel eligibility, so this only returns ``None`` when the
    kernel import itself fails (pallas unavailable) — the caller then
    falls back to the grouped full-matrix evaluation.
    """
    try:
        from repro.kernels.circle_score import ops as _cs_ops

        idx, val = _cs_ops.circle_score_ragged_argmin(
            base, cand, capacity, valid, num_angles, tuned=tuned
        )
        idx, val = np.asarray(idx), np.asarray(val)
    except ValueError:
        raise  # input-validation rejections must not become silent fallbacks
    except Exception:  # pragma: no cover - fallback if pallas unavailable
        return None
    if stats is not None:
        _account_ragged(stats, base.shape, num_angles)
        stats.bytes_returned += idx.nbytes + val.nbytes
    return idx, val


def _batched_segmin(
    base: np.ndarray,
    cand: np.ndarray,
    capacity: np.ndarray,
    valid: np.ndarray,
    seg_ids: np.ndarray,
    init_best: np.ndarray,
    *,
    backend: str,
    stats: BatchStats | None = None,
    tuned: bool = True,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray] | None:
    """Fused per-row search + segmented acceptance scan, fully on device.

    One segment = the contiguous product-grid rows of one link problem
    within the chunk; ``init_best`` carries each problem's incumbent best
    excess across chunk boundaries, so the device scan replays the host
    acceptance rule (strict 1e-12 improvement, rows in product order)
    exactly.  Returns ``(accepted, row, shift, best)`` per segment — four
    O(segments) vectors instead of the ``(B, A)`` matrix — or ``None``
    when not kernel-eligible.
    """
    l, a = np.asarray(base).shape
    if not _kernel_eligible(backend, a):
        return None
    try:
        from repro.kernels.circle_score import ops as _cs_ops

        acc, row, shift, best = _cs_ops.circle_score_segmin(
            base, cand, capacity, valid, seg_ids, init_best, tuned=tuned
        )
        acc, row, shift, best = (
            np.asarray(acc), np.asarray(row), np.asarray(shift), np.asarray(best)
        )
    except Exception:  # pragma: no cover - fallback if pallas unavailable
        return None
    if stats is not None:
        stats.device_reduced += 1
        stats.launches += 1
        stats.bytes_returned += acc.nbytes + row.nbytes + shift.nbytes + best.nbytes
        stats.bytes_matrix += l * a * 4
    return acc, row, shift, best


def _batched_segmin_ragged(
    base: np.ndarray,
    cand: np.ndarray,
    capacity: np.ndarray,
    valid: np.ndarray,
    num_angles: np.ndarray,
    seg_ids: np.ndarray,
    init_best: np.ndarray,
    *,
    stats: BatchStats | None = None,
    tuned: bool = True,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray] | None:
    """Ragged fused search + segmented acceptance scan: ONE launch per
    chunk, whatever mix of angle counts the chunk's problems carry (see
    :func:`_batched_segmin` for the segment semantics).  Returns ``None``
    only when the kernel import fails."""
    try:
        from repro.kernels.circle_score import ops as _cs_ops

        acc, row, shift, best = _cs_ops.circle_score_ragged_segmin(
            base, cand, capacity, valid, num_angles, seg_ids, init_best,
            tuned=tuned,
        )
        acc, row, shift, best = (
            np.asarray(acc), np.asarray(row), np.asarray(shift), np.asarray(best)
        )
    except ValueError:
        raise  # input-validation rejections must not become silent fallbacks
    except Exception:  # pragma: no cover - fallback if pallas unavailable
        return None
    if stats is not None:
        _account_ragged(stats, base.shape, num_angles)
        stats.bytes_returned += acc.nbytes + row.nbytes + shift.nbytes + best.nbytes
    return acc, row, shift, best


def _account_ragged(
    stats: BatchStats, shape: tuple[int, int], num_angles: np.ndarray
) -> None:
    """Launch/row/padding telemetry shared by the ragged evaluators.

    ``bytes_matrix`` grows by each row's *real* width (Σ A_l · 4), exactly
    what the grouped full-matrix path would account for the same rows, so
    ragged-on/off byte comparisons stay apples-to-apples.  The padded
    footprint uses the launch's *bucketed* width (the wrapper rounds the
    packed width up to a power-of-two multiple of the lane size), so
    ``pad_fraction`` reports what actually shipped.
    """
    l, w = shape
    try:
        from repro.kernels.circle_score.ops import bucket_width

        wl = bucket_width(w)
    except Exception:  # pragma: no cover - pallas unavailable
        wl = w
    stats.device_reduced += 1
    stats.launches += 1
    stats.ragged_rows += l
    stats.ragged_real_elems += int(np.sum(num_angles))
    stats.ragged_pad_elems += l * wl
    stats.bytes_matrix += int(np.sum(num_angles)) * 4


@functools.lru_cache(maxsize=16)
def _roll_index(a: int) -> np.ndarray:
    """``idx[s, α] = (α − s) mod A`` — the gather realizing all A rolls."""
    return (np.arange(a)[None, :] - np.arange(a)[:, None]) % a


def compatibility_score(
    patterns: Sequence[CommPattern], capacity_gbps: float, **kw
) -> float:
    """Convenience: just the score (paper's compatibility *rank* input)."""
    return find_rotations(patterns, capacity_gbps, **kw).score


# ---------------------------------------------------------------------- #
# search strategies
# ---------------------------------------------------------------------- #
def _exact_search(
    circle: UnifiedCircle,
    grids: Sequence[int],
    capacity: float,
    backend: str,
) -> tuple[int, ...]:
    """Full product grid over jobs 1..n−1 (job 0 pinned at 0 by rotation
    invariance); the innermost job is scored for *all* its rotations at once
    via :func:`score_all_shifts`."""
    n = len(grids)
    if n == 1:
        return (0,)
    last = n - 1
    best_excess = np.inf
    best: tuple[int, ...] = (0,) * n
    outer_grids = [range(g) for g in grids[1:last]]  # jobs 1..n−2
    base0 = circle.bw[0]
    for mid in itertools.product(*outer_grids):
        base = base0.copy()
        for j, s in enumerate(mid, start=1):
            base += circle.rotated(j, s)
        ex = score_all_shifts(base, circle.bw[last], capacity, backend=backend)
        ex = ex[: grids[last]]  # Eq. 4 bound: distinct rotations only
        s_last = int(np.argmin(ex))
        if ex[s_last] < best_excess - ACCEPT_SLACK:
            best_excess = float(ex[s_last])
            best = (0, *mid, s_last)
        if best_excess == 0.0:
            break  # fully compatible; nothing can beat zero excess
    return best


def _coordinate_descent(
    circle: UnifiedCircle,
    grids: Sequence[int],
    capacity: float,
    backend: str,
    seed: int,
) -> tuple[int, ...]:
    """Seeded coordinate descent: repeatedly re-place each job against the sum
    of all the others, scoring every rotation at once."""
    rng = np.random.default_rng(seed)
    n = len(grids)
    best: tuple[int, ...] = (0,) * n
    best_excess = np.inf
    for trial in range(_COORD_DESCENT_SEEDS):
        if trial == 0:
            shifts = np.zeros(n, dtype=np.int64)
        else:
            shifts = np.array([rng.integers(0, g) for g in grids], dtype=np.int64)
        rotated = np.stack([circle.rotated(j, int(shifts[j])) for j in range(n)])
        total = rotated.sum(axis=0)
        for _ in range(_COORD_DESCENT_SWEEPS):
            changed = False
            for j in range(n):
                base = total - rotated[j]
                ex = score_all_shifts(base, circle.bw[j], capacity, backend=backend)
                ex = ex[: grids[j]]
                s_new = int(np.argmin(ex))
                if s_new != shifts[j]:
                    shifts[j] = s_new
                    new_rot = circle.rotated(j, s_new)
                    total = base + new_rot
                    rotated[j] = new_rot
                    changed = True
            if not changed:
                break
        ex_now = float(np.maximum(total - capacity, 0.0).sum())
        if ex_now < best_excess - ACCEPT_SLACK:
            best_excess = ex_now
            best = tuple(int(s) for s in shifts)
        if best_excess == 0.0:
            break
    return best


# ---------------------------------------------------------------------- #
# batched search (k-job product grids + lockstep coordinate descent)
# ---------------------------------------------------------------------- #
class _GridProblem:
    """One ≤ MAX_EXACT_JOBS link problem destined for the batched exact grid.

    Mirrors :func:`_exact_search` exactly: job 0 is pinned at shift 0, jobs
    1..k−2 span the outer product grid (one base-demand row per
    combination), and the last job is scored for *all* its admissible
    rotations within each row.  ``update`` replays the scalar loop's
    acceptance rule (strict improvement with 1e-12 slack, rows visited in
    ``itertools.product`` order), so the arg-result is bit-identical.
    """

    __slots__ = ("index", "circle", "grids", "capacity", "last",
                 "best", "best_excess")

    def __init__(
        self, index: int, circle: UnifiedCircle, grids: Sequence[int], capacity: float
    ) -> None:
        self.index = index
        self.circle = circle
        self.grids = list(grids)
        self.capacity = capacity
        self.last = len(grids) - 1
        self.best: tuple[int, ...] = (0,) * len(grids)
        self.best_excess = float(np.inf)

    def iter_rows(self):
        """Yield ``(mid_shifts, base_row)`` in scalar product order.

        ``base_row`` is accumulated in float64 in the same job order as the
        scalar search (bw[0] + rotated(1) + …) so the float32 cast inside
        :func:`_batched_excess` sees identical inputs.
        """
        base0 = self.circle.bw[0]
        outer = [range(g) for g in self.grids[1:self.last]]
        for mid in itertools.product(*outer):
            if self.best_excess == 0.0:
                return  # fully compatible; nothing can beat zero excess
            base = base0.copy()
            for j, s in enumerate(mid, start=1):
                base += self.circle.rotated(j, s)
            yield mid, base

    def update(self, mid: tuple[int, ...], row: np.ndarray) -> None:
        ex = row[: self.grids[self.last]]  # Eq. 4 bound
        s_last = int(np.argmin(ex))
        if float(ex[s_last]) < self.best_excess - ACCEPT_SLACK:
            self.best_excess = float(ex[s_last])
            self.best = (0, *mid, s_last)


def _solve_grids_batched(
    probs: Sequence[_GridProblem],
    backend: str,
    stats: BatchStats,
    device_reduce: bool = True,
    ragged: bool = True,
    tuned: bool = True,
) -> None:
    """Evaluate every problem's product grid through chunked batched calls.

    On the default ragged kernel path every kernel-eligible problem —
    whatever its angle count — feeds ONE shared pending-row stream,
    flushed every :data:`GRID_CHUNK_ROWS` rows as a single ragged launch
    (:func:`_solve_grids_ragged`).  Non-eligible problems (and the
    ``ragged=False`` / ``device_reduce=False`` comparison modes) keep the
    per-angle-count grouping (:func:`_solve_grids_grouped`).  All paths
    replay the scalar loop's tie-breaking exactly; flushing between
    chunks also lets ``iter_rows`` early-out the moment a problem reaches
    zero excess, exactly like the scalar break.
    """
    if ragged and device_reduce:
        kernel_probs = [
            p for p in probs if _kernel_eligible(backend, p.circle.num_angles)
        ]
        if kernel_probs:
            _solve_grids_ragged(kernel_probs, backend, stats, tuned)
        probs = [
            p for p in probs if not _kernel_eligible(backend, p.circle.num_angles)
        ]
    if probs:
        _solve_grids_grouped(probs, backend, stats, device_reduce, tuned)


def _grid_segments(
    pending: Sequence[tuple["_GridProblem", tuple[int, ...], np.ndarray]],
) -> tuple[list["_GridProblem"], np.ndarray, np.ndarray]:
    """Contiguous per-problem segments of a pending-row chunk (rows were
    appended problem-by-problem in product order): ``(segs, seg_ids,
    init)`` where ``init`` carries each problem's incumbent best excess
    into the device acceptance scan."""
    segs: list[_GridProblem] = []
    seg_ids = np.empty(len(pending), dtype=np.int32)
    for r, (p, _, _) in enumerate(pending):
        if not segs or segs[-1] is not p:
            segs.append(p)
        seg_ids[r] = len(segs) - 1
    init = np.array([p.best_excess for p in segs], dtype=np.float64)
    return segs, seg_ids, init


def _apply_segmin(
    segs: Sequence["_GridProblem"],
    pending: Sequence[tuple["_GridProblem", tuple[int, ...], np.ndarray]],
    reduced: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray],
) -> None:
    """Write the device acceptance scan's per-segment results back into
    the problems (shared by the ragged and grouped flushes — the two
    paths must stay bit-identical)."""
    acc, row, shift, best = reduced
    for s, p in enumerate(segs):
        if acc[s]:
            p.best_excess = float(best[s])
            p.best = (0, *pending[row[s]][1], int(shift[s]))


def _solve_grids_ragged(
    probs: Sequence[_GridProblem],
    backend: str,
    stats: BatchStats,
    tuned: bool = True,
) -> None:
    """One ragged launch per grid chunk: rows from *all* problems, mixed
    angle counts, packed to the chunk's max width with per-row
    ``num_angles`` riding into the kernel.  Segments stay contiguous
    (rows are appended problem-by-problem in product order) and each
    problem's incumbent best rides in as its segment's init, so the
    device acceptance scan replays the host rule exactly — results are
    bit-identical to the per-group launches by the fold-sum padding
    invariance."""
    pending: list[tuple[_GridProblem, tuple[int, ...], np.ndarray]] = []

    def flush() -> None:
        if not pending:
            return
        stats.batched_calls += 1
        stats.grid_rows += len(pending)
        widths = np.array(
            [p.circle.num_angles for p, _, _ in pending], dtype=np.int32
        )
        w = int(widths.max())
        base = np.zeros((len(pending), w))
        cand = np.zeros((len(pending), w))
        for r, (p, _, row) in enumerate(pending):
            base[r, : row.shape[0]] = row
            cand[r, : row.shape[0]] = p.circle.bw[p.last]
        caps = np.array([p.capacity for p, _, _ in pending], dtype=np.float32)
        valid = np.array([p.grids[p.last] for p, _, _ in pending], dtype=np.int32)
        segs, seg_ids, init = _grid_segments(pending)
        reduced = _batched_segmin_ragged(
            base, cand, caps, valid, widths, seg_ids, init,
            stats=stats, tuned=tuned,
        )
        if reduced is not None:
            _apply_segmin(segs, pending, reduced)
        else:  # pragma: no cover - pallas unavailable: grouped full-matrix
            by_angles: dict[int, list[int]] = {}
            for r, (p, _, _) in enumerate(pending):
                by_angles.setdefault(p.circle.num_angles, []).append(r)
            for a, rows in by_angles.items():
                ex = _batched_excess(
                    base[rows][:, :a], cand[rows][:, :a], caps[rows],
                    backend=backend, stats=stats, tuned=tuned,
                )
                for r, row_ex in zip(rows, ex):
                    pending[r][0].update(pending[r][1], row_ex)
        pending.clear()

    for p in probs:
        for mid, base_row in p.iter_rows():
            pending.append((p, mid, base_row))
            if len(pending) >= GRID_CHUNK_ROWS:
                flush()
    flush()


def _solve_grids_grouped(
    probs: Sequence[_GridProblem],
    backend: str,
    stats: BatchStats,
    device_reduce: bool = True,
    tuned: bool = True,
) -> None:
    """Per-angle-count grouping (the pre-ragged layout, kept for the
    vectorized-numpy rows and as the ragged comparison path): rows are
    grouped by angle count — per-row capacities let links with different
    capacities share a call — and flushed every :data:`GRID_CHUNK_ROWS`
    rows, one launch per group per chunk.

    On kernel-eligible shapes (``device_reduce=True``) each chunk goes
    through :func:`_batched_segmin`: one segment per problem (rows stay in
    product order, the problem's incumbent best rides in as the segment's
    init), and the per-row argmin *and* the acceptance scan run on device —
    only per-problem ``(accepted, row, shift, best)`` scalars come back.
    Otherwise the full ``(B, A)`` matrix is evaluated and the sequential
    ``update`` scan runs host-side.
    """
    by_angles: dict[int, list[_GridProblem]] = {}
    for p in probs:
        by_angles.setdefault(p.circle.num_angles, []).append(p)

    for num_angles, group in by_angles.items():
        pending: list[tuple[_GridProblem, tuple[int, ...], np.ndarray]] = []
        # hoisted: on the numpy path (small grids) the per-chunk segment
        # bookkeeping below would be pure overhead
        try_device = device_reduce and _kernel_eligible(backend, num_angles)

        def flush() -> None:
            if not pending:
                return
            base = np.stack([row for _, _, row in pending])
            cand = np.stack([p.circle.bw[p.last] for p, _, _ in pending])
            caps = np.array([p.capacity for p, _, _ in pending], dtype=np.float32)
            stats.batched_calls += 1
            stats.grid_rows += len(pending)
            reduced = None
            if try_device:
                segs, seg_ids, init = _grid_segments(pending)
                valid = np.array(
                    [p.grids[p.last] for p, _, _ in pending], dtype=np.int32
                )
                reduced = _batched_segmin(
                    base, cand, caps, valid, seg_ids, init,
                    backend=backend, stats=stats, tuned=tuned,
                )
            if reduced is not None:
                _apply_segmin(segs, pending, reduced)
            else:
                ex = _batched_excess(
                    base, cand, caps, backend=backend, stats=stats, tuned=tuned
                )
                for (p, mid, _), row_ex in zip(pending, ex):
                    p.update(mid, row_ex)
            pending.clear()

        for p in group:
            for mid, base_row in p.iter_rows():
                pending.append((p, mid, base_row))
                if len(pending) >= GRID_CHUNK_ROWS:
                    flush()
        flush()


class _DescentState:
    """Per-problem state of the lockstep-batched coordinate descent.

    Replays :func:`_coordinate_descent` step for step — same zero/random
    trial seeds drawn from a per-problem ``default_rng(seed)`` in the same
    order, same sweep convergence break, same end-of-trial acceptance and
    zero-excess early exit — with only the "score every rotation of job j"
    evaluation delegated to a shared batched call.
    """

    __slots__ = ("index", "circle", "grids", "capacity", "n", "rng",
                 "best", "best_excess", "done", "in_sweep", "changed",
                 "shifts", "rotated", "total")

    def __init__(
        self,
        index: int,
        circle: UnifiedCircle,
        grids: Sequence[int],
        capacity: float,
        seed: int,
    ) -> None:
        self.index = index
        self.circle = circle
        self.grids = list(grids)
        self.capacity = capacity
        self.n = len(grids)
        self.rng = np.random.default_rng(seed)
        self.best: tuple[int, ...] = (0,) * self.n
        self.best_excess = float(np.inf)
        self.done = False
        self.in_sweep = False
        self.changed = False
        self.shifts: np.ndarray | None = None
        self.rotated: np.ndarray | None = None
        self.total: np.ndarray | None = None

    def start_trial(self, trial: int) -> None:
        if trial == 0:
            self.shifts = np.zeros(self.n, dtype=np.int64)
        else:
            self.shifts = np.array(
                [self.rng.integers(0, g) for g in self.grids], dtype=np.int64
            )
        self.rotated = np.stack(
            [self.circle.rotated(j, int(self.shifts[j])) for j in range(self.n)]
        )
        self.total = self.rotated.sum(axis=0)
        self.in_sweep = True

    def job_row(self, j: int) -> tuple[np.ndarray, np.ndarray]:
        """(base, cand) for re-placing job ``j`` against all the others."""
        return self.total - self.rotated[j], self.circle.bw[j]

    def apply(self, j: int, base: np.ndarray, row: np.ndarray) -> None:
        """Host-side acceptance: argmin over job ``j``'s admissible shifts."""
        ex = row[: self.grids[j]]
        self.apply_shift(j, base, int(np.argmin(ex)))

    def apply_shift(self, j: int, base: np.ndarray, s_new: int) -> None:
        """Accept the (host- or device-computed) best shift for job ``j``."""
        if s_new != self.shifts[j]:
            self.shifts[j] = s_new
            new_rot = self.circle.rotated(j, s_new)
            self.total = base + new_rot
            self.rotated[j] = new_rot
            self.changed = True

    def end_trial(self) -> None:
        ex_now = float(np.maximum(self.total - self.capacity, 0.0).sum())
        if ex_now < self.best_excess - ACCEPT_SLACK:
            self.best_excess = ex_now
            self.best = tuple(int(s) for s in self.shifts)
        if self.best_excess == 0.0:
            self.done = True


def _solve_descent_batched(
    states: Sequence[_DescentState],
    backend: str,
    stats: BatchStats,
    device_reduce: bool = True,
    ragged: bool = True,
    tuned: bool = True,
) -> None:
    """Run all coordinate descents in lockstep, batching each step's rows.

    At step (trial, sweep, job j) the base-vs-candidate rows of every
    problem still active at that step are scored in one batched call —
    one row per problem, every candidate shift of job ``j`` covered by
    the call's rotation axis.  On the default ragged kernel path *all*
    kernel-eligible rows ship as ONE launch per step whatever mix of
    angle counts they carry (:func:`_batched_argmin_ragged` — the
    padding/masking invariants make the result bit-identical to the
    per-group launches); ``ragged=False`` restores the per-angle-count
    grouping, and non-eligible rows always take the grouped full-matrix
    evaluation plus host ``np.argmin``.  Per-problem updates between
    steps keep the exact scalar semantics (sequential-within-sweep,
    convergence breaks, seeded restarts) — accepted-shift sequences are
    identical on every path.
    """
    def step_grouped(group_states: list[_DescentState], j: int) -> None:
        by_angles: dict[int, list[_DescentState]] = {}
        for s in group_states:
            by_angles.setdefault(s.circle.num_angles, []).append(s)
        for num_angles, group in by_angles.items():
            rows = [s.job_row(j) for s in group]
            base = np.stack([b for b, _ in rows])
            cand = np.stack([c for _, c in rows])
            caps = np.array([s.capacity for s in group], dtype=np.float32)
            stats.batched_calls += 1
            stats.descent_rows += len(group)
            reduced = None
            if device_reduce and _kernel_eligible(backend, num_angles):
                valid = np.array([s.grids[j] for s in group], dtype=np.int32)
                reduced = _batched_argmin(
                    base, cand, caps, valid,
                    backend=backend, stats=stats, tuned=tuned,
                )
            if reduced is not None:
                s_new, _ = reduced
                for s, (b, _), sn in zip(group, rows, s_new):
                    s.apply_shift(j, b, int(sn))
            else:
                ex = _batched_excess(
                    base, cand, caps, backend=backend, stats=stats, tuned=tuned
                )
                for s, (b, _), row in zip(group, rows, ex):
                    s.apply(j, b, row)

    def step_ragged(group: list[_DescentState], j: int) -> list[_DescentState]:
        """One ragged launch for the step's kernel-eligible rows; returns
        the states a failed kernel import pushes back to the grouped path."""
        rows = [s.job_row(j) for s in group]
        widths = np.array([s.circle.num_angles for s in group], dtype=np.int32)
        w = int(widths.max())
        base = np.zeros((len(group), w))
        cand = np.zeros((len(group), w))
        for r, (b, c) in enumerate(rows):
            base[r, : b.shape[0]] = b
            cand[r, : c.shape[0]] = c
        caps = np.array([s.capacity for s in group], dtype=np.float32)
        valid = np.array([s.grids[j] for s in group], dtype=np.int32)
        reduced = _batched_argmin_ragged(
            base, cand, caps, valid, widths, stats=stats, tuned=tuned
        )
        if reduced is None:  # pragma: no cover - pallas unavailable
            return group
        stats.batched_calls += 1
        stats.descent_rows += len(group)
        s_new, _ = reduced
        for s, (b, _), sn in zip(group, rows, s_new):
            s.apply_shift(j, b, int(sn))
        return []

    for trial in range(_COORD_DESCENT_SEEDS):
        live = [s for s in states if not s.done]
        if not live:
            break
        for s in live:
            s.start_trial(trial)
        for _ in range(_COORD_DESCENT_SWEEPS):
            sweeping = [s for s in live if s.in_sweep]
            if not sweeping:
                break
            for s in sweeping:
                s.changed = False
            for j in range(max(s.n for s in sweeping)):
                stepping = [s for s in sweeping if j < s.n]
                grouped = stepping
                if ragged and device_reduce:
                    eligible = [
                        s for s in stepping
                        if _kernel_eligible(backend, s.circle.num_angles)
                    ]
                    grouped = [
                        s for s in stepping
                        if not _kernel_eligible(backend, s.circle.num_angles)
                    ]
                    if eligible:
                        grouped = grouped + step_ragged(eligible, j)
                if grouped:
                    step_grouped(grouped, j)
            for s in sweeping:
                s.in_sweep = s.changed
        for s in live:
            s.end_trial()


def _normalize_shifts(
    circle: UnifiedCircle, shifts: Sequence[int]
) -> tuple[int, ...]:
    """Rotate all jobs together so job 0's shift becomes 0, then reduce each
    job's shift modulo its own distinct-rotation count (identity rotations)."""
    s0 = shifts[0]
    out = []
    for j, s in enumerate(shifts):
        g = circle.shift_grid(j)
        out.append(int((s - s0) % circle.num_angles) % g)
    return tuple(out)
