"""CASSINI compatibility optimization (paper §3, Table 1).

Given the unified circle of jobs ``J^l`` sharing link ``l`` with capacity
``C^l``, find per-job rotation angles that maximize

    score = 1 − Σ_α Excess(demand_α) / (|A| · C)          (Table 1, Eq. 2)
    Excess(d) = max(0, d − C)                             (Eq. 1)

subject to Δ_j ∈ [0, 2π / r_j)                            (Eq. 4)

The paper solves this with an off-the-shelf optimizer; because the angle
grid is discrete (5° default) and each job only has ``|A| / r_j`` distinct
rotations, the search space is small and we solve it *exactly* for ≤ 3 jobs
(full product grid) and with seeded coordinate descent above that.  The
inner scoring loop — "score every rotation of one job against a base
demand" — is the compute hot-spot and is implemented three ways:

  * numpy (always available, used for tiny inputs),
  * a vectorized jnp path, and
  * the Pallas TPU kernel :mod:`repro.kernels.circle_score` (batched tiles).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .circle import CommPattern, UnifiedCircle, DEFAULT_PRECISION_DEG, DEFAULT_QUANTUM_MS

__all__ = [
    "CompatResult",
    "excess",
    "score_for_shifts",
    "score_all_shifts",
    "find_rotations",
    "find_rotations_batched",
    "compatibility_score",
]

# Above this many jobs on one link, fall back from the exact product grid to
# coordinate descent (the paper's links carry 2–4 jobs in practice).
EXACT_SEARCH_MAX_JOBS = 3
_COORD_DESCENT_SWEEPS = 4
_COORD_DESCENT_SEEDS = 3


@dataclass(frozen=True)
class CompatResult:
    """Output of the link-level optimization (Table 1 output block)."""

    score: float                    # compatibility score (≤ 1, may be negative)
    shifts_steps: tuple[int, ...]   # per-job rotation, in discrete angle steps
    shifts_ms: tuple[float, ...]    # per-job time-shift (Eq. 5), milliseconds
    deltas_rad: tuple[float, ...]   # per-job rotation angle Δ_j in radians
    circle: UnifiedCircle
    capacity_gbps: float
    # The optimization treats job j as exactly periodic with period
    # perimeter / r_j (its *quantized* iteration time).  Workers must pace
    # their iterations at this period for the interleaving to hold — real
    # periods that differ from it precess and collide.
    paced_periods_ms: tuple[float, ...] = ()

    @property
    def fully_compatible(self) -> bool:
        return self.score >= 1.0 - 1e-9


# ---------------------------------------------------------------------- #
# scoring primitives
# ---------------------------------------------------------------------- #
def excess(demand: np.ndarray, capacity: float) -> np.ndarray:
    """Eq. 1."""
    return np.maximum(demand - capacity, 0.0)


def score_from_demand(total_demand: np.ndarray, capacity: float) -> float:
    """Eq. 2 given the summed demand per angle."""
    if capacity <= 0:
        raise ValueError("link capacity must be positive")
    return float(1.0 - excess(total_demand, capacity).mean() / capacity)


def score_for_shifts(
    circle: UnifiedCircle, shifts: Sequence[int], capacity: float
) -> float:
    """Compatibility score for a concrete rotation assignment."""
    return score_from_demand(circle.total_demand(shifts), capacity)


def score_all_shifts(
    base: np.ndarray, cand: np.ndarray, capacity: float, *, backend: str = "auto"
) -> np.ndarray:
    """Score every rotation of one candidate-job demand against a base demand.

    Args:
      base: (A,) summed demand of already-placed jobs at each angle.
      cand: (A,) candidate job demand at each angle.
      capacity: link capacity (Gbps).

    Returns:
      (A,) array: ``out[s] = Σ_α max(0, base[α] + cand[(α − s) mod A] − C)``
      — the *excess sum* for delaying the candidate by ``s`` steps (lower is
      better; the score follows as ``1 − out[s] / (A·C)``).
    """
    base = np.asarray(base, dtype=np.float32)
    cand = np.asarray(cand, dtype=np.float32)
    return _batched_excess(base[None, :], cand[None, :], capacity, backend=backend)[0]


# ---------------------------------------------------------------------- #
# optimization (Table 1)
# ---------------------------------------------------------------------- #
def find_rotations(
    patterns: Sequence[CommPattern],
    capacity_gbps: float,
    *,
    precision_deg: float = DEFAULT_PRECISION_DEG,
    quantum_ms: float = DEFAULT_QUANTUM_MS,
    backend: str = "auto",
    seed: int = 0,
    dilate_steps: int = 1,
) -> CompatResult:
    """Solve Table 1 for the jobs in ``patterns`` sharing one link.

    Returns the best rotation assignment found (exact for ≤ 3 jobs on the
    discrete grid; coordinate descent with multiple seeds above that) and
    the corresponding compatibility score and per-job time-shifts.

    ``dilate_steps`` widens every job's demand arcs by that many discrete
    angles (max-pool) before scoring.  The optimization is discretized, so a
    zero-excess solution *at the sample points* can still overlap by up to
    one angle step in continuous time; scoring on dilated arcs makes
    ``score == 1`` mean true zero overlap (with margin), which is what the
    per-worker alignment agents need to hold the shift without systematic
    drift.
    """
    circle = _build_circle(
        patterns, precision_deg=precision_deg, quantum_ms=quantum_ms,
        dilate_steps=dilate_steps,
    )
    shifts = _search(circle, capacity_gbps, backend=backend, seed=seed)
    return _finalize(circle, shifts, capacity_gbps)


def find_rotations_batched(
    problems: Sequence[tuple[Sequence[CommPattern], float]],
    *,
    precision_deg: float = DEFAULT_PRECISION_DEG,
    quantum_ms: float = DEFAULT_QUANTUM_MS,
    backend: str = "auto",
    seed: int = 0,
    dilate_steps: int = 1,
) -> list[CompatResult]:
    """Solve many independent link-level Table-1 problems in one pass.

    ``problems`` is a sequence of ``(patterns, capacity_gbps)`` pairs — one
    per contended link (across *all* placement candidates of a scheduling
    epoch).  Two-job links — the overwhelmingly common case in the paper's
    traces — reduce to a single "score every rotation of job 1 against job
    0" row; those rows are grouped by (angle count, capacity), packed into
    ``(L, A)`` arrays and evaluated in one batched :func:`_batched_excess`
    call (Pallas ``circle_score`` kernel on large grids, vectorized numpy
    otherwise) instead of ``L`` separate scalar searches.  Links with other
    job counts (or any exotic shape) fall back to the scalar
    :func:`find_rotations` path, so the result is always defined.

    Returns one :class:`CompatResult` per problem, in input order, identical
    to what per-problem ``find_rotations`` calls would produce (same circle
    construction, same argmin tie-breaking, same normalization).
    """
    results: list[CompatResult | None] = [None] * len(problems)
    # rows of the batchable 2-job case, grouped by (num_angles, capacity)
    groups: dict[tuple[int, float], list[tuple[int, UnifiedCircle]]] = {}
    for i, (patterns, capacity) in enumerate(problems):
        circle = _build_circle(
            patterns, precision_deg=precision_deg, quantum_ms=quantum_ms,
            dilate_steps=dilate_steps,
        )
        # batch only where the scalar path would also search the full grid
        # (same prod(grids) <= 20k cutoff as _search), so both paths stay
        # result-identical at any precision.
        if len(patterns) == 2 and circle.shift_grid(1) <= 20_000:
            groups.setdefault((circle.num_angles, float(capacity)), []).append(
                (i, circle)
            )
        else:
            shifts = _search(circle, capacity, backend=backend, seed=seed)
            results[i] = _finalize(circle, shifts, capacity)

    for (_, capacity), rows in groups.items():
        base = np.stack([c.bw[0] for _, c in rows])
        cand = np.stack([c.bw[1] for _, c in rows])
        ex = _batched_excess(base, cand, capacity, backend=backend)
        for (i, circle), row in zip(rows, ex):
            # Eq. 4 bound: only the job's distinct rotations are admissible
            s1 = int(np.argmin(row[: circle.shift_grid(1)]))
            results[i] = _finalize(circle, (0, s1), capacity)
    return [r for r in results if r is not None]


def _build_circle(
    patterns: Sequence[CommPattern],
    *,
    precision_deg: float,
    quantum_ms: float,
    dilate_steps: int,
) -> UnifiedCircle:
    """Unified circle with optional arc dilation (see find_rotations)."""
    import dataclasses

    circle = UnifiedCircle.build(
        patterns, precision_deg=precision_deg, quantum_ms=quantum_ms
    )
    if dilate_steps > 0:
        bw = circle.bw
        dilated = bw.copy()
        for s in range(1, dilate_steps + 1):
            dilated = np.maximum(dilated, np.roll(bw, s, axis=1))
            dilated = np.maximum(dilated, np.roll(bw, -s, axis=1))
        circle = dataclasses.replace(circle, bw=dilated)
    return circle


def _search(
    circle: UnifiedCircle, capacity_gbps: float, *, backend: str, seed: int
) -> tuple[int, ...]:
    """Pick the search strategy for one circle (Table 1 solve)."""
    n = len(circle.patterns)
    grids = [circle.shift_grid(j) for j in range(n)]
    if n == 1:
        return (0,)
    if n <= EXACT_SEARCH_MAX_JOBS and int(np.prod([g for g in grids[1:]])) <= 20_000:
        return _exact_search(circle, grids, capacity_gbps, backend)
    return _coordinate_descent(circle, grids, capacity_gbps, backend, seed)


def _finalize(
    circle: UnifiedCircle, shifts: Sequence[int], capacity_gbps: float
) -> CompatResult:
    """Score + normalize a rotation assignment into a CompatResult."""
    n = len(circle.patterns)
    score = score_for_shifts(circle, shifts, capacity_gbps)
    # normalize so the first job's shift is zero: only *relative* rotations
    # matter (global rotation leaves the score unchanged), and a zero shift
    # for the reference job makes time-shifts minimal / reproducible.
    shifts = _normalize_shifts(circle, shifts)
    shifts_ms = tuple(circle.shift_steps_to_ms(j, s) for j, s in enumerate(shifts))
    deltas = tuple(2.0 * np.pi * s / circle.num_angles for s in shifts)
    paced = tuple(circle.perimeter_ms / circle.wraps[j] for j in range(n))
    return CompatResult(
        score=score,
        shifts_steps=tuple(shifts),
        shifts_ms=shifts_ms,
        deltas_rad=deltas,
        circle=circle,
        capacity_gbps=capacity_gbps,
        paced_periods_ms=paced,
    )


def _batched_excess(
    base: np.ndarray, cand: np.ndarray, capacity: float, *, backend: str = "auto"
) -> np.ndarray:
    """Excess sums for every rotation of ``L`` independent rows at once.

    ``out[l, s] = Σ_α max(0, base[l, α] + cand[l, (α − s) mod A] − C)``.

    ``backend="auto"`` routes large angle grids to the Pallas
    ``circle_score`` kernel (one batched call over all rows — the TPU
    target's hot path) and everything else to a vectorized numpy evaluation;
    ``"pallas"`` / ``"numpy"`` force a path.  Both produce float32 sums like
    the scalar :func:`score_all_shifts`.
    """
    base = np.asarray(base, dtype=np.float32)
    cand = np.asarray(cand, dtype=np.float32)
    a = base.shape[-1]
    if backend == "pallas" or (backend == "auto" and a >= 512):
        try:
            from repro.kernels.circle_score import ops as _cs_ops

            return np.asarray(_cs_ops.circle_score(base, cand, capacity))
        except Exception:  # pragma: no cover - fallback if pallas unavailable
            pass
    idx = (np.arange(a)[None, :] - np.arange(a)[:, None]) % a  # (S, A)
    rolled = cand[:, idx]                                      # (L, S, A)
    total = base[:, None, :] + rolled
    return np.maximum(total - capacity, 0.0).sum(axis=-1)


def compatibility_score(
    patterns: Sequence[CommPattern], capacity_gbps: float, **kw
) -> float:
    """Convenience: just the score (paper's compatibility *rank* input)."""
    return find_rotations(patterns, capacity_gbps, **kw).score


# ---------------------------------------------------------------------- #
# search strategies
# ---------------------------------------------------------------------- #
def _exact_search(
    circle: UnifiedCircle,
    grids: Sequence[int],
    capacity: float,
    backend: str,
) -> tuple[int, ...]:
    """Full product grid over jobs 1..n−1 (job 0 pinned at 0 by rotation
    invariance); the innermost job is scored for *all* its rotations at once
    via :func:`score_all_shifts`."""
    n = len(grids)
    if n == 1:
        return (0,)
    last = n - 1
    best_excess = np.inf
    best: tuple[int, ...] = (0,) * n
    outer_grids = [range(g) for g in grids[1:last]]  # jobs 1..n−2
    base0 = circle.bw[0]
    for mid in itertools.product(*outer_grids):
        base = base0.copy()
        for j, s in enumerate(mid, start=1):
            base += circle.rotated(j, s)
        ex = score_all_shifts(base, circle.bw[last], capacity, backend=backend)
        ex = ex[: grids[last]]  # Eq. 4 bound: distinct rotations only
        s_last = int(np.argmin(ex))
        if ex[s_last] < best_excess - 1e-12:
            best_excess = float(ex[s_last])
            best = (0, *mid, s_last)
        if best_excess == 0.0:
            break  # fully compatible; nothing can beat zero excess
    return best


def _coordinate_descent(
    circle: UnifiedCircle,
    grids: Sequence[int],
    capacity: float,
    backend: str,
    seed: int,
) -> tuple[int, ...]:
    """Seeded coordinate descent: repeatedly re-place each job against the sum
    of all the others, scoring every rotation at once."""
    rng = np.random.default_rng(seed)
    n = len(grids)
    best: tuple[int, ...] = (0,) * n
    best_excess = np.inf
    for trial in range(_COORD_DESCENT_SEEDS):
        if trial == 0:
            shifts = np.zeros(n, dtype=np.int64)
        else:
            shifts = np.array([rng.integers(0, g) for g in grids], dtype=np.int64)
        rotated = np.stack([circle.rotated(j, int(shifts[j])) for j in range(n)])
        total = rotated.sum(axis=0)
        for _ in range(_COORD_DESCENT_SWEEPS):
            changed = False
            for j in range(n):
                base = total - rotated[j]
                ex = score_all_shifts(base, circle.bw[j], capacity, backend=backend)
                ex = ex[: grids[j]]
                s_new = int(np.argmin(ex))
                if s_new != shifts[j]:
                    shifts[j] = s_new
                    new_rot = circle.rotated(j, s_new)
                    total = base + new_rot
                    rotated[j] = new_rot
                    changed = True
            if not changed:
                break
        ex_now = float(np.maximum(total - capacity, 0.0).sum())
        if ex_now < best_excess - 1e-12:
            best_excess = ex_now
            best = tuple(int(s) for s in shifts)
        if best_excess == 0.0:
            break
    return best


def _normalize_shifts(
    circle: UnifiedCircle, shifts: Sequence[int]
) -> tuple[int, ...]:
    """Rotate all jobs together so job 0's shift becomes 0, then reduce each
    job's shift modulo its own distinct-rotation count (identity rotations)."""
    s0 = shifts[0]
    out = []
    for j, s in enumerate(shifts):
        g = circle.shift_grid(j)
        out.append(int((s - s0) % circle.num_angles) % g)
    return tuple(out)
