"""Rotation-angle → time-shift conversion (paper Eq. 5) and the drift
adjustment policy applied by per-server agents (paper §4.2 step 3, §5.7).

Eq. 5:  t_j^l = (Δ_j^l / 2π · p^l) mod iter_time_j

A worker applies its unique cluster-level time-shift by delaying the start
of the next immediate training iteration.  Because servers drift (noise,
stragglers), an agent re-aligns whenever the observed start of the
communication phase deviates from its ideal position by more than
``drift_tolerance`` (5 % of iteration time in the paper).
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["rotation_to_time_shift", "DriftAdjuster"]


def rotation_to_time_shift(
    delta_rad: float, perimeter_ms: float, iter_time_ms: float
) -> float:
    """Paper Eq. 5."""
    import math

    if iter_time_ms <= 0:
        raise ValueError("iteration time must be positive")
    return (delta_rad / (2.0 * math.pi) * perimeter_ms) % iter_time_ms


@dataclass
class DriftAdjuster:
    """Per-worker agent logic for keeping the applied time-shift aligned.

    The agent records the observed start time of each iteration's
    communication phase; the *ideal* start of iteration ``i`` is
    ``epoch_start + time_shift + i · iter_time``.  When
    ``|observed − ideal| > drift_tolerance · iter_time`` the agent issues an
    adjustment (an extra delay of ``(ideal − observed) mod iter_time``) and
    counts it — paper §5.7 reports < 2 adjustments/min for compatible jobs.
    """

    iter_time_ms: float
    time_shift_ms: float
    epoch_start_ms: float = 0.0
    drift_tolerance: float = 0.05
    adjustments: int = 0
    history: list[float] = field(default_factory=list)

    def ideal_start(self, iteration: int) -> float:
        return self.epoch_start_ms + self.time_shift_ms + iteration * self.iter_time_ms

    def observe(self, iteration: int, observed_start_ms: float) -> float:
        """Record an observed comm-phase start; return the extra delay (ms)
        the worker must insert before its next iteration (0.0 if within
        tolerance)."""
        self.history.append(observed_start_ms)
        drift = observed_start_ms - self.ideal_start(iteration)
        if abs(drift) <= self.drift_tolerance * self.iter_time_ms:
            return 0.0
        self.adjustments += 1
        # delay (never "undelay": we cannot travel back) to the next ideal slot
        return (-drift) % self.iter_time_ms

    @property
    def adjustments_per_minute(self) -> float:
        if len(self.history) < 2:
            return 0.0
        span_min = (self.history[-1] - self.history[0]) / 60_000.0
        return self.adjustments / span_min if span_min > 0 else 0.0
