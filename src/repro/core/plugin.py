"""CASSINI's pluggable scheduler module (paper §4.2, Algorithm 2).

Host schedulers (Themis, Pollux, …) are modified to emit up to ``N``
*candidate placements* instead of one; this module

  1. builds the affinity graph of every candidate (jobs ↔ contended links),
  2. discards candidates whose affinity graph has a loop (Theorem 1
     precondition),
  3. solves the Table-1 optimization on every contended link to obtain the
     link's compatibility score and per-job link-level time-shifts,
  4. ranks candidates by the mean link score (tail/other aggregations are
     supported, cf. paper footnote 1),
  5. runs Algorithm 1 on the winner to produce unique per-job time-shifts.

The module is deliberately independent of any concrete cluster model: a
candidate is fully described by ``job → links traversed``, per-link
capacities and per-job communication patterns.

Scoring (steps 1–4) and alignment (step 5) are exposed separately —
:meth:`CassiniModule.score_candidates` / ``score_candidates_batched`` and
:meth:`CassiniModule.align` — so :class:`repro.engine.SchedulingPipeline`
can run them as independent stages; :meth:`CassiniModule.decide` composes
them (Algorithm 2 end-to-end).
"""

from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np

from .affinity import AffinityGraph, JobId, LinkId
from .circle import CommPattern, DEFAULT_PRECISION_DEG, DEFAULT_QUANTUM_MS
from .compat import BatchStats, CompatResult, find_rotations, find_rotations_batched

__all__ = ["PlacementCandidate", "CassiniDecision", "CassiniModule"]

# (candidate, affinity graph or None when loop-discarded, per-link results)
Evaluated = tuple[
    "PlacementCandidate", AffinityGraph | None, dict[LinkId, CompatResult]
]


@dataclass
class PlacementCandidate:
    """One candidate placement returned by the host scheduler.

    ``job_links`` maps every placed job to the network links its traffic
    traverses (as computed by the host's topology/routing); ``meta`` carries
    the host scheduler's own payload (e.g. the concrete server assignment)
    through CASSINI untouched.
    """

    job_links: Mapping[JobId, Sequence[LinkId]]
    meta: object = None
    # filled in by CassiniModule:
    score: float = float("nan")
    link_scores: dict[LinkId, float] = field(default_factory=dict)
    discarded_loop: bool = False


@dataclass
class CassiniDecision:
    """Output of Algorithm 2."""

    top_placement: PlacementCandidate
    time_shifts_ms: dict[JobId, float]
    link_results: dict[LinkId, CompatResult]
    candidates: list[PlacementCandidate]  # all, with scores filled in
    # per-job isochronous pacing period (max across the job's links):
    paced_periods_ms: dict[JobId, float] = field(default_factory=dict)
    # per-job minimum compatibility score across its contended links --
    # pacing is only worth holding when interleaving can actually succeed
    job_min_score: dict[JobId, float] = field(default_factory=dict)

    @property
    def score(self) -> float:
        return self.top_placement.score


class CassiniModule:
    """Algorithm 2, reusable across host schedulers."""

    def __init__(
        self,
        *,
        precision_deg: float = DEFAULT_PRECISION_DEG,
        quantum_ms: float = DEFAULT_QUANTUM_MS,
        aggregate: Callable[[Sequence[float]], float] | None = None,
        max_workers: int | None = None,
        seed: int = 0,
        device_reduce: bool = True,
        ragged: bool = True,
        tuned: bool = True,
    ) -> None:
        self.precision_deg = precision_deg
        self.quantum_ms = quantum_ms
        self.aggregate = aggregate or (lambda xs: float(np.mean(xs)))
        self.max_workers = max_workers
        self.seed = seed
        # Batched solves keep the rotation-search argmin/acceptance on the
        # device for kernel-eligible shapes (fused circle_score reduction);
        # False forces the full-matrix + host-reduction path everywhere.
        self.device_reduce = device_reduce
        # Ragged single-launch batching: all kernel-eligible link problems
        # of an epoch ship as ONE kernel launch per grid-chunk/descent
        # step, whatever mix of unified-circle angle counts they carry;
        # False restores the per-angle-count launch grouping (comparison
        # path — results are bit-identical either way).
        self.ragged = ragged
        # Per-bucket tuned launch schedules from the committed tuning
        # table (repro.kernels.tune); False pins the untuned kernel
        # defaults — a comparison/debug switch, bit-identical either way.
        self.tuned = tuned
        # Candidates at one epoch mostly share link job-sets: memoize the
        # per-link optimization across candidates (and epochs).  All reads
        # and writes go through ``_cache_lock`` so the ThreadPoolExecutor
        # path (``max_workers``) and the batched path stay race-free; the
        # cached CompatResults themselves are frozen dataclasses.
        self._link_cache: dict[tuple, CompatResult] = {}
        self._cache_lock = threading.Lock()
        # serve-mode telemetry: cache_hits counts successful link-cache
        # lookups (what the speculative epoch-prefetch buys), cache_misses
        # counts link problems actually *solved* (scalar or batched)
        self.cache_hits: int = 0
        self.cache_misses: int = 0
        # Telemetry of the most recent score_candidates_batched call (None
        # until one runs, or when every link problem was already cached):
        # benches and tests use it to prove no silent scalar fallback.
        self.last_batch_stats: BatchStats | None = None

    # -------------------------------------------------------------- #
    def contended_links(
        self, cand: PlacementCandidate
    ) -> dict[LinkId, list[JobId]]:
        """Links carrying more than one job (the V vertex set)."""
        by_link: dict[LinkId, list[JobId]] = {}
        for job, links in cand.job_links.items():
            for l in links:
                by_link.setdefault(l, []).append(job)
        return {l: js for l, js in by_link.items() if len(js) > 1}

    @staticmethod
    def merge_equivalent_links(
        shared: Mapping[LinkId, Sequence[JobId]],
        capacities: Mapping[LinkId, float],
    ) -> tuple[dict[LinkId, list[JobId]], dict[LinkId, float]]:
        """Collapse parallel links that carry an *identical* job set.

        Two links with the same job set impose the same interleaving
        constraint and would produce identical per-job time-shifts; keeping
        both as affinity-graph vertices creates a spurious 2-cycle that
        Algorithm 2 would needlessly discard (e.g. a job pair spanning the
        same two racks shares both racks' uplinks).  We keep one merged
        vertex per job set, with the group's *minimum* capacity (the most
        constrained member governs).  True loops — cycles through links
        with different job sets — are still detected and discarded.
        """
        groups: dict[tuple, list[LinkId]] = {}
        for l, js in shared.items():
            key = tuple(sorted(js, key=repr))
            groups.setdefault(key, []).append(l)
        merged_links: dict[LinkId, list[JobId]] = {}
        merged_caps: dict[LinkId, float] = {}
        for key, ls in groups.items():
            rep = min(ls, key=repr)
            merged_links[rep] = list(key)
            merged_caps[rep] = min(capacities[l] for l in ls)
        return merged_links, merged_caps

    # -------------------------------------------------------------- #
    def _link_key(
        self, js: Sequence[JobId], patterns: Mapping[JobId, CommPattern], cap: float
    ) -> tuple:
        return (
            tuple(
                (patterns[j].name, patterns[j].iter_time_ms, patterns[j].phases)
                for j in js
            ),
            cap,
        )

    def _cached(self, key: tuple) -> CompatResult | None:
        with self._cache_lock:
            res = self._link_cache.get(key)
            if res is not None:
                self.cache_hits += 1
            return res

    def _cache_put(self, key: tuple, res: CompatResult) -> None:
        with self._cache_lock:
            self._link_cache[key] = res

    # ------------------------- delta updates ---------------------- #
    def add_job(self, pattern: CommPattern) -> None:
        """Job arrival: nothing to precompute — entries fill lazily on the
        first solve involving the new pattern.  Kept as the explicit
        counterpart of :meth:`remove_job` so serve-mode churn drives both
        sides of the cache's lifecycle through one API."""

    def remove_job(self, pattern: CommPattern | str) -> int:
        """Job departure: evict every cached link solve involving the
        departed pattern (matched by pattern name — a cache key embeds the
        ``(name, iter_time, phases)`` triple of each participant).

        A long-running service would otherwise accumulate solves for jobs
        that can never communicate again.  Evicting by name is safe even
        when another running job shares the pattern: the next epoch's solve
        misses and recomputes the identical frozen ``CompatResult``, so
        delta-evicted and rebuilt-from-scratch caches stay interchangeable
        (tests/test_serve_incremental.py pins the parity).

        Returns the number of evicted entries.
        """
        name = pattern if isinstance(pattern, str) else pattern.name
        with self._cache_lock:
            doomed = [
                key
                for key in self._link_cache
                if any(entry[0] == name for entry in key[0])
            ]
            for key in doomed:
                del self._link_cache[key]
        return len(doomed)

    def _prepare_candidate(
        self,
        cand: PlacementCandidate,
        patterns: Mapping[JobId, CommPattern],
        capacities: Mapping[LinkId, float],
    ) -> tuple[dict[LinkId, list[JobId]], dict[LinkId, float], AffinityGraph] | None:
        """Lines 3–13 of Algorithm 2: contention map + loop check.

        Returns None (and marks the candidate discarded) when the affinity
        graph has a loop — the Theorem 1 precondition fails.
        """
        shared, caps = self.merge_equivalent_links(
            self.contended_links(cand), capacities
        )
        graph = AffinityGraph()
        # Build graph edges with weight 0 first (Alg. 2 line 11) so the loop
        # check runs before paying for any optimization.
        for l, js in shared.items():
            for j in sorted(js, key=repr):
                graph.add_edge(j, l, 0.0, patterns[j].iter_time_ms)
        if graph.has_loop():
            cand.discarded_loop = True
            cand.score = -float("inf")
            return None
        return shared, caps, graph

    def _fill_candidate(
        self,
        cand: PlacementCandidate,
        shared: Mapping[LinkId, list[JobId]],
        caps: Mapping[LinkId, float],
        graph: AffinityGraph,
        patterns: Mapping[JobId, CommPattern],
    ) -> Evaluated:
        """Lines 14–23 of Algorithm 2: per-link optimization + aggregation.

        Link results are pulled from the cache; misses are solved scalar
        (the batched path pre-populates the cache, so it only pays for
        genuinely new link job-sets).
        """
        link_results: dict[LinkId, CompatResult] = {}
        scores: list[float] = []
        for l, js in sorted(shared.items(), key=lambda kv: repr(kv[0])):
            js = sorted(js, key=repr)
            key = self._link_key(js, patterns, caps[l])
            res = self._cached(key)
            if res is None:
                self.cache_misses += 1
                res = find_rotations(
                    [patterns[j] for j in js],
                    caps[l],
                    precision_deg=self.precision_deg,
                    quantum_ms=self.quantum_ms,
                    seed=self.seed,
                )
                self._cache_put(key, res)
            link_results[l] = res
            scores.append(res.score)
            cand.link_scores[l] = res.score
            graph.perimeter_ms[l] = res.circle.perimeter_ms
            for j, t_ms in zip(js, res.shifts_ms):
                # edge weight = link-level time-shift t_j^l (§4.1)
                graph.add_edge(j, l, t_ms, patterns[j].iter_time_ms)

        cand.score = self.aggregate(scores) if scores else 1.0
        return cand, graph, link_results

    def _evaluate_candidate(
        self,
        cand: PlacementCandidate,
        patterns: Mapping[JobId, CommPattern],
        capacities: Mapping[LinkId, float],
    ) -> Evaluated:
        """Lines 3–23 of Algorithm 2 for one candidate (scalar path)."""
        prep = self._prepare_candidate(cand, patterns, capacities)
        if prep is None:
            return cand, None, {}
        return self._fill_candidate(cand, *prep, patterns)

    # -------------------------------------------------------------- #
    def score_candidates(
        self,
        candidates: Sequence[PlacementCandidate],
        patterns: Mapping[JobId, CommPattern],
        capacities: Mapping[LinkId, float],
    ) -> list[Evaluated]:
        """Score every candidate with per-link scalar optimizations."""
        if self.max_workers and len(candidates) > 1:
            with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                return list(
                    pool.map(
                        lambda c: self._evaluate_candidate(c, patterns, capacities),
                        candidates,
                    )
                )
        return [
            self._evaluate_candidate(c, patterns, capacities) for c in candidates
        ]

    def score_candidates_batched(
        self,
        candidates: Sequence[PlacementCandidate],
        patterns: Mapping[JobId, CommPattern],
        capacities: Mapping[LinkId, float],
    ) -> list[Evaluated]:
        """Score every candidate, solving all uncached link problems at once.

        Candidates at one epoch share most of their contended-link job-sets;
        instead of optimizing link-by-link inside a per-candidate loop, this
        path collects every *distinct uncached* (job-set, capacity) problem
        across all candidates and hands them to
        :func:`repro.core.compat.find_rotations_batched`, which packs every
        k-job link's shift product grid into batched ``circle_score``
        evaluations (Pallas kernel / vectorized numpy) and lockstep-batches
        the coordinate-descent sweeps above the exact-grid cutoff — no link
        shape drops to the scalar path.  With ``device_reduce`` (the
        default) the kernel-eligible evaluations use the *fused* reduction:
        argmin and grid acceptance run inside the kernel and only
        per-problem scalars return to the host, never the ``(B, A)`` excess
        matrix (``last_batch_stats.device_reduced`` / ``bytes_returned``
        prove it).  Results land in the shared link
        cache, so the final per-candidate assembly is pure cache hits and
        the scalar and batched paths produce identical Evaluated tuples;
        ``self.last_batch_stats`` records which batched path each problem
        took.
        """
        prepared = [
            self._prepare_candidate(c, patterns, capacities) for c in candidates
        ]
        todo: dict[tuple, tuple[list[CommPattern], float]] = {}
        for prep in prepared:
            if prep is None:
                continue
            shared, caps, _ = prep
            for l, js in shared.items():
                js = sorted(js, key=repr)
                key = self._link_key(js, patterns, caps[l])
                if key not in todo and self._cached(key) is None:
                    todo[key] = ([patterns[j] for j in js], caps[l])
        # reset first so a fully-cached epoch reads None, not stale counts
        self.last_batch_stats = None
        if todo:
            keys = list(todo)
            self.cache_misses += len(keys)
            stats = BatchStats()
            solved = find_rotations_batched(
                [todo[k] for k in keys],
                precision_deg=self.precision_deg,
                quantum_ms=self.quantum_ms,
                seed=self.seed,
                stats=stats,
                device_reduce=self.device_reduce,
                ragged=self.ragged,
                tuned=self.tuned,
            )
            self.last_batch_stats = stats
            for key, res in zip(keys, solved):
                self._cache_put(key, res)
        out: list[Evaluated] = []
        for cand, prep in zip(candidates, prepared):
            if prep is None:
                out.append((cand, None, {}))
            else:
                out.append(self._fill_candidate(cand, *prep, patterns))
        return out

    # -------------------------------------------------------------- #
    def align(self, evaluated: Sequence[Evaluated]) -> CassiniDecision:
        """Rank scored candidates and run Algorithm 1 on the winner."""
        if not evaluated:
            raise ValueError("need at least one scored candidate")
        # Sort decreasing by compatibility score; stable on input order.
        order = sorted(
            range(len(evaluated)), key=lambda i: evaluated[i][0].score, reverse=True
        )
        top_cand, top_graph, top_links = evaluated[order[0]]

        if top_graph is None:
            # every candidate had a loop: fall back to the first candidate
            # with no time-shifts (plain host-scheduler behaviour).
            return CassiniDecision(
                top_placement=evaluated[0][0],
                time_shifts_ms={},
                link_results={},
                candidates=[e[0] for e in evaluated],
            )

        shifts = top_graph.bfs_time_shifts(seed=self.seed)
        paced: dict[JobId, float] = {}
        min_score: dict[JobId, float] = {}
        for l, res in top_links.items():
            for j, pp in zip(
                sorted(top_graph.link_jobs.get(l, []), key=repr),
                res.paced_periods_ms,
            ):
                paced[j] = max(paced.get(j, 0.0), pp)
                min_score[j] = min(min_score.get(j, 1.0), res.score)
        return CassiniDecision(
            top_placement=top_cand,
            time_shifts_ms=shifts,
            link_results=top_links,
            candidates=[e[0] for e in evaluated],
            paced_periods_ms=paced,
            job_min_score=min_score,
        )

    def decide(
        self,
        candidates: Sequence[PlacementCandidate],
        patterns: Mapping[JobId, CommPattern],
        capacities: Mapping[LinkId, float],
        *,
        batched: bool = False,
    ) -> CassiniDecision:
        """Algorithm 2 end-to-end (score + align)."""
        if not candidates:
            raise ValueError("need at least one placement candidate")
        score = self.score_candidates_batched if batched else self.score_candidates
        return self.align(score(candidates, patterns, capacities))
