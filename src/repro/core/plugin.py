"""CASSINI's pluggable scheduler module (paper §4.2, Algorithm 2).

Host schedulers (Themis, Pollux, …) are modified to emit up to ``N``
*candidate placements* instead of one; this module

  1. builds the affinity graph of every candidate (jobs ↔ contended links),
  2. discards candidates whose affinity graph has a loop (Theorem 1
     precondition),
  3. solves the Table-1 optimization on every contended link to obtain the
     link's compatibility score and per-job link-level time-shifts,
  4. ranks candidates by the mean link score (tail/other aggregations are
     supported, cf. paper footnote 1),
  5. runs Algorithm 1 on the winner to produce unique per-job time-shifts.

The module is deliberately independent of any concrete cluster model: a
candidate is fully described by ``job → links traversed``, per-link
capacities and per-job communication patterns.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Hashable, Mapping, Sequence

import numpy as np

from .affinity import AffinityGraph, JobId, LinkId
from .circle import CommPattern, DEFAULT_PRECISION_DEG, DEFAULT_QUANTUM_MS
from .compat import CompatResult, find_rotations

__all__ = ["PlacementCandidate", "CassiniDecision", "CassiniModule"]


@dataclass
class PlacementCandidate:
    """One candidate placement returned by the host scheduler.

    ``job_links`` maps every placed job to the network links its traffic
    traverses (as computed by the host's topology/routing); ``meta`` carries
    the host scheduler's own payload (e.g. the concrete server assignment)
    through CASSINI untouched.
    """

    job_links: Mapping[JobId, Sequence[LinkId]]
    meta: object = None
    # filled in by CassiniModule:
    score: float = float("nan")
    link_scores: dict[LinkId, float] = field(default_factory=dict)
    discarded_loop: bool = False


@dataclass
class CassiniDecision:
    """Output of Algorithm 2."""

    top_placement: PlacementCandidate
    time_shifts_ms: dict[JobId, float]
    link_results: dict[LinkId, CompatResult]
    candidates: list[PlacementCandidate]  # all, with scores filled in
    # per-job isochronous pacing period (max across the job's links):
    paced_periods_ms: dict[JobId, float] = field(default_factory=dict)
    # per-job minimum compatibility score across its contended links --
    # pacing is only worth holding when interleaving can actually succeed
    job_min_score: dict[JobId, float] = field(default_factory=dict)

    @property
    def score(self) -> float:
        return self.top_placement.score


class CassiniModule:
    """Algorithm 2, reusable across host schedulers."""

    def __init__(
        self,
        *,
        precision_deg: float = DEFAULT_PRECISION_DEG,
        quantum_ms: float = DEFAULT_QUANTUM_MS,
        aggregate: Callable[[Sequence[float]], float] = None,
        max_workers: int | None = None,
        seed: int = 0,
    ) -> None:
        self.precision_deg = precision_deg
        self.quantum_ms = quantum_ms
        self.aggregate = aggregate or (lambda xs: float(np.mean(xs)))
        self.max_workers = max_workers
        self.seed = seed
        # candidates at one epoch mostly share link job-sets: memoize the
        # per-link optimization across candidates (and epochs).
        self._link_cache: dict[tuple, CompatResult] = {}

    # -------------------------------------------------------------- #
    def contended_links(
        self, cand: PlacementCandidate
    ) -> dict[LinkId, list[JobId]]:
        """Links carrying more than one job (the V vertex set)."""
        by_link: dict[LinkId, list[JobId]] = {}
        for job, links in cand.job_links.items():
            for l in links:
                by_link.setdefault(l, []).append(job)
        return {l: js for l, js in by_link.items() if len(js) > 1}

    @staticmethod
    def merge_equivalent_links(
        shared: Mapping[LinkId, Sequence[JobId]],
        capacities: Mapping[LinkId, float],
    ) -> tuple[dict[LinkId, list[JobId]], dict[LinkId, float]]:
        """Collapse parallel links that carry an *identical* job set.

        Two links with the same job set impose the same interleaving
        constraint and would produce identical per-job time-shifts; keeping
        both as affinity-graph vertices creates a spurious 2-cycle that
        Algorithm 2 would needlessly discard (e.g. a job pair spanning the
        same two racks shares both racks' uplinks).  We keep one merged
        vertex per job set, with the group's *minimum* capacity (the most
        constrained member governs).  True loops — cycles through links
        with different job sets — are still detected and discarded.
        """
        groups: dict[tuple, list[LinkId]] = {}
        for l, js in shared.items():
            key = tuple(sorted(js, key=repr))
            groups.setdefault(key, []).append(l)
        merged_links: dict[LinkId, list[JobId]] = {}
        merged_caps: dict[LinkId, float] = {}
        for key, ls in groups.items():
            rep = min(ls, key=repr)
            merged_links[rep] = list(key)
            merged_caps[rep] = min(capacities[l] for l in ls)
        return merged_links, merged_caps

    def _evaluate_candidate(
        self,
        cand: PlacementCandidate,
        patterns: Mapping[JobId, CommPattern],
        capacities: Mapping[LinkId, float],
    ) -> tuple[PlacementCandidate, AffinityGraph | None, dict[LinkId, CompatResult]]:
        """Lines 3–23 of Algorithm 2 for one candidate."""
        shared, capacities = self.merge_equivalent_links(
            self.contended_links(cand), capacities
        )
        graph = AffinityGraph()
        link_results: dict[LinkId, CompatResult] = {}

        # Build graph edges with weight 0 first (Alg. 2 line 11) so the loop
        # check runs before paying for any optimization.
        for l, js in shared.items():
            for j in sorted(js, key=repr):
                graph.add_edge(j, l, 0.0, patterns[j].iter_time_ms)
        if graph.has_loop():
            cand.discarded_loop = True
            cand.score = -float("inf")
            return cand, None, link_results

        scores: list[float] = []
        for l, js in sorted(shared.items(), key=lambda kv: repr(kv[0])):
            js = sorted(js, key=repr)
            key = (
                tuple(
                    (patterns[j].name, patterns[j].iter_time_ms, patterns[j].phases)
                    for j in js
                ),
                capacities[l],
            )
            res = self._link_cache.get(key)
            if res is None:
                res = find_rotations(
                    [patterns[j] for j in js],
                    capacities[l],
                    precision_deg=self.precision_deg,
                    quantum_ms=self.quantum_ms,
                    seed=self.seed,
                )
                self._link_cache[key] = res
            link_results[l] = res
            scores.append(res.score)
            cand.link_scores[l] = res.score
            graph.perimeter_ms[l] = res.circle.perimeter_ms
            for j, t_ms in zip(js, res.shifts_ms):
                # edge weight = link-level time-shift t_j^l (§4.1)
                graph.add_edge(j, l, t_ms, patterns[j].iter_time_ms)

        cand.score = self.aggregate(scores) if scores else 1.0
        return cand, graph, link_results

    # -------------------------------------------------------------- #
    def decide(
        self,
        candidates: Sequence[PlacementCandidate],
        patterns: Mapping[JobId, CommPattern],
        capacities: Mapping[LinkId, float],
    ) -> CassiniDecision:
        """Algorithm 2 end-to-end."""
        if not candidates:
            raise ValueError("need at least one placement candidate")

        if self.max_workers and len(candidates) > 1:
            with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                evaluated = list(
                    pool.map(
                        lambda c: self._evaluate_candidate(c, patterns, capacities),
                        candidates,
                    )
                )
        else:
            evaluated = [
                self._evaluate_candidate(c, patterns, capacities) for c in candidates
            ]

        # Sort decreasing by compatibility score; stable on input order.
        order = sorted(
            range(len(evaluated)), key=lambda i: evaluated[i][0].score, reverse=True
        )
        top_cand, top_graph, top_links = evaluated[order[0]]

        if top_graph is None:
            # every candidate had a loop: fall back to the first candidate
            # with no time-shifts (plain host-scheduler behaviour).
            top_cand = candidates[0]
            return CassiniDecision(
                top_placement=top_cand,
                time_shifts_ms={},
                link_results={},
                candidates=[e[0] for e in evaluated],
            )

        shifts = top_graph.bfs_time_shifts(seed=self.seed)
        paced: dict[JobId, float] = {}
        min_score: dict[JobId, float] = {}
        for l, res in top_links.items():
            for j, pp in zip(
                sorted(top_graph.link_jobs.get(l, []), key=repr),
                res.paced_periods_ms,
            ):
                paced[j] = max(paced.get(j, 0.0), pp)
                min_score[j] = min(min_score.get(j, 1.0), res.score)
        return CassiniDecision(
            top_placement=top_cand,
            time_shifts_ms=shifts,
            link_results=top_links,
            candidates=[e[0] for e in evaluated],
            paced_periods_ms=paced,
            job_min_score=min_score,
        )
