"""Bridge between the two halves of the system: derive a CASSINI
communication profile for an *assigned architecture* from its own multi-pod
dry-run artifact.

The dry-run cache records, per (arch × shape), the per-device HLO FLOPs and
collective bytes of one training step on the production mesh.  On the
TPU-v5e target those give the step's compute time and its DCN-visible
communication burst — exactly the (iteration time, Up-phase) pair CASSINI's
geometric abstraction consumes.  This is how a production deployment would
profile tenants: from their compiled step, not from NIC counters.

    >>> pattern = dryrun_pattern("llama3.2-1b")     # CommPattern
    >>> find_rotations([pattern, other], capacity_gbps=50.0)

The DP-gradient fraction of the collective bytes is what crosses pod
boundaries (DCN) in a multi-pod job — we expose ``dcn_fraction`` to scale
the Up phase for cluster-level scheduling of pod-sized workers.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.circle import CommPattern, Phase

PEAK_FLOPS = 197e12
ICI_BW = 50e9

CACHE = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_cache"

__all__ = ["dryrun_pattern", "available_archs"]


def _load(arch: str, shape: str, mesh: str):
    f = CACHE / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    rec = json.loads(f.read_text())
    return rec if rec.get("status") == "ok" else None


def available_archs() -> list[str]:
    return sorted(
        {f.name.split("__")[0] for f in CACHE.glob("*__train_4k__single.json")}
    )


def dryrun_pattern(
    arch: str,
    *,
    shape: str = "train_4k",
    mesh: str = "single",
    nic_gbps: float = 50.0,
    dcn_fraction: float = 0.15,
) -> CommPattern:
    """CommPattern of one training iteration, derived from the dry-run.

    iteration time ≈ max(compute, collective) term of the compiled step;
    the Up phase carries the DCN-crossing share of the collective bytes at
    the job's NIC rate, placed at the end of the iteration (DP gradient
    sync after backprop — the Fig. 1(a) shape).
    """
    rec = _load(arch, shape, mesh)
    if rec is None:
        raise FileNotFoundError(
            f"no dry-run cell for {arch}×{shape}×{mesh}; run "
            f"`python -m repro.launch.dryrun --arch {arch}`"
        )
    t_comp = rec["flops"] / PEAK_FLOPS * 1e3                      # ms
    coll_bytes = rec["collectives"]["bytes"]["total"]
    t_coll = coll_bytes / ICI_BW * 1e3                            # ms
    iter_ms = max(t_comp, t_coll, 1.0)

    dcn_gbit = coll_bytes * dcn_fraction * 8e-9
    up_ms = max(1.0, dcn_gbit / (nic_gbps * 0.9) * 1e3)
    iter_ms = max(iter_ms, up_ms * 1.25)
    return CommPattern(
        iter_time_ms=iter_ms,
        phases=(Phase(start_ms=iter_ms - up_ms, duration_ms=up_ms,
                      gbps=nic_gbps * 0.9),),
        name=f"{arch}:{shape}",
    )
