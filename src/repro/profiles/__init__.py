"""DNN workload communication profiles (paper Table 3 + Fig. 1)."""

from .from_dryrun import available_archs, dryrun_pattern
from .models import PROFILES, ModelProfile, get_profile, paper_models

__all__ = [
    "PROFILES", "ModelProfile", "get_profile", "paper_models",
    "dryrun_pattern", "available_archs",
]
