"""Communication profiles of the paper's 13 DNN workloads (§5.1, Table 3).

The paper profiles each job on the testbed with InfiniBand port counters;
we generate the same information analytically:

- **data-parallel** models (VGG/ResNet/BERT families): one compute (Down)
  segment followed by one AllReduce (Up) segment per iteration — Fig. 1(a).
  Up bytes = ring-AllReduce traffic ``2 · P · (n−1)/n`` at the model's
  achievable NIC utilization.
- **model/hybrid-parallel** models (GPT family, DLRM): multi-phase patterns
  transcribed from Fig. 1(b)–(d) (activation peaks during forward, heavy
  AllReduce / all-to-all phases), scaled to the model's iteration time.

Solo iteration times are anchored to the paper's Table 2 snapshot numbers
(≈ 55–300 ms) at the listed reference batch sizes.  The scheduler may change
worker counts / batch sizes; patterns rescale accordingly.

Duty cycles reproduce the paper's compatibility structure, e.g.
WideResNet101+VGG16 fully compatible, BERT+VGG19 only partially (§2.2).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.circle import CommPattern, Phase

__all__ = ["ModelProfile", "PROFILES", "get_profile", "paper_models"]


@dataclass(frozen=True)
class ModelProfile:
    """Analytic communication profile of one DNN workload.

    ``phases_frac`` are (start_frac, dur_frac, gbps) templates on the solo
    iteration; data-parallel models instead derive their single Up phase
    from ``param_mb`` (ring AllReduce bytes) and ``peak_gbps``.
    """

    name: str
    kind: str                    # "vision" | "language" | "recommendation"
    parallelism: str             # "dp" | "mp"
    param_mb: float              # Table 3 memory requirement
    ref_batch: int               # reference per-GPU batch size
    ref_workers: int = 4
    compute_ms: float = 100.0    # Down-phase duration at ref batch
    peak_gbps: float = 45.0      # achievable NIC demand during Up phases
    comm_efficiency: float = 0.9 # fraction of peak actually sustained
    phases_frac: tuple[tuple[float, float, float], ...] = ()  # mp only
    mp_iter_ms: float = 0.0      # solo iteration time for mp templates

    # -------------------------------------------------------------- #
    def allreduce_gbit(self, num_workers: int) -> float:
        """Ring AllReduce bytes per NIC per iteration, in Gbit."""
        n = max(2, num_workers)
        return 2.0 * self.param_mb * 8e-3 * (n - 1) / n

    def comm_ms(self, num_workers: int) -> float:
        rate = self.peak_gbps * self.comm_efficiency
        return self.allreduce_gbit(num_workers) / rate * 1e3

    def iter_time_ms(
        self, num_workers: int | None = None, batch_per_gpu: int | None = None
    ) -> float:
        n = num_workers or self.ref_workers
        b = batch_per_gpu or self.ref_batch
        if self.parallelism == "mp":
            return self.mp_iter_ms * (0.5 + 0.5 * b / self.ref_batch)
        return self.compute_ms * (b / self.ref_batch) + self.comm_ms(n)

    # -------------------------------------------------------------- #
    def pattern(
        self,
        num_workers: int | None = None,
        batch_per_gpu: int | None = None,
    ) -> CommPattern:
        """The job's :class:`CommPattern` at the given configuration."""
        n = num_workers or self.ref_workers
        b = batch_per_gpu or self.ref_batch
        iter_ms = self.iter_time_ms(n, b)
        if self.parallelism == "mp":
            phases = tuple(
                Phase(start_ms=f0 * iter_ms, duration_ms=fd * iter_ms, gbps=g)
                for (f0, fd, g) in self.phases_frac
            )
        else:
            compute = self.compute_ms * (b / self.ref_batch)
            phases = (Phase(start_ms=compute, duration_ms=self.comm_ms(n),
                            gbps=self.peak_gbps),)
        return CommPattern(iter_time_ms=iter_ms, phases=phases, name=self.name)

    @property
    def duty_cycle(self) -> float:
        p = self.pattern()
        return sum(ph.duration_ms for ph in p.phases) / p.iter_time_ms


# ---------------------------------------------------------------------- #
# The 13 workloads (Table 3).  compute_ms / peak_gbps calibrated to the
# paper's measured iteration times and compatibility structure (§2.2,
# Table 2): VGG family ≈ 45 % duty, WideResNet101 ≈ 50 %, ResNet50 light,
# BERT-family 60–75 % duty (only partially compatible with VGGs),
# GPT/DLRM multi-phase hybrid-parallel templates from Fig. 1.
# ---------------------------------------------------------------------- #
PROFILES: dict[str, ModelProfile] = {
    p.name: p
    for p in [
        # --- vision, data parallel ---------------------------------- #
        # compute_ms chosen so solo iteration times at the reference config
        # land on a small set of period classes (320 / 160 / 210 / 260 ms):
        # jobs the paper calls compatible share a (quantized) period class,
        # so their unified circles stay small and interleaving is feasible.
        ModelProfile("vgg11", "vision", "dp", param_mb=507, ref_batch=1400,
                     compute_ms=176.0, peak_gbps=45.0, comm_efficiency=0.94),
        ModelProfile("vgg16", "vision", "dp", param_mb=528, ref_batch=1400,
                     compute_ms=170.2, peak_gbps=45.0, comm_efficiency=0.94),
        ModelProfile("vgg19", "vision", "dp", param_mb=549, ref_batch=1400,
                     compute_ms=163.9, peak_gbps=45.0, comm_efficiency=0.94),
        ModelProfile("resnet50", "vision", "dp", param_mb=98, ref_batch=1600,
                     compute_ms=51.0, peak_gbps=12.0),
        ModelProfile("wideresnet101", "vision", "dp", param_mb=243, ref_batch=800,
                     compute_ms=239.0, peak_gbps=40.0),
        # --- language, data parallel -------------------------------- #
        ModelProfile("bert", "language", "dp", param_mb=450, ref_batch=8,
                     compute_ms=90.0, peak_gbps=40.0),
        ModelProfile("roberta", "language", "dp", param_mb=800, ref_batch=12,
                     compute_ms=150.0, peak_gbps=42.0),
        ModelProfile("camembert", "language", "dp", param_mb=266, ref_batch=8,
                     compute_ms=113.3, peak_gbps=38.0),
        ModelProfile("xlm", "language", "dp", param_mb=1116, ref_batch=8,
                     compute_ms=82.9, peak_gbps=42.0),
        # --- language + recommendation, model/hybrid parallel -------- #
        # phase templates transcribed from Fig. 1(b)–(d); low-bandwidth
        # forward/activation peaks can co-exist on a link, the heavy
        # AllReduce/all-to-all arcs are what interleaving must separate.
        # Period classes drive compatibility: GPT-1/GPT-2 live on the
        # 320 ms class, GPT-3/DLRM on the 560 ms class.  Matched periods
        # interleave (high score); mismatched periods precess across the
        # unified circle and collide in most iterations (low score) — the
        # paper's ⟨GPT-1,GPT-2⟩ / ⟨GPT-3,DLRM⟩ vs ⟨GPT-3,GPT-2⟩ /
        # ⟨GPT-1,DLRM⟩ structure (§5.2, §5.4).
        ModelProfile("gpt1", "language", "mp", param_mb=9000, ref_batch=48,
                     mp_iter_ms=320.0,
                     phases_frac=((0.05, 0.07, 15.0), (0.48, 0.45, 40.0))),
        ModelProfile("gpt2", "language", "mp", param_mb=27000, ref_batch=48,
                     mp_iter_ms=320.0,
                     phases_frac=((0.04, 0.04, 15.0), (0.11, 0.04, 15.0),
                                  (0.18, 0.04, 15.0), (0.55, 0.40, 42.0))),
        ModelProfile("gpt3", "language", "mp", param_mb=155000, ref_batch=32,
                     mp_iter_ms=560.0,
                     phases_frac=((0.00, 0.09, 25.0), (0.105, 0.08, 35.0),
                                  (0.20, 0.12, 20.0), (0.50, 0.09, 40.0),
                                  (0.605, 0.08, 30.0), (0.70, 0.12, 45.0))),
        ModelProfile("dlrm", "recommendation", "mp", param_mb=1962, ref_batch=512,
                     mp_iter_ms=560.0,
                     phases_frac=((0.05, 0.17, 45.0), (0.55, 0.17, 45.0))),
    ]
}


def get_profile(name: str) -> ModelProfile:
    try:
        return PROFILES[name.lower()]
    except KeyError:
        raise KeyError(f"unknown model profile {name!r}; have {sorted(PROFILES)}")


def paper_models() -> Sequence[str]:
    return tuple(PROFILES)
