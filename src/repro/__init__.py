"""CASSINI reproduction: network-aware ML-cluster scheduling on a
production-grade JAX training/serving substrate."""

__version__ = "1.0.0"
