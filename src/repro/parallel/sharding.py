"""Parameter/optimizer-state sharding rules for the (pod, data, model) mesh.

``param_shardings(params, mesh)`` walks the parameter pytree and assigns a
NamedSharding per array from its *key name* (embed, wq, w_gate, …) and
rank.  Two axes are used:

- "model" — tensor-parallel dim (heads / d_ff / experts / vocab),
- ba = ("pod","data") — **FSDP/ZeRO dim**: a second weight dimension
  (usually d_model) shards over the data axes, so parameters and Adam
  moments are *fully* sharded across all 512 devices; XLA inserts the
  per-layer weight all-gathers (classic FSDP) which the roofline
  accounts under the collective term.

Per-dimension divisibility fallback: a dim that does not divide its mesh
axis is replicated (GQA KV heads fall back to sharding head_dim; small
expert counts fall back to sharding the expert FFN hidden dim).  Leading
layer-stack dimensions (from scan stacking) are never sharded.

Optimizer state (AdamW mu/nu mirror the params) reuses the same function.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import make_spec

__all__ = ["param_shardings", "batch_spec", "named"]


def _rules(key: str, shape: tuple[int, ...], model: int, ba, fsdp: bool):
    """Logical axes for the array (len == rank); leading stack dims None."""
    r = len(shape)
    last = lambda *axes: (None,) * (r - len(axes)) + tuple(axes)
    dp = ba if fsdp else None
    if r <= 1:
        return (None,) * r

    if key == "embed":
        return last("model", dp)
    if key in ("unembed", "in_proj", "patch_proj", "frame_proj"):
        return last(dp, "model")
    if key == "out_proj":
        return last("model", dp)
    if key == "conv_w":
        return last(None, "model")
    if key == "wq":
        h = shape[-2]
        return last(dp, "model", None) if h % model == 0 else last(dp, None, "model")
    if key in ("wk", "wv"):
        kv = shape[-2]
        return last(dp, "model", None) if kv % model == 0 else last(dp, None, "model")
    if key == "wo":
        h = shape[-3]
        return last("model", None, dp) if h % model == 0 else last(None, "model", dp)
    if key in ("w_gate", "w_up"):
        if _looks_expert(shape):
            return _expert_axes(shape, model, ba, order="df")
        return last(dp, "model")
    if key == "w_down":
        if _looks_expert(shape):
            return _expert_axes(shape, model, ba, order="fd")
        return last("model", dp)
    if key in ("router", "enc_pos", "bq", "bk", "bv"):
        return (None,) * r
    return (None,) * r


# §Perf iteration (kimi-k2): expert-resident weights + token all-to-all
# (Switch/GShard-style EP) were hypothesized to beat FSDP weight gathers.
# MEASURED RESULT: refuted on this GSPMD version — the dispatch einsum's
# backward inserts E-major all-gathers (6.4 TB/dev) and replicates compute
# (+60 % FLOPs).  The FSDP layout stays the default; flip this flag to
# reproduce the experiment (EXPERIMENTS.md §Perf, kimi iterations 1-2).
EXPERT_RESIDENT = False


def _expert_axes(shape, model, ba, *, order: str):
    """Expert-stacked FFN weights (…, E, D, F) / (…, E, F, D).

    Preferred layout (§Perf iteration: 'resident expert weights'): shard
    the expert dim over the data axes and the FFN hidden dim over the model
    axis — weights never move; the token dispatch becomes an all-to-all
    over the data axis (tokens travel to their experts), which is orders of
    magnitude less traffic than FSDP-regathering TBs of expert weights
    every layer.  Falls back to expert-over-model + FSDP-D when the expert
    count does not divide the data axes (mixtral: 8 experts).
    """
    r = len(shape)
    last = lambda *axes: (None,) * (r - len(axes)) + tuple(axes)
    e = shape[-3]
    ff_axis = "model"
    if EXPERT_RESIDENT and ba is not None and e % _axes_size_hint.get(ba, 0) == 0:
        return last(ba, None, ff_axis) if order == "df" else last(ba, ff_axis, None)
    if e % model == 0:
        return last("model", ba, None) if order == "df" else last("model", None, ba)
    return last(None, ba, "model") if order == "df" else last(None, "model", ba)


# populated by param_shardings with the actual mesh axis sizes
_axes_size_hint: dict = {}

_EXPERT_HINT: set[int] = set()


def _looks_expert(shape: tuple[int, ...]) -> bool:
    """(…, E, D, F) expert stacks have E in the known expert counts."""
    return len(shape) >= 3 and shape[-3] in _EXPERT_HINT


def param_shardings(
    params: Any, mesh: Mesh, *, num_experts: int = 0, fsdp: bool = True
):
    """NamedSharding pytree matching ``params``."""
    if num_experts:
        _EXPERT_HINT.add(num_experts)
    model = mesh.shape.get("model", 1)
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape) or None
    if ba is not None:
        n = 1
        for a in ba:
            n *= mesh.shape[a]
        _axes_size_hint[ba] = n

    def assign(path, leaf):
        key = ""
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                key = str(p.key)
                break
        axes = _rules(key, leaf.shape, model, ba, fsdp)
        return NamedSharding(mesh, make_spec(mesh, leaf.shape, axes))

    return jax.tree_util.tree_map_with_path(assign, params)


def batch_spec(mesh: Mesh, shape: tuple[int, ...]) -> NamedSharding:
    """Batch-sharded input spec: dim0 over (pod, data), divisibility-safe."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return NamedSharding(
        mesh, make_spec(mesh, shape, (ba,) + (None,) * (len(shape) - 1))
    )


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))
