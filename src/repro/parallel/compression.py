"""Gradient compression hooks (distributed-optimization toolbox).

Two classic schemes with **error feedback** so compression noise does not
bias convergence:

- ``int8_compress``  — per-tensor scale + int8 quantization (4× over f32);
- ``topk_compress``  — keep the top-k fraction of entries by magnitude.

``CompressedState`` carries the residual; apply around the DP AllReduce:

    c, st = int8_compress(g, st)      # before the all-reduce
    g_hat  = decompress(c)            # after

In the dry-run roofline these shrink the DP-gradient collective term
proportionally (§Perf discusses when that matters: only when the
collective term dominates and links are DCN-grade, not ICI).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "EFState",
    "init_ef",
    "int8_compress",
    "int8_decompress",
    "topk_compress",
    "topk_decompress",
]


class EFState(NamedTuple):
    residual: Any  # same pytree as grads


def init_ef(grads) -> EFState:
    return EFState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads))


# ----------------------------- int8 ----------------------------------- #
def int8_compress(grads, ef: EFState):
    def one(g, r):
        x = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_r = x - q.astype(jnp.float32) * scale
        return (q, scale), new_r

    pairs = jax.tree.map(one, grads, ef.residual)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2 and isinstance(t[0], tuple)
    comp = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    resid = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return comp, EFState(resid)


def int8_decompress(comp):
    is_qs = lambda t: isinstance(t, tuple) and len(t) == 2
    return jax.tree.map(
        lambda t: t[0].astype(jnp.float32) * t[1], comp, is_leaf=is_qs
    )


# ----------------------------- top-k ----------------------------------- #
def topk_compress(grads, ef: EFState, frac: float = 0.1):
    def one(g, r):
        x = (g.astype(jnp.float32) + r).reshape(-1)
        k = max(1, int(x.size * frac))
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        kept = x[idx]
        new_r = x.at[idx].set(0.0).reshape(g.shape)
        return (kept, idx, g.shape), new_r

    pairs = jax.tree.map(one, grads, ef.residual)
    is_p = lambda t: isinstance(t, tuple) and len(t) == 2 and isinstance(t[0], tuple)
    comp = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_p)
    resid = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_p)
    return comp, EFState(resid)


def topk_decompress(comp):
    is_c = lambda t: isinstance(t, tuple) and len(t) == 3
    def one(t):
        kept, idx, shape = t
        flat = jnp.zeros(int(jnp.prod(jnp.array(shape))), jnp.float32)
        return flat.at[idx].set(kept).reshape(shape)
    return jax.tree.map(one, comp, is_leaf=is_c)
