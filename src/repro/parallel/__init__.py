"""Distribution: sharding rules, gradient compression."""
