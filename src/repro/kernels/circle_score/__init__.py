from . import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
