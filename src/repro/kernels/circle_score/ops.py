"""Jitted public entry point for circle_score.

``circle_score(base, cand, capacity)`` dispatches to the Pallas kernel
(interpret mode on CPU — the TPU target compiles the same kernel with
``interpret=False``) and is what :mod:`repro.core.compat` calls for large
angle grids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import circle_score_pallas
from .ref import circle_score_ref

__all__ = ["circle_score", "circle_score_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def circle_score(base, cand, capacity) -> jax.Array:
    """``capacity`` may be a scalar (shared by all rows) or an ``(L,)`` /
    ``(L, 1)`` array of per-row link capacities."""
    base = jnp.atleast_2d(jnp.asarray(base, jnp.float32))
    cand = jnp.atleast_2d(jnp.asarray(cand, jnp.float32))
    cap = jnp.asarray(capacity, jnp.float32)
    return circle_score_pallas(base, cand, cap, interpret=not _ON_TPU)
