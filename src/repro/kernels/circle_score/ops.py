"""Jitted public entry points for the circle_score kernel family.

``circle_score(base, cand, capacity)`` dispatches to the full-matrix
Pallas kernel (interpret mode on CPU — the TPU target compiles the same
kernel with ``interpret=False``) and is what :mod:`repro.core.compat`
calls for its numpy-free fallback paths and what the tests oracle against.

``circle_score_argmin`` is the fused reduction: per-row
``(best_shift, best_excess)`` computed inside the kernel (chunked
tournament-tree argmin), so only O(L) scalars cross the device→host
boundary instead of the O(L·A) excess matrix.

``circle_score_ragged_argmin`` is the same kernel with per-row angle
counts: rows built on *different* unified circles (mixed ``A_l``) ship
as ONE launch, each row masked to its own ``num_angles[l]`` angles and
``valid[l]`` admissible shifts.  The fold-sum row reduction is
padding-invariant, so ragged results are bit-identical to per-group
launches of the uniform entry point (tests assert it).

``circle_score_segmin`` / ``circle_score_ragged_segmin`` layer the
segmented accept-scan on top: rows belong to contiguous *segments* (one
segment = one link problem's product-grid rows within a chunk) and the
scan replays the host coordinate-search acceptance rule — visit rows in
order, accept a row's best shift iff it beats the segment's incumbent by
more than the 1e-12 slack — entirely on device, returning four
O(num_segments) vectors.  The scan runs in float64 (via
:func:`jax.experimental.enable_x64`) so the ``excess < best − 1e-12``
predicate is evaluated in exactly the arithmetic the host search uses
(python floats), keeping accepted-shift sequences bit-identical even for
sub-ulp float32 excess differences.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .kernel import LANE_MULTIPLE, circle_score_argmin_pallas, circle_score_pallas
from .ref import circle_score_argmin_ref, circle_score_ref

__all__ = [
    "circle_score",
    "circle_score_argmin",
    "circle_score_ragged_argmin",
    "circle_score_segmin",
    "circle_score_ragged_segmin",
    "circle_score_ref",
    "circle_score_argmin_ref",
    "bucket_width",
    "ACCEPT_SLACK",
]


def bucket_width(w: int) -> int:
    """Bucketed ragged launch width: the smallest power-of-two multiple of
    :data:`LANE_MULTIPLE` ≥ ``w`` (128, 256, 512, 1024, …).

    Ragged batches ship at their chunk's max angle count, and a long-tailed
    mix of unified-circle sizes would otherwise present the jit cache with
    one distinct lane width — hence one Mosaic recompile — per chunk.
    Rounding the packed width up to a small fixed set of buckets caps the
    compile count at O(log max_width) for any angle-count distribution;
    the fold-sum padding invariance makes the wider launch bit-exact
    (tests assert both the cache bound and the parity).
    """
    if w < 1:
        raise ValueError(f"width must be positive, got {w}")
    b = LANE_MULTIPLE
    while b < w:
        b *= 2
    return b

_ON_TPU = jax.default_backend() == "tpu"

# The host rotation search's strict-improvement slack — ONE source of truth,
# owned by repro.core.compat (numpy-only, no import cycle: compat only loads
# this module lazily inside functions).  Re-exported here because the device
# accept scan below evaluates the same predicate.
from repro.core.compat import ACCEPT_SLACK  # noqa: E402


def _schedule(variant: str, width: int, tuned: bool, **explicit) -> dict:
    """Resolve a launch's schedule parameters (block_l, shift_chunk, …).

    Explicit non-``None`` kwargs always win; otherwise ``tuned=True``
    consults the per-bucket tuning table (:mod:`repro.kernels.tune` —
    every loader failure mode already falls back to defaults inside
    ``lookup``) and ``tuned=False`` pins the kernels' module defaults
    (the untuned comparison path the autotuner and benches measure
    against).  Schedule parameters are bit-inert for this family, so
    this choice can only ever move wall time.
    """
    from repro.kernels import tune

    params = (
        tune.lookup(variant, width) if tuned else dict(tune.DEFAULTS[variant])
    )
    params.update({k: v for k, v in explicit.items() if v is not None})
    return params


def circle_score(base, cand, capacity, *, tuned=True, block_l=None) -> jax.Array:
    """``capacity`` may be a scalar (shared by all rows) or an ``(L,)`` /
    ``(L, 1)`` array of per-row link capacities.  ``tuned`` / ``block_l``
    select the launch schedule (see :func:`_schedule`); outputs are
    bit-identical for every choice."""
    base = jnp.atleast_2d(jnp.asarray(base, jnp.float32))
    cand = jnp.atleast_2d(jnp.asarray(cand, jnp.float32))
    cap = jnp.asarray(capacity, jnp.float32)
    sched = _schedule("circle_score", base.shape[1], tuned, block_l=block_l)
    return circle_score_pallas(base, cand, cap, interpret=not _ON_TPU, **sched)


def circle_score_argmin(
    base, cand, capacity, valid=None,
    *, tuned=True, block_l=None, shift_chunk=None,
):
    """Fused rotation search: ``(best_shift, best_excess)`` per row.

    ``valid`` bounds the admissible shifts per row (Eq. 4: job ``j`` only
    has ``A / r_j`` distinct rotations); ``None`` admits all ``A`` shifts.
    Bit-identical to ``np.argmin`` over ``circle_score(...)[l, :valid[l]]``
    (first-index tie-breaking) without ever materializing the matrix —
    for every launch schedule, tuned or not.
    """
    base = jnp.atleast_2d(jnp.asarray(base, jnp.float32))
    cand = jnp.atleast_2d(jnp.asarray(cand, jnp.float32))
    cap = jnp.asarray(capacity, jnp.float32)
    l, a = base.shape
    if valid is None:
        valid = jnp.full((l,), a, jnp.int32)
    else:
        valid = jnp.broadcast_to(jnp.asarray(valid, jnp.int32).reshape(-1), (l,))
    sched = _schedule(
        "circle_score_argmin", a, tuned,
        block_l=block_l, shift_chunk=shift_chunk,
    )
    return circle_score_argmin_pallas(
        base, cand, cap, valid, interpret=not _ON_TPU, **sched
    )


def circle_score_ragged_argmin(
    base, cand, capacity, valid, num_angles, *, pad_to=None,
    tuned=True, block_l=None, shift_chunk=None, _variant="circle_score_argmin",
):
    """Ragged fused rotation search: ONE launch over mixed angle counts.

    Args:
      base, cand: (L, W) float32, row ``l`` real in ``[:num_angles[l]]``
        and zero-padded above (W = the packed batch width ≥ max A_l).
      capacity: scalar or (L,) per-row link capacities.
      valid: (L,) int32 admissible shifts per row (1 ≤ valid ≤ A_l).
      num_angles: (L,) int32 per-row real angle counts (1 ≤ A_l ≤ W).
      pad_to: optionally force a wider launch width (tests); the actual
        launch width is always rounded up to a :func:`bucket_width`
        bucket — bit-exact by the fold-sum padding invariance — so
        long-tailed angle-count mixes stop paying one jit recompile per
        distinct packed width.
      tuned, block_l, shift_chunk: launch schedule selection (see
        :func:`_schedule`) — the table lookup is keyed by the bucketed
        launch width; outputs are bit-identical for every schedule.

    Returns ``(best_shift, best_excess)`` per row, bit-identical to
    invoking :func:`circle_score_argmin` once per angle-count group on
    the tightly-sliced rows.
    """
    base = np.atleast_2d(np.asarray(base, np.float32))
    cand = np.atleast_2d(np.asarray(cand, np.float32))
    l, w = base.shape
    na = np.broadcast_to(np.asarray(num_angles, np.int32), (l,))
    valid = np.broadcast_to(np.asarray(valid, np.int32), (l,))
    if np.any(na < 1) or np.any(na > w):
        raise ValueError(f"num_angles must lie in [1, {w}], got {na}")
    if np.any(valid < 1) or np.any(valid > na):
        # valid == 0 is the *internal* block-padding convention of the
        # kernel (rows the wrapper slices off); a caller-supplied row with
        # no admissible shift would come back as a fabricated perfect
        # (shift 0, excess 0) — reject it instead
        raise ValueError("valid shift counts must lie in [1, num_angles]")
    # bucket the packed width host-side (zero-pad the angle axis) so the
    # jit cache key only ever sees O(log max_width) distinct widths; rows
    # are masked to num_angles in-kernel, so padding is provably inert
    wb = bucket_width(max(w, pad_to or 0))
    if wb != w:
        base = np.pad(base, ((0, 0), (0, wb - w)))
        cand = np.pad(cand, ((0, 0), (0, wb - w)))
    cap = jnp.asarray(capacity, jnp.float32)
    # the table is keyed by exactly this bucketed launch width, so the
    # lookup and the jit cache see the same (variant, bucket) universe
    sched = _schedule(
        _variant, wb, tuned, block_l=block_l, shift_chunk=shift_chunk
    )
    return circle_score_argmin_pallas(
        jnp.asarray(base), jnp.asarray(cand), cap,
        jnp.asarray(valid), jnp.asarray(na),
        interpret=not _ON_TPU, **sched,
    )


@jax.jit
def _accept_scan(val, idx, seg_ids, init_best):
    """Sequential accept fold over rows, segmented by ``seg_ids``.

    Path-dependent by design (the slack rule is not associative), hence a
    scan rather than a segmented min.  Must run under x64 so the predicate
    matches the host's float64 comparison exactly.
    """
    num_segs = init_best.shape[0]
    rows = jnp.arange(val.shape[0], dtype=jnp.int32)

    def step(state, xs):
        best, row, shift, acc = state
        v, i, sid, r = xs
        take = v < best[sid] - ACCEPT_SLACK
        best = best.at[sid].set(jnp.where(take, v, best[sid]))
        row = row.at[sid].set(jnp.where(take, r, row[sid]))
        shift = shift.at[sid].set(jnp.where(take, i, shift[sid]))
        acc = acc.at[sid].set(jnp.logical_or(acc[sid], take))
        return (best, row, shift, acc), None

    init = (
        init_best.astype(jnp.float64),
        jnp.zeros(num_segs, jnp.int32),
        jnp.zeros(num_segs, jnp.int32),
        jnp.zeros(num_segs, jnp.bool_),
    )
    (best, row, shift, acc), _ = jax.lax.scan(
        step, init, (val.astype(jnp.float64), idx, seg_ids, rows)
    )
    return acc, row, shift, best


def _segmin_from(idx, val, seg_ids, init_best):
    """Shared accept-scan tail of the (ragged) segmin entry points."""
    seg = jnp.asarray(np.asarray(seg_ids), jnp.int32)
    with enable_x64():
        acc, row, shift, best = _accept_scan(
            val, idx, seg, jnp.asarray(np.asarray(init_best, np.float64))
        )
    return acc, row, shift, best


def circle_score_segmin(
    base, cand, capacity, valid, seg_ids, init_best,
    *, tuned=True, block_l=None, shift_chunk=None,
):
    """Fused rotation search + segmented acceptance, fully device-side.

    Args:
      base, cand, capacity, valid: as :func:`circle_score_argmin`.
      seg_ids: (L,) int — segment index of each row (rows of one segment
        must be contiguous and in host visit order).
      init_best: (S,) float64 — each segment's incumbent best excess from
        previous chunks (``inf`` for a fresh segment).
      tuned, block_l, shift_chunk: launch schedule, resolved against the
        ``circle_score_segmin`` table entries (the grid path's tall
        chunks tune differently from the descent path's short steps).

    Returns ``(accepted (S,) bool, row (S,) int32, shift (S,) int32,
    best (S,) float64)`` — ``row`` is the chunk-global index of the
    accepted row; entries with ``accepted == False`` carry their init
    state.  Only these four O(S) vectors leave the device.
    """
    a = np.atleast_2d(np.asarray(base)).shape[1]
    sched = _schedule(
        "circle_score_segmin", a, tuned,
        block_l=block_l, shift_chunk=shift_chunk,
    )
    idx, val = circle_score_argmin(
        base, cand, capacity, valid, tuned=False, **sched
    )
    return _segmin_from(idx, val, seg_ids, init_best)


def circle_score_ragged_segmin(
    base, cand, capacity, valid, num_angles, seg_ids, init_best, *,
    pad_to=None, tuned=True, block_l=None, shift_chunk=None,
):
    """Ragged :func:`circle_score_segmin`: one launch over mixed angle
    counts (see :func:`circle_score_ragged_argmin`), then the same
    segmented device-side acceptance scan.  The schedule resolves against
    the ``circle_score_segmin`` table entries, keyed by the bucketed
    launch width."""
    idx, val = circle_score_ragged_argmin(
        base, cand, capacity, valid, num_angles, pad_to=pad_to,
        tuned=tuned, block_l=block_l, shift_chunk=shift_chunk,
        _variant="circle_score_segmin",
    )
    return _segmin_from(idx, val, seg_ids, init_best)
