"""Pure oracles for the circle_score kernel family.

The kernels' row sums are power-of-two halving-folds (padding-invariant —
see ``kernel._fold_sum``), which is part of their arithmetic contract:
the oracles reproduce the same fold in plain numpy so exact-parity tests
can compare the fused reductions against an independent implementation
bit for bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _fold_sum_np(x: np.ndarray) -> np.ndarray:
    """Numpy twin of ``kernel._fold_sum``: (L, W) → (L,) float32 row sums
    via the same ascending sequential accumulation of 128-lane groups
    (same order, same IEEE adds).  The closing 128-lane reduce goes
    through the same jitted ``jnp.sum`` the kernels use — numpy's
    pairwise summation groups differently (measured), and the oracle
    must reproduce the kernel arithmetic exactly for the bit-parity
    tests."""
    from .kernel import LANE_MULTIPLE

    x = np.asarray(x, np.float32)
    wp = -(-x.shape[1] // LANE_MULTIPLE) * LANE_MULTIPLE
    if wp != x.shape[1]:
        x = np.pad(x, ((0, 0), (0, wp - x.shape[1])))
    acc = x[:, :LANE_MULTIPLE]
    for k in range(1, wp // LANE_MULTIPLE):
        acc = acc + x[:, k * LANE_MULTIPLE : (k + 1) * LANE_MULTIPLE]
    return np.asarray(_final_reduce(jnp.asarray(acc)))


@jax.jit
def _final_reduce(x):
    return jnp.sum(x, axis=-1)


def circle_score_ref(base: jax.Array, cand: jax.Array, capacity) -> jax.Array:
    """out[l, s] = fold_Σ_α max(0, base[l,α] + cand[l,(α−s) mod A] − C_l).

    ``capacity`` is a scalar or an ``(L,)`` / ``(L, 1)`` per-row array,
    mirroring the kernel's per-row capacity support.
    """
    base = np.asarray(base, np.float32)
    cand = np.asarray(cand, np.float32)
    l, a = base.shape
    idx = (np.arange(a)[None, :] - np.arange(a)[:, None]) % a    # (S, A)
    rolled = cand[:, idx]                                        # (L, S, A)
    cap = np.asarray(capacity, np.float32)
    cap = cap.reshape(-1, 1, 1) if cap.ndim else cap
    excess = np.maximum(base[:, None, :] + rolled - cap, 0.0)
    out = _fold_sum_np(excess.reshape(l * a, a)).reshape(l, a)
    return jnp.asarray(out)


def circle_score_argmin_ref(base, cand, capacity, valid=None, num_angles=None):
    """Host oracle for the fused reduction: full matrix, then per-row
    ``np.argmin`` over the first ``valid[l]`` admissible shifts (first-index
    tie-breaking — exactly what the scalar rotation search does).

    ``num_angles`` makes the oracle ragged: row ``l`` is scored on its own
    ``A_l``-angle circle (``base[l, :A_l]`` / ``cand[l, :A_l]``), matching
    the ragged kernel's per-row masking.
    """
    base = np.asarray(base, np.float32)
    cand = np.asarray(cand, np.float32)
    l, a = base.shape
    valid = np.full(l, a) if valid is None else np.broadcast_to(valid, (l,))
    na = (
        np.full(l, a)
        if num_angles is None
        else np.broadcast_to(num_angles, (l,))
    )
    cap = np.broadcast_to(np.asarray(capacity, np.float32).reshape(-1), (l,))
    idx = np.empty(l, np.int32)
    val = np.empty(l, np.float32)
    for i in range(l):
        w = int(na[i])
        mat = np.asarray(
            circle_score_ref(base[i : i + 1, :w], cand[i : i + 1, :w], cap[i])
        )[0]
        s = int(np.argmin(mat[: valid[i]]))
        idx[i] = s
        val[i] = mat[s]
    return idx, val
