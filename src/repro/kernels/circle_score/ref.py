"""Pure oracles for the circle_score kernel family."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def circle_score_ref(base: jax.Array, cand: jax.Array, capacity) -> jax.Array:
    """out[l, s] = Σ_α max(0, base[l,α] + cand[l,(α−s) mod A] − C_l).

    ``capacity`` is a scalar or an ``(L,)`` / ``(L, 1)`` per-row array,
    mirroring the kernel's per-row capacity support.
    """
    l, a = base.shape
    idx = (jnp.arange(a)[None, :] - jnp.arange(a)[:, None]) % a  # (S, A)
    rolled = cand[:, idx]                                        # (L, S, A)
    cap = jnp.asarray(capacity, base.dtype)
    cap = cap.reshape(-1, 1, 1) if cap.ndim else cap
    total = base[:, None, :] + rolled - cap
    return jnp.maximum(total, 0.0).sum(axis=-1)


def circle_score_argmin_ref(base, cand, capacity, valid=None):
    """Host oracle for the fused reduction: full matrix, then per-row
    ``np.argmin`` over the first ``valid[l]`` admissible shifts (first-index
    tie-breaking — exactly what the scalar rotation search does)."""
    mat = np.asarray(circle_score_ref(
        jnp.asarray(base, jnp.float32), jnp.asarray(cand, jnp.float32), capacity
    ))
    l, a = mat.shape
    valid = np.full(l, a) if valid is None else np.broadcast_to(valid, (l,))
    idx = np.empty(l, np.int32)
    val = np.empty(l, np.float32)
    for i in range(l):
        s = int(np.argmin(mat[i, : valid[i]]))
        idx[i] = s
        val[i] = mat[i, s]
    return idx, val
