"""Pure-jnp oracle for the circle_score kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def circle_score_ref(base: jax.Array, cand: jax.Array, capacity) -> jax.Array:
    """out[l, s] = Σ_α max(0, base[l,α] + cand[l,(α−s) mod A] − C_l).

    ``capacity`` is a scalar or an ``(L,)`` / ``(L, 1)`` per-row array,
    mirroring the kernel's per-row capacity support.
    """
    l, a = base.shape
    idx = (jnp.arange(a)[None, :] - jnp.arange(a)[:, None]) % a  # (S, A)
    rolled = cand[:, idx]                                        # (L, S, A)
    cap = jnp.asarray(capacity, base.dtype)
    cap = cap.reshape(-1, 1, 1) if cap.ndim else cap
    total = base[:, None, :] + rolled - cap
    return jnp.maximum(total, 0.0).sum(axis=-1)
