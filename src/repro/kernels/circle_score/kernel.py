"""Pallas TPU kernels for CASSINI compatibility scoring (paper Table 1).

For every link row ``l`` and candidate rotation ``s``:

    out[l, s] = Σ_α max(0, base[l, α] + cand[l, (α − s) mod A_l] − C_l)

This is the inner loop of the rotation search (:mod:`repro.core.compat`) —
a circular-shift correlation with a ReLU inside the reduction, evaluated
for *all* admissible rotations of a candidate job against the
already-placed demand ``base``.  The scheduler evaluates thousands of
(candidate × link) rows per epoch at 10 candidates × O(links)
(Algorithm 2), so the batched form is the hot-spot.

Two kernel variants share the same inner arithmetic:

  * :func:`circle_score_pallas` — the full ``(L, A)`` excess matrix
    (kept for the host-reduction fallback paths and for tests);
  * :func:`circle_score_argmin_pallas` — the fused *ragged* reduction:
    every row carries its own angle count ``num_angles[l]`` (``A_l``) and
    admissible-shift bound ``valid[l]``, so link problems built on
    *different* unified circles ship in ONE launch.  The argmin is a
    **chunked tournament tree**: each round evaluates
    :data:`SHIFT_CHUNK` independent shifts, reduces them with a
    log-depth pairwise ``(value, index)`` tournament and merges one
    champion into the ``(BL, 1)`` running best — the lexicographic
    compare (take the right operand iff ``(rv < lv) or (rv == lv and
    ri < li)``) preserves the strict-``<`` lowest-shift tie-break of
    host ``np.argmin`` for *any* tree shape, and the sequential depth
    drops by the chunk factor versus the old one-shift-per-iteration
    scan.  Only ``O(L)`` scalars ever leave the device.

Ragged row layout and masking invariants (see docs/architecture.md):

  * the angle axis is padded to the batch-wide lane width ``AP`` (a
    multiple of :data:`LANE_MULTIPLE`); ``base`` is zero beyond ``A_l``;
  * the candidate ships as a *periodic* buffer
    ``cc[l, u] = cand[l, (u − AP) mod A_l]`` of width ``2·AP``, so the
    roll by any shift ``s`` is one dynamic slice at the row-independent
    start ``AP − s`` — no in-kernel gathers, any mix of periods;
  * per-shift excess terms at angles ``α ≥ A_l`` are masked to exactly
    ``0.0`` before the row reduction, and shifts ``s ≥ valid[l]`` are
    masked to ``+inf`` before the tournament — padded angles and
    inadmissible shifts provably cannot win any reduction;
  * row sums use :func:`_fold_sum`: ascending sequential accumulation
    of 128-lane groups into one fixed-width partial plus one fixed-shape
    reduce.  Zero groups appended by wider padding are exact additive
    identities, so the fold at *any* padded width ``≥ A_l`` produces
    bit-identical float32 sums — this is what makes a ragged launch
    bit-identical to per-group launches (and to the full-matrix kernel
    the scalar search scores through), regardless of what other rows
    share the batch.

The tournament loop exits early once every row's running best has
reached zero — excess sums are non-negative and ties resolve to the
earlier shift, so nothing can displace a found zero, and each row's
evaluated prefix is guaranteed to contain its first zero shift, which
the tournament selects exactly like ``np.argmin`` over the full window.

TPU mapping: the circle rows live in VMEM (A ≤ ~2k angles ⇒ a (BL, AP)
f32 tile is ≤ 1 MiB); rolls are realized as dynamic slices of the
periodic (BL, 2·AP) buffer — no gathers — the chunk's shift evaluations
are independent (pipelineable; the only carried state is the (BL, 1)
champion pair) and both reductions (fold sum, tournament argmin) are
log-depth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8-row blocks amortize poorly; 32 measured ~1.5-2x faster for both kernel
# variants on large batches (and is still one VREG sublane tile on TPU).
DEFAULT_BLOCK_L = 32
# Mosaic wants the lane (minor) dimension a multiple of 128; the wrappers
# zero-pad the angle axis up to this multiple by default (masked in-kernel,
# exact — see module docstring).
LANE_MULTIPLE = 128
# Default shifts evaluated per tournament round of the fused argmin
# kernel: each loop iteration scores this many consecutive shifts
# (independent slices, unrolled — no carried dependence between them),
# reduces them with a log-depth tournament and merges one (value, index)
# champion pair into the (BL, 1) running best.  Cuts the loop's
# sequential depth by the chunk factor while keeping the carried state
# tiny — materializing the full per-shift value matrix instead (one
# store per iteration) measured ~4x slower because the loop then drags a
# (BL, AP) buffer through every iteration.  The chunk width is a
# traced-static kernel parameter (``shift_chunk``); this module constant
# is only the untuned default — per-bucket winners live in the
# repro.kernels.tune tables and flow in through the ops wrappers.
SHIFT_CHUNK = 8


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _fold_sum(x: jax.Array) -> jax.Array:
    """Padding-invariant row sums: ``(BL, W) → (BL, 1)``.

    Pads to a multiple of :data:`LANE_MULTIPLE` with zeros, accumulates
    the 128-lane groups **sequentially in ascending order** into one
    128-wide partial, then reduces that partial with one fused
    ``jnp.sum``.

    Invariance: if ``x[l, α] == 0`` for all ``α ≥ A_l``, lane ``i`` of
    the partial is ``(...(x[l,i] + x[l,i+128]) + x[l,i+256]) + ...`` —
    appending all-zero groups (any wider padding) only appends
    ``v + 0.0`` steps, which are exact in IEEE (all operands ``≥ +0.0``),
    so the partial is elementwise identical for every batch width
    ``≥ A_l``.  The closing reduce then always runs on the same static
    ``(·, 128)`` shape, so XLA emits one fixed reduction whose result is
    a function of the partial alone (batch-width, row-count and
    pallas-vs-host invariant — pinned by the parity tests).  Plain
    ``jnp.sum`` over the raw row does NOT have this property (XLA
    regroups partials per width, measured), which is why every
    kernel-family row sum goes through this fold.
    """
    bl, w = x.shape
    wp = -(-w // LANE_MULTIPLE) * LANE_MULTIPLE
    if wp != w:
        x = jnp.pad(x, ((0, 0), (0, wp - w)))
    acc = x[:, :LANE_MULTIPLE]
    for k in range(1, wp // LANE_MULTIPLE):
        acc = acc + x[:, k * LANE_MULTIPLE : (k + 1) * LANE_MULTIPLE]
    return jnp.sum(acc, axis=-1, keepdims=True)


def _tournament_min(
    lv: jax.Array, li: jax.Array, rv: jax.Array, ri: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One tournament round: elementwise lexicographic ``(value, index)``
    min.  The right operand wins iff ``rv < lv or (rv == lv and ri < li)``
    — so ties always resolve to the lowest index no matter how a tree
    pairs elements: at every internal node the survivor is the
    lexicographic minimum of the leaves below it, hence the root is the
    global ``(min value, first index of it)`` — exactly ``np.argmin``
    (proof sketch in docs/architecture.md)."""
    take_r = jnp.logical_or(rv < lv, jnp.logical_and(rv == lv, ri < li))
    return jnp.where(take_r, rv, lv), jnp.where(take_r, ri, li)


def _tournament_argmin(
    vals: jax.Array, idx: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Tournament-tree argmin: ``(BL, S) → ((BL, 1) val, (BL, 1) idx)``.

    Log-depth pairwise halving over ``(value, index)`` pairs using
    :func:`_tournament_min`; the lexicographic compare makes the result
    independent of the tree shape.  Padding columns are ``+inf`` and can
    only win when a whole row is ``+inf`` (then the lowest index wins,
    like argmin over a constant row).
    """
    bl, s = vals.shape
    p = _next_pow2(s)
    if p != s:
        vals = jnp.pad(vals, ((0, 0), (0, p - s)), constant_values=jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, p - s)))
    while vals.shape[1] > 1:
        h = vals.shape[1] // 2
        vals, idx = _tournament_min(
            vals[:, :h], idx[:, :h], vals[:, h:], idx[:, h:]
        )
    return vals, idx


def _circle_score_kernel(a: int, base_ref, cc_ref, cap_ref, out_ref):
    """Full-matrix variant: ``out[:, s]`` for every shift ``s < a``.

    ``a`` is the shared *real* (unpadded) angle count, closed over
    statically; ``cc_ref`` is the periodic candidate buffer (see
    ``_prep_inputs``).  Rows use the same masked fold-sum as the ragged
    argmin kernel, so full-matrix values and fused values are
    bit-identical.
    """
    base = base_ref[...]                                # (BL, AP)
    cc = cc_ref[...]                                    # (BL, 2*AP)
    cap = cap_ref[...]                                  # (BL, 1) per-row
    bl, ap = base.shape
    # mask angles >= a to exactly 0 before the fold: the reduction then
    # sees the unpadded operands plus exact additive identities, so lane
    # padding provably cannot change a single output bit
    mask = jax.lax.broadcasted_iota(jnp.int32, (bl, ap), 1) < a

    def body(s, _):
        # rolled[α] = cand[(α − s) mod a] == cc[AP − s : 2·AP − s][:AP]
        rolled = jax.lax.dynamic_slice(cc, (0, ap - s), (bl, ap))
        excess = jnp.maximum(base + rolled - cap, 0.0)
        val = _fold_sum(jnp.where(mask, excess, 0.0))   # (BL, 1)
        pl.store(out_ref, (slice(None), pl.dslice(s, 1)), val)
        return 0

    jax.lax.fori_loop(0, a, body, 0)


def _circle_score_argmin_kernel(
    shift_chunk: int,
    base_ref, cc_ref, cap_ref, valid_ref, na_ref, idx_ref, val_ref,
):
    """Ragged fused variant: per-row angle counts, chunked tournament.

    Each loop round evaluates ``shift_chunk`` consecutive shifts —
    independent slices, unrolled, no carried dependence between them —
    masks shifts ``s ≥ valid[row]`` to ``+inf`` (Eq. 4 bound) and angles
    ``α ≥ num_angles[row]`` to exactly ``0.0`` before the fold (ragged
    masking invariant), reduces the chunk with a log-depth tournament
    and merges the champion into the ``(BL, 1)`` running ``(best_val,
    best_idx)`` pair with the same lexicographic compare.  Chunks are
    visited in ascending shift order, so the running pair always carries
    the lowest-index minimum — exactly ``np.argmin`` over each row's
    admissible window.

    The loop stops at the block's largest admissible shift count and
    exits early once every row's running best hit zero (excess sums are
    non-negative, ties resolve to the earlier shift — nothing can
    displace a found zero).  Each row's evaluated prefix therefore
    provably contains its own first-zero shift (or its whole admissible
    window), independent of which other rows share the block.
    """
    base = base_ref[...]                                # (BL, AP)
    cc = cc_ref[...]                                    # (BL, 2*AP)
    cap = cap_ref[...]                                  # (BL, 1)
    valid = valid_ref[...]                              # (BL, 1) int32
    na = na_ref[...]                                    # (BL, 1) int32
    bl, ap = base.shape
    mask = jax.lax.broadcasted_iota(jnp.int32, (bl, ap), 1) < na
    nvalid = jnp.max(valid)

    def cond(carry):
        c, best_val, _ = carry
        return jnp.logical_and(c < nvalid, jnp.max(best_val) > 0.0)

    def body(carry):
        c, best_val, best_idx = carry
        cols_v, cols_i = [], []
        for i in range(shift_chunk):                    # unrolled: no deps
            s = c + i
            # rolled[α] = cand[(α − s) mod A] == cc[AP − s : 2·AP − s][:AP]
            # (dynamic_slice clamps s ≥ AP starts; those shifts are ≥ valid
            # and masked to +inf below, so the clamped values never matter)
            rolled = jax.lax.dynamic_slice(cc, (0, ap - s), (bl, ap))
            excess = jnp.maximum(base + rolled - cap, 0.0)
            val = _fold_sum(jnp.where(mask, excess, 0.0))   # (BL, 1)
            cols_v.append(jnp.where(s < valid, val, jnp.inf))
            cols_i.append(jnp.broadcast_to(jnp.reshape(s, (1, 1)), (bl, 1)))
        chunk_v, chunk_i = _tournament_argmin(
            jnp.concatenate(cols_v, axis=1), jnp.concatenate(cols_i, axis=1)
        )
        best_val, best_idx = _tournament_min(
            best_val, best_idx, chunk_v, chunk_i
        )
        return c + shift_chunk, best_val, best_idx

    # rows with valid == 0 (block padding) start "done" so they can never
    # hold the early-exit condition open
    init_val = jnp.where(valid > 0, jnp.inf, 0.0).astype(jnp.float32)
    init = (jnp.int32(0), init_val, jnp.zeros((bl, 1), jnp.int32))
    _, best_val, best_idx = jax.lax.while_loop(cond, body, init)
    idx_ref[...] = best_idx
    val_ref[...] = best_val


# ---------------------------------------------------------------------- #
def _prep_inputs(
    base, cand, capacity, block_l: int, lane_pad: bool,
    *, num_angles=None, pad_to: int | None = None,
):
    """Row-pad to the block size and lane-pad the angle axis.

    Returns ``(base, cc, cap, na, l, a, ap)`` where ``cc`` is the
    *periodic* candidate buffer ``cc[r, u] = cand[r, (u − AP) mod A_r]``
    of width ``2·AP``: the roll by shift ``s`` is then the single slice
    ``cc[:, AP − s : 2·AP − s]`` for *every* row at once, whatever mix
    of real angle counts ``A_r ≤ a`` the batch carries.  For a uniform
    batch (``num_angles=None`` ⇒ ``A_r = a``) this reads exactly the
    doubled-candidate values the pre-ragged kernels used.

    ``pad_to`` forces a wider lane-padded width (still masked in-kernel,
    still bit-exact by the fold invariance) — used to bucket ragged
    launch widths and to exercise the all-rows-padded case in tests.
    """
    l, a = base.shape
    ap = -(-a // LANE_MULTIPLE) * LANE_MULTIPLE if lane_pad else a
    if pad_to is not None:
        want = -(-pad_to // LANE_MULTIPLE) * LANE_MULTIPLE if lane_pad else pad_to
        ap = max(ap, want)
    pad_rows = (-l) % block_l
    cap = jnp.asarray(capacity, jnp.float32)
    cap = jnp.broadcast_to(cap.reshape(-1, 1) if cap.ndim else cap, (l, 1))
    base = base.astype(jnp.float32)
    cand = cand.astype(jnp.float32)
    if num_angles is None:
        na = jnp.full((l, 1), a, jnp.int32)
        # uniform fast path: the periodic buffer has one shared period, so
        # tile + static slice builds it without the per-row gather below
        # (bit-identical — same elements, exact copies; gathers lower far
        # worse than concat/tile on the TPU target)
        reps = -(-ap // a)                              # ceil(AP / A)
        off = reps * a - ap                             # phase: (−AP) mod A
        cc = jnp.tile(cand, (1, 2 * reps))[:, off : off + 2 * ap]
    else:
        na = jnp.asarray(num_angles, jnp.int32).reshape(-1, 1)
        u = jnp.arange(2 * ap, dtype=jnp.int32)[None, :]    # (1, 2*AP)
        cc = jnp.take_along_axis(cand, (u - ap) % na, axis=1)
    base = jnp.pad(base, ((0, pad_rows), (0, ap - a)))
    cc = jnp.pad(cc, ((0, pad_rows), (0, 0)))
    cap = jnp.pad(cap, ((0, pad_rows), (0, 0)))
    # padding rows get A = 1 (their demand is all-zero anyway) so the
    # periodic index arithmetic stays well-defined
    na = jnp.pad(na, ((0, pad_rows), (0, 0)), constant_values=1)
    return base, cc, cap, na, l, a, ap


@functools.partial(
    jax.jit, static_argnames=("block_l", "interpret", "lane_pad")
)
def circle_score_pallas(
    base: jax.Array,      # (L, A) float32
    cand: jax.Array,      # (L, A) float32
    capacity: jax.Array,  # scalar shared by all rows, or (L,)/(L, 1) per-row
    *,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
    lane_pad: bool = True,
) -> jax.Array:
    """Batched scoring; returns (L, A) excess sums (lower = better).

    Per-row capacities let one launch cover links with different
    capacities; a scalar capacity is broadcast to every row.  Values are
    bit-identical to the fused ragged kernel (same masked fold-sum).
    """
    base, cc, cap, _na, l, a, ap = _prep_inputs(
        base, cand, capacity, block_l, lane_pad
    )
    lp = base.shape[0]

    out = pl.pallas_call(
        functools.partial(_circle_score_kernel, a),
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 2 * ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, ap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp, ap), jnp.float32),
        interpret=interpret,
    )(base, cc, cap)
    return out[:l, :a]


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_l", "interpret", "lane_pad", "pad_to", "shift_chunk"
    ),
)
def circle_score_argmin_pallas(
    base: jax.Array,      # (L, A) float32 — zero-padded beyond num_angles[l]
    cand: jax.Array,      # (L, A) float32 — row l real in [:num_angles[l]]
    capacity: jax.Array,  # scalar, or (L,)/(L, 1) per-row
    valid: jax.Array,     # (L,) int32 admissible shifts per row (≤ num_angles)
    num_angles: jax.Array | None = None,  # (L,) int32 per-row angle counts
    *,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
    lane_pad: bool = True,
    pad_to: int | None = None,
    shift_chunk: int = SHIFT_CHUNK,
) -> tuple[jax.Array, jax.Array]:
    """Fused ragged reduction; one launch for any mix of angle counts.

    Returns ``(best_shift (L,) int32, best_excess (L,) float32)`` —
    bit-identical to ``np.argmin(full_matrix[l, :valid[l]])`` per row
    (same fold-sum excess values, first-index tie-breaking via the
    tournament tree) while returning O(L) scalars instead of the O(L·A)
    matrix.  ``num_angles=None`` treats the batch as uniform (every row
    spans all ``A`` angles); per-group launches are exactly this kernel
    invoked once per distinct angle count, so ragged-vs-grouped
    equivalence reduces to the fold's padding invariance.

    ``block_l`` and ``shift_chunk`` are pure schedule parameters: per-row
    fold sums and the tree-shape-independent tournament make the returned
    pair bit-identical for every (block_l, shift_chunk) combination —
    larger chunks only evaluate extra shifts past a found zero, and those
    can never displace a lower-index champion.  That invariance is what
    lets the autotuner (:mod:`repro.kernels.tune`) swap them per width
    bucket without a numerics audit; it is re-verified for every search
    candidate and pinned by the parity tests.
    """
    l, a = base.shape
    valid = jnp.broadcast_to(jnp.asarray(valid, jnp.int32).reshape(-1, 1), (l, 1))
    base, cc, cap, na, l, a, ap = _prep_inputs(
        base, cand, capacity, block_l, lane_pad,
        num_angles=num_angles, pad_to=pad_to,
    )
    lp = base.shape[0]
    valid = jnp.pad(valid, ((0, lp - l), (0, 0)))

    idx, val = pl.pallas_call(
        functools.partial(_circle_score_argmin_kernel, shift_chunk),
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 2 * ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lp, 1), jnp.int32),
            jax.ShapeDtypeStruct((lp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(base, cc, cap, valid, na)
    return idx[:l, 0], val[:l, 0]
