"""Pallas TPU kernel for CASSINI compatibility scoring (paper Table 1).

For every link row ``l`` and candidate rotation ``s``:

    out[l, s] = Σ_α max(0, base[l, α] + cand[l, (α − s) mod A] − C)

This is the inner loop of the rotation search (:mod:`repro.core.compat`) —
a circular-shift correlation with a ReLU inside the reduction, evaluated
for *all* A rotations of a candidate job against the already-placed demand
``base``.  The scheduler evaluates thousands of (candidate × link) rows
per epoch at 10 candidates × O(links) (Algorithm 2), so the batched form
is the hot-spot.

TPU mapping: the circle rows live in VMEM (A ≤ ~2k angles ⇒ a (BL, A)
f32 tile is ≤ 1 MiB); rolls are realized as dynamic slices of a
concatenated (BL, 2A) buffer — no gathers — and the shift loop is a
``fori_loop`` so the kernel is O(A²) VPU work per row with a single HBM
round-trip.  For Mosaic lowering pick ``A`` as a multiple of 128 (the
unified-circle builder's angle counts can always be rounded up);
interpret mode (CPU validation) accepts any A.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_L = 8


def _circle_score_kernel(base_ref, cand_ref, cap_ref, out_ref):
    base = base_ref[...].astype(jnp.float32)            # (BL, A)
    cand = cand_ref[...].astype(jnp.float32)            # (BL, A)
    cap = cap_ref[...].astype(jnp.float32)              # (BL, 1) per-row
    bl, a = base.shape
    cc = jnp.concatenate([cand, cand], axis=-1)         # (BL, 2A)

    def body(s, _):
        # rolled[α] = cand[(α − s) mod A] == concat[A − s : 2A − s]
        rolled = jax.lax.dynamic_slice(cc, (0, a - s), (bl, a))
        excess = jnp.maximum(base + rolled - cap, 0.0)
        val = jnp.sum(excess, axis=-1, keepdims=True)   # (BL, 1)
        pl.store(out_ref, (slice(None), pl.dslice(s, 1)), val)
        return 0

    jax.lax.fori_loop(0, a, body, 0)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def circle_score_pallas(
    base: jax.Array,      # (L, A) float32
    cand: jax.Array,      # (L, A) float32
    capacity: jax.Array,  # scalar shared by all rows, or (L,)/(L, 1) per-row
    *,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
) -> jax.Array:
    """Batched scoring; returns (L, A) excess sums (lower = better).

    Per-row capacities let one launch cover links with different capacities
    (the k-job grid batching groups rows by angle count only); a scalar
    capacity is broadcast to every row.
    """
    l, a = base.shape
    pad = (-l) % block_l
    cap = jnp.asarray(capacity, jnp.float32)
    cap = jnp.broadcast_to(cap.reshape(-1, 1) if cap.ndim else cap, (l, 1))
    if pad:
        base = jnp.pad(base, ((0, pad), (0, 0)))
        cand = jnp.pad(cand, ((0, pad), (0, 0)))
        cap = jnp.pad(cap, ((0, pad), (0, 0)))
    lp = base.shape[0]

    out = pl.pallas_call(
        _circle_score_kernel,
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, a), lambda i: (i, 0)),
            pl.BlockSpec((block_l, a), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp, a), jnp.float32),
        interpret=interpret,
    )(base.astype(jnp.float32), cand.astype(jnp.float32), cap)
    return out[:l]
