"""Pallas TPU kernels for CASSINI compatibility scoring (paper Table 1).

For every link row ``l`` and candidate rotation ``s``:

    out[l, s] = Σ_α max(0, base[l, α] + cand[l, (α − s) mod A] − C)

This is the inner loop of the rotation search (:mod:`repro.core.compat`) —
a circular-shift correlation with a ReLU inside the reduction, evaluated
for *all* A rotations of a candidate job against the already-placed demand
``base``.  The scheduler evaluates thousands of (candidate × link) rows
per epoch at 10 candidates × O(links) (Algorithm 2), so the batched form
is the hot-spot.

Two kernel variants share the same inner loop:

  * :func:`circle_score_pallas` — the full ``(L, A)`` excess matrix
    (kept for the numpy fallback paths and for tests);
  * :func:`circle_score_argmin_pallas` — the fused argmin/accept
    reduction: the running ``(best_shift, best_excess)`` per row is
    carried *inside* the shift loop, so only ``O(L)`` scalars ever leave
    the device instead of the ``O(L·A)`` matrix.  The loop is a
    ``while_loop`` bounded by the per-row admissible-shift counts
    (``valid`` — Eq. 4 only admits ``A / r_j`` distinct rotations) and
    exits early once every row in the block has reached zero excess
    (excess sums are non-negative and acceptance is strict, so nothing
    can beat zero).  Tie-breaking is lowest-shift-wins (strict ``<``
    against the running min while scanning shifts in ascending order),
    bit-identical to host ``np.argmin``.

TPU mapping: the circle rows live in VMEM (A ≤ ~2k angles ⇒ a (BL, A)
f32 tile is ≤ 1 MiB); rolls are realized as dynamic slices of a
concatenated (BL, 2A) buffer — no gathers — and the shift loop is
sequential so the kernel is O(A²) VPU work per row with a single HBM
round-trip.  Mosaic lowering wants lane-aligned tiles: with
``lane_pad=True`` (the default) the angle axis is zero-padded up to a
multiple of :data:`LANE_MULTIPLE` and statically re-sliced to the real
width before each reduction, so *any* unified-circle angle count
satisfies the alignment requirement while the padding provably cannot
change a single output bit (the reductions see exactly the unpadded
operands).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8-row blocks amortize poorly; 32 measured ~1.5-2x faster for both kernel
# variants on large batches (and is still one VREG sublane tile on TPU).
DEFAULT_BLOCK_L = 32
# Mosaic wants the lane (minor) dimension a multiple of 128; the wrappers
# zero-pad the angle axis up to this multiple by default (masked in-kernel,
# exact — see module docstring).
LANE_MULTIPLE = 128


def _circle_score_kernel(a: int, base_ref, cc_ref, cap_ref, out_ref):
    """Full-matrix variant: ``out[:, s]`` for every shift ``s < a``.

    ``a`` is the *real* (unpadded) angle count, closed over statically;
    ``cc_ref`` is the doubled candidate buffer (see ``_prep_inputs``).
    """
    base = base_ref[...]                                # (BL, AP)
    cc = cc_ref[...]                                    # (BL, 2*AP)
    cap = cap_ref[...]                                  # (BL, 1) per-row
    bl, ap = base.shape

    def body(s, _):
        # rolled[α] = cand[(α − s) mod A] == concat[A − s : A − s + AP]
        rolled = jax.lax.dynamic_slice(cc, (0, a - s), (bl, ap))
        excess = jnp.maximum(base + rolled - cap, 0.0)
        # static re-slice to the real width: the reduction sees exactly the
        # same operands as the unpadded kernel, so lane padding provably
        # cannot change a single output bit
        val = jnp.sum(excess[:, :a], axis=-1, keepdims=True)  # (BL, 1)
        pl.store(out_ref, (slice(None), pl.dslice(s, 1)), val)
        return 0

    jax.lax.fori_loop(0, a, body, 0)


def _circle_score_argmin_kernel(
    a: int, base_ref, cc_ref, cap_ref, valid_ref, idx_ref, val_ref
):
    """Fused variant: running (best_shift, best_excess) carried in-loop.

    Scans shifts in ascending order with a strict ``<`` acceptance, so the
    result is the *first* index of the minimum — ``np.argmin`` semantics.
    Shifts ``s ≥ valid[row]`` are masked to ``+inf`` (Eq. 4 bound), the
    loop stops at the block's largest admissible shift count, and exits
    early once every row's running best hit zero (excess sums are
    non-negative, acceptance strict — nothing can improve on zero).
    """
    base = base_ref[...]                                # (BL, AP)
    cc = cc_ref[...]                                    # (BL, 2*AP)
    cap = cap_ref[...]                                  # (BL, 1)
    valid = valid_ref[...]                              # (BL, 1) int32
    bl, ap = base.shape
    nvalid = jnp.max(valid)

    def cond(carry):
        s, best_val, _ = carry
        return jnp.logical_and(s < nvalid, jnp.max(best_val) > 0.0)

    def body(carry):
        s, best_val, best_idx = carry
        rolled = jax.lax.dynamic_slice(cc, (0, a - s), (bl, ap))
        excess = jnp.maximum(base + rolled - cap, 0.0)
        # static re-slice to the real width (see _circle_score_kernel)
        val = jnp.sum(excess[:, :a], axis=-1, keepdims=True)  # (BL, 1)
        val = jnp.where(s < valid, val, jnp.inf)
        take = val < best_val
        best_val = jnp.where(take, val, best_val)
        best_idx = jnp.where(take, s, best_idx)
        return s + 1, best_val, best_idx

    # rows with valid == 0 (block padding) start "done" so they can never
    # hold the early-exit condition open
    init_val = jnp.where(valid > 0, jnp.inf, 0.0).astype(jnp.float32)
    init = (jnp.int32(0), init_val, jnp.zeros((bl, 1), jnp.int32))
    _, best_val, best_idx = jax.lax.while_loop(cond, body, init)
    idx_ref[...] = best_idx
    val_ref[...] = best_val


# ---------------------------------------------------------------------- #
def _prep_inputs(base, cand, capacity, block_l: int, lane_pad: bool):
    """Row-pad to the block size and lane-pad the angle axis.

    Returns ``(base, cc, cap, l, a, ap)`` where ``cc`` is the doubled
    candidate buffer: ``concat([cand, cand])`` built at the *real* width
    ``2a`` (so the modular roll stays contiguous) and only then zero-padded
    on the right to ``2·ap``.  The slice ``cc[:, a − s : a − s + ap]``
    therefore reads real candidate values at angles ``< a`` and padding
    above — which the kernels discard by statically re-slicing to the real
    width before every reduction.
    """
    l, a = base.shape
    ap = (a + LANE_MULTIPLE - 1) // LANE_MULTIPLE * LANE_MULTIPLE if lane_pad else a
    pad_rows = (-l) % block_l
    cap = jnp.asarray(capacity, jnp.float32)
    cap = jnp.broadcast_to(cap.reshape(-1, 1) if cap.ndim else cap, (l, 1))
    base = base.astype(jnp.float32)
    cand = cand.astype(jnp.float32)
    cc = jnp.concatenate([cand, cand], axis=-1)         # (L, 2A), contiguous
    base = jnp.pad(base, ((0, pad_rows), (0, ap - a)))
    cc = jnp.pad(cc, ((0, pad_rows), (0, 2 * ap - 2 * a)))
    cap = jnp.pad(cap, ((0, pad_rows), (0, 0)))
    return base, cc, cap, l, a, ap


@functools.partial(
    jax.jit, static_argnames=("block_l", "interpret", "lane_pad")
)
def circle_score_pallas(
    base: jax.Array,      # (L, A) float32
    cand: jax.Array,      # (L, A) float32
    capacity: jax.Array,  # scalar shared by all rows, or (L,)/(L, 1) per-row
    *,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
    lane_pad: bool = True,
) -> jax.Array:
    """Batched scoring; returns (L, A) excess sums (lower = better).

    Per-row capacities let one launch cover links with different capacities
    (the k-job grid batching groups rows by angle count only); a scalar
    capacity is broadcast to every row.
    """
    base, cc, cap, l, a, ap = _prep_inputs(base, cand, capacity, block_l, lane_pad)
    lp = base.shape[0]

    out = pl.pallas_call(
        functools.partial(_circle_score_kernel, a),
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 2 * ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, ap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp, ap), jnp.float32),
        interpret=interpret,
    )(base, cc, cap)
    return out[:l, :a]


@functools.partial(
    jax.jit, static_argnames=("block_l", "interpret", "lane_pad")
)
def circle_score_argmin_pallas(
    base: jax.Array,      # (L, A) float32
    cand: jax.Array,      # (L, A) float32
    capacity: jax.Array,  # scalar, or (L,)/(L, 1) per-row
    valid: jax.Array,     # (L,) int32 admissible shifts per row (≤ A)
    *,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = True,
    lane_pad: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused reduction; returns ``(best_shift (L,) int32, best_excess (L,))``.

    Bit-identical to ``np.argmin(full_matrix[l, :valid[l]])`` per row —
    same excess sums (identical in-kernel arithmetic), first-index
    tie-breaking — while returning O(L) scalars instead of the O(L·A)
    matrix, and scanning only the admissible shifts of each block.
    """
    l, a = base.shape
    valid = jnp.broadcast_to(jnp.asarray(valid, jnp.int32).reshape(-1, 1), (l, 1))
    base, cc, cap, l, a, ap = _prep_inputs(base, cand, capacity, block_l, lane_pad)
    lp = base.shape[0]
    valid = jnp.pad(valid, ((0, lp - l), (0, 0)))

    idx, val = pl.pallas_call(
        functools.partial(_circle_score_argmin_kernel, a),
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 2 * ap), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lp, 1), jnp.int32),
            jax.ShapeDtypeStruct((lp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(base, cc, cap, valid)
    return idx[:l, 0], val[:l, 0]
