"""Pure-jnp oracle: naive sequential SSM recurrence (the ground truth both
the Pallas kernel and the model's chunked path must reproduce)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a_log, Bm, Cm) -> jax.Array:
    """x: (B,S,H,P), dt: (B,S,H), a_log: (H,), Bm/Cm: (B,S,N) → (B,S,H,P).

    state_{t} = state_{t-1}·exp(dt_t·a) + B_t ⊗ (x_t·dt_t);  y_t = C_t·state_t
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp                      # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a[None, :])          # (B,H)
        upd = jnp.einsum("bn,bhp->bhnp", bt, xt * dtt[..., None])
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    init = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        Bm.transpose(1, 0, 2).astype(jnp.float32),
        Cm.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
