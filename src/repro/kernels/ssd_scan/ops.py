"""Public entry point for the SSD chunk-scan kernel."""

from __future__ import annotations

import jax

from .kernel import ssd_scan_pallas
from .ref import ssd_ref

__all__ = ["ssd_scan", "ssd_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def ssd_scan(
    x, dt, a_log, Bm, Cm, *, tuned: bool = True, chunk: int | None = None
) -> jax.Array:
    """Chunk length defaults to the per-bucket tuning table keyed by the
    sequence length (``tuned=False`` or any loader fallback pins the
    historical 256); an explicit ``chunk`` always wins.  Rechunking
    re-associates the inter-chunk state accumulation, so tuned outputs
    match to float tolerance, not bit-exactly."""
    if chunk is None:
        from repro.kernels import tune

        s = x.shape[1]
        sched = (
            tune.lookup("ssd_scan", s) if tuned
            else dict(tune.DEFAULTS["ssd_scan"])
        )
        # table entries are searched at the bucket width; clamp for real
        # lengths they do not divide (gcd keeps a power-of-two divisor)
        chunk = tune.clamp_to_width("ssd_scan", s, sched)["chunk"]
    return ssd_scan_pallas(x, dt, a_log, Bm, Cm, chunk=chunk,
                           interpret=not _ON_TPU)
