"""Public entry point for the SSD chunk-scan kernel."""

from __future__ import annotations

import jax

from .kernel import ssd_scan_pallas
from .ref import ssd_ref

__all__ = ["ssd_scan", "ssd_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def ssd_scan(x, dt, a_log, Bm, Cm, *, chunk: int = 256) -> jax.Array:
    return ssd_scan_pallas(x, dt, a_log, Bm, Cm, chunk=chunk,
                           interpret=not _ON_TPU)
