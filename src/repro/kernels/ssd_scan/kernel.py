"""Mamba-2 SSD chunk-scan Pallas kernel.

Grid: (batch·heads, num_chunks) with the chunk axis iterated sequentially;
the inter-chunk SSM state (N × P) lives in fp32 VMEM scratch and is
carried across grid steps (TPU grids iterate the trailing axis innermost,
so each (b,h) row sees its chunks in order — the standard Pallas carry
idiom).  Per chunk the kernel computes

    intra: (C_l · B_m^T ⊙ decay[l,m]) · x_m     (chunk² matmuls → MXU)
    inter: C_l · state_in · decay_in[l]
    state_out = state_in · exp(Σ log a) + Σ B_l x_l decay_end[l]

which is exactly :func:`repro.models.mamba.ssd_chunked` per chunk — the
oracle in ``ref.py`` is the naive sequential recurrence both must match.

VMEM budget per program: chunk=256, N=128, P=64 ⇒ x (256·64), B/C
(256·128), decay (256·256) and state (128·64), all fp32 < 1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref,
                *, chunk: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)        # (chunk, P)
    dt = dt_ref[...].astype(jnp.float32)      # (chunk,)
    a = a_ref[0].astype(jnp.float32)          # scalar: -exp(a_log) for head
    Bm = b_ref[...].astype(jnp.float32)       # (chunk, N)
    Cm = c_ref[...].astype(jnp.float32)       # (chunk, N)

    log_decay = dt * a                        # (chunk,) ≤ 0
    cum = jnp.cumsum(log_decay)
    xdt = x * dt[:, None]

    # intra-chunk: L[l, m] = exp(cum_l − cum_m) for m ≤ l
    diff = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(mi <= li, jnp.exp(diff), 0.0)
    scores = (Cm @ Bm.T) * L                  # (chunk, chunk)
    intra = scores @ xdt                      # (chunk, P)

    # inter-chunk from carried state
    state = state_ref[...].astype(jnp.float32)  # (N, P)
    decay_in = jnp.exp(cum)[:, None]            # (chunk, 1)
    inter = (Cm @ state) * decay_in             # (chunk, P)

    o_ref[...] = (intra + inter).astype(o_ref.dtype)

    # state update for the next chunk
    total = cum[-1]
    decay_end = jnp.exp(total - cum)[:, None]   # (chunk, 1)
    new_state = state * jnp.exp(total) + Bm.T @ (xdt * decay_end)
    state_ref[...] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H) softplus'd
    a_log: jax.Array,  # (H,)
    Bm: jax.Array,     # (B, S, N)
    Cm: jax.Array,     # (B, S, N)
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,)

    # flatten (b, h) rows; broadcast B/C over heads
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    af = jnp.tile(a, b).reshape(b * h, 1)
    Bf = jnp.broadcast_to(Bm[:, None], (b, h, s, n)).reshape(b * h, s, n)
    Cf = jnp.broadcast_to(Cm[:, None], (b, h, s, n)).reshape(b * h, s, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((None, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((None, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, Bf, Cf)
    return out.reshape(b, h, s, p).transpose(0, 2, 1, 3)
