"""Pallas TPU kernels for the perf-critical compute layers:

- circle_score     — CASSINI compatibility scoring (paper Table 1 inner loop)
- flash_attention  — blocked causal attention (32k-prefill enabler)
- ssd_scan         — Mamba-2 state-space-duality chunk scan

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper), ref.py (pure-jnp oracle).  Validated in interpret mode on CPU;
``interpret=False`` on the TPU target.
"""
