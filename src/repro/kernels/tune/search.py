"""Measured per-bucket parameter search (grid + successive halving).

For one ``(variant, bucket)`` key the search builds a representative
workload, verifies every candidate schedule against the untuned path —
**bit-identical** outputs for the circle family (the fold-sum /
tournament invariants guarantee it; a mismatch means a kernel bug, and
the candidate is dropped with a warning), tight ``allclose`` for
flash/ssd (block-shape changes re-associate the softmax / scan
accumulation, so exact equality is not the contract there) — then times
the survivors with warmup + min-of-N single-call measurements through
two successive-halving rungs: one cheap pass over the full grid, then
the final ``repeats`` pass over the top quartile (defaults always
re-seeded into the final rung so the winner is compared against them
under identical measurement conditions).

The winner only replaces the defaults when it beats them by more than
the ``hysteresis`` margin (5% by default): near-ties keep the shipped
schedule, which is what lets the bench gate assert "tuned is never
slower than default" across machines without chasing noise.

Workload shapes encode where each variant actually runs in production:

  * ``circle_score_segmin`` / ``circle_score`` serve the product-grid
    path, which flushes :data:`~repro.core.compat.GRID_CHUNK_ROWS`-row
    chunks — hundreds of rows per launch, so the workload uses a tall
    batch (large ``block_l`` wins by cutting interpret-mode grid steps);
  * ``circle_score_argmin`` serves the lockstep coordinate descent — one
    row per still-active problem per step, a few dozen rows — so its
    workload is short and the tuned block is small;
  * flash/ssd use one model-shaped batch at the bucket's sequence length.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np

from . import space
from .table import DEFAULTS, SCHEMA_VERSION, current_backend

__all__ = [
    "TuneResult",
    "make_workload",
    "tune_variant",
    "tune_all",
    "results_to_table",
]

# final-rung workload rows; see the module docstring for why segmin is
# tall and argmin short
_GRID_ROWS = 384
_DESCENT_ROWS = 32
_SEGMENT_ROWS = 24


@dataclass(frozen=True)
class TuneResult:
    """Outcome of one (variant, bucket) search."""

    variant: str
    bucket: int
    params: Mapping[str, int]          # the winner (== defaults on a near-tie)
    default_params: Mapping[str, int]
    tuned_us: float                    # winner's final-rung min-of-N
    default_us: float                  # defaults' final-rung min-of-N
    candidates: int                    # grid size for this key
    rejected: tuple[str, ...] = field(default_factory=tuple)

    @property
    def speedup(self) -> float:
        return self.default_us / self.tuned_us if self.tuned_us else 1.0

    @property
    def is_default(self) -> bool:
        return dict(self.params) == dict(self.default_params)


def _timeit(fn: Callable[[], object], *, warmup: int, repeats: int) -> float:
    """Min-of-N wall time of ``fn`` in microseconds."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _bucket_widths(bucket: int) -> tuple[int, int]:
    """Two real widths landing inside ``bucket`` (strictly above the next
    bucket down), off the lane multiple so the masking paths are live."""
    hi = (7 * bucket) // 8
    lo = bucket // 2 + max(1, bucket // 16)
    return lo, hi


def _contended(rng: np.random.Generator, l: int, w: int) -> np.ndarray:
    # demands that overflow the capacity at every shift: the argmin loop
    # then runs its full admissible window (no early zero exit), which is
    # the regime the schedule parameters actually matter in
    return (rng.random((l, w)) * 60).astype(np.float32)


def make_workload(
    variant: str, bucket: int, *, seed: int = 0
) -> Callable[..., tuple[np.ndarray, ...]]:
    """Build ``run(params, tuned=False) -> outputs`` for one key.

    The callable executes the variant's *public* ops entry point with the
    given schedule parameters (``tuned=False`` + explicit overrides by
    default, so the committed table never leaks into the search) and
    returns host arrays — forcing completion, so wall-clocking the call
    measures the launch, and letting the caller compare candidate outputs
    bit-for-bit.  ``run({}, tuned=True)`` dispatches through the
    committed table instead — the bench harness uses that to time tuned
    vs default on the very workloads the table was searched on.
    """
    rng = np.random.default_rng(seed)
    lo, hi = _bucket_widths(bucket)

    if variant in ("circle_score", "circle_score_argmin",
                   "circle_score_segmin"):
        from repro.kernels.circle_score import ops as cs

        if variant == "circle_score":
            l = _GRID_ROWS
            base = _contended(rng, l, hi)
            cand = _contended(rng, l, hi)

            def run(params: Mapping[str, int], *,
                    tuned: bool = False) -> tuple[np.ndarray, ...]:
                out = cs.circle_score(
                    base, cand, 50.0, tuned=tuned, **params
                )
                return (np.asarray(out),)

            return run

        l = _DESCENT_ROWS if variant == "circle_score_argmin" else _GRID_ROWS
        na = np.where(np.arange(l) % 2 == 0, hi, lo).astype(np.int32)
        base = _contended(rng, l, hi)
        cand = _contended(rng, l, hi)
        for r in range(l):  # ragged rows: zero beyond each row's width
            base[r, na[r]:] = 0.0
            cand[r, na[r]:] = 0.0
        valid = np.where(np.arange(l) % 3 == 0, na // 2, na).astype(np.int32)
        caps = (40.0 + rng.random(l) * 20).astype(np.float32)

        if variant == "circle_score_argmin":

            def run(params: Mapping[str, int], *,
                    tuned: bool = False) -> tuple[np.ndarray, ...]:
                idx, val = cs.circle_score_ragged_argmin(
                    base, cand, caps, valid, na, tuned=tuned, **params
                )
                return np.asarray(idx), np.asarray(val)

            return run

        seg_ids = np.arange(l) // _SEGMENT_ROWS
        init = np.full(int(seg_ids[-1]) + 1, np.inf)

        def run(params: Mapping[str, int], *,
                tuned: bool = False) -> tuple[np.ndarray, ...]:
            acc, row, shift, best = cs.circle_score_ragged_segmin(
                base, cand, caps, valid, na, seg_ids, init,
                tuned=tuned, **params,
            )
            return (np.asarray(acc), np.asarray(row),
                    np.asarray(shift), np.asarray(best))

        return run

    if variant == "flash_attention":
        import jax.numpy as jnp

        from repro.kernels.flash_attention.ops import flash_attention

        q = jnp.asarray(rng.standard_normal((1, bucket, 2, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, bucket, 1, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, bucket, 1, 64)), jnp.bfloat16)

        def run(params: Mapping[str, int], *,
                tuned: bool = False) -> tuple[np.ndarray, ...]:
            out = flash_attention(q, k, v, tuned=tuned, **params)
            return (np.asarray(out),)

        return run

    if variant == "ssd_scan":
        import jax.numpy as jnp

        from repro.kernels.ssd_scan.ops import ssd_scan

        x = jnp.asarray(rng.standard_normal((1, bucket, 2, 32)), jnp.float32)
        dt = jnp.asarray(rng.random((1, bucket, 2)) * 0.3 + 0.05, jnp.float32)
        al = jnp.asarray(rng.standard_normal(2) * 0.3, jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((1, bucket, 16)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((1, bucket, 16)), jnp.float32)

        def run(params: Mapping[str, int], *,
                tuned: bool = False) -> tuple[np.ndarray, ...]:
            out = ssd_scan(x, dt, al, Bm, Cm, tuned=tuned, **params)
            return (np.asarray(out),)

        return run

    raise KeyError(f"unknown variant {variant!r}")


# circle-family candidates must reproduce the untuned outputs bit for bit;
# flash/ssd re-associate their accumulations when the block shape moves
_EXACT = ("circle_score", "circle_score_argmin", "circle_score_segmin")


def _matches(variant: str, got, want) -> bool:
    if variant in _EXACT:
        return all(np.array_equal(g, w) for g, w in zip(got, want))
    return all(
        np.allclose(np.asarray(g, np.float32), np.asarray(w, np.float32),
                    rtol=2e-2, atol=2e-2)
        for g, w in zip(got, want)
    )


def tune_variant(
    variant: str,
    bucket: int,
    *,
    repeats: int = 3,
    hysteresis: float = 0.05,
    seed: int = 0,
) -> TuneResult:
    """Search one (variant, bucket) key; returns the measured winner."""
    run = make_workload(variant, bucket, seed=seed)
    # the verification/timing anchor is the schedule the *runtime* would
    # use untuned at this width — module defaults, clamped to divide it
    defaults = space.clamp_to_width(variant, bucket, DEFAULTS[variant])
    want = run(defaults)  # compiles + anchors verification
    survivors: list[dict[str, int]] = []
    rejected: list[str] = []
    cands = space.candidates(variant, bucket)
    for cand in cands:
        got = run(cand)  # also the compile warmup for the timing rungs
        if _matches(variant, got, want):
            survivors.append(cand)
        else:  # pragma: no cover - would indicate a kernel invariant bug
            rejected.append(repr(cand))
            warnings.warn(
                f"{variant}/{bucket}: candidate {cand} failed output "
                "verification against the untuned path; dropped",
                RuntimeWarning, stacklevel=2,
            )

    # rung 1: one cheap timing of every verified candidate
    coarse = [(c, _timeit(lambda c=c: run(c), warmup=0, repeats=1))
              for c in survivors]
    coarse.sort(key=lambda cu: cu[1])
    keep = max(4, len(coarse) // 4)
    finalists = [c for c, _ in coarse[:keep]]
    if defaults not in finalists:
        finalists.append(defaults)

    # rung 2: min-of-N over the finalists, defaults measured identically
    timed = {
        tuple(sorted(c.items())): _timeit(
            lambda c=c: run(c), warmup=1, repeats=repeats
        )
        for c in finalists
    }
    default_us = timed[tuple(sorted(defaults.items()))]
    best_key = min(timed, key=timed.get)  # type: ignore[arg-type]
    tuned_us = timed[best_key]
    params = dict(best_key)
    if tuned_us > default_us * (1.0 - hysteresis):
        params, tuned_us = defaults, default_us  # near-tie: keep shipped
    return TuneResult(
        variant=variant, bucket=bucket, params=params,
        default_params=defaults, tuned_us=tuned_us, default_us=default_us,
        candidates=len(cands), rejected=tuple(rejected),
    )


def tune_all(
    variants: Sequence[str] | None = None,
    buckets: Sequence[int] | None = None,
    *,
    repeats: int = 3,
    hysteresis: float = 0.05,
    seed: int = 0,
    progress: Callable[[TuneResult], None] | None = None,
) -> list[TuneResult]:
    """Sweep the full (variant, bucket) grid; returns every result."""
    out: list[TuneResult] = []
    for v in (variants or space.variants()):
        for b in (buckets or space.BUCKETS):
            r = tune_variant(
                v, b, repeats=repeats, hysteresis=hysteresis, seed=seed
            )
            out.append(r)
            if progress is not None:
                progress(r)
    return out


def results_to_table(
    results: Sequence[TuneResult], *, backend: str | None = None
) -> dict:
    """Serialize search results into the committed table schema.

    Only non-default winners are persisted: a bucket absent from the
    table *means* defaults, so near-ties and untouched keys stay
    invisible (and the table diff in review shows exactly the schedules
    that changed).
    """
    entries = {
        f"{r.variant}/{r.bucket}": dict(r.params)
        for r in results
        if not r.is_default
    }
    return {
        "schema_version": SCHEMA_VERSION,
        "backend": backend or current_backend(),
        "generated_by": "benchmarks/autotune.py --retune",
        "entries": dict(sorted(entries.items())),
    }
