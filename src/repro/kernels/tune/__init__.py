"""Per-bucket kernel autotuning: search offline, commit the table,
dispatch from it at runtime.

The three pieces (see docs/architecture.md, "Kernel autotune"):

  * :mod:`~repro.kernels.tune.space` — the per-variant search spaces,
    keyed by (backend, variant, power-of-two width bucket);
  * :mod:`~repro.kernels.tune.search` — the measured grid /
    successive-halving search with per-candidate output verification;
  * :mod:`~repro.kernels.tune.table` — the committed JSON tables under
    ``tables/`` plus the runtime loader, whose every failure mode falls
    back to the kernels' module defaults.

Runtime consumers only ever call :func:`lookup` (through the ops
wrappers); ``benchmarks/autotune.py`` drives the search.
"""

from .space import BUCKETS, SPACES, candidates, clamp_to_width, variants
from .table import (
    DEFAULTS,
    SCHEMA_VERSION,
    TuningTable,
    bucket_for,
    current_backend,
    default_table_path,
    get_table,
    load_table,
    lookup,
    reset_cache,
)

__all__ = [
    "BUCKETS",
    "SPACES",
    "DEFAULTS",
    "SCHEMA_VERSION",
    "TuningTable",
    "bucket_for",
    "candidates",
    "clamp_to_width",
    "current_backend",
    "default_table_path",
    "get_table",
    "load_table",
    "lookup",
    "reset_cache",
    "variants",
]
