"""Persistent per-bucket tuning table: schema, loader, fallback rules.

The autotuner (:mod:`repro.kernels.tune.search`) measures each variant's
candidate schedules per power-of-two width bucket and commits the winners
to a JSON table under ``src/repro/kernels/tune/tables/<backend>.json``.
At runtime the ops wrappers resolve their schedule parameters through
:func:`lookup`; anything that goes wrong — missing file, corrupt JSON,
schema drift, a table generated for another backend, an unknown bucket,
or parameter values outside the declared search space — silently falls
back to the module defaults the kernels shipped with.  A bad table can
therefore only ever cost performance, never correctness or an import
error (the loader never raises).

Table schema (``schema_version`` 1)::

    {
      "schema_version": 1,
      "backend": "cpu-interpret",
      "generated_by": "benchmarks/autotune.py --retune",
      "entries": {
        "circle_score_argmin/1024": {"block_l": 128, "shift_chunk": 16},
        ...
      }
    }

Entry keys are ``"<variant>/<bucket>"``; values carry exactly the
variant's search-space parameters.  The backend key is coarse on purpose
(``cpu-interpret`` / ``tpu-mosaic`` / ...): interpret-mode timings are
dominated by grid-step count, not host microarchitecture, so one
committed CPU table transfers across CI runners, while a Mosaic table
must never be consumed by an interpret run (hence the mismatch → defaults
rule).
"""

from __future__ import annotations

import json
import os
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Mapping

import jax

from repro.kernels.circle_score.kernel import DEFAULT_BLOCK_L, SHIFT_CHUNK

from .space import SPACES

__all__ = [
    "SCHEMA_VERSION",
    "DEFAULTS",
    "TuningTable",
    "bucket_for",
    "current_backend",
    "default_table_path",
    "get_table",
    "load_table",
    "lookup",
    "reset_cache",
]

SCHEMA_VERSION = 1

# Environment override consumed by get_table(): point it at an alternate
# table file (tests, nightly drift checks) without touching the tree.
TABLE_ENV = "REPRO_TUNE_TABLE"

# The untuned schedules — what every kernel shipped with before the
# autotuner existed and what every fallback resolves to.  The circle
# family's values come straight from the kernel module so the two can
# never drift; flash/ssd defaults mirror their kernels' historical
# signature defaults (asserted against the search spaces below).
DEFAULTS: Mapping[str, Mapping[str, int]] = {
    "circle_score": {"block_l": DEFAULT_BLOCK_L},
    "circle_score_argmin": {
        "block_l": DEFAULT_BLOCK_L, "shift_chunk": SHIFT_CHUNK,
    },
    "circle_score_segmin": {
        "block_l": DEFAULT_BLOCK_L, "shift_chunk": SHIFT_CHUNK,
    },
    "flash_attention": {"block_q": 128, "block_k": 128},
    "ssd_scan": {"chunk": 256},
}

for _v, _params in DEFAULTS.items():
    assert set(_params) == set(SPACES[_v]), (_v, _params)
    assert all(_params[_k] in SPACES[_v][_k] for _k in _params), (_v, _params)


def current_backend() -> str:
    """Coarse backend key for table files: execution target + lowering."""
    b = jax.default_backend()
    return f"{b}-mosaic" if b == "tpu" else f"{b}-interpret"


def tables_dir() -> Path:
    return Path(__file__).resolve().parent / "tables"


def default_table_path(backend: str | None = None) -> Path:
    return tables_dir() / f"{backend or current_backend()}.json"


def bucket_for(width: int) -> int:
    """The power-of-two lane bucket a launch of ``width`` lands in."""
    from repro.kernels.circle_score.ops import bucket_width

    return bucket_width(width)


@dataclass(frozen=True)
class TuningTable:
    """Validated, immutable view of one table file (or the defaults)."""

    backend: str
    entries: Mapping[str, Mapping[str, int]] = field(default_factory=dict)
    source: str = "<defaults>"

    def lookup(self, variant: str, width: int) -> dict[str, int]:
        """Schedule parameters for a ``width``-wide launch of ``variant``.

        Unknown buckets (and every fallback path that produced an empty
        table) resolve to :data:`DEFAULTS`; unknown variants are a
        programming error and raise.
        """
        defaults = DEFAULTS[variant]
        entry = self.entries.get(f"{variant}/{bucket_for(width)}")
        if entry is None:
            return dict(defaults)
        return {**defaults, **entry}


def _valid_entry(key: str, params: object) -> bool:
    """One table entry is usable iff its key parses to a known
    (variant, bucket) and every parameter sits inside the declared search
    space — anything else is skipped (that bucket then uses defaults)."""
    variant, _, bucket = key.partition("/")
    if variant not in SPACES or not bucket.isdigit():
        return False
    if not isinstance(params, dict) or set(params) - set(SPACES[variant]):
        return False
    return all(
        isinstance(v, int) and not isinstance(v, bool)
        and v in SPACES[variant][k]
        for k, v in params.items()
    )


def load_table(
    path: str | os.PathLike | None = None, backend: str | None = None
) -> TuningTable:
    """Load and validate a tuning table; never raises.

    Fallback ladder (each rung warns once and lands on defaults):
    missing file → defaults; unparseable JSON / non-object top level →
    defaults; ``schema_version`` mismatch → defaults; ``backend``
    mismatch → defaults; individually invalid entries are dropped while
    the rest of the table still applies.
    """
    backend = backend or current_backend()
    p = Path(path) if path is not None else default_table_path(backend)
    if not p.is_file():
        return TuningTable(backend=backend)
    try:
        raw = json.loads(p.read_text())
    except (OSError, ValueError) as e:
        warnings.warn(
            f"tuning table {p} unreadable ({e}); using kernel defaults",
            RuntimeWarning, stacklevel=2,
        )
        return TuningTable(backend=backend)
    if not isinstance(raw, dict) or raw.get("schema_version") != SCHEMA_VERSION:
        warnings.warn(
            f"tuning table {p} has unsupported schema "
            f"{raw.get('schema_version') if isinstance(raw, dict) else raw!r}"
            f" (want {SCHEMA_VERSION}); using kernel defaults",
            RuntimeWarning, stacklevel=2,
        )
        return TuningTable(backend=backend)
    if raw.get("backend") != backend:
        warnings.warn(
            f"tuning table {p} was tuned for backend {raw.get('backend')!r} "
            f"but this process runs {backend!r}; using kernel defaults",
            RuntimeWarning, stacklevel=2,
        )
        return TuningTable(backend=backend)
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        entries = {}
    kept = {
        k: dict(v) for k, v in entries.items() if _valid_entry(k, v)
    }
    dropped = set(entries) - set(kept)
    if dropped:
        warnings.warn(
            f"tuning table {p}: dropped invalid entries {sorted(dropped)}",
            RuntimeWarning, stacklevel=2,
        )
    return TuningTable(backend=backend, entries=kept, source=str(p))


_CACHE: TuningTable | None = None


def get_table() -> TuningTable:
    """The process-wide table: loaded once from ``$REPRO_TUNE_TABLE`` or
    the committed per-backend file, then cached (the hot path is one dict
    probe per launch)."""
    global _CACHE
    if _CACHE is None:
        _CACHE = load_table(os.environ.get(TABLE_ENV) or None)
    return _CACHE


def reset_cache() -> None:
    """Forget the cached table (tests / after a retune wrote a new file)."""
    global _CACHE
    _CACHE = None


def lookup(variant: str, width: int) -> dict[str, int]:
    """Module-level convenience: :func:`get_table` + table lookup."""
    return get_table().lookup(variant, width)
