"""Search spaces for the per-bucket kernel autotuner.

Every tunable kernel *variant* exposes a small set of schedule parameters
— block shapes and chunk widths that change how the launch is tiled but
provably (circle family) or tolerably (flash/ssd) never what it computes.
The PR 5 power-of-two width bucketing is what keeps this tractable: a
(backend, variant, bucket) key sees at most a few dozen candidates, so an
exhaustive measured search per bucket is cheap enough to re-run nightly.

The spaces are deliberately coarse powers of two: Mosaic's tiling wants
the sublane dimension in {8, 16, 32, ...} and interpret mode's overhead
scales with the grid step count, so intermediate values never win by more
than noise (measured).  Growing a space here automatically grows the
nightly retune sweep — no other file needs to change.
"""

from __future__ import annotations

import itertools
import math
from typing import Mapping, Sequence

__all__ = ["BUCKETS", "SPACES", "candidates", "clamp_to_width", "variants"]

# Width buckets the tuner searches, mirroring :func:`bucket_width`'s
# image over the angle counts real scenarios produce (precision 5° on
# ring sizes 2..16 unified circles ⇒ A ≤ ~2.9k ⇒ widths 128..4096; the
# fine-grid A ≥ 512 buckets are the only kernel-eligible ones on the
# "auto" backend, the small ones matter for forced-pallas callers).
BUCKETS: tuple[int, ...] = (128, 256, 512, 1024, 2048)

# variant -> parameter name -> admissible values.  The *first* value set
# must contain the module defaults (table.DEFAULTS) so the search always
# scores the untuned schedule and can never regress below it on the
# machine it ran on.
SPACES: Mapping[str, Mapping[str, Sequence[int]]] = {
    # full-matrix scorer: only the row-block height is free
    "circle_score": {"block_l": (8, 16, 32, 64, 128)},
    # fused ragged argmin: row blocks x tournament chunk width
    "circle_score_argmin": {
        "block_l": (8, 16, 32, 64, 128),
        "shift_chunk": (4, 8, 16, 32),
    },
    # argmin + device accept scan; same kernel parameters, timed through
    # the segmin entry point because the scan shifts the optimum slightly
    "circle_score_segmin": {
        "block_l": (8, 16, 32, 64, 128),
        "shift_chunk": (4, 8, 16, 32),
    },
    # flash attention: q/k tile heights (must divide the sequence length,
    # enforced per-bucket in candidates())
    "flash_attention": {
        "block_q": (64, 128, 256),
        "block_k": (64, 128, 256),
    },
    # SSD chunk scan: the chunk length (must divide the sequence length)
    "ssd_scan": {"chunk": (64, 128, 256, 512)},
}

# parameters that must divide the bucket width (kernel asserts
# seq % block == 0); the circle family has no such constraint — its
# wrappers row-pad to any block_l
_DIVIDES_BUCKET = {
    "flash_attention": ("block_q", "block_k"),
    "ssd_scan": ("chunk",),
}


def variants() -> tuple[str, ...]:
    return tuple(SPACES)


def clamp_to_width(variant: str, width: int, params: dict) -> dict:
    """Make ``params`` launchable at ``width`` sequence length.

    Divide-the-bucket parameters are replaced by ``gcd(value, width)`` —
    for the power-of-two values in the spaces this is the largest
    power-of-two divisor of ``width`` not exceeding the requested value,
    so the module defaults (e.g. ``ssd_scan``'s chunk 256 on a 128-wide
    launch) stay valid at every bucket.  Returns ``params`` unchanged for
    variants with no divisibility constraint.
    """
    out = dict(params)
    for name in _DIVIDES_BUCKET.get(variant, ()):
        out[name] = math.gcd(out[name], width)
    return out


def candidates(variant: str, bucket: int) -> list[dict[str, int]]:
    """Full grid of parameter dicts for one (variant, bucket) key.

    Candidates whose divide-the-bucket parameters do not divide the
    bucket width are dropped (they would trip the kernel's shape
    assertion); the circle family's grid is bucket-independent.
    """
    space = SPACES[variant]
    names = tuple(space)
    must_divide = _DIVIDES_BUCKET.get(variant, ())
    out: list[dict[str, int]] = []
    for values in itertools.product(*(space[n] for n in names)):
        cand = dict(zip(names, values))
        if any(bucket % cand[n] != 0 or cand[n] > bucket for n in must_divide):
            continue
        out.append(cand)
    return out
