"""Pure-jnp oracle: dense softmax attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q/k/v: (B, H, S, D) → (B, H, S, D)."""
    b, h, s, d = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / (d ** 0.5)
    if causal:
        pos = jnp.arange(s)
        mask = pos[None, :] <= pos[:, None]
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
