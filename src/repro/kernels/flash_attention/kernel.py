"""Blocked (flash) causal attention Pallas kernel.

Standard online-softmax formulation: the grid iterates (batch·head,
q_block); each program streams K/V blocks through VMEM keeping running
max/denominator/accumulator, so HBM traffic is O(S·d) instead of the
O(S²) score matrix — the 32k-prefill enabler on the TPU target.

BlockSpec tiling: q tile (block_q, d), k/v tiles (block_k, d) with d the
head dim (64–128, MXU-aligned); accumulators live in fp32 VMEM scratch.
The causal mask is applied per (q_block, k_block) tile pair; k blocks
beyond the diagonal are skipped entirely.

The wrapper handles GQA by repeating KV heads; the pure-jnp oracle is
``ref.py``; models use the XLA q-chunked attention by default on CPU
(interpret-mode Pallas is orders of magnitude slower than XLA:CPU) and
this kernel on the TPU target (``ArchConfig.use_flash_kernel``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_len: int,
                  scale: float, causal: bool):
    q = q_ref[...].astype(jnp.float32) * scale          # (block_q, d)
    block_q, d = q.shape
    q_idx = pl.program_id(1)
    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    m = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q, 1), jnp.float32)
    acc = jnp.zeros((block_q, d), jnp.float32)

    num_k = seq_len // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T                  # (block_q, block_k)
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1
            )
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    if causal:
        # only blocks at or below the diagonal contribute
        last = (q_idx + 1) * block_q
        num_live = (last + block_k - 1) // block_k
    else:
        num_live = num_k
    m, l, acc = jax.lax.fori_loop(0, num_live, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "causal", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, H, S, D)
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = True,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kernel = functools.partial(
        _flash_kernel, block_k=block_k, seq_len=s, scale=scale, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
