"""Public entry point: GQA-aware flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,  # (B, S, H, D)  — model layout
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Returns (B, S, H, D); repeats KV heads for grouped-query attention."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    groups = h // hkv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k,
        interpret=not _ON_TPU,
    )
    return out.transpose(0, 2, 1, 3)
