"""Public entry point: GQA-aware flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]

_ON_TPU = jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,  # (B, S, H, D)  — model layout
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    tuned: bool = True,
    block_q: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """Returns (B, S, H, D); repeats KV heads for grouped-query attention.

    Block shapes default to the per-bucket tuning table keyed by the
    sequence length (``tuned=False`` or the loader's fallback ladder pin
    the historical 128x128 tiles); explicit values always win.  Unlike
    the circle family, retiling re-associates the online-softmax
    accumulation, so tuned outputs match the untuned path to float
    tolerance, not bit-exactly.
    """
    b, s, h, d = q.shape
    from repro.kernels import tune

    sched = (
        tune.lookup("flash_attention", s) if tuned
        else dict(tune.DEFAULTS["flash_attention"])
    )
    # table entries are searched at the bucket width; a caller's real S
    # inside the bucket may not be divisible by them — clamp rather than
    # trip the kernel's shape assert (gcd keeps a power-of-two divisor)
    sched = tune.clamp_to_width("flash_attention", s, sched)
    block_q = block_q if block_q is not None else sched["block_q"]
    block_k = block_k if block_k is not None else sched["block_k"]
    hkv = k.shape[2]
    groups = h // hkv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k,
        interpret=not _ON_TPU,
    )
    return out.transpose(0, 2, 1, 3)
