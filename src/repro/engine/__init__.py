"""Composable scheduling engine: typed pipeline, alignment plans, scenarios.

- :mod:`repro.engine.plan`      — :class:`AlignmentPlan` / :class:`JobAlignment`,
  the typed scheduler → simulator alignment contract
- :mod:`repro.engine.pipeline`  — :class:`SchedulingPipeline` with the
  Allocate → Propose → Score → Align stages (batched candidate scoring)
- :mod:`repro.engine.scenarios` — :class:`ScenarioSpec` registry building
  topology + trace + scheduler + simulator from a name

Attributes resolve lazily (PEP 562): ``repro.engine.plan`` is imported by
low-level modules (``repro.cluster.job``, ``repro.sched.base``) while
``repro.engine.scenarios`` imports those same packages — eager re-exports
here would create an import cycle.
"""

from __future__ import annotations

import importlib

_EXPORTS = {
    # plan
    "AlignmentPlan": ".plan",
    "JobAlignment": ".plan",
    # pipeline
    "Allocation": ".pipeline",
    "ProposalSet": ".pipeline",
    "ScoredProposals": ".pipeline",
    "PipelineStage": ".pipeline",
    "AllocateStage": ".pipeline",
    "ProposeStage": ".pipeline",
    "ScoreStage": ".pipeline",
    "AlignStage": ".pipeline",
    "SchedulingPipeline": ".pipeline",
    # scenarios
    "ScenarioSpec": ".scenarios",
    "BuiltScenario": ".scenarios",
    "ScenarioRun": ".scenarios",
    "default_scheduler_factories": ".scenarios",
    "register_scenario": ".scenarios",
    "get_scenario": ".scenarios",
    "list_scenarios": ".scenarios",
    "MULTITENANT_SWEEP": ".scenarios",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    return getattr(importlib.import_module(module, __name__), name)


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(_EXPORTS))
