"""Composable scheduling pipeline: Allocate → Propose → Score → Align.

The seed code wired CASSINI's pluggable module into host schedulers through
one monolithic ``CassiniAugmented.schedule()`` method.  This module
decomposes that flow into four typed, independently-testable stages:

  ``AllocateStage``  host's own objective: workers per job
  ``ProposeStage``   up to N candidate placements realizing the allocation
  ``ScoreStage``     Algorithm 2 lines 3–23: affinity graphs + link scores
                     (batched through ``score_candidates_batched`` by
                     default — every k-job link's shift grid packed into
                     batched kernel calls per epoch instead of a per-link
                     scalar loop; ``ScoreStage.last_batch_stats`` exposes
                     which batched path each link took)
  ``AlignStage``     Algorithm 1 on the winner → a Decision carrying a
                     typed :class:`~repro.engine.plan.AlignmentPlan`

Each stage consumes the previous stage's typed output
(:class:`Allocation`, :class:`ProposalSet`, :class:`ScoredProposals`) and
the shared :class:`~repro.sched.base.ClusterState`, so a stage can be unit
tested — or swapped — in isolation.  :class:`SchedulingPipeline` chains
them; :class:`~repro.sched.cassini_augmented.CassiniAugmented` is now a
thin wrapper over ``SchedulingPipeline.cassini(host)``.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import TYPE_CHECKING, Mapping, Sequence

from repro.core.circle import CommPattern
from repro.core.plugin import CassiniModule, Evaluated, PlacementCandidate
from repro.engine.plan import AlignmentPlan
from repro.sched.base import ClusterState, Decision, PlacementMap, Scheduler

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.job import Job

__all__ = [
    "Allocation",
    "ProposalSet",
    "ScoredProposals",
    "PipelineStage",
    "AllocateStage",
    "ProposeStage",
    "ScoreStage",
    "AlignStage",
    "SchedulingPipeline",
]


# ---------------------------------------------------------------------- #
# typed stage payloads
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class Allocation:
    """Output of Allocate: workers per job under the host's objective."""

    workers: Mapping[str, int]


@dataclass(frozen=True)
class ProposalSet:
    """Output of Propose: candidate placements realizing the allocation."""

    workers: Mapping[str, int]
    placements: tuple[PlacementMap, ...]


@dataclass(frozen=True)
class ScoredProposals:
    """Output of Score: every candidate evaluated by the CASSINI module.

    ``evaluated[i]`` is ``(candidate, affinity_graph | None, link_results)``
    for ``placements[i]``; ``patterns`` / ``capacities`` are the inputs the
    module scored against (kept for the Align stage and for inspection).
    """

    workers: Mapping[str, int]
    placements: tuple[PlacementMap, ...]
    evaluated: tuple[Evaluated, ...]
    patterns: Mapping[str, CommPattern]
    capacities: Mapping[str, float]


# ---------------------------------------------------------------------- #
# stages
# ---------------------------------------------------------------------- #
class PipelineStage(abc.ABC):
    """One typed stage of the scheduling pipeline."""

    name: str = "stage"

    @abc.abstractmethod
    def run(self, state: ClusterState, inp):
        """Transform the previous stage's output (None for the first)."""


class AllocateStage(PipelineStage):
    name = "allocate"

    def __init__(self, host: Scheduler) -> None:
        self.host = host

    def run(self, state: ClusterState, inp: None = None) -> Allocation:
        return Allocation(workers=self.host.allocate_workers(state))


class ProposeStage(PipelineStage):
    name = "propose"

    def __init__(self, host: Scheduler, num_candidates: int = 10) -> None:
        self.host = host
        self.num_candidates = num_candidates

    def run(self, state: ClusterState, inp: Allocation) -> ProposalSet:
        cands = self.host.propose(state, dict(inp.workers), self.num_candidates)
        return ProposalSet(workers=inp.workers, placements=tuple(cands))


class ScoreStage(PipelineStage):
    """Build PlacementCandidates from the cluster topology and score them.

    With ``batched=True`` (the default) all uncached link problems of the
    epoch — any job count — are solved through the batched grid /
    lockstep-descent paths of ``find_rotations_batched``, and (with the
    module's ``device_reduce``, also the default) kernel-eligible rotation
    searches keep the argmin/acceptance reduction on device, returning
    per-problem scalars instead of the ``(B, A)`` excess matrices.  With
    the module's ``ragged`` (also the default) those kernel-eligible
    problems additionally ship as ONE ragged launch per grid-chunk /
    descent step regardless of their unified-circle angle counts — a
    heterogeneous fabric no longer pays one dispatch per angle-count
    group.  :attr:`last_batch_stats` reflects the most recent batched
    solve (``device_reduced`` / ``bytes_returned`` expose the transfer
    savings; ``launches`` / ``ragged_rows`` / ``pad_fraction`` the launch
    consolidation).
    """

    name = "score"

    def __init__(self, module: CassiniModule, *, batched: bool = True) -> None:
        self.module = module
        self.batched = batched

    @property
    def last_batch_stats(self):
        """Telemetry of the module's most recent batched solve (or None)."""
        return self.module.last_batch_stats

    # ------------------------------------------------------------- #
    def build_candidates(
        self, state: ClusterState, placements: Sequence[PlacementMap]
    ) -> tuple[list[PlacementCandidate], dict[str, CommPattern], dict[str, float]]:
        """Translate host placements into the module's topology-free form."""
        topo = state.topology
        by_id: dict[str, Job] = {j.job_id: j for j in state.jobs}
        patterns: dict[str, CommPattern] = {}
        workers_seen: dict[str, int] = {}
        capacities: dict[str, float] = {}
        candidates: list[PlacementCandidate] = []
        for pl in placements:
            job_links: dict[str, list[str]] = {}
            for jid, servers in pl.items():
                links = topo.job_links(servers)
                job_links[jid] = [l.name for l in links]
                for l in links:
                    capacities[l.name] = l.capacity_gbps
                if jid not in patterns:
                    patterns[jid] = by_id[jid].pattern(num_workers=len(servers))
                    workers_seen[jid] = len(servers)
                elif workers_seen[jid] != len(servers):
                    # CASSINI scores one communication pattern per job across
                    # all candidates (paper §4.2: candidates are equivalent
                    # under the host's objective).  A proposal set that varies
                    # a job's worker count would be silently mis-scored
                    # against a stale pattern — reject it loudly instead.
                    raise ValueError(
                        f"candidate placements disagree on worker count for "
                        f"{jid!r} ({workers_seen[jid]} vs {len(servers)}); "
                        f"all candidates must realize the same allocation"
                    )
            candidates.append(PlacementCandidate(job_links=job_links, meta=pl))
        return candidates, patterns, capacities

    def run(self, state: ClusterState, inp: ProposalSet) -> ScoredProposals:
        candidates, patterns, capacities = self.build_candidates(
            state, inp.placements
        )
        if not candidates:
            evaluated: tuple[Evaluated, ...] = ()
        elif self.batched:
            evaluated = tuple(
                self.module.score_candidates_batched(candidates, patterns, capacities)
            )
        else:
            evaluated = tuple(
                self.module.score_candidates(candidates, patterns, capacities)
            )
        return ScoredProposals(
            workers=inp.workers,
            placements=inp.placements,
            evaluated=evaluated,
            patterns=patterns,
            capacities=capacities,
        )


class AlignStage(PipelineStage):
    """Algorithm 1 on the top candidate → Decision with an AlignmentPlan."""

    name = "align"

    def __init__(self, module: CassiniModule, *, pace_threshold: float = 0.9) -> None:
        self.module = module
        self.pace_threshold = pace_threshold

    def run(self, state: ClusterState, inp: ScoredProposals) -> Decision:
        if not inp.evaluated:
            return Decision(placements={})
        cassini = self.module.align(inp.evaluated)
        chosen: PlacementMap = cassini.top_placement.meta  # the host's map
        plan = AlignmentPlan(
            time_shifts_ms=dict(cassini.time_shifts_ms),
            paced_periods_ms=dict(cassini.paced_periods_ms),
            job_min_score=dict(cassini.job_min_score),
            link_scores={
                f"{l}": s for l, s in cassini.top_placement.link_scores.items()
            },
            pace_threshold=self.pace_threshold,
            num_candidates=len(inp.placements),
        )
        return Decision(
            placements=chosen,
            time_shifts_ms=dict(cassini.time_shifts_ms),
            compat_score=cassini.top_placement.score,
            plan=plan,
        )


# ---------------------------------------------------------------------- #
@dataclass
class SchedulingPipeline:
    """Chain of typed stages ending in a Decision."""

    stages: tuple[PipelineStage, ...]

    def schedule(self, state: ClusterState) -> Decision:
        out = None
        for stage in self.stages:
            out = stage.run(state, out)
        if not isinstance(out, Decision):
            raise TypeError(
                f"pipeline must end in a Decision, got {type(out).__name__} "
                f"from stage {self.stages[-1].name!r}"
            )
        return out

    # ------------------------------------------------------------- #
    @classmethod
    def cassini(
        cls,
        host: Scheduler,
        *,
        num_candidates: int = 10,
        module: CassiniModule | None = None,
        pace_threshold: float = 0.9,
        batched: bool = True,
        **module_kw,
    ) -> "SchedulingPipeline":
        """The paper's pipeline: host allocation/proposals + CASSINI
        scoring and alignment.  ``module_kw`` (precision_deg, quantum_ms,
        seed, …) configure a fresh :class:`CassiniModule` when ``module``
        is not given."""
        module = module or CassiniModule(**module_kw)
        return cls(
            stages=(
                AllocateStage(host),
                ProposeStage(host, num_candidates),
                ScoreStage(module, batched=batched),
                AlignStage(module, pace_threshold=pace_threshold),
            )
        )
