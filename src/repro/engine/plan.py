"""Typed alignment payload flowing from the scheduler to the simulator.

The seed code smuggled CASSINI's per-job alignment state through a
stringly-typed ``Decision.meta`` dict (``align_ok``, ``paced_ms``) that
:class:`~repro.cluster.simulator.ClusterSimulator` had to know how to
unpack.  :class:`AlignmentPlan` replaces that contract: the Align stage of
the scheduling pipeline emits one typed plan per decision, the simulator
asks it for a per-job :class:`JobAlignment` directive, and the fluid
network model consumes the directive straight off the job — no dict keys
anywhere along the path.

This module is dependency-free on purpose (no imports from ``repro.sched``
or ``repro.cluster``): every layer of the stack can import it without
creating a cycle.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable, Mapping

__all__ = ["JobAlignment", "AlignmentPlan"]

JobId = Hashable


@dataclass(frozen=True)
class JobAlignment:
    """Per-job alignment directive (what one job's workers must do).

    Attributes:
      shift_ms:        cumulative target time-shift (Algorithm 1 output);
                       workers realize the *delta* against what they have
                       already applied.
      hold:            arm the isochronous pacing agent (§4.2 step 3, §5.7).
                       Only set when every contended link of the job scored
                       at least the plan's ``pace_threshold`` — holding the
                       grid on a sub-interleavable link burns time on
                       re-alignment.
      paced_period_ms: the grid period the agent paces at (the optimizer's
                       quantized iteration time); None when not paced.
    """

    shift_ms: float = 0.0
    hold: bool = False
    paced_period_ms: float | None = None


@dataclass(frozen=True)
class AlignmentPlan:
    """Typed output of the Align stage for one scheduling decision.

    ``time_shifts_ms`` are the unique per-job shifts from Algorithm 1;
    ``job_min_score`` is each job's minimum compatibility score across its
    contended links (gates pacing against ``pace_threshold``);
    ``paced_periods_ms`` the per-job isochronous grid periods;
    ``link_scores`` the winning candidate's per-link compatibility scores
    (diagnostics); ``num_candidates`` how many placements were scored.
    """

    time_shifts_ms: Mapping[JobId, float] = field(default_factory=dict)
    paced_periods_ms: Mapping[JobId, float] = field(default_factory=dict)
    job_min_score: Mapping[JobId, float] = field(default_factory=dict)
    link_scores: Mapping[str, float] = field(default_factory=dict)
    pace_threshold: float = 0.9
    num_candidates: int = 1

    # -------------------------------------------------------------- #
    def align_ok(self, job_id: JobId) -> bool:
        """Should ``job_id`` hold its shift on the isochronous grid?"""
        return (
            job_id in self.time_shifts_ms
            and self.job_min_score.get(job_id, 1.0) >= self.pace_threshold
        )

    def directive_for(self, job_id: JobId) -> JobAlignment | None:
        """The job's directive, or None when the plan has no shift for it
        (job uncontended this epoch — keep whatever shift it already has)."""
        shift = self.time_shifts_ms.get(job_id)
        if shift is None:
            return None
        return JobAlignment(
            shift_ms=float(shift),
            hold=self.align_ok(job_id),
            paced_period_ms=self.paced_periods_ms.get(job_id),
        )
