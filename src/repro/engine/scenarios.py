"""Declarative scenario registry: topology + trace + scheduler + simulator.

Every benchmark and example in the seed rebuilt the same experiment by hand
— construct a topology, sample a trace, instantiate a scheduler, wire a
simulator, pick an horizon.  A :class:`ScenarioSpec` captures that recipe
declaratively; the registry maps a name to a spec so a driver is three
lines:

    from repro.engine import get_scenario
    run = get_scenario("dynamic-burst").run("th+cassini")
    print(run.metrics.summary())

Adding a new workload (trace × topology × scheduler set) is one
``register_scenario`` call — not a new copy-pasted driver file.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Mapping, Sequence

from repro.cluster import (
    ARRIVAL_PATTERNS,
    ClusterSimulator,
    Metrics,
    Topology,
    arrival_trace,
    dynamic_trace,
    ideal_metrics,
    iter_arrival_trace,
    iter_poisson_trace,
    poisson_trace,
    snapshot_trace,
)
from repro.cluster.job import Job
from repro.sched import (
    CassiniAugmented,
    PolluxScheduler,
    RandomScheduler,
    ThemisScheduler,
)
from repro.sched.base import Scheduler
from repro.sched.fixed import FixedPlacementScheduler

__all__ = [
    "ScenarioSpec",
    "BuiltScenario",
    "ScenarioRun",
    "default_scheduler_factories",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "MULTITENANT_SWEEP",
    "RACK_SCALING_SWEEP",
    "RACK_SCALING_XL",
    "ARRIVAL_SWEEP",
]

SchedulerFactory = Callable[[], Scheduler]


def default_scheduler_factories() -> dict[str, SchedulerFactory]:
    """The paper's scheduler line-up, shared by most scenarios."""
    return {
        "themis": lambda: ThemisScheduler(),
        "th+cassini": lambda: CassiniAugmented(ThemisScheduler()),
        "pollux": lambda: PolluxScheduler(),
        "po+cassini": lambda: CassiniAugmented(PolluxScheduler()),
        "random": lambda: RandomScheduler(),
    }


# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class BuiltScenario:
    """A scenario instantiated for one scheduler: ready to ``sim.run(jobs)``."""

    spec: "ScenarioSpec"
    topology: Topology
    jobs: list[Job]
    scheduler: Scheduler
    simulator: ClusterSimulator


@dataclass(frozen=True)
class ScenarioRun:
    """Result of one scenario × scheduler execution."""

    spec: "ScenarioSpec"
    scheduler_name: str
    metrics: Metrics
    wall_s: float
    simulator: ClusterSimulator


@dataclass(frozen=True)
class ScenarioSpec:
    """Declarative experiment: how to build topology, trace and scheduler.

    ``schedulers`` maps scheduler names to factories; scenarios that only
    make sense with specific schedulers (e.g. fixed-placement snapshots)
    override it, everything else shares
    :func:`default_scheduler_factories`.
    """

    name: str
    description: str
    topology: Callable[[], Topology]
    trace: Callable[[Topology], list[Job]]
    # Optional generator form of ``trace`` for serve mode: yields jobs in
    # arrival order without materializing the whole trace (O(1) memory for
    # unbounded streams).  When unset, :meth:`arrival_stream` falls back to
    # iterating the materialized list — same jobs either way.
    trace_stream: Callable[[Topology], Iterator[Job]] | None = None
    schedulers: Mapping[str, SchedulerFactory] = field(
        default_factory=default_scheduler_factories
    )
    epoch_ms: float = 300_000.0
    compute_jitter: float = 0.005
    horizon_ms: float = 7_200_000.0
    sim_seed: int = 0
    # array-resident fluid engine (False = the scalar oracle; results are
    # identical — the equivalence harness pins it on every registered spec)
    vectorized: bool = True
    # incremental water-filling re-solve (256+-rack fabrics): rates match
    # the scalar oracle within documented tolerance bands instead of bit-
    # exactly, so the bit-exact equivalence harness skips these specs and
    # dedicated tolerance/parity tests cover them instead
    incremental: bool = False
    # device-sharded component fills on top of the incremental re-solve
    # (repro.cluster.shard): dirty components batch onto jax.devices()
    # instead of one fused host fill; same tolerance band as incremental
    sharded: bool = False
    # optional deterministic fault schedule (repro.chaos): called with the
    # built (topology, jobs) so seeded generators can target real link
    # names / job ids; the simulator replays it during run().  The churn-*
    # scenarios use this — the fault application is engine-symmetric, so
    # the bit-exact equivalence harness sweeps them like any other spec.
    fault_schedule: Callable[[Topology, list[Job]], object] | None = None

    # ------------------------------------------------------------- #
    def scheduler_names(self) -> tuple[str, ...]:
        return tuple(self.schedulers)

    def make_scheduler(self, name: str) -> Scheduler:
        try:
            return self.schedulers[name]()
        except KeyError:
            raise KeyError(
                f"scenario {self.name!r} has no scheduler {name!r}; "
                f"available: {sorted(self.schedulers)}"
            ) from None

    def build(
        self,
        scheduler: str | Scheduler,
        *,
        vectorized: bool | None = None,
        incremental: bool | None = None,
        sharded: bool | None = None,
    ) -> BuiltScenario:
        """Instantiate topology, trace, scheduler and simulator.

        ``vectorized`` / ``incremental`` / ``sharded`` override the
        spec's fluid-engine choices (the equivalence harness runs every
        spec both ways, with the incremental re-solve forced off for
        bit-exact comparisons)."""
        topo = self.topology()
        jobs = self.trace(topo)
        sched = (
            scheduler
            if isinstance(scheduler, Scheduler)
            else self.make_scheduler(scheduler)
        )
        sim = ClusterSimulator(
            topo,
            sched,
            epoch_ms=self.epoch_ms,
            compute_jitter=self.compute_jitter,
            vectorized=self.vectorized if vectorized is None else vectorized,
            incremental=(
                self.incremental if incremental is None else incremental
            ),
            sharded=self.sharded if sharded is None else sharded,
            seed=self.sim_seed,
            fault_schedule=self.make_fault_schedule(topo, jobs),
        )
        return BuiltScenario(
            spec=self, topology=topo, jobs=jobs, scheduler=sched,
            simulator=sim,
        )

    def run(
        self,
        scheduler: str | Scheduler,
        *,
        horizon_ms: float | None = None,
        vectorized: bool | None = None,
        incremental: bool | None = None,
        sharded: bool | None = None,
    ) -> ScenarioRun:
        """Build and simulate to the horizon; returns metrics + wall time."""
        built = self.build(
            scheduler,
            vectorized=vectorized,
            incremental=incremental,
            sharded=sharded,
        )
        t0 = time.time()
        metrics = built.simulator.run(
            built.jobs,
            horizon_ms=self.horizon_ms if horizon_ms is None else horizon_ms,
        )
        name = scheduler if isinstance(scheduler, str) else scheduler.name
        return ScenarioRun(
            spec=self,
            scheduler_name=name,
            metrics=metrics,
            wall_s=time.time() - t0,
            simulator=built.simulator,
        )

    def make_fault_schedule(self, topo: Topology, jobs: list[Job]):
        """The spec's FaultSchedule for one built (topology, trace) — or
        None.  Serve-side replays call this with their own topology/job
        instances so batch and serve apply value-identical schedules to
        independent state."""
        if self.fault_schedule is None:
            return None
        return self.fault_schedule(topo, jobs)

    def arrival_stream(self, topo: Topology | None = None) -> Iterator[Job]:
        """Jobs in arrival order as a lazy stream (serve-mode input).

        Uses ``trace_stream`` when the spec provides one (unbounded traces
        never materialize); otherwise iterates the ``trace`` list.
        """
        topo = topo if topo is not None else self.topology()
        if self.trace_stream is not None:
            return self.trace_stream(topo)
        return iter(self.trace(topo))

    def ideal(self) -> Metrics:
        """Dedicated-cluster reference metrics for this scenario's trace."""
        topo = self.topology()
        return ideal_metrics(topo, self.trace(topo))


# ---------------------------------------------------------------------- #
# registry
# ---------------------------------------------------------------------- #
_REGISTRY: dict[str, ScenarioSpec] = {}


def register_scenario(spec: ScenarioSpec, *, replace_existing: bool = False) -> ScenarioSpec:
    if spec.name in _REGISTRY and not replace_existing:
        raise ValueError(f"scenario {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def list_scenarios() -> dict[str, str]:
    """name → one-line description of every registered scenario."""
    return {name: spec.description for name, spec in sorted(_REGISTRY.items())}


# ---------------------------------------------------------------------- #
# built-in scenarios (the paper's figures as registry entries)
# ---------------------------------------------------------------------- #
_FIG2_PLACEMENTS = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}


def _fig2_trace(_: Topology, *, iters: int = 500) -> list[Job]:
    return snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=iters)


register_scenario(ScenarioSpec(
    name="fig2-interleave",
    description="Fig. 2: two VGG19 jobs pinned onto one uplink — fair-share "
                "DCQCN vs a CASSINI time-shift",
    topology=Topology.paper_testbed,
    trace=_fig2_trace,
    schedulers={
        "fair-share": lambda: FixedPlacementScheduler(_FIG2_PLACEMENTS),
        "cassini": lambda: CassiniAugmented(
            FixedPlacementScheduler(_FIG2_PLACEMENTS), num_candidates=1
        ),
    },
    compute_jitter=0.0,
))


_POISSON_PAPER_KW = dict(
    load=0.95, num_jobs=16, seed=7, min_iters=150, max_iters=400,
    models=["vgg16", "vgg19", "wideresnet101", "resnet50", "bert",
            "roberta", "xlm", "gpt1", "gpt2", "gpt3", "dlrm"],
)


def _poisson_paper_trace(topo: Topology) -> list[Job]:
    return poisson_trace(topo, **_POISSON_PAPER_KW)


register_scenario(ScenarioSpec(
    name="poisson-paper",
    description="Fig. 8/9: Poisson arrivals at ~0.95 load, 11 paper models, "
                "all schedulers",
    topology=Topology.paper_testbed,
    trace=_poisson_paper_trace,
    trace_stream=lambda topo: iter_poisson_trace(topo, **_POISSON_PAPER_KW),
))


def _burst_trace(
    topo: Topology,
    *,
    base_models: Sequence[str],
    burst_models: Sequence[str],
    burst_at_ms: float,
    workers: int,
    burst_workers: int,
    iters: int,
) -> list[Job]:
    jobs = dynamic_trace(
        topo, base_models=tuple(base_models), burst_models=tuple(burst_models),
        burst_at_ms=burst_at_ms, workers=workers, iters=iters,
    )
    for j in jobs:
        if j.job_id.startswith("burst"):
            j.num_workers = burst_workers
    return jobs


register_scenario(ScenarioSpec(
    name="dynamic-burst",
    description="Fig. 10: DLRM + ResNet50 arrive into a busy fragmented "
                "cluster (congestion stress test)",
    topology=Topology.paper_testbed,
    trace=lambda topo: _burst_trace(
        topo, base_models=("vgg19", "wideresnet101", "gpt1"),
        burst_models=("dlrm", "resnet50"), burst_at_ms=90_000.0,
        workers=7, burst_workers=4, iters=350,
    ),
))


register_scenario(ScenarioSpec(
    name="modelpar-burst",
    description="Fig. 11: all-model-parallel trace (GPT family + DLRM); "
                "CASSINI must pick the compatible pairings",
    topology=Topology.paper_testbed,
    trace=lambda topo: _burst_trace(
        topo, base_models=("gpt1", "gpt2", "gpt3"),
        burst_models=("dlrm", "gpt2"), burst_at_ms=120_000.0,
        workers=7, burst_workers=5, iters=300,
    ),
))


def _hetero_16rack_topology(oversubscription: float = 2.0) -> Topology:
    """16 racks × 4 servers with alternating 50/100 Gbps NIC generations —
    the ROADMAP's "larger fabrics, heterogeneous NIC rates" open item."""
    return Topology(
        num_racks=16,
        servers_per_rack=4,
        nic_gbps=50.0,
        rack_nic_gbps=tuple(100.0 if r % 2 else 50.0 for r in range(16)),
        oversubscription=oversubscription,
    )


register_scenario(ScenarioSpec(
    name="hetero-16rack",
    description="16 racks x 4 servers, alternating 50/100 Gbps NIC racks; "
                "Poisson multi-tenant arrivals drive >=3-job uplink "
                "contention across mixed link capacities",
    topology=_hetero_16rack_topology,
    trace=lambda topo: poisson_trace(
        topo, load=1.4, num_jobs=14, seed=11, min_iters=120, max_iters=280,
        models=["vgg19", "wideresnet101", "dlrm", "gpt2", "resnet50", "bert"],
    ),
    epoch_ms=240_000.0,
    horizon_ms=3_600_000.0,
))


# Table-2-style multi-tenant snapshots, promoted from the hand-rolled
# benchmarks/table2_snapshots driver into registry entries: N concurrent
# 4-worker tenants pinned onto the heterogeneous 16-rack fabric at t=0 in
# a deliberately *fragmented* half-rack chain — tenant i takes the back
# half of rack i and the front half of rack i+1, so every tenant's
# traffic crosses two rack uplinks and every interior rack's uplink
# carries two tenants (what fragmentation does in a busy cluster, cf.
# Table 2's forced r0↔r1 placements) across alternating 50/100 Gbps NIC
# racks — while no two tenants ever share a server.  Like the paper's
# snapshots, the placement is fixed and only the time-shift interleaving
# differs between the two schedulers (ROADMAP scenario-diversity item).
MULTITENANT_SWEEP: tuple[int, ...] = (2, 4, 8)
_MULTITENANT_MENU = [
    ("wideresnet101", 800), ("vgg16", 1400), ("vgg19", 1400),
    ("resnet50", 1600), ("roberta", 12), ("bert", 8),
]
_MULTITENANT_WORKERS = 4  # half of rack i + half of rack i+1


def _multitenant_specs(tenants: int) -> list[tuple[str, int, int]]:
    return [
        (model, _MULTITENANT_WORKERS, batch)
        for model, batch in (
            _MULTITENANT_MENU[i % len(_MULTITENANT_MENU)] for i in range(tenants)
        )
    ]


def _multitenant_trace(_: Topology, *, tenants: int, iters: int = 200) -> list[Job]:
    return snapshot_trace(_multitenant_specs(tenants), iters=iters)


def _multitenant_placements(tenants: int) -> dict[str, tuple[int, ...]]:
    """Tenant i → back half of rack i + front half of rack i+1.

    Adjacent tenants meet in every interior rack (shared uplink) but the
    server sets are pairwise disjoint — no GPU is double-booked.
    """
    jobs = snapshot_trace(_multitenant_specs(tenants), iters=1)
    placements: dict[str, tuple[int, ...]] = {}
    for i, j in enumerate(jobs):
        placements[j.job_id] = (
            4 * i + 2, 4 * i + 3, 4 * (i + 1), 4 * (i + 1) + 1
        )
    return placements


def _multitenant_schedulers(tenants: int) -> dict[str, SchedulerFactory]:
    placements = _multitenant_placements(tenants)
    return {
        "fair-share": lambda: FixedPlacementScheduler(placements),
        "cassini": lambda: CassiniAugmented(
            FixedPlacementScheduler(placements), num_candidates=1
        ),
    }


for _n in MULTITENANT_SWEEP:
    register_scenario(ScenarioSpec(
        name=f"multitenant-{_n}",
        description=f"Table-2-style snapshot sweep: {_n} concurrent 4-worker "
                    "tenants half-rack-chained across the hetero-16rack "
                    "fabric at 4:1 oversubscription (one contended spine "
                    "uplink per rack, 50/100 Gbps aggregate, no shared "
                    "servers); fixed placement, fair-share vs CASSINI "
                    "time-shifts",
        # 4:1 oversubscription collapses each rack onto a single spine
        # uplink (4 servers / 4), so chained tenants genuinely share it —
        # at the default 2:1 the two ECMP uplinks often separate the pair
        # and the snapshot degenerates to zero contention
        topology=functools.partial(_hetero_16rack_topology, oversubscription=4.0),
        trace=functools.partial(_multitenant_trace, tenants=_n),
        schedulers=_multitenant_schedulers(_n),
        epoch_ms=240_000.0,
        horizon_ms=1_800_000.0,
        compute_jitter=0.0,
    ))


# Rack-count scaling sweep (ROADMAP "scaling curves" item): the same
# heterogeneous-NIC recipe as hetero-16rack, instantiated at 16/32/64
# racks with a Poisson multi-tenant load that grows with the fabric, so
# network-placement effects can be measured as a function of scale
# (Dally: schedulers only separate convincingly at larger fabrics).
# These are what the vectorized fluid engine makes affordable — the
# 64-rack entry is the benchmark/CI anchor for the ≥5x advance gate.
RACK_SCALING_SWEEP: tuple[int, ...] = (16, 32, 64)


def _rack_scaling_topology(racks: int, oversubscription: float = 2.0) -> Topology:
    """``racks`` × 4 servers with alternating 50/100 Gbps NIC generations."""
    return Topology(
        num_racks=racks,
        servers_per_rack=4,
        nic_gbps=50.0,
        rack_nic_gbps=tuple(100.0 if r % 2 else 50.0 for r in range(racks)),
        oversubscription=oversubscription,
    )


def _rack_scaling_trace(topo: Topology, *, racks: int) -> list[Job]:
    return poisson_trace(
        topo,
        load=1.4,
        num_jobs=max(8, (7 * racks) // 8),   # ~0.9 jobs/rack, 14 at 16 racks
        seed=11,
        min_iters=120,
        max_iters=280,
        models=["vgg19", "wideresnet101", "dlrm", "gpt2", "resnet50", "bert"],
    )


for _racks in RACK_SCALING_SWEEP:
    register_scenario(ScenarioSpec(
        name=f"rack-scaling-{_racks}",
        description=f"Rack-count scaling sweep: {_racks} racks x 4 servers, "
                    "alternating 50/100 Gbps NIC generations, Poisson "
                    "multi-tenant load growing with the fabric "
                    "(~0.9 jobs/rack at 1.4x offered load)",
        topology=functools.partial(_rack_scaling_topology, _racks),
        trace=functools.partial(_rack_scaling_trace, racks=_racks),
        epoch_ms=240_000.0,
        horizon_ms=3_600_000.0,
    ))


# 256/1024-rack fabrics (ROADMAP "scale past 64 racks" item): the same
# recipe again, but the from-scratch water-filling solve is no longer
# affordable per event — these specs opt into the incremental re-solve
# (tolerance-band equivalent to the scalar oracle; bit-exact with
# ``incremental=False``, pinned at a short horizon by the slow harness)
# and the device-sharded component fills on top of it (large dirty
# unions batch onto jax.devices(); same tolerance band, pinned by
# tests/test_fluid_sharded.py under the forced-host-device CI leg).
RACK_SCALING_XL: tuple[int, ...] = (256, 1024)

for _racks in RACK_SCALING_XL:
    register_scenario(ScenarioSpec(
        name=f"rack-scaling-{_racks}",
        description=f"Rack-count scaling, XL tier: {_racks} racks x 4 "
                    "servers, alternating 50/100 Gbps NIC generations, "
                    "Poisson multi-tenant load growing with the fabric; "
                    "fluid engine runs the incremental water-filling "
                    "re-solve (tolerance-band oracle equivalence) with "
                    "device-sharded component fills",
        topology=functools.partial(_rack_scaling_topology, _racks),
        trace=functools.partial(_rack_scaling_trace, racks=_racks),
        epoch_ms=240_000.0,
        horizon_ms=1_800_000.0,
        incremental=True,
        sharded=True,
    ))


# Arrival-pattern sweep (ROADMAP "arrival-pattern sweeps" item): the
# paper's Poisson trace population under three arrival processes — the
# online-scheduling axis of Bao et al.  Same RNG stream for the job
# population, so the sweep isolates the arrival process itself.
ARRIVAL_SWEEP: tuple[str, ...] = ARRIVAL_PATTERNS
_ARRIVAL_DESCRIPTIONS = {
    "poisson": "homogeneous Poisson arrivals (the paper's §5.1 process)",
    "burst": "clustered arrivals: 4-job bursts with the inter-arrival mass "
             "released between bursts (fragmentation stress)",
    "diurnal": "non-homogeneous Poisson, 1 + 0.8·sin day/night intensity "
               "swing over a 30-min period",
}


def _arrival_pattern_trace(topo: Topology, *, pattern: str) -> list[Job]:
    return arrival_trace(topo, pattern=pattern, **_POISSON_PAPER_KW)


def _arrival_pattern_stream(topo: Topology, *, pattern: str):
    return iter_arrival_trace(topo, pattern=pattern, **_POISSON_PAPER_KW)


for _pat in ARRIVAL_SWEEP:
    register_scenario(ScenarioSpec(
        name=f"arrival-{_pat}",
        description=f"Arrival-pattern sweep on the paper trace: "
                    f"{_ARRIVAL_DESCRIPTIONS[_pat]}",
        topology=Topology.paper_testbed,
        trace=functools.partial(_arrival_pattern_trace, pattern=_pat),
        trace_stream=functools.partial(_arrival_pattern_stream, pattern=_pat),
    ))


register_scenario(ScenarioSpec(
    name="multigpu",
    description="Fig. 13: 3 racks x 2 servers x 2 GPUs; jobs larger than a "
                "server still cross the network",
    topology=lambda: Topology(num_racks=3, servers_per_rack=2, gpus_per_server=2),
    trace=lambda topo: _burst_trace(
        topo, base_models=("xlm", "resnet50"), burst_models=("dlrm",),
        burst_at_ms=60_000.0, workers=5, burst_workers=4, iters=300,
    ),
))


# ---------------------------------------------------------------------- #
# churn-* family (ROADMAP "elastic/failure churn" + "timing-perturbation
# replay" items): the paper's dynamic-arrival stress (§Fig. 10) taken to
# adversarial state churn — deterministic, seeded fault schedules from
# repro.chaos replayed against the running cluster.  Fault application is
# engine-symmetric and the schedules are generated up front, so these
# specs sweep through the bit-exact vectorized-vs-scalar harness like any
# other scenario, and batch-vs-serve replays stay decision-identical
# (tests/test_chaos.py).
from repro.chaos.schedule import FaultSchedule  # noqa: E402  (registry tail)

# spec horizon: generous enough that the trace completes even under
# faults; the harness's 600k cap therefore sweeps the *whole* scenario
_CHURN_HORIZON_MS = 600_000.0
# fault windows are aimed at the trace's live span (makespan ~355k ms for
# the seeded trace below) so incidents actually hit running jobs
_CHURN_FAULT_WINDOW_MS = 360_000.0
_CHURN_TRACE_KW = dict(
    load=1.3, num_jobs=10, seed=23, min_iters=120, max_iters=260,
    models=["vgg19", "wideresnet101", "dlrm", "resnet50", "bert", "gpt2"],
)


def _churn_trace(topo: Topology) -> list[Job]:
    return poisson_trace(topo, **_CHURN_TRACE_KW)


def _churn_linkfail_schedule(topo: Topology, jobs: list[Job]) -> FaultSchedule:
    return FaultSchedule.linkfail(
        topo, seed=5, horizon_ms=_CHURN_FAULT_WINDOW_MS, events=6
    )


def _churn_elastic_schedule(topo: Topology, jobs: list[Job]) -> FaultSchedule:
    return FaultSchedule.elastic(
        jobs, seed=7, horizon_ms=_CHURN_FAULT_WINDOW_MS, resizes=5
    )


def _churn_jitter_schedule(topo: Topology, jobs: list[Job]) -> FaultSchedule:
    return FaultSchedule.jitter(
        jobs, seed=9, horizon_ms=_CHURN_FAULT_WINDOW_MS, magnitude_ms=8.0,
        events=64,
    )


register_scenario(ScenarioSpec(
    name="churn-linkfail",
    description="Paper testbed under Poisson load with seeded link churn: "
                "6 host/uplink incidents (full outages and 30-70% "
                "degrades) mid-run, each triggering re-alignment; tests "
                "whether interleaving benefit survives capacity faults",
    topology=Topology.paper_testbed,
    trace=_churn_trace,
    horizon_ms=_CHURN_HORIZON_MS,
    fault_schedule=_churn_linkfail_schedule,
))

register_scenario(ScenarioSpec(
    name="churn-elastic",
    description="Elastic resize churn on the paper testbed: 5 jobs shrink "
                "(train/elastic.py remesh: data axis first) then regrow "
                "after a dwell, forcing mid-epoch pattern changes and "
                "re-alignment passes",
    topology=Topology.paper_testbed,
    trace=_churn_trace,
    horizon_ms=_CHURN_HORIZON_MS,
    fault_schedule=_churn_elastic_schedule,
))

register_scenario(ScenarioSpec(
    name="churn-jitter",
    description="Timing-perturbation replay (psim-style deltas): 64 seeded "
                "gauss(0, 8ms) phase slips against the running set; "
                "measures how much aligned-interleaving benefit survives "
                "imperfect time-shifts (benchmarks/robustness_curves.py "
                "sweeps the magnitude)",
    topology=Topology.paper_testbed,
    trace=_churn_trace,
    horizon_ms=_CHURN_HORIZON_MS,
    fault_schedule=_churn_jitter_schedule,
))
