"""Latency telemetry for serve mode.

One :class:`LatencyRecorder` per service instance: sliding-window service
latencies per event kind (p50/p95/p99 via the repo's shared nearest-rank
percentile), monotonic counters (configure delta vs rebuild, prefetch
launches, …) and gauges (queue depth).  ``snapshot()`` exports everything
as a flat dict — the ``serve_query`` benchmark row and the service's
``telemetry()`` are both views over it.
"""

from __future__ import annotations

import threading
from collections import deque

from repro.cluster.simulator import nearest_rank

__all__ = ["LatencyRecorder"]

_PCTS = (50.0, 95.0, 99.0)


class LatencyRecorder:
    """Thread-safe sliding-window latency percentiles + counters/gauges."""

    def __init__(self, *, window: int = 8192) -> None:
        if window < 1:
            # fail at construction, not mid-incident on the first observe()
            # (deque(maxlen=-1) raises from inside the worker loop)
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = window
        self._lock = threading.Lock()
        self._samples: dict[str, deque[float]] = {}
        self._totals: dict[str, int] = {}
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}
        self._gauge_peaks: dict[str, float] = {}

    # ------------------------------------------------------------- #
    def observe(self, kind: str, latency_ms: float) -> None:
        """Record one service latency sample (ms) for an event kind."""
        with self._lock:
            dq = self._samples.get(kind)
            if dq is None:
                dq = self._samples[kind] = deque(maxlen=self.window)
            dq.append(float(latency_ms))
            self._totals[kind] = self._totals.get(kind, 0) + 1

    def count(self, name: str, inc: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        """Set a gauge; its running peak is kept alongside."""
        with self._lock:
            self._gauges[name] = float(value)
            self._gauge_peaks[name] = max(
                self._gauge_peaks.get(name, float("-inf")), float(value)
            )

    # ------------------------------------------------------------- #
    def percentiles(self, kind: str) -> dict[str, float]:
        """{'p50': …, 'p95': …, 'p99': …} ms over the current window
        (NaN before the first sample)."""
        with self._lock:
            xs = list(self._samples.get(kind, ()))
        return {f"p{q:g}": nearest_rank(xs, q) for q in _PCTS}

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def snapshot(self) -> dict[str, float]:
        """Flat export: per-kind latency percentiles/counts, counters and
        gauges (with ``_peak`` companions)."""
        with self._lock:
            out: dict[str, float] = {}
            for kind, dq in self._samples.items():
                xs = list(dq)
                for q in _PCTS:
                    out[f"{kind}_p{q:g}_ms"] = nearest_rank(xs, q)
                out[f"{kind}_count"] = float(self._totals[kind])
            for name, v in self._counters.items():
                out[name] = float(v)
            for name, v in self._gauges.items():
                out[name] = v
                out[f"{name}_peak"] = self._gauge_peaks[name]
            return out
