"""Typed events of the scheduling service's request stream.

Events carry *simulated* time: the service's clock is the fluid engine's,
and a client replaying a trace submits events in non-decreasing event-time
order (the stream contract — :class:`~repro.serve.service.SchedulerService`
rejects time travel).  Wall-clock only enters through the latency recorder,
which measures how long the service takes to process each event.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Union

from repro.cluster.job import Job, JobState

__all__ = ["JobArrival", "JobDeparture", "QueryPlacement", "PlacementView",
           "ServeEvent"]


@dataclass(frozen=True)
class JobArrival:
    """A job entering the cluster at ``job.arrival_ms``.

    Arrivals sharing one timestamp are admitted as ONE batch with one
    scheduling decision — exactly like the batch simulator — so the
    service defers admission until the stream's watermark moves past the
    batch's timestamp (a later event or an explicit drain).
    """

    job: Job

    @property
    def at_ms(self) -> float:
        return self.job.arrival_ms


@dataclass(frozen=True)
class JobDeparture:
    """Client-initiated cancellation of a job at ``at_ms``.

    Finish-departures need no event — the fluid engine raises them
    internally; this is the external "stop training now" request.
    """

    job_id: str
    at_ms: float


@dataclass(frozen=True)
class QueryPlacement:
    """Read-only query: where is ``job_id`` (or everyone) placed?

    ``at_ms`` optionally moves the stream watermark first (processing all
    actions strictly before it); with ``at_ms=None`` the query answers at
    the current watermark without advancing anything.
    """

    job_id: str | None = None
    at_ms: float | None = None


@dataclass(frozen=True)
class PlacementView:
    """Reply to a :class:`QueryPlacement`.

    ``placements`` maps job → server ids for every queried job;
    ``shifts_ms`` the realized CASSINI time-shift targets; ``states`` the
    job lifecycle states.  ``as_of_ms`` is the fluid clock at answer time
    (the watermark may lag the query's ``at_ms`` when nothing forced an
    advance — fluid time only moves in exact event steps).
    """

    placements: dict[str, tuple[int, ...]]
    shifts_ms: dict[str, float]
    states: dict[str, JobState]
    as_of_ms: float


ServeEvent = Union[JobArrival, JobDeparture, QueryPlacement]
