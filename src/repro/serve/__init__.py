"""Serve mode: the scheduling pipeline as a long-running online service.

Batch experiments drive :class:`~repro.cluster.ClusterSimulator` over a
fully-materialized trace; serve mode accepts the same workload as a
*stream* of :class:`JobArrival` / :class:`JobDeparture` /
:class:`QueryPlacement` events through a bounded request queue, keeps the
fluid-engine / incidence / link-cache state up to date with delta updates
(:meth:`FluidNetworkSim.configure_incremental`) instead of per-event
rebuilds, and answers placement queries with recorded service-latency
percentiles (docs/architecture.md, "Serve mode").
"""

from repro.serve.events import (
    JobArrival,
    JobDeparture,
    PlacementView,
    QueryPlacement,
)
from repro.serve.metrics import LatencyRecorder
from repro.serve.service import QueueFullError, SchedulerService

__all__ = [
    "JobArrival",
    "JobDeparture",
    "QueryPlacement",
    "PlacementView",
    "LatencyRecorder",
    "SchedulerService",
    "QueueFullError",
]
