"""`SchedulerService`: the scheduling pipeline as a long-running service.

One worker thread owns all scheduling state and consumes a bounded request
queue (FIFO — processing order equals submission order, so results are
deterministic regardless of thread timing).  The embedded event loop is the
batch :class:`~repro.cluster.ClusterSimulator` loop, run *incrementally*
against a stream watermark:

  - events carry simulated time and must arrive in non-decreasing order;
  - an event at time ``T`` first *pumps* the loop — executing every
    arrival-admission / epoch-expiry / finish-departure action whose time
    is strictly before ``T`` — then buffers (arrival) or applies
    (departure/query) itself;
  - arrivals sharing one timestamp therefore accumulate in the buffer and
    are admitted as ONE batch with one scheduling decision when the
    watermark moves past them, exactly like the batch simulator;
  - :meth:`drain` runs the remaining buffered work to a horizon with the
    batch loop verbatim and returns batch-identical :class:`Metrics`.

State updates go through :meth:`FluidNetworkSim.configure_incremental`
(slot deltas + retained water-filling cache; bit-exact vs rebuild), and an
optional prefetch thread warms the CASSINI link cache for the predicted
next epoch while the fluid engine advances — speculation only ever *adds*
pure cache entries, so the authoritative scoring path stays bit-identical
with prefetch on or off.
"""

from __future__ import annotations

import math
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field

from repro.cluster.job import Job, JobState
from repro.cluster.network import FluidNetworkSim
from repro.cluster.simulator import Metrics
from repro.cluster.topology import Topology
from repro.sched.base import ClusterState, Decision, Scheduler
from repro.serve.events import (
    JobArrival,
    JobDeparture,
    PlacementView,
    QueryPlacement,
    ServeEvent,
)
from repro.serve.metrics import LatencyRecorder

__all__ = ["SchedulerService", "QueueFullError"]

_EPS = 1e-9


class QueueFullError(RuntimeError):
    """The bounded request queue rejected a submission (backpressure)."""


@dataclass
class _Request:
    event: ServeEvent
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)


_SHUTDOWN = object()


class SchedulerService:
    """Long-running scheduling service over the fluid cluster model.

    Construction mirrors :class:`~repro.cluster.ClusterSimulator` (same
    topology / scheduler / epoch semantics) so a served arrival replay is
    decision-for-decision identical to the batch run — the golden
    equivalence pinned by tests/test_serve.py.
    """

    def __init__(
        self,
        topology: Topology,
        scheduler: Scheduler,
        *,
        epoch_ms: float = 600_000.0,
        compute_jitter: float = 0.0,
        migration_pause_ms: float = 1000.0,
        congested_efficiency: float = 0.88,
        vectorized: bool = True,
        incremental: bool = False,
        sharded: bool = False,
        seed: int = 0,
        queue_size: int = 1024,
        submit_timeout_s: float | None = None,
        prefetch: bool = True,
        start: bool = True,
        fault_schedule=None,
        fallback: bool = True,
        realign_timeout_ms: float | None = None,
    ) -> None:
        self.topo = topology
        self.scheduler = scheduler
        self.epoch_ms = epoch_ms
        self.net = FluidNetworkSim(
            topology,
            compute_jitter=compute_jitter,
            migration_pause_ms=migration_pause_ms,
            congested_efficiency=congested_efficiency,
            vectorized=vectorized,
            incremental=incremental,
            sharded=sharded,
            seed=seed,
        )
        # optional repro.chaos.FaultSchedule replayed against the embedded
        # loop at exactly the batch simulator's injection point
        self.fault_schedule = fault_schedule
        self._chaos = None
        if fault_schedule is not None and not fault_schedule.empty:
            from repro.chaos.inject import FaultInjector

            self._chaos = FaultInjector(self.net, fault_schedule)
        # graceful degradation: on pipeline exception or a decision that
        # exceeds realign_timeout_ms, fall back to the host scheduler's
        # placement (counted as degraded_decisions) instead of killing the
        # worker; the next trigger retries the full pipeline, so one bad
        # epoch degrades one decision, not the service
        self.fallback = bool(fallback)
        self.realign_timeout_ms = realign_timeout_ms
        self._host = getattr(scheduler, "host", None)
        self.decisions: list[tuple[float, Decision]] = []
        self.metrics = LatencyRecorder()
        self.submit_timeout_s = submit_timeout_s
        # scheduling state (owned by the worker thread once started)
        self._arrivals: list[Job] = []      # buffered, not yet admitted
        self._running: list[Job] = []
        self._done: list[Job] = []
        self._next_epoch = 0.0
        self._watermark = 0.0               # highest event time seen
        # epoch-prefetch: warms the CASSINI link cache on a side thread
        # while the worker advances the fluid engine (pipeline-bearing
        # schedulers only — plain hosts have nothing device-side to warm)
        self._pipeline = getattr(scheduler, "pipeline", None)
        self.prefetch = bool(prefetch and self._pipeline is not None)
        self._prefetch_pool = (
            ThreadPoolExecutor(max_workers=1, thread_name_prefix="serve-prefetch")
            if self.prefetch
            else None
        )
        self._prefetch_future: Future | None = None
        # bounded request queue + worker
        self._queue: queue.Queue[_Request | object] = queue.Queue(
            maxsize=queue_size
        )
        self._worker: threading.Thread | None = None
        # exception that escaped the worker loop itself (not a per-request
        # handler error): stored here and re-raised to the next caller, so
        # a crashed worker fails fast instead of leaving requests queued
        # forever against a silently dead service
        self._worker_exc: BaseException | None = None
        self._closed = False
        if start:
            self.start()

    # ---------------------- lifecycle ----------------------------- #
    def start(self) -> None:
        if self._worker is not None:
            return
        self._worker = threading.Thread(
            target=self._worker_loop, name="serve-worker", daemon=True
        )
        self._worker.start()

    def close(self, timeout_s: float = 10.0) -> None:
        """Stop the worker after the queued requests finish.

        Joins with a timeout so a wedged (or already-crashed) worker can
        never hang shutdown, and is idempotent — including after a worker
        crash, where the queue may be full and the thread already dead.
        """
        if self._closed:
            return
        self._closed = True
        worker = self._worker
        if worker is not None:
            if worker.is_alive():
                try:
                    # a crashed worker stops consuming: don't block forever
                    # trying to hand it the shutdown sentinel
                    self._queue.put(_SHUTDOWN, timeout=timeout_s)
                except queue.Full:
                    pass
            worker.join(timeout=timeout_s)
            if worker.is_alive():
                raise RuntimeError(
                    f"serve worker did not stop within {timeout_s}s"
                )
            self._worker = None
        self._join_prefetch()
        if self._prefetch_pool is not None:
            self._prefetch_pool.shutdown(wait=True)

    def __enter__(self) -> "SchedulerService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------- client API ---------------------------- #
    def submit(self, event: ServeEvent) -> Future:
        """Enqueue one event; returns a Future with the handler's result.

        Raises :class:`QueueFullError` when the bounded queue stays full
        past ``submit_timeout_s`` (no timeout → immediate rejection).
        """
        if self._closed:
            raise RuntimeError("service is closed")
        self._check_worker()
        req = _Request(event=event)
        try:
            if self.submit_timeout_s is None:
                self._queue.put_nowait(req)
            else:
                self._queue.put(req, timeout=self.submit_timeout_s)
        except queue.Full:
            self.metrics.count("queue_rejected")
            raise QueueFullError(
                f"request queue full ({self._queue.maxsize} pending)"
            ) from None
        self.metrics.gauge("queue_depth", self._queue.qsize())
        return req.future

    def query(
        self, job_id: str | None = None, at_ms: float | None = None
    ) -> PlacementView:
        """Synchronous :class:`QueryPlacement` (submit + wait)."""
        return self.submit(QueryPlacement(job_id=job_id, at_ms=at_ms)).result()

    def drain(self, horizon_ms: float) -> Metrics:
        """Process queued events, then run everything to ``horizon_ms``
        with batch-loop semantics; returns batch-identical Metrics."""
        self._check_worker()
        fut: Future = Future()
        req = _Request(event=("__drain__", horizon_ms))  # type: ignore[arg-type]
        req.future = fut
        self._queue.put(req)
        return fut.result()

    def _check_worker(self) -> None:
        """Fail fast once the worker loop has died (vs hanging forever on
        a Future no thread will ever resolve)."""
        if self._worker_exc is not None:
            raise RuntimeError(
                "serve worker crashed; service is dead"
            ) from self._worker_exc

    def telemetry(self) -> dict[str, float]:
        """Latency percentiles + counters + cache telemetry, one flat dict.

        Never raises: this is what an operator polls *during* an incident,
        so a half-broken scheduler/module must degrade to fewer keys, not
        to a stack trace (the core snapshot itself is total — see
        ``LatencyRecorder.snapshot``).
        """
        out = self.metrics.snapshot()
        try:
            out["alloc_cache_solves"] = float(self.net.alloc_solves)
            out["alloc_cache_hits"] = float(self.net.alloc_hits)
        except Exception:  # pragma: no cover - defensive
            pass
        try:
            module = getattr(self.scheduler, "module", None)
            if module is not None:
                out["link_cache_hits"] = float(module.cache_hits)
                out["link_cache_misses"] = float(module.cache_misses)
        except Exception:  # pragma: no cover - defensive
            pass
        out["decisions"] = float(len(self.decisions))
        # always present, even before the first fallback, so dashboards
        # and the never-dies acceptance test can key on it unconditionally
        out.setdefault("degraded_decisions", 0.0)
        if self._chaos is not None:
            out["faults_applied"] = float(self._chaos.applied_count)
            out["faults_skipped"] = float(self._chaos.skipped)
        return out

    # ---------------------- worker -------------------------------- #
    def _worker_loop(self) -> None:
        try:
            while True:
                item = self._queue.get()
                if item is _SHUTDOWN:
                    break
                req: _Request = item  # type: ignore[assignment]
                kind = (
                    req.event[0].strip("_")
                    if isinstance(req.event, tuple)
                    else type(req.event).__name__
                )
                try:
                    result = self._handle(req.event)
                except BaseException as exc:  # propagate to the caller
                    req.future.set_exception(exc)
                    self.metrics.count(f"{kind}_errors")
                else:
                    req.future.set_result(result)
                    self.metrics.observe(
                        kind, (time.perf_counter() - req.t_submit) * 1e3
                    )
        except BaseException as exc:
            # anything escaping the loop body itself (result delivery,
            # telemetry, queue internals) kills the worker: record it so
            # submit/drain re-raise instead of enqueueing into a void, and
            # fail whatever is already queued so no caller blocks forever
            self._worker_exc = exc
            self.metrics.count("worker_crashed")
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, _Request) and not item.future.done():
                    item.future.set_exception(
                        RuntimeError("serve worker crashed")
                    )

    def _handle(self, event):
        if isinstance(event, tuple) and event[0] == "__drain__":
            return self._drain(event[1])
        if isinstance(event, JobArrival):
            return self._handle_arrival(event)
        if isinstance(event, JobDeparture):
            return self._handle_departure(event)
        if isinstance(event, QueryPlacement):
            return self._handle_query(event)
        raise TypeError(f"unknown serve event {type(event).__name__}")

    # ---------------------- event handlers ------------------------ #
    def _check_watermark(self, at_ms: float) -> None:
        if at_ms < self._watermark - _EPS:
            raise ValueError(
                f"event at t={at_ms} ms behind the stream watermark "
                f"({self._watermark} ms); events must arrive in "
                "non-decreasing time order"
            )
        self._watermark = max(self._watermark, at_ms)

    def _handle_arrival(self, ev: JobArrival) -> None:
        self._check_watermark(ev.at_ms)
        # everything strictly before this arrival is now decidable
        self._pump(ev.at_ms)
        self._arrivals.append(ev.job)

    def _handle_departure(self, ev: JobDeparture) -> None:
        self._check_watermark(ev.at_ms)
        self._pump(ev.at_ms)
        for i, job in enumerate(self._arrivals):
            if job.job_id == ev.job_id:  # cancelled before admission
                self._arrivals.pop(i)
                self._done.append(job)
                return
        for job in self._running:
            if job.job_id == ev.job_id:
                self._running.remove(job)
                # stopped without finishing: same lifecycle terminal the
                # batch horizon cutoff uses (finish_ms/jct stay None)
                job.state = JobState.CUTOFF
                self._done.append(job)
                # departure-triggered re-placement, like a finish
                self._reschedule(self.net.now_ms, "departure")
                return
        raise KeyError(f"job {ev.job_id!r} is not queued or running")

    def _handle_query(self, ev: QueryPlacement) -> PlacementView:
        if ev.at_ms is not None:
            self._check_watermark(ev.at_ms)
            self._pump(ev.at_ms)
        jobs = self._running if ev.job_id is None else [
            j for j in self._running + self._arrivals + self._done
            if j.job_id == ev.job_id
        ]
        if ev.job_id is not None and not jobs:
            raise KeyError(f"unknown job {ev.job_id!r}")
        return PlacementView(
            placements={j.job_id: tuple(j.placement) for j in jobs},
            shifts_ms={j.job_id: j.alignment.shift_ms for j in jobs},
            states={j.job_id: j.state for j in jobs},
            as_of_ms=self.net.now_ms,
        )

    # ---------------------- embedded event loop ------------------- #
    # This is ClusterSimulator.run's loop body.  _pump runs it with a
    # *deferral bound*: an action at or beyond the bound (within the batch
    # loop's 1e-9 tie window) is left for a later pump, so same-timestamp
    # arrival batches stay whole and the fluid clock advances in exactly
    # the steps the batch run takes (two-phase advances would change float
    # accumulation).  _drain runs it verbatim to a horizon.
    def _loop(self, bound_ms: float, *, defer: bool) -> None:
        net = self.net
        chaos = self._chaos
        while (self._arrivals or self._running) and net.now_ms < bound_ms:
            now = net.now_ms
            t_arrival = (
                self._arrivals[0].arrival_ms if self._arrivals else math.inf
            )
            t_fault = chaos.next_ms if chaos is not None else math.inf
            if defer and (
                min(t_arrival, self._next_epoch, t_fault) >= bound_ms - _EPS
            ):
                break
            t_event = min(t_arrival, self._next_epoch, t_fault, bound_ms)

            if t_event > now:
                finished = net.advance(t_event)
                if finished:
                    for job in finished:
                        self._running.remove(job)
                        self._done.append(job)
                    self._reschedule(net.now_ms, "departure")
                    continue
            now = net.now_ms
            if chaos is not None and now >= chaos.next_ms - _EPS:
                # same injection point (and same same-instant arrival
                # suppression) as ClusterSimulator.run — replay parity
                if chaos.apply_due(now, self._running) and not (
                    self._arrivals
                    and self._arrivals[0].arrival_ms <= now + _EPS
                ):
                    self._reschedule(now, "fault")
            if self._arrivals and now >= self._arrivals[0].arrival_ms - _EPS:
                while (
                    self._arrivals
                    and self._arrivals[0].arrival_ms <= now + _EPS
                ):
                    self._running.append(self._arrivals.pop(0))
                self._reschedule(now, "arrival")
            if now >= self._next_epoch - _EPS:
                self._next_epoch = now + self.epoch_ms
                if not (
                    self._arrivals
                    and self._arrivals[0].arrival_ms <= now + _EPS
                ):
                    self._reschedule(now, "epoch")

    def _pump(self, watermark_ms: float) -> None:
        self._loop(watermark_ms, defer=True)

    def _drain(self, horizon_ms: float) -> Metrics:
        self._loop(horizon_ms, defer=False)
        self._join_prefetch()
        for job in self._running:  # cut off like the batch horizon does
            if job.state == JobState.RUNNING:
                job.state = JobState.CUTOFF
        return Metrics(jobs=self._done + self._running)

    # ---------------------- scheduling ---------------------------- #
    def _reschedule(self, now: float, trigger: str) -> None:
        self._join_prefetch()  # the pipeline/module is single-consumer
        state = ClusterState(
            topology=self.topo, now_ms=now, running=list(self._running),
            pending=[],
        )
        t0 = time.perf_counter()
        decision = self._decide(state)
        self.metrics.observe("schedule", (time.perf_counter() - t0) * 1e3)
        self.metrics.count(f"reschedule_{trigger}")
        self.decisions.append((now, decision))
        placed: list[Job] = []
        for job in self._running:
            servers = decision.placements.get(job.job_id, ())
            if servers:
                job.placement = tuple(servers)
                job.state = JobState.RUNNING
                directive = (
                    decision.plan.directive_for(job.job_id)
                    if decision.plan is not None
                    else None
                )
                if directive is not None:
                    job.apply_directive(directive)
                else:
                    job.clear_directive()
                placed.append(job)
            else:
                job.placement = ()
                job.state = JobState.PENDING  # queued: no GPUs this epoch
        mode = self.net.configure_incremental(placed)
        self.metrics.count(f"configure_{mode}")
        self._maybe_prefetch()

    def _decide(self, state: ClusterState) -> Decision:
        """One scheduling decision, degrading gracefully when allowed.

        The fallback state machine is stateless by design: HEALTHY on
        every call; a pipeline exception or a decision slower than
        ``realign_timeout_ms`` degrades *this* decision to the host
        scheduler's placement (or, with no host, to freezing the current
        placements) and the very next trigger retries the full CASSINI
        pipeline — recovery needs no operator action and no reset, just
        one healthy epoch.
        """
        if not self.fallback:
            return self.scheduler.schedule(state)
        t0 = time.perf_counter()
        decision: Decision | None
        try:
            decision = self.scheduler.schedule(state)
        except Exception:
            self.metrics.count("pipeline_errors")
            decision = None
        if (
            decision is not None
            and self.realign_timeout_ms is not None
            and (time.perf_counter() - t0) * 1e3 > self.realign_timeout_ms
        ):
            # the decision arrived, but after the re-alignment budget: a
            # real deployment has already had to act, so act like it did —
            # discard the stale plan and take the host placement now
            self.metrics.count("realign_timeouts")
            decision = None
        if decision is None:
            decision = self._fallback_decision(state)
        return decision

    def _fallback_decision(self, state: ClusterState) -> Decision:
        """Degraded-mode decision: host scheduler, else freeze in place."""
        self.metrics.count("degraded_decisions")
        if self._host is not None:
            try:
                return self._host.schedule(state)
            except Exception:
                self.metrics.count("fallback_errors")
        # last resort (host also failing, or no host to fall back to):
        # keep every placed job exactly where it is, no new directives
        return Decision(
            placements={
                j.job_id: tuple(j.placement)
                for j in state.running
                if j.placement
            }
        )

    # ---------------------- epoch prefetch ------------------------ #
    def _maybe_prefetch(self) -> None:
        """Speculatively score the predicted next-epoch candidate grids.

        Runs Allocate → Propose → Score for the state the next epoch-expiry
        reschedule would see (same running set, ``now = next epoch``) on a
        side thread, so the ragged ``circle_score`` launches execute on
        device while the worker advances the fluid engine / applies the
        current alignment.  The value is the *link cache* it fills: the
        authoritative reschedule always re-runs Score itself and simply
        hits the warmed entries (CompatResults are pure functions of the
        link problem), so a wrong prediction — membership changed, an
        arrival preempted the epoch — costs only wasted device work and
        can never alter a decision.
        """
        if not self.prefetch:
            return
        pipeline = self._pipeline
        pred_now = self._next_epoch
        pred_running = list(self._running)

        def warm():
            st = ClusterState(
                topology=self.topo, now_ms=pred_now, running=pred_running,
                pending=[],
            )
            out = None
            for stage in pipeline.stages[:-1]:  # Allocate, Propose, Score
                out = stage.run(st, out)
            return out

        self._prefetch_future = self._prefetch_pool.submit(warm)
        self.metrics.count("prefetch_launched")

    def _join_prefetch(self) -> None:
        fut = self._prefetch_future
        if fut is None:
            return
        self._prefetch_future = None
        try:
            fut.result()
        except Exception:
            # speculation is best-effort; the real pass recomputes anyway
            self.metrics.count("prefetch_errors")
