"""Static roofline analysis of post-SPMD HLO text.

``jax.stages.Compiled.cost_analysis()`` on the CPU backend counts each
while-loop body **once**, but scan-over-layers puts ~all of a model's work
inside a while loop — so FLOPs/bytes would be under-counted by ~num_layers.
This module re-derives the roofline inputs from the HLO text itself:

- builds the computation call graph (while bodies weighted by their trip
  count, parsed from the loop condition's comparison constant; fusions and
  calls weighted 1),
- FLOPs: every ``dot`` contributes ``2 · |result| · |contracted dims|``
  (via a per-computation symbol table for operand shapes), times its
  computation's multiplier,
- bytes: result + operand bytes of *buffer-level* ops (dot, fusion,
  slices/updates, copies, reduces, transposes, gathers, collectives) —
  top-level elementwise ops are skipped since the TPU target fuses them;
  this is an HBM-traffic estimate, documented as such,
- collective bytes: result bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, same multipliers.

All quantities are **per device** (the HLO is the partitioned module).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_BUFFER_OPS = _COLLECTIVES + (
    "dot", "fusion", "dynamic-slice", "dynamic-update-slice", "copy",
    "reduce", "reduce-window", "transpose", "gather", "scatter", "sort",
    "convolution", "custom-call", "cholesky", "triangular-solve",
)

_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\b([a-z][\w\-]*)\(")


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: dict = field(default_factory=dict)
    collective_count: dict = field(default_factory=dict)
    while_trip_counts: dict = field(default_factory=dict)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def _parse_computations(hlo: str) -> dict[str, list[tuple[str, str, str, str]]]:
    """name -> list of (op_name, result_type_text, opcode, rest_of_line).

    Robust to tuple result types with ``/*index=N*/`` comments (while ops):
    the opcode is the first ``word(`` token after ``=``, the result-type
    text is everything before it.
    """
    comps: dict[str, list] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if line.endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if m and "(" in line and "->" in line:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is None or " = " not in line:
            continue
        m = _NAME_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        om = _OPCODE_RE.search(rest)
        if not om:
            continue
        rtype = rest[: om.start()]
        opcode = om.group(1)
        tail = rest[om.end():]
        comps[cur].append((name, rtype, opcode, tail))
    return comps


def analyze_hlo(hlo: str) -> HloStats:
    comps = _parse_computations(hlo)

    # ---- call graph: who calls whom, with what weight ---------------- #
    callers: dict[str, list[tuple[str, float]]] = {}
    cond_of_body: dict[str, str] = {}
    for cname, ops in comps.items():
        for (_, _, opcode, rest) in ops:
            for ref in re.finditer(
                r"(?:body|to_apply|calls)=\{?%?([\w\.\-]+)", rest
            ):
                callers.setdefault(ref.group(1), []).append((cname, 1.0))
            m = re.search(r"condition=%?([\w\.\-]+)", rest)
            mb = re.search(r"body=%?([\w\.\-]+)", rest)
            if m and mb:
                cond_of_body[mb.group(1)] = m.group(1)
            # branch computations of conditionals
            for ref in re.finditer(
                r"(?:branch_computations|true_computation|false_computation)="
                r"\{?%?([\w\.\-]+)", rest
            ):
                callers.setdefault(ref.group(1), []).append((cname, 1.0))

    trip: dict[str, int] = {}
    for body, cond in cond_of_body.items():
        consts = []
        for (_, _, opcode, rest) in comps.get(cond, []):
            if opcode == "constant":
                m = re.match(r"\s*(\d+)\s*\)", rest)
                if m:
                    consts.append(int(m.group(1)))
        # the loop bound is usually the largest compare constant
        trip[body] = max(consts) if consts else 1

    mult_cache: dict[str, float] = {}

    def multiplier(cname: str) -> float:
        if cname in mult_cache:
            return mult_cache[cname]
        mult_cache[cname] = 0.0  # break cycles
        if cname not in callers:      # ENTRY (or dead)
            m = 1.0
        else:
            m = 0.0
            for caller, w in callers[cname]:
                m += w * multiplier(caller)
        if cname in trip:
            m *= trip[cname]
        mult_cache[cname] = m
        return m

    # ---- walk ops ----------------------------------------------------- #
    st = HloStats(while_trip_counts=dict(trip))
    st.collective_by_op = {c: 0.0 for c in _COLLECTIVES}
    st.collective_count = {c: 0 for c in _COLLECTIVES}

    for cname, ops in comps.items():
        mult = multiplier(cname)
        if mult == 0.0:
            continue
        symbols = {name: rtype for (name, rtype, _, _) in ops}
        in_fusion = cname.startswith("fused_") or ".fused" in cname

        for (name, rtype, opcode, rest) in ops:
            if opcode == "dot":
                res_dims = _shape_dims(rtype)
                lhs_m = re.match(r"\s*%?([\w\.\-]+)", rest)
                lc_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                lhs_dims = _shape_dims(symbols.get(lhs_m.group(1), "")) if lhs_m else []
                contract = 1
                if lc_m and lhs_dims:
                    for idx in lc_m.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                res_n = 1
                for d in res_dims:
                    res_n *= d
                st.flops += mult * 2.0 * res_n * contract

            if in_fusion:
                continue  # fused ops don't touch HBM; the fusion op counts

            for c in _COLLECTIVES:
                if opcode == c:
                    b = _shape_bytes(rtype)
                    st.collective_bytes += mult * b
                    st.collective_by_op[c] += mult * b
                    st.collective_count[c] += int(mult)
                    break

            if opcode in _BUFFER_OPS:
                if opcode in ("dynamic-slice", "slice", "gather"):
                    # reads only the sliced region, writes the result:
                    # counting the (possibly layer-stacked) source operand
                    # would charge the whole stack per loop trip
                    b = 2 * _shape_bytes(rtype)
                elif opcode == "dynamic-update-slice":
                    # reads + writes the update region; the full-array
                    # "result" aliases the input buffer in place
                    ops_refs = re.findall(r"%([\w\.\-]+)", rest.split(")", 1)[0])
                    upd = symbols.get(ops_refs[1], "") if len(ops_refs) > 1 else ""
                    b = 2 * _shape_bytes(upd)
                else:
                    b = _shape_bytes(rtype)
                    for ref in re.finditer(r"%([\w\.\-]+)", rest.split(")", 1)[0]):
                        b += _shape_bytes(symbols.get(ref.group(1), ""))
                st.bytes_accessed += mult * b

    return st
