"""Launchers: mesh builders, multi-pod dry-run, training driver."""
