"""Single-job training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 [--reduced] [--resume]

On a real cluster this process is what the CASSINI-augmented scheduler
starts per job; ``--time-shift-ms`` is how the scheduler's unique per-job
shift (Algorithm 1) reaches the worker (paper Fig. 7 "apply time-shifts").
"""

from __future__ import annotations

import argparse


from repro.configs import get_config, list_archs
from repro.models.api import build_model
from repro.train.data import SyntheticLM
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-sized sibling config (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--time-shift-ms", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(remat="none")
    model = build_model(cfg)
    model.opt = type(model.opt)(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(10, args.steps // 20))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch)
    trainer = Trainer(
        model, data,
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      time_shift_ms=args.time_shift_ms),
    )
    res = trainer.run()
    print(f"arch={cfg.name} steps={res.steps_run} restored_from={res.restored_from}")
    print("losses:", " ".join(f"{l:.3f}" for l in res.losses))
    if len(res.losses) >= 2:
        print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
              f"({'improved' if res.losses[-1] < res.losses[0] else 'NOT improved'})")


if __name__ == "__main__":
    main()
