"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then builds these meshes out of host placeholder devices.

Target: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod mesh adds
a leading "pod" axis (2 pods = 512 chips for the dry-run; scaling the pod
count is config-only).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(num_devices: int | None = None):
    """Small mesh over whatever devices exist (tests: 1 CPU device)."""
    n = num_devices or len(jax.devices())
    model = 1
    for m in (4, 2):
        if n % m == 0 and n >= m:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
