import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against 512 host placeholder devices, and extract the roofline
inputs from the compiled artifact.

For each cell we record into a JSON cache (benchmarks/roofline reads it):

- ``memory_analysis``  — bytes per device (argument/output/temp/peak),
- ``cost_analysis``    — HLO FLOPs and bytes accessed,
- ``collective_bytes`` — per-collective operand bytes parsed from the
  post-SPMD HLO text, with while-loop bodies multiplied by their trip
  counts (scan-over-layers puts the interesting collectives inside loops,
  where a naive text scan would count them once).

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.api import SHAPES, build_model
from repro.parallel.sharding import param_shardings
from repro.models.common import make_spec
from jax.sharding import NamedSharding, PartitionSpec as P

CACHE_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_cache"
VERSION = 2  # bump to invalidate cached cells after analyzer changes

# long_500k is only defined for sub-quadratic decoders (DESIGN.md §4)
def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "full-attention arch: 500k-context decode is quadratic-history "
            "(skip per assignment; DESIGN.md §4)"
        )
    return True, ""


# ---------------------------------------------------------------------- #
# HLO collective analysis
# ---------------------------------------------------------------------- #
_SHAPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_ARRAY_RE = re.compile(
    r"(f32|bf16|f16|f64|s32|u32|s64|u64|s8|u8|pred|f8e4m3fn)\[([0-9,]*)\]"
)


def _first_array_bytes(line: str) -> int:
    """Bytes of the result shape(s) on an HLO op line (covers tuples)."""
    total = 0
    # result is everything left of ' = '; ops like all-gather list result first
    lhs = line.split(" = ", 1)
    text = lhs[1] if len(lhs) == 2 else line
    # take shapes up to the opcode's operand list start
    head = text.split("(", 1)[0]
    for m in _ARRAY_RE.finditer(head):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _SHAPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum collective result bytes, multiplying loop bodies by trip count."""
    # split into computations
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{", line.strip())
        if m and ("{" in line):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)

    # find while ops: body=%name, and trip counts from cond constants
    body_of: dict[str, str] = {}
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mb:
                    body_of[mb.group(1)] = mc.group(1) if mc else ""

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = []
        for line in lines:
            for m in re.finditer(r"constant\((\d+)\)", line):
                consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    multiplier = {name: trip_count(cond) for name, cond in body_of.items()}

    per_op: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    count: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for cname, lines in comps.items():
        mult = multiplier.get(cname, 1)
        for line in lines:
            ls = line.strip()
            for c in _COLLECTIVES:
                if (
                    re.search(rf"= [^=]*\b{c}\(", ls)
                    or f" {c}(" in ls.split("=")[-1][:80]
                ):
                    b = _first_array_bytes(ls)
                    per_op[c] += b * mult
                    count[c] += mult
                    break
    per_op["total"] = sum(v for k, v in per_op.items())
    return {"bytes": per_op, "count": count}


# ---------------------------------------------------------------------- #
def build_cell(arch: str, shape_name: str, multi_pod: bool):
    """Construct (jitted_fn, example_args) for one cell — no allocation."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg, mesh)
    shape = SHAPES[shape_name]

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = param_shardings(params_shapes, mesh, num_experts=cfg.num_experts)

    def arr_shardings(specs: dict):
        out = {}
        for k, v in specs.items():
            ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
            axes = (ba,) + (None,) * (len(v.shape) - 1)
            out[k] = NamedSharding(mesh, make_spec(mesh, v.shape, axes))
        return out

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(model.init_opt, params_shapes)
        from repro.train.optimizer import AdamWState

        # mu/nu mirror the parameter shardings; step is replicated
        o_shard = AdamWState(
            step=NamedSharding(mesh, P()),
            mu=param_shardings(params_shapes, mesh, num_experts=cfg.num_experts),
            nu=param_shardings(params_shapes, mesh, num_experts=cfg.num_experts),
        )
        batch_specs = model.input_specs(shape)
        b_shard = arr_shardings(batch_specs)
        fn = jax.jit(
            model.train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            donate_argnums=(0, 1),
        )
        args = (params_shapes, opt_shapes, batch_specs)
    elif shape.kind == "prefill":
        batch_specs = model.input_specs(shape)
        b_shard = arr_shardings(batch_specs)
        fn = jax.jit(model.prefill_step, in_shardings=(p_shard, b_shard))
        args = (params_shapes, batch_specs)
    else:  # decode
        B, S = shape.global_batch, shape.seq_len
        if cfg.family == "audio":
            from repro.models import encdec

            s_enc, _ = encdec.enc_seq_split(cfg, S)
            frames = jax.ShapeDtypeStruct((B, s_enc, cfg.d_model), jnp.float32)
            state_shapes = jax.eval_shape(
                lambda p, f: model.init_decode_state(B, S, params=p, frames=f),
                params_shapes, frames,
            )
        else:
            state_shapes = jax.eval_shape(lambda: model.init_decode_state(B, S))
        s_shard = model.decode_state_shardings(state_shapes, B)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        t_shard = NamedSharding(mesh, make_spec(mesh, (B, 1), (
            tuple(a for a in ("pod", "data") if a in mesh.shape), None)))
        fn = jax.jit(
            model.serve_step,
            in_shardings=(p_shard, t_shard, s_shard),
            donate_argnums=(2,),
        )
        args = (params_shapes, tok, state_shapes)
    return fn, args, mesh, model


def run_cell(arch: str, shape_name: str, mesh_kind: str, cache_dir: Path) -> dict:
    cache_dir.mkdir(parents=True, exist_ok=True)
    out_file = cache_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_file.exists():
        rec = json.loads(out_file.read_text())
        if rec.get("status") in ("ok", "skip") and rec.get("version") == VERSION:
            return rec

    ok, why = cell_supported(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skip", reason=why, version=VERSION)
        out_file.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.time()
    try:
        fn, args, mesh, model = build_cell(arch, shape_name, mesh_kind == "multi")
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        try:
            mem = compiled.memory_analysis()
        except Exception:
            mem = None
        try:
            cost = compiled.cost_analysis()
        except Exception:
            cost = None
        hlo = compiled.as_text()
        stats = analyze_hlo(hlo)
        coll = {
            "bytes": {**stats.collective_by_op, "total": stats.collective_bytes},
            "count": stats.collective_count,
        }
        rec.update(
            status="ok",
            version=VERSION,
            devices=int(mesh.devices.size),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=(
                {
                    k: int(getattr(mem, k))
                    for k in (
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "peak_memory_in_bytes",
                        "alias_size_in_bytes",
                    )
                    if hasattr(mem, k)
                }
                if mem is not None
                else {}
            ),
            # per-device quantities from the call-graph HLO analyzer
            # (cost_analysis() counts while bodies once; see hlo_analysis.py)
            flops=stats.flops,
            bytes_accessed=stats.bytes_accessed,
            xla_cost_flops=float(cost.get("flops", -1)) if cost else -1,
            xla_cost_bytes=float(cost.get("bytes accessed", -1)) if cost else -1,
            collectives=coll,
            while_trips=dict(stats.while_trip_counts),
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # record failures; they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    out_file.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cache", default=str(CACHE_DIR))
    args = ap.parse_args()

    cache = Path(args.cache)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(arch, shape, mk, cache)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skip"
                n_err += tag == "error"
                extra = ""
                if tag == "ok":
                    peak = rec["memory"].get("peak_memory_in_bytes", 0) / 2**30
                    extra = (
                        f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
                        f"peak/dev {peak:.2f} GiB coll "
                        f"{rec['collectives']['bytes']['total']/2**30:.2f} GiB"
                    )
                elif tag == "error":
                    extra = rec["error"][:140]
                print(f"[{tag:5s}] {arch} × {shape} × {mk}: {extra}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} error={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
