"""AdamW with cosine schedule and global-norm clipping, dependency-free.

Optimizer state shards exactly like the parameters (first/second moments
are tree-mapped), so the (pod, data, model) weight sharding carries over
with no extra code — the ZeRO-style trick of sharding optimizer state over
the data axis is applied on top in ``shard_opt_state``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_adamw(params, moments_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=moments_dtype), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    cfg: AdamWConfig, grads, params, state: AdamWState
) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
    """One optimizer step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m.astype(mdt),
            v.astype(mdt),
        )

    flat = jax.tree.map(upd, grads, params, state.mu, state.nu)
    new_params = jax.tree.map(
        lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple)
    )
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        AdamWState(step=step, mu=new_mu, nu=new_nu),
        {"grad_norm": gnorm, "lr": lr},
    )
