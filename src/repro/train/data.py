"""Synthetic-but-learnable token pipeline.

Deterministic, seekable (resume at any step without replaying), and
host-shardable: ``batch_at(step)`` is a pure function of (seed, step), so
after a restart — or an elastic re-shard that changes the per-host slice —
the pipeline continues exactly where training left off.

The token stream is an order-2 Markov chain over the vocabulary, so the
causal-LM loss has real structure to learn (loss decreasing ⇒ the whole
train loop, not just the plumbing, works).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM"]


@dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        # sparse-ish markov transition: each symbol has ~8 likely successors
        k = min(8, self.vocab)
        self._succ = rng.integers(0, self.vocab, size=(self.vocab, k))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        b, s = self.global_batch, self.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        choices = rng.integers(0, self._succ.shape[1], size=(b, s))
        noise = rng.random((b, s)) < 0.05
        rand = rng.integers(0, self.vocab, size=(b, s))
        for t in range(s):
            nxt = self._succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def jax_batch_at(self, step: int) -> dict[str, jnp.ndarray]:
        return {k: jnp.asarray(v) for k, v in self.batch_at(step).items()}
