"""Sharded checkpointing with atomic manifests (fault tolerance layer).

Layout of a checkpoint directory::

    step_000123/
      manifest.json      # tree structure, shapes, dtypes, shard files
      arr_00000.npy ...  # one file per leaf (host-gathered)
      COMMIT             # written last: a checkpoint without it is ignored

Writes go to ``step_X.tmp/`` and are renamed into place after COMMIT, so a
crash mid-save never corrupts the latest checkpoint — restore always picks
the newest *committed* step.  At cluster scale each host would write its
own shard files; the manifest format already records per-leaf files, so
swapping the gather for per-host writes is a transport change, not a
format change.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype not in ("float64", "float32", "float16", "int64", "int32",
                         "int16", "int8", "uint8", "uint32", "uint64", "bool"):
            # npy files cannot carry extension dtypes (bfloat16, fp8):
            # store a bit-exact uint16/uint8 view and restore via the
            # manifest dtype
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"path": p, "file": fname, "dtype": dtype, "shape": list(arr.shape)}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") and (d / "COMMIT").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, tree_like: Any, step: int | None = None):
    """Restore into the structure of ``tree_like`` (shapes must match).
    Returns (tree, step) or (None, None) when no committed checkpoint."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_path = {e["path"]: e for e in manifest["leaves"]}

    import ml_dtypes

    paths, leaves, treedef = _flatten_with_paths(tree_like)
    new_leaves = []
    for p, leaf in zip(paths, leaves):
        e = by_path[p]
        arr = np.load(d / e["file"])
        if str(arr.dtype) != e["dtype"]:  # stored as a bit view
            arr = arr.view(np.dtype(getattr(ml_dtypes, e["dtype"], e["dtype"])))
        dtype = getattr(leaf, "dtype", arr.dtype)
        new_leaves.append(jnp.asarray(arr).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
