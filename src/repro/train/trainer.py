"""Fault-tolerant training driver.

A production-shaped loop around Model.train_step:

- deterministic, seekable data (resume without replay),
- periodic checkpoints with atomic commit; automatic restore on start,
- simulated failure injection (``fail_at_step``) to exercise the
  checkpoint→restore→continue path in tests,
- CASSINI time-shift agent: when the scheduler assigns this job a
  time-shift (multi-tenant cluster), the driver delays the iteration start
  and re-aligns on drift (§4.2 step 3 / §5.7) — on real hardware this
  paces the AllReduce phase away from a co-located job's bursts.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from repro.core.timeshift import DriftAdjuster
from repro.models.api import Model
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.data import SyntheticLM

__all__ = ["TrainerConfig", "Trainer", "TrainResult"]


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0
    # CASSINI agent (set by the cluster scheduler for multi-tenant runs)
    time_shift_ms: float = 0.0
    paced_iter_ms: float = 0.0
    drift_tolerance: float = 0.05
    # failure injection for tests
    fail_at_step: int | None = None


@dataclass
class TrainResult:
    losses: list[float] = field(default_factory=list)
    steps_run: int = 0
    restored_from: int | None = None
    drift_adjustments: int = 0


class Trainer:
    def __init__(self, model: Model, data: SyntheticLM, cfg: TrainerConfig):
        self.model = model
        self.data = data
        self.cfg = cfg
        self._step_fn = jax.jit(model.train_step, donate_argnums=(0, 1))

    # -------------------------------------------------------------- #
    def run(self) -> TrainResult:
        cfg = self.cfg
        rng = jax.random.PRNGKey(cfg.seed)
        params = self.model.init(rng)
        opt = self.model.init_opt(params)
        start = 0
        res = TrainResult()

        # resume from the newest committed checkpoint, if any
        restored, step = restore_checkpoint(cfg.ckpt_dir, (params, opt))
        if restored is not None:
            params, opt = restored
            start = step
            res.restored_from = step

        adjuster = None
        if cfg.time_shift_ms > 0 or cfg.paced_iter_ms > 0:
            period = cfg.paced_iter_ms or 1.0
            adjuster = DriftAdjuster(
                iter_time_ms=period,
                time_shift_ms=cfg.time_shift_ms,
                epoch_start_ms=time.monotonic() * 1e3,
                drift_tolerance=cfg.drift_tolerance,
            )
            time.sleep(cfg.time_shift_ms / 1e3)  # apply the shift once

        for step in range(start, cfg.steps):
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            if adjuster is not None:
                extra = adjuster.observe(step - start, time.monotonic() * 1e3)
                if extra > 0:
                    time.sleep(min(extra, adjuster.iter_time_ms) / 1e3)
            batch = self.data.jax_batch_at(step)
            params, opt, metrics = self._step_fn(params, opt, batch)
            if step % cfg.log_every == 0 or step == cfg.steps - 1:
                loss = float(metrics["loss"])
                res.losses.append(loss)
            if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
                save_checkpoint(cfg.ckpt_dir, step + 1, (params, opt))
            res.steps_run += 1
        if adjuster is not None:
            res.drift_adjustments = adjuster.adjustments
        save_checkpoint(cfg.ckpt_dir, cfg.steps, (params, opt))
        self.final_params = params
        return res
