"""Elastic scaling / failure handling: re-mesh planning.

When hosts fail mid-run, the job must restart on the surviving device set
with a coherent mesh (and resharded state).  ``plan_remesh`` picks the
largest usable (pod, data, model) factorization of the surviving devices
subject to keeping the model axis intact (weight shards must still tile),
then reports the per-axis changes.  ``reshard`` moves a checkpointed state
onto the new mesh's shardings — with our npz checkpoints that is simply a
restore-with-new-shardings, which is exactly how production JAX stacks
(e.g. Orbax single-controller) handle elastic restarts.

Straggler mitigation is the CASSINI drift-adjustment agent (§5.7): slow
workers re-align their communication phase rather than dragging the
collective; see repro/cluster/network.py and repro/train/timeshift_agent.py.
"""

from __future__ import annotations

from dataclasses import dataclass


__all__ = ["RemeshPlan", "plan_remesh"]


@dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axes: tuple[str, ...]
    dropped_devices: int
    data_scale: float          # batch rescale factor (new/old data parallelism)

    @property
    def viable(self) -> bool:
        return all(s >= 1 for s in self.new_shape)


def plan_remesh(
    old_shape: tuple[int, ...],
    axes: tuple[str, ...],
    failed: int,
    *,
    keep_model_axis: bool = True,
) -> RemeshPlan:
    """Plan the new mesh after ``failed`` devices die.

    Shrinks the data axis first (gradient accumulation makes up the batch),
    then the pod axis; the model axis is preserved so weight shards remain
    valid (changing TP degree requires a full reshard of every tensor).
    """
    sizes = dict(zip(axes, old_shape))
    total = 1
    for s in old_shape:
        total *= s
    alive = total - failed

    model = sizes.get("model", 1)
    if keep_model_axis and alive < model:
        raise ValueError(f"cannot keep model axis {model} with {alive} devices")
    rest = alive // model if keep_model_axis else alive

    pod = sizes.get("pod", 1)
    data = sizes.get("data", 1)
    # shrink data, then pods, to the largest factorization ≤ rest
    new_pod, new_data = pod, data
    while new_pod * new_data > rest and new_data > 1:
        new_data -= 1
    while new_pod * new_data > rest and new_pod > 1:
        new_pod -= 1
        new_data = data
        while new_pod * new_data > rest and new_data > 1:
            new_data -= 1

    new_sizes = dict(sizes)
    if "data" in new_sizes:
        new_sizes["data"] = new_data
    if "pod" in new_sizes:
        new_sizes["pod"] = new_pod
    new_shape = tuple(new_sizes[a] for a in axes)
    old_dp = pod * data
    new_dp = new_pod * new_data
    return RemeshPlan(
        old_shape=tuple(old_shape),
        new_shape=new_shape,
        axes=tuple(axes),
        dropped_devices=failed,
        data_scale=new_dp / old_dp,
    )
