"""Training substrate: optimizer, data, checkpointing, elastic, trainer."""
