"""Cross-PR bench regression gate: diff a BENCH.json run against the
committed baseline.

    PYTHONPATH=src python -m benchmarks.compare [--current BENCH.json] \
        [--baseline benchmarks/baselines/bench_baseline.json] \
        [--threshold 0.20] [--summary report.md] [--update-baseline]

The bench harness (``benchmarks/run.py --json``) writes machine-readable
rows; this tool holds every PR's run against the baseline committed at
``benchmarks/baselines/bench_baseline.json`` and exits nonzero when the
perf trajectory regresses:

  * the current run recorded a bench failure (``"failed"`` in the doc);
  * a baseline row is missing from the current run (a silently dropped
    bench can never "pass" by absence);
  * a row's wall time drifted more than ``--threshold`` (default +20%)
    above baseline — unless both sides sit below the ``--floor-us``
    absolute floor (default 5ms), where relative drift is timer noise
    and is reported as ``noise`` without failing the gate;
  * a *lost speedup assertion*: a row whose baseline ``speedup`` was
    ≥ 1.0 (a claimed win over some reference path) now measures < 1.0,
    or no longer reports a speedup at all.

A per-row delta table is printed to stdout and, with ``--summary PATH``,
appended as markdown (CI passes ``$GITHUB_STEP_SUMMARY`` so the table
lands in the job summary).  New rows (present only in the current run)
are reported but never fail the gate.

``--history [PATH]`` additionally appends the run's rows to a JSONL
trend file (default ``benchmarks/baselines/bench_history.jsonl``, an
artifact the CI bench job uploads next to ``BENCH.json``) and renders a
per-row trend column — the last 5 runs' wall times, oldest→newest — so
the perf *trajectory* across PRs is visible, not just the one-baseline
diff.  ``--trend-plot [PNG]`` renders the same history as sparkline
small multiples (one mini-panel per bench row, default
``benchmarks/artifacts/bench_trend.png``), which CI uploads next to the
markdown report.

When a regression is intentional (e.g. a bench was redesigned or a
slower-but-correct fix landed), the builder refreshes the baseline with
``--update-baseline`` and commits the result.

Caveat: the wall-time gate compares *absolute* microseconds against a
baseline measured on whatever machine last updated it, so heterogeneous
CI runner hardware can trip it without a code change — the speedup
checks are machine-relative and robust; if the wall gate proves noisy on
a runner pool, raise ``--threshold`` in the workflow rather than
laundering baselines.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

DEFAULT_CURRENT = "BENCH.json"
DEFAULT_BASELINE = os.path.join(
    os.path.dirname(__file__), "baselines", "bench_baseline.json"
)
DEFAULT_HISTORY = os.path.join(
    os.path.dirname(__file__), "baselines", "bench_history.jsonl"
)
DEFAULT_TREND_PLOT = os.path.join(
    os.path.dirname(__file__), "artifacts", "bench_trend.png"
)
DEFAULT_THRESHOLD = 0.20
# Sub-floor rows are exempt from the *relative* drift gate: a 200us row
# drifting +30% is 60us of timer jitter, not a regression.  A row only
# faces the relative gate once either side of the diff reaches this wall
# time (the speedup gates still apply below the floor).
DEFAULT_FLOOR_US = 5_000.0
TREND_RUNS = 5
TREND_PLOT_RUNS = 20


def load_rows(path: str) -> tuple[dict[str, dict], dict]:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc.get("rows", [])}, doc


def fmt_us(v) -> str:
    return f"{v:,.0f}" if isinstance(v, (int, float)) else "—"


def fmt_speedup(v) -> str:
    return f"{v:.2f}x" if isinstance(v, (int, float)) else "—"


def fmt_compact(v) -> str:
    """Compact microseconds for the trend column (123 / 12.3k / 3.5M)."""
    if not isinstance(v, (int, float)):
        return "?"
    if v >= 1e6:
        return f"{v / 1e6:.1f}M"
    if v >= 1e3:
        return f"{v / 1e3:.1f}k"
    return f"{v:.0f}"


# ---------------------------------------------------------------------- #
# trend history (JSONL, one line per bench run)
# ---------------------------------------------------------------------- #
def append_history(path: str, current: dict[str, dict], cur_doc: dict) -> None:
    """Append the current run's rows as one JSONL line."""
    entry = {
        "wall_s": cur_doc.get("wall_s"),
        "rows": {
            name: {"us": r.get("us_per_call"), "speedup": r.get("speedup")}
            for name, r in current.items()
        },
    }
    if "failed" in cur_doc:
        entry["failed"] = cur_doc["failed"]
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "a") as f:
        json.dump(entry, f)
        f.write("\n")


def load_history(path: str, limit: int = TREND_RUNS) -> list[dict]:
    """Last ``limit`` well-formed runs from the JSONL trend file."""
    if not os.path.exists(path):
        return []
    runs: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                doc = json.loads(line)
            except json.JSONDecodeError:
                continue  # a torn write must not break the gate
            if isinstance(doc, dict) and isinstance(doc.get("rows"), dict):
                runs.append(doc)
    return runs[-limit:]


def render_trends(history: list[dict]) -> dict[str, str]:
    """Per-row ``a→b→c`` wall-time trail over the last runs (oldest first)."""
    names: list[str] = []
    for run in history:
        for name in run["rows"]:
            if name not in names:
                names.append(name)
    return {
        name: "→".join(
            fmt_compact(run["rows"][name].get("us"))
            for run in history
            if name in run["rows"]
        )
        for name in names
    }


def render_trend_plot(history: list[dict], path: str) -> bool:
    """Sparkline small multiples: one mini-panel per bench row, wall time
    over the last runs (oldest→newest).

    One single-hue series per panel — the panel title carries identity,
    so no legend and no multi-line spaghetti; rows of wildly different
    magnitude never share a y-axis.  Returns False (and leaves no file)
    when matplotlib is unavailable or there is nothing to plot.
    """
    if not history:
        return False
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("trend plot skipped: matplotlib not installed", file=sys.stderr)
        return False

    # chart tokens (validated reference palette)
    surface, ink, ink2, muted = "#fcfcfb", "#0b0b0b", "#52514e", "#898781"
    gridline, axisline, series = "#e1e0d9", "#c3c2b7", "#2a78d6"

    names: list[str] = []
    for run in history:
        for name in run["rows"]:
            if name not in names:
                names.append(name)
    ncols = 3
    nrows = (len(names) + ncols - 1) // ncols
    fig, axes = plt.subplots(
        nrows, ncols, figsize=(3.4 * ncols, 1.7 * nrows), dpi=150,
        squeeze=False,
    )
    fig.patch.set_facecolor(surface)
    for i, name in enumerate(names):
        ax = axes[i // ncols][i % ncols]
        pts = [
            (ri, run["rows"][name].get("us"))
            for ri, run in enumerate(history)
            if name in run["rows"]
            and isinstance(run["rows"][name].get("us"), (int, float))
        ]
        xs, ys = [p[0] for p in pts], [p[1] for p in pts]
        ax.set_facecolor(surface)
        for side in ("top", "right", "left"):
            ax.spines[side].set_visible(False)
        ax.spines["bottom"].set_color(axisline)
        ax.grid(axis="y", color=gridline, linewidth=0.6)
        ax.set_axisbelow(True)
        ax.set_yticks([])
        ax.set_xticks([])
        ax.set_title(name, fontsize=8, color=ink2, loc="left")
        if xs:
            ax.plot(xs, ys, color=series, linewidth=2, marker="o",
                    markersize=4 if len(xs) > 1 else 6,
                    markeredgecolor=surface, markeredgewidth=0.8)
            ax.annotate(
                f"{fmt_compact(ys[-1])}us", (xs[-1], ys[-1]),
                xytext=(4, 0), textcoords="offset points", va="center",
                fontsize=8, color=ink2,
            )
            pad = 0.15 * (max(ys) - min(ys) or max(ys) or 1.0)
            ax.set_ylim(min(ys) - pad, max(ys) + pad)
            ax.set_xlim(-0.5, len(history) - 0.5 + 0.9)  # room for the label
    for i in range(len(names), nrows * ncols):
        axes[i // ncols][i % ncols].axis("off")
    fig.suptitle(
        f"Bench wall-time trend — last {len(history)} runs, oldest→newest",
        fontsize=10, color=ink, x=0.01, ha="left",
    )
    fig.tight_layout(rect=(0, 0, 1, 0.96))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fig.savefig(path, facecolor=surface)
    plt.close(fig)
    return True


def compare(
    current: dict[str, dict],
    baseline: dict[str, dict],
    threshold: float,
    floor_us: float = DEFAULT_FLOOR_US,
) -> tuple[list[tuple], list[str]]:
    """Returns (table_rows, failures).  Each table row is
    ``(name, base_us, cur_us, delta_str, base_speedup, cur_speedup,
    status)``."""
    table: list[tuple] = []
    failures: list[str] = []
    for name, b in baseline.items():
        c = current.get(name)
        if c is None:
            table.append((name, b.get("us_per_call"), None, "—",
                          b.get("speedup"), None, "MISSING"))
            failures.append(f"row {name!r} present in baseline but missing "
                            f"from the current run")
            continue
        b_us, c_us = b.get("us_per_call"), c.get("us_per_call")
        delta = (c_us - b_us) / b_us if b_us else 0.0
        status = "ok"
        if delta > threshold:
            # absolute floor: relative drift on sub-floor rows is timer
            # noise, not signal — report it, but never fail the gate on it
            if max(b_us or 0.0, c_us or 0.0) < floor_us:
                status = "noise"
            else:
                status = "SLOWER"
                failures.append(
                    f"row {name!r} wall time drifted +{delta:.0%} "
                    f"({fmt_us(b_us)}us → {fmt_us(c_us)}us, "
                    f"gate +{threshold:.0%})"
                )
        b_sp, c_sp = b.get("speedup"), c.get("speedup")
        if isinstance(b_sp, (int, float)) and b_sp >= 1.0:
            if not isinstance(c_sp, (int, float)) or c_sp < 1.0:
                status = "LOST-SPEEDUP"
                failures.append(
                    f"row {name!r} lost its speedup assertion "
                    f"(baseline {fmt_speedup(b_sp)} → {fmt_speedup(c_sp)})"
                )
        table.append((name, b_us, c_us, f"{delta:+.1%}", b_sp, c_sp, status))
    for name, c in current.items():
        if name not in baseline:
            table.append((name, None, c.get("us_per_call"), "—",
                          None, c.get("speedup"), "new"))
    return table, failures


def render_markdown(table, failures, threshold, wall_note, trends=None) -> str:
    trend_col = trends is not None
    lines = [
        "## Bench regression gate",
        "",
        f"Per-row wall-time gate: +{threshold:.0%} vs committed baseline; "
        f"speedup assertions must not drop below 1.0x. {wall_note}",
        "",
        "| bench row | baseline us | current us | Δ wall | baseline speedup "
        "| current speedup |"
        + (f" trend (last {TREND_RUNS}) |" if trend_col else "")
        + " status |",
        "|---|---:|---:|---:|---:|---:|" + ("---|" if trend_col else "") + "---|",
    ]
    for name, b_us, c_us, delta, b_sp, c_sp, status in table:
        mark = {"ok": "✅", "new": "🆕", "noise": "✅"}.get(status, "❌")
        trend = f" {trends.get(name, '—')} |" if trend_col else ""
        lines.append(
            f"| `{name}` | {fmt_us(b_us)} | {fmt_us(c_us)} | {delta} "
            f"| {fmt_speedup(b_sp)} | {fmt_speedup(c_sp)} |{trend} {mark} {status} |"
        )
    lines.append("")
    if failures:
        lines.append(f"**GATE FAILED** ({len(failures)} regression(s)):")
        lines.extend(f"- {f}" for f in failures)
        lines.append("")
        lines.append(
            "If intentional, refresh the baseline: `PYTHONPATH=src python -m "
            "benchmarks.run --only kernels --json BENCH.json && python -m "
            "benchmarks.compare --update-baseline` and commit it."
        )
    else:
        lines.append("Gate passed: no wall-time drift beyond threshold, all "
                     "speedup assertions held.")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default=DEFAULT_CURRENT,
                    help="BENCH.json of the current run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max tolerated per-row wall-time drift "
                         "(fraction, default 0.20)")
    ap.add_argument("--floor-us", type=float, default=DEFAULT_FLOOR_US,
                    help="absolute wall floor (us) below which relative "
                         "drift is treated as timer noise and never fails "
                         f"the gate (default {DEFAULT_FLOOR_US:g})")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append the markdown delta table to PATH "
                         "(CI: $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--history", nargs="?", const=DEFAULT_HISTORY,
                    default=None, metavar="PATH",
                    help="append this run's rows to a JSONL trend file and "
                         "render a per-row trend column (last "
                         f"{TREND_RUNS} runs). Bare --history uses "
                         "benchmarks/baselines/bench_history.jsonl")
    ap.add_argument("--trend-plot", nargs="?", const=DEFAULT_TREND_PLOT,
                    default=None, metavar="PNG",
                    help="render the trend history as sparkline small "
                         f"multiples (last {TREND_PLOT_RUNS} runs; needs "
                         "matplotlib — skipped with a note otherwise). "
                         "Bare --trend-plot writes "
                         "benchmarks/artifacts/bench_trend.png")
    ap.add_argument("--update-baseline", action="store_true",
                    help="replace the baseline with the current run "
                         "(intentional perf change) and exit")
    args = ap.parse_args()

    if args.update_baseline:
        # refuse to install a failed/partial run as the new baseline: the
        # missing-row gate only protects rows the baseline knows about, so
        # a truncated doc would permanently un-gate every dropped bench
        _, cur_doc = load_rows(args.current)
        if "failed" in cur_doc:
            print(
                f"refusing to update baseline: {args.current} records a "
                f"failed bench run ({cur_doc['failed']})",
                file=sys.stderr,
            )
            raise SystemExit(1)
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated from {args.current} -> {args.baseline}")
        return

    current, cur_doc = load_rows(args.current)
    baseline, base_doc = load_rows(args.baseline)
    table, failures = compare(
        current, baseline, args.threshold, floor_us=args.floor_us
    )
    if "failed" in cur_doc:
        failures.insert(0, f"current bench run failed its own gate: "
                           f"{cur_doc['failed']}")
    trends = None
    if args.history:
        append_history(args.history, current, cur_doc)
        trends = render_trends(load_history(args.history))
    if args.trend_plot:
        history_path = args.history or DEFAULT_HISTORY
        if render_trend_plot(
            load_history(history_path, limit=TREND_PLOT_RUNS), args.trend_plot
        ):
            print(f"trend plot written to {args.trend_plot}", file=sys.stderr)
    wall_note = (
        f"Total wall: baseline {base_doc.get('wall_s', '?')}s, "
        f"current {cur_doc.get('wall_s', '?')}s."
    )
    md = render_markdown(table, failures, args.threshold, wall_note, trends)
    print(md)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(md)
    if failures:
        for f in failures:
            print(f"BENCH REGRESSION: {f}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
