"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import statistics
import time
from typing import Callable

from repro.cluster import ClusterSimulator, nearest_rank
from repro.engine.scenarios import default_scheduler_factories

# the paper's scheduler line-up, shared with the scenario registry
SCHEDULERS: dict[str, Callable] = default_scheduler_factories()

# ONE percentile definition repo-wide: the benchmarks report the same
# nearest-rank statistic Metrics does (the seed had a subtly different
# floor-indexed copy here)
pct = nearest_rank


def run_trace(topo, jobs, sched, *, epoch_ms=300_000.0, jitter=0.005,
              horizon_ms=7_200_000.0, seed=0):
    sim = ClusterSimulator(topo, sched, epoch_ms=epoch_ms,
                           compute_jitter=jitter, seed=seed)
    t0 = time.time()
    metrics = sim.run(jobs, horizon_ms=horizon_ms)
    return metrics, time.time() - t0, sim


def timed(fn, *args, repeat=3, **kw):
    ts = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    return out, statistics.median(ts)


def scoring_problems(num_links=24, jobs_per_link=2, capacity=50.0):
    """Synthetic k-job link problems for the batched-scoring benches.

    Every link carries ``jobs_per_link`` staggered single-phase jobs on a
    shared iteration time; at the default 5° precision a 3-job link lands
    on the batched exact product grid (the Algorithm-2 hot path for the
    paper's multi-tenant snapshots), and finer grids push the same
    problems onto the batched coordinate descent.
    """
    from repro.core.circle import CommPattern, Phase

    out = []
    for i in range(num_links):
        it = 300.0 + 10.0 * (i % 7)
        pats = []
        for k in range(jobs_per_link):
            start = (0.12 + 0.3 * k) % 1.0 * it
            dur = max(0.12, 0.42 - 0.06 * k) * it
            pats.append(
                CommPattern(it, (Phase(start, dur, 45.0 - 4.0 * k),),
                            name=f"l{i}j{k}")
            )
        out.append((pats, capacity))
    return out


def large_grid_k3_problems(num_links=8, capacity=50.0):
    """k=3 links that land on the batched exact grid with a *large* angle
    count — the regime where the ``(B, A)`` result round-trip dominated the
    PR-2 batched path.

    Each link carries one slow job (800 ms) and two fast ones (100 ms): at
    0.5° precision the unified circle has A = 720 angles (kernel-eligible)
    while the fast jobs wrap r = 8 times, so their admissible shift grids
    are 90 steps each — 8100 combinations, inside ``EXACT_GRID_LIMIT``, 90
    base-demand rows per link.  Half the links are lightly loaded (a
    zero-excess interleaving exists, so the fused kernel's early exit
    fires); half stay contended end to end.
    """
    from repro.core.circle import CommPattern, Phase

    out = []
    for i in range(num_links):
        light = i % 2 == 0
        scale = 0.55 if light else 1.0
        pats = [
            CommPattern(800.0, (Phase(60.0 + 35.0 * i, 260.0, 38.0 * scale),),
                        name=f"g{i}slow"),
            CommPattern(100.0, (Phase(12.0 + 3.0 * i, 34.0, 30.0 * scale),),
                        name=f"g{i}fast0"),
            CommPattern(100.0, (Phase(55.0 + 2.0 * i, 28.0, 34.0 * scale),),
                        name=f"g{i}fast1"),
        ]
        out.append((pats, capacity))
    return out


def mixed_angle_problems(wraps=(7, 11, 13, 17, 19, 23), links_per=4,
                         capacity=50.0):
    """k=2 link problems whose unified circles land on *different* angle
    counts — the heterogeneous-fabric regime the ragged launch targets.

    Each group pairs a slow job (period ``100·w`` ms) with a fast one
    (100 ms): at 0.5° precision the base 720-angle circle is rounded up to
    a multiple of ``lcm(wraps) = w``, so ``w ∈ {7, 11, 13, 17, 19, 23}``
    yields six distinct angle counts (721, 726, 728, 731, 722, 736 — all
    kernel-eligible).  The per-angle-count launch path pays one dispatch
    (and one under-filled 32-row block, scanned to its own shift bound)
    per group; the ragged path packs every row into ONE launch whose
    blocks share the scan.  Demands are kept contended so the zero-excess
    early exit does not shortcut either path.
    """
    from repro.core.circle import CommPattern, Phase

    out = []
    for wi, w in enumerate(wraps):
        for i in range(links_per):
            slow = CommPattern(
                100.0 * w,
                (Phase((5.0 + 9.0 * wi + 3.0 * i) * w, 38.0 * w, 44.0),),
                name=f"m{w}s{i}",
            )
            fast = CommPattern(
                100.0, (Phase(11.0 + 5.0 * i + 2.0 * wi, 41.0, 39.0),),
                name=f"m{w}f{i}",
            )
            out.append(([slow, fast], capacity))
    return out


def fluid_advance_case(racks, tenants=2):
    """A contended fluid-sim state from the ``rack-scaling-{racks}``
    scenario: ``tenants`` copies of its trace population in the shared
    :func:`repro.cluster.contended_snapshot` wrap-around pile-up — the
    allocator-bound multi-tenant regime the vectorized engine and the
    incremental re-solver target (the bench window never drains it)."""
    from repro.cluster import contended_snapshot
    from repro.engine.scenarios import get_scenario

    spec = get_scenario(f"rack-scaling-{racks}")
    topo = spec.topology()
    jobs = contended_snapshot(topo, lambda: spec.trace(topo), tenants=tenants)
    return topo, jobs


def sched_epoch_state(scenario_name="hetero-16rack", max_jobs=10):
    """A mid-simulation ``ClusterState`` for end-to-end epoch benches:
    the scenario's first ``max_jobs`` trace jobs, treated as running."""
    from repro.engine.scenarios import get_scenario
    from repro.sched.base import ClusterState

    spec = get_scenario(scenario_name)
    topo = spec.topology()
    jobs = spec.trace(topo)[:max_jobs]
    return ClusterState(topology=topo, now_ms=0.0, running=jobs, pending=[])
