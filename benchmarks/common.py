"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import statistics
import time
from typing import Callable

from repro.cluster import ClusterSimulator
from repro.engine.scenarios import default_scheduler_factories

# the paper's scheduler line-up, shared with the scenario registry
SCHEDULERS: dict[str, Callable] = default_scheduler_factories()


def pct(xs, q):
    if not xs:
        return float("nan")
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(q / 100.0 * len(ys)))]


def run_trace(topo, jobs, sched, *, epoch_ms=300_000.0, jitter=0.005,
              horizon_ms=7_200_000.0, seed=0):
    sim = ClusterSimulator(topo, sched, epoch_ms=epoch_ms,
                           compute_jitter=jitter, seed=seed)
    t0 = time.time()
    metrics = sim.run(jobs, horizon_ms=horizon_ms)
    return metrics, time.time() - t0, sim


def timed(fn, *args, repeat=3, **kw):
    ts = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    return out, statistics.median(ts)


def scoring_problems(num_links=24, jobs_per_link=2, capacity=50.0):
    """Synthetic k-job link problems for the batched-scoring benches.

    Every link carries ``jobs_per_link`` staggered single-phase jobs on a
    shared iteration time; at the default 5° precision a 3-job link lands
    on the batched exact product grid (the Algorithm-2 hot path for the
    paper's multi-tenant snapshots), and finer grids push the same
    problems onto the batched coordinate descent.
    """
    from repro.core.circle import CommPattern, Phase

    out = []
    for i in range(num_links):
        it = 300.0 + 10.0 * (i % 7)
        pats = []
        for k in range(jobs_per_link):
            start = (0.12 + 0.3 * k) % 1.0 * it
            dur = max(0.12, 0.42 - 0.06 * k) * it
            pats.append(
                CommPattern(it, (Phase(start, dur, 45.0 - 4.0 * k),),
                            name=f"l{i}j{k}")
            )
        out.append((pats, capacity))
    return out
