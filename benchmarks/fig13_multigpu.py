"""Paper Fig. 13: multi-GPU-per-server topology (6 servers × 2 GPUs).
Jobs larger than one server still cross the network; CASSINI's placement
choice + time-shifts beat network-oblivious Themis.

Driven by the ``multigpu`` entry of the scenario registry."""

from __future__ import annotations

from repro.engine import get_scenario


def run() -> list[dict]:
    scenario = get_scenario("multigpu")
    rows = {}
    out = []
    for name in ("themis", "th+cassini"):
        r = scenario.run(name)
        m = r.metrics
        rows[name] = dict(sl_avg=m.avg_slowdown, sl_p99=m.pct_slowdown(99),
                          ecn=m.ecn_per_iter())
        d = rows[name]
        out.append({
            "name": f"fig13/{name}", "us_per_call": r.wall_s * 1e6,
            "derived": (f"slowdown avg={d['sl_avg']:.3f} p99={d['sl_p99']:.2f} "
                        f"ecn={d['ecn']:.0f}"),
        })
    a, b = rows["themis"], rows["th+cassini"]
    out.append({
        "name": "fig13/speedup", "us_per_call": 0.0,
        "derived": (
            f"slowdown avg {a['sl_avg']/b['sl_avg']:.2f}x "
            f"p99 {a['sl_p99']/b['sl_p99']:.2f}x ecn "
            f"{a['ecn']/max(b['ecn'],1e-9):.1f}x (paper: 1.4x/1.9x)"
        ),
    })
    return out
