"""Paper Fig. 13: multi-GPU-per-server topology (6 servers × 2 GPUs).
Jobs larger than one server still cross the network; CASSINI's placement
choice + time-shifts beat network-oblivious Themis."""

from __future__ import annotations

from repro.cluster import Topology, dynamic_trace

from .common import SCHEDULERS, pct, run_trace


def run() -> list[dict]:
    # 3 racks × 2 servers × 2 GPUs = 12 GPUs (the paper rewires to 6×2)
    topo = Topology(num_racks=3, servers_per_rack=2, gpus_per_server=2)
    rows = {}
    out = []
    for name in ("themis", "th+cassini"):
        jobs = dynamic_trace(
            topo,
            base_models=("xlm", "resnet50"),
            burst_models=("dlrm",),
            burst_at_ms=60_000.0,
            workers=5,
            iters=300,
        )
        for j in jobs:
            if j.job_id.startswith("burst"):
                j.num_workers = 4
        m, wall, _ = run_trace(topo, jobs, SCHEDULERS[name]())
        its = m.iter_times()
        rows[name] = dict(sl_avg=m.avg_slowdown, sl_p99=m.pct_slowdown(99),
                          ecn=m.ecn_per_iter())
        r = rows[name]
        out.append({
            "name": f"fig13/{name}", "us_per_call": wall * 1e6,
            "derived": (f"slowdown avg={r['sl_avg']:.3f} p99={r['sl_p99']:.2f} "
                        f"ecn={r['ecn']:.0f}"),
        })
    a, b = rows["themis"], rows["th+cassini"]
    out.append({
        "name": "fig13/speedup", "us_per_call": 0.0,
        "derived": (
            f"slowdown avg {a['sl_avg']/b['sl_avg']:.2f}x "
            f"p99 {a['sl_p99']/b['sl_p99']:.2f}x ecn "
            f"{a['ecn']/max(b['ecn'],1e-9):.1f}x (paper: 1.4x/1.9x)"
        ),
    })
    return out
