"""Jitter-robustness curves: how much CASSINI benefit survives phase noise.

    PYTHONPATH=src python -m benchmarks.robustness_curves \
        [--magnitudes 0,2,5,10,20,40] [--iters 400] [--events 64] \
        [--out benchmarks/artifacts/robustness_curves.png]

The paper's time-shifts are only as good as the cluster's ability to hold
them: §5.7's drift agent absorbs *small* slips, but a fabric with real
phase noise erodes the aligned interleaving.  This driver measures that
erosion on the cleanest CASSINI win in the repo — the Fig. 2 interleave
(two VGG19 jobs pinned across one rack uplink, ~1.3-1.4× from alignment
alone; placement is fixed so the curve isolates alignment benefit from
placement luck) — by replaying a seeded ``FaultSchedule.jitter`` stream
(repro.chaos) of increasing magnitude against both the unaligned (Themis
stand-in: same fixed placement, no time-shifts) and CASSINI runs.

Per magnitude m the sweep reports the aligned speedup and the
*retained-benefit fraction*

    retained(m) = (speedup(m) - 1) / (speedup(0) - 1)

i.e. how much of the zero-jitter benefit is left once iteration phases
slip by gauss(0, m) ms.  Both runs at one magnitude replay the *same*
schedule, so the curve is deterministic end to end.  The PNG and a JSON
sidecar land under ``benchmarks/artifacts/`` (gitignored; the nightly CI
robustness job uploads the directory as an artifact).
"""

from __future__ import annotations

import argparse
import json
import os

DEFAULT_OUT = os.path.join(
    os.path.dirname(__file__), "artifacts", "robustness_curves.png"
)
DEFAULT_MAGNITUDES = "0,2,5,10,20,40"
DEFAULT_ITERS = 400
DEFAULT_EVENTS = 64
# jitter window: covers the bulk of both runs' ~110-150s makespan
JITTER_WINDOW_MS = 100_000.0
HORIZON_MS = 3_600_000.0
_PLACEMENTS = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}

# chart tokens (validated reference palette — shared with scaling_curves)
SERIES_HUES = (
    "#2a78d6",  # blue
    "#eb6834",  # orange
    "#1baf7a",  # aqua
    "#eda100",  # yellow
)
SURFACE = "#fcfcfb"
INK = "#0b0b0b"
INK_SECONDARY = "#52514e"
MUTED = "#898781"
GRIDLINE = "#e1e0d9"
AXISLINE = "#c3c2b7"


def _run_one(magnitude_ms: float, iters: int, events: int,
             with_cassini: bool, seed: int):
    from repro.chaos.schedule import FaultSchedule
    from repro.cluster import ClusterSimulator, Topology, snapshot_trace
    from repro.sched import CassiniAugmented
    from repro.sched.fixed import FixedPlacementScheduler

    topo = Topology.paper_testbed()
    jobs = snapshot_trace(
        [("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=iters
    )
    schedule = FaultSchedule.jitter(
        jobs, seed=seed, horizon_ms=JITTER_WINDOW_MS,
        magnitude_ms=magnitude_ms, events=events,
    )
    sched = FixedPlacementScheduler(_PLACEMENTS)
    if with_cassini:
        sched = CassiniAugmented(sched, num_candidates=1)
    sim = ClusterSimulator(topo, sched, fault_schedule=schedule)
    return sim.run(jobs, horizon_ms=HORIZON_MS)


def sweep(magnitudes: list[float], iters: int, events: int,
          seed: int = 11) -> list[dict]:
    """One point per jitter magnitude: iteration times for both schedulers,
    aligned speedup, and the retained-benefit fraction vs magnitude 0."""
    points: list[dict] = []
    print("magnitude_ms,themis_iter_ms,cassini_iter_ms,speedup,retained")
    base_gain: float | None = None
    for m in magnitudes:
        themis = _run_one(m, iters, events, with_cassini=False, seed=seed)
        cassini = _run_one(m, iters, events, with_cassini=True, seed=seed)
        speedup = themis.avg_iter_ms / cassini.avg_iter_ms
        if base_gain is None:
            base_gain = max(speedup - 1.0, 1e-9)
        retained = (speedup - 1.0) / base_gain
        point = {
            "magnitude_ms": m,
            "themis_iter_ms": themis.avg_iter_ms,
            "cassini_iter_ms": cassini.avg_iter_ms,
            "themis_ecn_per_iter": themis.ecn_per_iter(),
            "cassini_ecn_per_iter": cassini.ecn_per_iter(),
            "speedup": speedup,
            "retained": retained,
        }
        points.append(point)
        print(
            f"{m:g},{point['themis_iter_ms']:.2f},"
            f"{point['cassini_iter_ms']:.2f},{speedup:.3f},{retained:.3f}",
            flush=True,
        )
    return points


def _style_axis(ax) -> None:
    ax.set_facecolor(SURFACE)
    for side in ("top", "right"):
        ax.spines[side].set_visible(False)
    for side in ("bottom", "left"):
        ax.spines[side].set_color(AXISLINE)
        ax.spines[side].set_linewidth(0.8)
    ax.grid(axis="y", color=GRIDLINE, linewidth=0.8)
    ax.set_axisbelow(True)
    ax.tick_params(colors=MUTED, labelcolor=INK_SECONDARY, labelsize=9)


def render(points: list[dict], out_png: str) -> None:
    """Two stacked panels over a shared magnitude axis: iteration time per
    scheduler, then the retained-benefit fraction."""
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, (ax_iter, ax_ret) = plt.subplots(
        2, 1, sharex=True, figsize=(7.0, 6.4), dpi=150
    )
    fig.patch.set_facecolor(SURFACE)
    xs = [p["magnitude_ms"] for p in points]
    series = (
        ("themis", [p["themis_iter_ms"] for p in points]),
        ("th+cassini", [p["cassini_iter_ms"] for p in points]),
    )
    for idx, (name, ys) in enumerate(series):
        hue = SERIES_HUES[idx % len(SERIES_HUES)]
        ax_iter.plot(xs, ys, color=hue, linewidth=2, marker="o",
                     markersize=6, markeredgecolor=SURFACE,
                     markeredgewidth=1.0, label=name)
        # direct label at the line end (identity never rests on color alone)
        ax_iter.annotate(
            name, (xs[-1], ys[-1]), xytext=(8, 0),
            textcoords="offset pixels", va="center", fontsize=9,
            color=INK_SECONDARY,
        )
    ax_ret.plot(
        xs, [p["retained"] for p in points], color=SERIES_HUES[2],
        linewidth=2, marker="o", markersize=6, markeredgecolor=SURFACE,
        markeredgewidth=1.0,
    )
    ax_ret.axhline(1.0, color=GRIDLINE, linewidth=1.2, linestyle="--")
    ax_iter.set_ylabel("avg iteration (ms)", color=INK_SECONDARY,
                       fontsize=10)
    ax_ret.set_ylabel("retained benefit fraction", color=INK_SECONDARY,
                      fontsize=10)
    ax_ret.set_xlabel("phase-jitter magnitude (ms, gauss σ)",
                      color=INK_SECONDARY, fontsize=10)
    ax_ret.set_xticks(xs)
    for ax in (ax_iter, ax_ret):
        _style_axis(ax)
        span = (xs[-1] - xs[0]) or 1.0
        ax.set_xlim(xs[0] - 0.04 * span, xs[-1] + 0.18 * span)
    ax_iter.set_ylim(bottom=0.0)
    ax_ret.set_ylim(bottom=min(0.0, min(p["retained"] for p in points)))
    ax_iter.set_title(
        "Jitter robustness: CASSINI interleaving under phase noise\n"
        "Fig. 2 workload (2×VGG19, shared uplink), seeded PhaseJitter "
        "replay",
        color=INK, fontsize=11, loc="left", pad=12,
    )
    ax_iter.legend(
        frameon=False, fontsize=9, labelcolor=INK_SECONDARY,
        loc="lower right",
    )
    fig.tight_layout()
    os.makedirs(os.path.dirname(out_png) or ".", exist_ok=True)
    fig.savefig(out_png, facecolor=SURFACE)
    plt.close(fig)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--magnitudes", default=DEFAULT_MAGNITUDES,
                    help="comma-separated jitter sigmas in ms "
                         f"(default {DEFAULT_MAGNITUDES}; 0 must come "
                         "first — it anchors the retained fraction)")
    ap.add_argument("--iters", type=int, default=DEFAULT_ITERS,
                    help=f"iterations per job (default {DEFAULT_ITERS})")
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS,
                    help="jitter events per schedule "
                         f"(default {DEFAULT_EVENTS})")
    ap.add_argument("--seed", type=int, default=11,
                    help="fault-schedule seed (default 11)")
    ap.add_argument("--out", default=DEFAULT_OUT, metavar="PNG",
                    help="output figure path (a .json sidecar with the "
                         "measured points is written next to it)")
    args = ap.parse_args()

    magnitudes = [float(s) for s in args.magnitudes.split(",") if s]
    points = sweep(magnitudes, args.iters, args.events, seed=args.seed)
    render(points, args.out)
    sidecar = os.path.splitext(args.out)[0] + ".json"
    with open(sidecar, "w") as f:
        json.dump(
            {"magnitudes_ms": magnitudes, "iters": args.iters,
             "events": args.events, "seed": args.seed, "points": points},
            f, indent=2,
        )
        f.write("\n")
    print(f"# wrote {args.out} and {sidecar}")


if __name__ == "__main__":
    main()
