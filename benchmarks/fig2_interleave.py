"""Paper Fig. 2: two VGG19 jobs sharing one uplink — fair-share DCQCN vs a
CASSINI time-shift.  Reports mean and p90 iteration time and ECN marks.

Driven by the ``fig2-interleave`` entry of the scenario registry."""

from __future__ import annotations

import statistics

from repro.engine import get_scenario

from .common import pct


def run() -> list[dict]:
    scenario = get_scenario("fig2-interleave")
    rows = []
    results = {}
    for label, sched in [("scenario1-fair-share", "fair-share"),
                         ("scenario2-cassini", "cassini")]:
        r = scenario.run(sched)
        its = r.metrics.iter_times("vgg19")
        results[label] = dict(
            mean=statistics.mean(its), p90=pct(its, 90),
            ecn=r.metrics.ecn_per_iter(),
        )
        shifts = {j.job_id: round(j.time_shift_ms, 1) for j in r.metrics.jobs}
        rows.append({"name": f"fig2/{label}", "us_per_call": r.wall_s * 1e6,
                     "derived": f"mean={results[label]['mean']:.0f}ms "
                                f"p90={results[label]['p90']:.0f}ms "
                                f"ecn={results[label]['ecn']:.0f} shifts={shifts}"})
    s1, s2 = results["scenario1-fair-share"], results["scenario2-cassini"]
    rows.append({
        "name": "fig2/speedup",
        "us_per_call": 0.0,
        "derived": (
            f"p90 {s1['p90']/s2['p90']:.2f}x (paper: 1.26x) "
            f"mean {s1['mean']/s2['mean']:.2f}x "
            f"ecn {s1['ecn']/max(s2['ecn'],1e-9):.0f}x"
        ),
    })
    return rows
