"""Paper Fig. 2: two VGG19 jobs sharing one uplink — fair-share DCQCN vs a
CASSINI time-shift.  Reports mean and p90 iteration time and ECN marks."""

from __future__ import annotations

import statistics

from repro.cluster import Topology, snapshot_trace
from repro.sched import CassiniAugmented
from repro.sched.fixed import FixedPlacementScheduler

from .common import pct, run_trace


def run() -> list[dict]:
    topo = Topology.paper_testbed()
    pl = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}
    rows = []
    results = {}
    for name, cass in [("scenario1-fair-share", False), ("scenario2-cassini", True)]:
        jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=500)
        sched = FixedPlacementScheduler(pl)
        if cass:
            sched = CassiniAugmented(sched, num_candidates=1)
        m, wall, sim = run_trace(topo, jobs, sched, jitter=0.0)
        its = m.iter_times("vgg19")
        results[name] = dict(
            mean=statistics.mean(its), p90=pct(its, 90), ecn=m.ecn_per_iter()
        )
        shifts = {j.job_id: round(j.time_shift_ms, 1) for j in m.jobs}
        rows.append({"name": f"fig2/{name}", "us_per_call": wall * 1e6,
                     "derived": f"mean={results[name]['mean']:.0f}ms "
                                f"p90={results[name]['p90']:.0f}ms "
                                f"ecn={results[name]['ecn']:.0f} shifts={shifts}"})
    s1, s2 = results["scenario1-fair-share"], results["scenario2-cassini"]
    rows.append({
        "name": "fig2/speedup",
        "us_per_call": 0.0,
        "derived": (
            f"p90 {s1['p90']/s2['p90']:.2f}x (paper: 1.26x) "
            f"mean {s1['mean']/s2['mean']:.2f}x ecn {s1['ecn']/max(s2['ecn'],1e-9):.0f}x"
        ),
    })
    return rows
