"""Beyond-paper ablations: which CASSINI ingredient buys what.

Scenario: the Fig. 2 forced-sharing pair, toggling one mechanism at a time:
  full          — placement choice + time-shifts + pacing agent (ours)
  no-pacing     — time-shifts applied once, agents disarmed
  1-candidate   — no placement choice (time-shifts only)
  coarse-30deg  — 30-degree angle grid instead of 5
"""

from __future__ import annotations

import statistics

from repro.cluster import ClusterSimulator, Topology, snapshot_trace
from repro.sched import CassiniAugmented
from repro.sched.fixed import FixedPlacementScheduler


def _run(topo, pl, *, pace_threshold=0.9, precision=5.0, jitter=0.003):
    jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=250)
    sched = CassiniAugmented(
        FixedPlacementScheduler(pl), num_candidates=1,
        precision_deg=precision, pace_threshold=pace_threshold,
    )
    sim = ClusterSimulator(topo, sched, compute_jitter=jitter)
    m = sim.run(jobs, horizon_ms=3_600_000)
    its = m.iter_times("vgg19")
    return statistics.mean(its), m.ecn_per_iter()


def run() -> list[dict]:
    topo = Topology.paper_testbed()
    pl = {"snap0-vgg19": (0, 6), "snap1-vgg19": (1, 7)}

    # baseline: no CASSINI at all
    jobs = snapshot_trace([("vgg19", 2, 1400), ("vgg19", 2, 1400)], iters=250)
    sim = ClusterSimulator(topo, FixedPlacementScheduler(pl), compute_jitter=0.003)
    m = sim.run(jobs, horizon_ms=3_600_000)
    base = statistics.mean(m.iter_times("vgg19"))

    rows = [{"name": "ablate/themis-baseline", "us_per_call": 0.0,
             "derived": f"mean={base:.0f}ms ecn={m.ecn_per_iter():.0f}"}]
    for name, kw in [
        ("full", {}),
        ("no-pacing", {"pace_threshold": 1.1}),   # threshold unreachable
        ("coarse-30deg", {"precision": 30.0}),
    ]:
        mean, ecn = _run(topo, pl, **kw)
        rows.append({
            "name": f"ablate/{name}", "us_per_call": 0.0,
            "derived": f"mean={mean:.0f}ms ecn={ecn:.0f} speedup={base/mean:.2f}x",
        })
    return rows
