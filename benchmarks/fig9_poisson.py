"""Paper Fig. 8/9: Poisson-arrival trace (load ≈ 0.9–1.0), Themis vs
Th+CASSINI vs Pollux vs Po+CASSINI vs Random vs Ideal.  Reports avg / p99
iteration times over all jobs (the paper's CDF summarized)."""

from __future__ import annotations

from repro.cluster import Topology, ideal_metrics, poisson_trace

from .common import SCHEDULERS, run_trace


def _jobs(topo, seed):
    return poisson_trace(
        topo, load=0.95, num_jobs=16, seed=seed, min_iters=150, max_iters=400,
        models=["vgg16", "vgg19", "wideresnet101", "resnet50", "bert",
                "roberta", "xlm", "gpt1", "gpt2", "gpt3", "dlrm"],
    )


def run(seed: int = 7) -> list[dict]:
    topo = Topology.paper_testbed()
    rows = []
    base = {}
    for name in ("themis", "th+cassini", "pollux", "po+cassini", "random"):
        jobs = _jobs(topo, seed)
        m, wall, _ = run_trace(topo, jobs, SCHEDULERS[name]())
        s = m.summary()
        base[name] = s
        rows.append({
            "name": f"fig9/{name}",
            "us_per_call": wall * 1e6,
            "derived": (
                f"avg_iter={s['avg_iter_ms']:.0f}ms p99={s['p99_iter_ms']:.0f}ms "
                f"slowdown avg={s['avg_slowdown']:.2f} p99={s['p99_slowdown']:.2f} "
                f"avg_jct={s['avg_jct_ms']/1000:.1f}s ecn={s['ecn_per_iter']:.0f}"
            ),
        })
    mi = ideal_metrics(topo, _jobs(topo, seed))
    rows.append({
        "name": "fig9/ideal", "us_per_call": 0.0,
        "derived": f"avg_iter={mi.avg_iter_ms:.0f}ms p99={mi.pct_iter_ms(99):.0f}ms",
    })
    for a, b in (("themis", "th+cassini"), ("pollux", "po+cassini")):
        rows.append({
            "name": f"fig9/{b}-vs-{a}", "us_per_call": 0.0,
            "derived": (
                f"avg {base[a]['avg_iter_ms']/base[b]['avg_iter_ms']:.2f}x "
                f"p99 {base[a]['p99_iter_ms']/base[b]['p99_iter_ms']:.2f}x "
                f"slowdown avg {base[a]['avg_slowdown']/base[b]['avg_slowdown']:.2f}x "
                f"p99 {base[a]['p99_slowdown']/base[b]['p99_slowdown']:.2f}x "
                f"(paper: 1.4x/1.5x)"
            ),
        })
    return rows
