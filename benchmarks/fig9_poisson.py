"""Paper Fig. 8/9: Poisson-arrival trace (load ≈ 0.9–1.0), Themis vs
Th+CASSINI vs Pollux vs Po+CASSINI vs Random vs Ideal.  Reports avg / p99
iteration times over all jobs (the paper's CDF summarized).

Driven by the ``poisson-paper`` entry of the scenario registry."""

from __future__ import annotations

from repro.engine import get_scenario


def run() -> list[dict]:
    scenario = get_scenario("poisson-paper")
    rows = []
    base = {}
    for name in scenario.scheduler_names():
        r = scenario.run(name)
        s = r.metrics.summary()
        base[name] = s
        rows.append({
            "name": f"fig9/{name}",
            "us_per_call": r.wall_s * 1e6,
            "derived": (
                f"avg_iter={s['avg_iter_ms']:.0f}ms p99={s['p99_iter_ms']:.0f}ms "
                f"slowdown avg={s['avg_slowdown']:.2f} p99={s['p99_slowdown']:.2f} "
                f"avg_jct={s['avg_jct_ms']/1000:.1f}s ecn={s['ecn_per_iter']:.0f}"
            ),
        })
    mi = scenario.ideal()
    rows.append({
        "name": "fig9/ideal", "us_per_call": 0.0,
        "derived": f"avg_iter={mi.avg_iter_ms:.0f}ms p99={mi.pct_iter_ms(99):.0f}ms",
    })
    for a, b in (("themis", "th+cassini"), ("pollux", "po+cassini")):
        rows.append({
            "name": f"fig9/{b}-vs-{a}", "us_per_call": 0.0,
            "derived": (
                f"avg {base[a]['avg_iter_ms']/base[b]['avg_iter_ms']:.2f}x "
                f"p99 {base[a]['p99_iter_ms']/base[b]['p99_iter_ms']:.2f}x "
                f"slowdown avg {base[a]['avg_slowdown']/base[b]['avg_slowdown']:.2f}x "
                f"p99 {base[a]['p99_slowdown']/base[b]['p99_slowdown']:.2f}x "
                f"(paper: 1.4x/1.5x)"
            ),
        })
    return rows
