"""Paper Table 2: five cluster snapshots — compatibility score, time-shifts
and measured iteration times under Themis vs Th+CASSINI — plus the
registry-driven multi-tenant sweep (``multitenant-{2,4,8}`` scenarios:
Table-2-style concurrent tenants on the hetero-16rack fabric)."""

from __future__ import annotations

import statistics

from repro.cluster import Topology, snapshot_trace
from repro.core import find_rotations
from repro.engine.scenarios import MULTITENANT_SWEEP, get_scenario
from repro.profiles import get_profile
from repro.sched import CassiniAugmented
from repro.sched.fixed import FixedPlacementScheduler

from .common import run_trace

# (models+batches, forced placement) — every job pair shares the r0↔r1 uplink
SNAPSHOTS = [
    ("snap1", [("wideresnet101", 800), ("vgg16", 1400)]),
    ("snap2", [("vgg19", 1400), ("vgg16", 1700), ("resnet50", 1600)]),
    ("snap3", [("vgg19", 1024), ("vgg16", 1200)]),
    ("snap4", [("roberta", 12), ("roberta", 12)]),
    ("snap5", [("bert", 8), ("vgg19", 1400), ("wideresnet101", 800)]),
]


def run() -> list[dict]:
    topo = Topology.paper_testbed()
    rows = []
    for snap_id, spec in SNAPSHOTS:
        pats = [get_profile(m).pattern(2, b) for m, b in spec]
        opt = find_rotations(pats, 50.0)

        # forced fragmented placement: job i on servers (i, 6+i) spanning r0-r1
        placements = {}
        specs = [(m, 2, b) for m, b in spec]
        jobs_tmpl = snapshot_trace(specs, iters=250)
        for i, j in enumerate(jobs_tmpl):
            placements[j.job_id] = (i, 6 + i)

        result = {}
        for name, cass in (("themis", False), ("th+cassini", True)):
            jobs = snapshot_trace(specs, iters=250)
            sched = FixedPlacementScheduler(placements)
            if cass:
                sched = CassiniAugmented(sched, num_candidates=1)
            m, _, _ = run_trace(topo, jobs, sched, jitter=0.0)
            result[name] = {
                j.model: statistics.mean(j.iter_times_ms) for j in m.jobs
            }
        per_model = " ".join(
            f"{mname}:{result['th+cassini'].get(mname, float('nan')):.0f}/"
            f"{result['themis'].get(mname, float('nan')):.0f}ms"
            for mname, _ in spec
        )
        rows.append({
            "name": f"table2/{snap_id}",
            "us_per_call": 0.0,
            "derived": (
                f"score={opt.score:.2f} "
                f"shifts={tuple(round(s) for s in opt.shifts_ms)} "
                f"iter(cassini/themis): {per_model}"
            ),
        })
    rows.extend(multitenant_sweep())
    return rows


def multitenant_sweep() -> list[dict]:
    """Registry-driven sweep: 2/4/8 concurrent tenants on hetero-16rack,
    avg JCT under Themis vs Th+CASSINI (scenario-diversity ROADMAP item)."""
    rows = []
    for n in MULTITENANT_SWEEP:
        spec = get_scenario(f"multitenant-{n}")
        jct = {}
        for sched_name in spec.scheduler_names():
            run = spec.run(sched_name)
            jct[sched_name] = run.metrics.avg_jct_ms / 1e3
        rows.append({
            "name": f"table2/multitenant-{n}",
            "us_per_call": 0.0,
            "derived": (
                f"{n} tenants on hetero-16rack; avg JCT "
                + " ".join(f"{k}={v:.0f}s" for k, v in jct.items())
            ),
        })
    return rows
