"""Roofline analysis from the multi-pod dry-run cache (deliverable g).

For every (arch × shape) cell on the single-pod mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs           [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / ICI link_bw   [s]

(The dry-run parses the *post-SPMD* per-device HLO, so FLOPs/bytes are
already per chip; the assignment's "÷ chips" of global quantities is the
same number.)  We also report MODEL_FLOPS = 6·N(_active)·D (train) or
2·N·D (prefill/decode) and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs · chips), which catches remat/redundancy waste.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax

from repro.configs import get_config, list_archs
from repro.models.api import SHAPES, build_model

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

CACHE = Path(__file__).resolve().parent / "dryrun_cache"

_PARAM_CACHE: dict[str, tuple[int, int]] = {}


def param_counts(arch: str) -> tuple[int, int]:
    """(total params, active params per token) — active discounts inactive
    experts for MoE archs."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = "/".join(str(k) for k in path)
        if cfg.is_moe and ("w_gate" in keys or "w_up" in keys or "w_down" in keys) \
                and len(leaf.shape) >= 3 and leaf.shape[-3] == cfg.num_experts:
            active += n * cfg.top_k // cfg.num_experts
        else:
            active += n
    _PARAM_CACHE[arch] = (total, active)
    return total, active


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    total, active = param_counts(arch)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * active * tokens


def cell_terms(rec: dict, arch: str, shape_name: str) -> dict:
    devices = rec.get("devices", 256)
    flops_dev = rec.get("flops", 0.0) or 0.0
    bytes_dev = rec.get("bytes_accessed", 0.0) or 0.0
    coll_dev = rec.get("collectives", {}).get("bytes", {}).get("total", 0.0)
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape_name)
    useful = mf / (flops_dev * devices) if flops_dev > 0 else float("nan")
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful-model-compute time over the bound term
    t_model = mf / devices / PEAK_FLOPS
    frac = t_model / bound if bound > 0 else float("nan")
    return dict(
        devices=devices, t_comp=t_comp, t_mem=t_mem, t_coll=t_coll,
        dominant=dom, model_flops=mf, useful_ratio=useful,
        roofline_frac=frac,
        peak_gib=rec.get("memory", {}).get("peak_memory_in_bytes", 0) / 2**30,
    )


def load_cell(arch: str, shape_name: str, mesh: str = "single") -> dict | None:
    f = CACHE / f"{arch}__{shape_name}__{mesh}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def run() -> list[dict]:
    rows = []
    for arch in list_archs():
        for shape_name in SHAPES:
            rec = load_cell(arch, shape_name)
            if rec is None:
                continue
            if rec["status"] == "skip":
                rows.append({
                    "name": f"roofline/{arch}/{shape_name}",
                    "us_per_call": 0.0,
                    "derived": f"SKIP ({rec['reason'][:60]}…)",
                })
                continue
            if rec["status"] != "ok":
                rows.append({
                    "name": f"roofline/{arch}/{shape_name}",
                    "us_per_call": 0.0,
                    "derived": f"ERROR {rec.get('error','?')[:80]}",
                })
                continue
            t = cell_terms(rec, arch, shape_name)
            rows.append({
                "name": f"roofline/{arch}/{shape_name}",
                "us_per_call": max(t["t_comp"], t["t_mem"], t["t_coll"]) * 1e6,
                "derived": (
                    f"comp={t['t_comp']*1e3:.2f}ms mem={t['t_mem']*1e3:.2f}ms "
                    f"coll={t['t_coll']*1e3:.2f}ms dom={t['dominant']} "
                    f"useful={t['useful_ratio']:.2f} "
                    f"roofline={t['roofline_frac']*100:.0f}% "
                    f"peak/dev={t['peak_gib']:.2f}GiB"
                ),
            })
    return rows


def table(mesh: str = "single") -> str:
    """Markdown roofline table for EXPERIMENTS.md."""
    lines = [
        "| arch | shape | devs | compute s | memory s | collective s | "
        "dominant | MODEL_FLOPs | useful | roofline | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_archs():
        for shape_name in SHAPES:
            rec = load_cell(arch, shape_name, mesh)
            if rec is None:
                continue
            if rec["status"] == "skip":
                lines.append(
                    f"| {arch} | {shape_name} | — | — | — | — | SKIP "
                    f"(full-attention 500k) | — | — | — | — |"
                )
                continue
            if rec["status"] != "ok":
                lines.append(f"| {arch} | {shape_name} | ERROR | | | | | | | | |")
                continue
            t = cell_terms(rec, arch, shape_name)
            lines.append(
                f"| {arch} | {shape_name} | {t['devices']} "
                f"| {t['t_comp']:.3e} | {t['t_mem']:.3e} | {t['t_coll']:.3e} "
                f"| **{t['dominant']}** | {t['model_flops']:.2e} "
                f"| {t['useful_ratio']:.2f} | {t['roofline_frac']*100:.0f}% "
                f"| {t['peak_gib']:.2f} |"
            )
    return "\n".join(lines)
