"""Paper Fig. 15: angle-discretization sweep — optimization wall time vs
time-shift accuracy (5° is the paper's sweet spot)."""

from __future__ import annotations

import time

from repro.core import find_rotations
from repro.profiles import get_profile


def run() -> list[dict]:
    pats = [get_profile("wideresnet101").pattern(4),
            get_profile("vgg16").pattern(4)]
    # reference: finest grid
    ref = find_rotations(pats, 50.0, precision_deg=1.0)
    ref_shift = ref.shifts_ms[1]
    rows = []
    for deg in (45.0, 20.0, 10.0, 5.0, 2.0, 1.0):
        t0 = time.perf_counter()
        res = find_rotations(pats, 50.0, precision_deg=deg)
        us = (time.perf_counter() - t0) * 1e6
        err = abs(res.shifts_ms[1] - ref_shift)
        err = min(err, pats[1].iter_time_ms - err)
        acc = 100.0 * max(0.0, 1.0 - err / pats[1].iter_time_ms)
        rows.append({
            "name": f"fig15/precision_{deg:g}deg",
            "us_per_call": us,
            "derived": (
                f"score={res.score:.3f} shift={res.shifts_ms[1]:.0f}ms "
                f"accuracy={acc:.1f}% (ref {ref_shift:.0f}ms)"
            ),
        })
    return rows
