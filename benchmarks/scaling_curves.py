"""Rack-count scaling curves: the JCT / ECN-vs-fabric-size artifact.

    PYTHONPATH=src python -m benchmarks.scaling_curves \
        [--schedulers themis,th+cassini] [--horizon-ms 600000] \
        [--out benchmarks/artifacts/scaling_curves.png]

Sweeps the ``rack-scaling-{16,32,64}`` scenarios (extend with ``--sizes
16,32,64,256`` — the 256/1024-rack points run on the incremental
re-solver their specs enable) with the requested schedulers and renders a
two-panel figure — average JCT and ECN marks per iteration against rack
count.  JCT and ECN are different measures on
different scales, so each gets its own panel over a shared rack-count
axis (two panels, never a second y-axis on one).  The PNG and a JSON
sidecar with the measured points land under ``benchmarks/artifacts/``
(gitignored; the CI bench job uploads the directory as an artifact next
to ``BENCH.json``).

The default horizon matches the slow-marked rack-scaling smoke tests
(600 s simulated), which keeps the full 3-point × 2-scheduler sweep
around half a minute of wall time; raise ``--horizon-ms`` for a
publication-grade run.

A psim-style **link-load heatmap** rides along (``--heatmap-racks 16``
by default, ``--heatmap-racks 0`` to skip): one extra ``th+cassini`` run
with a :class:`repro.cluster.linkload.LinkLoadRecorder` attached, whose
per-link utilization and ECN-mark timelines render as two links × time
heat panels (``link_load_heatmap.png`` + JSON sidecar with the raw
timelines, same artifact directory).
"""

from __future__ import annotations

import argparse
import json
import os

DEFAULT_OUT = os.path.join(
    os.path.dirname(__file__), "artifacts", "scaling_curves.png"
)
DEFAULT_SCHEDULERS = "themis,th+cassini"
DEFAULT_HORIZON_MS = 600_000.0

# chart tokens (validated reference palette: categorical slots in fixed
# order, hues assigned by position — a shorter scheduler list never
# repaints the survivors; ink/grid stay in text tokens, never series hues)
SERIES_HUES = (
    "#2a78d6",  # blue
    "#eb6834",  # orange
    "#1baf7a",  # aqua
    "#eda100",  # yellow
    "#e87ba4",  # magenta
    "#008300",  # green
    "#4a3aa7",  # violet
    "#e34948",  # red
)
SURFACE = "#fcfcfb"
INK = "#0b0b0b"
INK_SECONDARY = "#52514e"
MUTED = "#898781"
GRIDLINE = "#e1e0d9"
AXISLINE = "#c3c2b7"


def sweep(
    schedulers: list[str],
    horizon_ms: float,
    sizes: list[int] | None = None,
) -> dict[str, list[dict]]:
    """Run the requested rack-scaling scenarios × schedulers; returns the
    curve points (one list of dicts per scheduler, ordered by rack count).

    ``sizes`` defaults to the registered base sweep; the 256/1024-rack
    scenarios (``--sizes 16,32,64,256``) run on the incremental re-solver
    their specs enable, which is what keeps them affordable here."""
    from repro.engine.scenarios import RACK_SCALING_SWEEP, get_scenario

    if sizes is None:
        sizes = list(RACK_SCALING_SWEEP)
    results: dict[str, list[dict]] = {name: [] for name in schedulers}
    print("scenario,scheduler,avg_jct_ms,ecn_per_iter,jobs_finished,wall_s")
    for racks in sizes:
        spec = get_scenario(f"rack-scaling-{racks}")
        for name in schedulers:
            run = spec.run(name, horizon_ms=horizon_ms)
            s = run.metrics.summary()
            point = {
                "racks": racks,
                "avg_jct_ms": s["avg_jct_ms"],
                "ecn_per_iter": s["ecn_per_iter"],
                "jobs_finished": s["jobs_finished"],
                "wall_s": round(run.wall_s, 2),
            }
            results[name].append(point)
            print(
                f"rack-scaling-{racks},{name},{point['avg_jct_ms']:.0f},"
                f"{point['ecn_per_iter']:.2f},{point['jobs_finished']:.0f},"
                f"{point['wall_s']}",
                flush=True,
            )
    return results


def _style_axis(ax) -> None:
    ax.set_facecolor(SURFACE)
    for side in ("top", "right"):
        ax.spines[side].set_visible(False)
    for side in ("bottom", "left"):
        ax.spines[side].set_color(AXISLINE)
        ax.spines[side].set_linewidth(0.8)
    ax.grid(axis="y", color=GRIDLINE, linewidth=0.8)
    ax.set_axisbelow(True)
    ax.tick_params(colors=MUTED, labelcolor=INK_SECONDARY, labelsize=9)


def render(results: dict[str, list[dict]], out_png: str,
           horizon_ms: float) -> None:
    """Two stacked panels (avg JCT, ECN/iter) over a shared rack axis."""
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, (ax_jct, ax_ecn) = plt.subplots(
        2, 1, sharex=True, figsize=(7.0, 6.4), dpi=150
    )
    fig.patch.set_facecolor(SURFACE)
    racks_axis = sorted({p["racks"] for ps in results.values() for p in ps})
    line_ends: list[tuple[float, float, str]] = []  # (y_end, x_end, name)
    for idx, (name, points) in enumerate(results.items()):
        hue = SERIES_HUES[idx % len(SERIES_HUES)]
        xs = [p["racks"] for p in points]
        jct_min = [p["avg_jct_ms"] / 60_000.0 for p in points]
        ecn = [p["ecn_per_iter"] for p in points]
        for ax, ys in ((ax_jct, jct_min), (ax_ecn, ecn)):
            ax.plot(xs, ys, color=hue, linewidth=2, marker="o",
                    markersize=6, markeredgecolor=SURFACE,
                    markeredgewidth=1.0, label=name)
        line_ends.append((ecn[-1], xs[-1], name))
    ax_jct.set_ylabel("avg JCT (min)", color=INK_SECONDARY, fontsize=10)
    ax_ecn.set_ylabel("ECN marks / iteration", color=INK_SECONDARY,
                      fontsize=10)
    ax_ecn.set_xlabel("racks (4 servers each)", color=INK_SECONDARY,
                      fontsize=10)
    ax_ecn.set_xticks(racks_axis)
    for ax in (ax_jct, ax_ecn):
        _style_axis(ax)
        ax.set_ylim(bottom=0.0)
        # right headroom so the end-of-line labels stay inside the panel
        span = racks_axis[-1] - racks_axis[0]
        ax.set_xlim(racks_axis[0] - 0.04 * span,
                    racks_axis[-1] + 0.22 * span)
    # selective direct labels at the ECN line ends (identity never rests
    # on color alone — the legend covers the JCT panel).  Endpoints can
    # sit arbitrarily close, so labels are nudged apart in *pixel* space
    # (limits are final here, making transData usable for collision math).
    min_gap_px = 16.0
    placed_px = -float("inf")
    for y_end, x_end, name in sorted(line_ends):
        natural_px = ax_ecn.transData.transform((x_end, y_end))[1]
        label_px = max(natural_px, placed_px + min_gap_px)
        placed_px = label_px
        ax_ecn.annotate(
            name, (x_end, y_end), xytext=(8, label_px - natural_px),
            textcoords="offset pixels", va="center", fontsize=9,
            color=INK_SECONDARY,
        )
    ax_jct.set_title(
        "Rack-count scaling: job completion vs network congestion\n"
        f"rack-scaling-{{{','.join(str(r) for r in racks_axis)}}}, "
        f"{horizon_ms / 1000:.0f}s simulated horizon",
        color=INK, fontsize=11, loc="left", pad=12,
    )
    ax_jct.legend(
        frameon=False, fontsize=9, labelcolor=INK_SECONDARY,
        loc="lower right",
    )
    fig.tight_layout()
    os.makedirs(os.path.dirname(out_png) or ".", exist_ok=True)
    fig.savefig(out_png, facecolor=SURFACE)
    plt.close(fig)


def link_load_timeline(
    racks: int, scheduler: str, horizon_ms: float, bucket_ms: float
) -> dict:
    """One recorded ``rack-scaling-{racks}`` run; returns the dense
    timeline dict (see :meth:`LinkLoadRecorder.timeline`) plus run
    metadata."""
    from repro.cluster.linkload import LinkLoadRecorder
    from repro.engine.scenarios import get_scenario

    spec = get_scenario(f"rack-scaling-{racks}")
    built = spec.build(scheduler)
    rec = LinkLoadRecorder(bucket_ms=bucket_ms)
    built.simulator.net.attach_link_recorder(rec)
    built.simulator.run(built.jobs, horizon_ms=horizon_ms)
    tl = rec.timeline()
    tl["scenario"] = f"rack-scaling-{racks}"
    tl["scheduler"] = scheduler
    tl["recorder"] = rec
    return tl


def render_heatmap(tl: dict, out_png: str) -> None:
    """Links × time heat panels: utilization (top) and ECN-mark intensity
    (bottom), links ordered by mean utilization so the contended core of
    the fabric reads off the top rows."""
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
    import numpy as np
    from matplotlib.colors import LinearSegmentedColormap

    util = tl["utilization"]
    marks = tl["marks_per_ms"]
    t_min = tl["t_ms"] / 60_000.0
    order = np.argsort(-util.mean(axis=0), kind="stable")
    names = [tl["link_names"][i] for i in order]

    fig, (ax_u, ax_m) = plt.subplots(
        2, 1, sharex=True, figsize=(7.6, 7.2), dpi=150
    )
    fig.patch.set_facecolor(SURFACE)
    extent = (
        float(t_min[0] - 0.5 * tl["bucket_ms"] / 60_000.0),
        float(t_min[-1] + 0.5 * tl["bucket_ms"] / 60_000.0),
        util.shape[1] - 0.5, -0.5,
    )
    panels = (
        (ax_u, util, "utilization (rate / capacity)", SERIES_HUES[0], 1.0),
        (ax_m, marks, "ECN marks / ms", SERIES_HUES[1], None),
    )
    for ax, mat, label, hue, vmax in panels:
        cmap = LinearSegmentedColormap.from_list(
            f"load-{hue}", [SURFACE, hue]
        )
        im = ax.imshow(
            mat[:, order].T, aspect="auto", interpolation="nearest",
            cmap=cmap, vmin=0.0, vmax=vmax, extent=extent,
        )
        cb = fig.colorbar(im, ax=ax, pad=0.01, fraction=0.04)
        cb.outline.set_edgecolor(AXISLINE)
        cb.ax.tick_params(colors=MUTED, labelcolor=INK_SECONDARY,
                          labelsize=8)
        ax.set_ylabel(f"links (by mean util)\n{label}",
                      color=INK_SECONDARY, fontsize=9)
        ax.tick_params(colors=MUTED, labelcolor=INK_SECONDARY, labelsize=8)
        for side in ax.spines.values():
            side.set_color(AXISLINE)
    # name the hottest links so the heatmap is readable without the JSON
    # sidecar; one caption block — the hot rows are adjacent after the
    # mean-util sort, so per-row labels would overprint each other
    if names:
        hot = ", ".join(names[: min(3, len(names))])
        ax_u.text(
            0.01, -0.02, f"hottest rows: {hot}",
            transform=ax_u.transAxes, va="top", fontsize=8,
            color=INK_SECONDARY,
        )
    ax_m.set_xlabel("simulated time (min)", color=INK_SECONDARY, fontsize=10)
    ax_u.set_title(
        f"Per-link load: {tl['scenario']}, {tl['scheduler']}\n"
        "each row one fabric link; time-mean per "
        f"{tl['bucket_ms'] / 1000:.0f}s bucket",
        color=INK, fontsize=11, loc="left", pad=12,
    )
    fig.tight_layout()
    os.makedirs(os.path.dirname(out_png) or ".", exist_ok=True)
    fig.savefig(out_png, facecolor=SURFACE)
    plt.close(fig)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--schedulers", default=DEFAULT_SCHEDULERS,
                    help="comma-separated scheduler names from the "
                         "rack-scaling scenarios' line-up "
                         f"(default {DEFAULT_SCHEDULERS})")
    ap.add_argument("--horizon-ms", type=float, default=DEFAULT_HORIZON_MS,
                    help="simulated horizon per run (default 600000)")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated rack counts to sweep (default: "
                         "the registered base sweep; any registered "
                         "rack-scaling size works, e.g. 16,32,64,256)")
    ap.add_argument("--out", default=DEFAULT_OUT, metavar="PNG",
                    help="output figure path (a .json sidecar with the "
                         "measured points is written next to it)")
    ap.add_argument("--heatmap-racks", type=int, default=16,
                    help="rack count for the link-load heatmap run "
                         "(0 disables the heatmap; default 16)")
    ap.add_argument("--heatmap-bucket-ms", type=float, default=10_000.0,
                    help="time-bucket width for the link-load heatmap "
                         "(default 10000)")
    args = ap.parse_args()

    schedulers = [s for s in args.schedulers.split(",") if s]
    sizes = (
        [int(s) for s in args.sizes.split(",") if s] if args.sizes else None
    )
    results = sweep(schedulers, args.horizon_ms, sizes=sizes)
    render(results, args.out, args.horizon_ms)
    sidecar = os.path.splitext(args.out)[0] + ".json"
    with open(sidecar, "w") as f:
        json.dump(
            {"horizon_ms": args.horizon_ms, "schedulers": schedulers,
             "results": results},
            f, indent=2,
        )
        f.write("\n")
    print(f"# wrote {args.out} and {sidecar}")

    if args.heatmap_racks:
        tl = link_load_timeline(
            args.heatmap_racks, schedulers[-1], args.horizon_ms,
            args.heatmap_bucket_ms,
        )
        hm_png = os.path.join(
            os.path.dirname(args.out) or ".", "link_load_heatmap.png"
        )
        render_heatmap(tl, hm_png)
        hm_json = os.path.splitext(hm_png)[0] + ".json"
        doc = tl.pop("recorder").to_json()
        doc.update(scenario=tl["scenario"], scheduler=tl["scheduler"],
                   horizon_ms=args.horizon_ms)
        with open(hm_json, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        print(f"# wrote {hm_png} and {hm_json}")


if __name__ == "__main__":
    main()
