"""Paper Fig. 10: dynamic trace — DLRM + ResNet50 arrive into a busy
cluster (the congestion stress test).  Reports slowdowns (iter/solo) and
ECN marks per iteration (paper: 27-33x fewer marks under CASSINI)."""

from __future__ import annotations

from repro.cluster import Topology, dynamic_trace

from .common import SCHEDULERS, pct, run_trace


def _jobs(topo):
    # 3 base jobs x 7 workers fragment across racks; the burst takes the
    # scattered leftovers - the paper's "busy cluster" arrival scenario.
    jobs = dynamic_trace(
        topo,
        base_models=("vgg19", "wideresnet101", "gpt1"),
        burst_models=("dlrm", "resnet50"),
        burst_at_ms=90_000.0,
        workers=7,
        iters=350,
    )
    for j in jobs:
        if j.job_id.startswith("burst"):
            j.num_workers = 4
    return jobs


def run() -> list[dict]:
    topo = Topology.paper_testbed()
    rows = []
    res = {}
    for name in ("themis", "th+cassini", "pollux", "po+cassini"):
        jobs = _jobs(topo)
        m, wall, _ = run_trace(topo, jobs, SCHEDULERS[name]())
        sl = m.slowdowns()
        res[name] = dict(
            avg=m.avg_iter_ms, sl_avg=m.avg_slowdown, sl_p99=m.pct_slowdown(99),
            ecn=m.ecn_per_iter(),
            ecn_dlrm=m.ecn_per_iter("dlrm"),
            ecn_resnet=m.ecn_per_iter("resnet50"),
        )
        r = res[name]
        rows.append({
            "name": f"fig10/{name}", "us_per_call": wall * 1e6,
            "derived": (
                f"avg={r['avg']:.0f}ms slowdown avg={r['sl_avg']:.3f} "
                f"p99={r['sl_p99']:.2f} ecn={r['ecn']:.0f} "
                f"ecn_dlrm={r['ecn_dlrm']:.0f} ecn_resnet={r['ecn_resnet']:.0f}"
            ),
        })
    for a, b in (("themis", "th+cassini"), ("pollux", "po+cassini")):
        rows.append({
            "name": f"fig10/{b}-vs-{a}", "us_per_call": 0.0,
            "derived": (
                f"slowdown avg {res[a]['sl_avg']/res[b]['sl_avg']:.2f}x "
                f"p99 {res[a]['sl_p99']/res[b]['sl_p99']:.2f}x "
                f"ecn {res[a]['ecn']/max(res[b]['ecn'],1e-9):.1f}x "
                f"(paper: 1.5-1.6x avg / 2.2-2.5x p99 / 27-33x ecn)"
            ),
        })
    return rows
