"""Paper Fig. 10: dynamic trace — DLRM + ResNet50 arrive into a busy
cluster (the congestion stress test).  Reports slowdowns (iter/solo) and
ECN marks per iteration (paper: 27-33x fewer marks under CASSINI).

Driven by the ``dynamic-burst`` entry of the scenario registry."""

from __future__ import annotations

from repro.engine import get_scenario


def run() -> list[dict]:
    scenario = get_scenario("dynamic-burst")
    rows = []
    res = {}
    for name in ("themis", "th+cassini", "pollux", "po+cassini"):
        r = scenario.run(name)
        m = r.metrics
        res[name] = dict(
            avg=m.avg_iter_ms, sl_avg=m.avg_slowdown, sl_p99=m.pct_slowdown(99),
            ecn=m.ecn_per_iter(),
            ecn_dlrm=m.ecn_per_iter("dlrm"),
            ecn_resnet=m.ecn_per_iter("resnet50"),
        )
        d = res[name]
        rows.append({
            "name": f"fig10/{name}", "us_per_call": r.wall_s * 1e6,
            "derived": (
                f"avg={d['avg']:.0f}ms slowdown avg={d['sl_avg']:.3f} "
                f"p99={d['sl_p99']:.2f} ecn={d['ecn']:.0f} "
                f"ecn_dlrm={d['ecn_dlrm']:.0f} ecn_resnet={d['ecn_resnet']:.0f}"
            ),
        })
    for a, b in (("themis", "th+cassini"), ("pollux", "po+cassini")):
        rows.append({
            "name": f"fig10/{b}-vs-{a}", "us_per_call": 0.0,
            "derived": (
                f"slowdown avg {res[a]['sl_avg']/res[b]['sl_avg']:.2f}x "
                f"p99 {res[a]['sl_p99']/res[b]['sl_p99']:.2f}x "
                f"ecn {res[a]['ecn']/max(res[b]['ecn'],1e-9):.1f}x "
                f"(paper: 1.5-1.6x avg / 2.2-2.5x p99 / 27-33x ecn)"
            ),
        })
    return rows
