"""Paper Fig. 11: all-model-parallel trace (GPT family + DLRM).  CASSINI
must steer toward the compatible ⟨GPT-1,GPT-2⟩ / ⟨GPT-3,DLRM⟩ pairings."""

from __future__ import annotations

from repro.cluster import Topology, dynamic_trace

from .common import SCHEDULERS, pct, run_trace


def run() -> list[dict]:
    topo = Topology.paper_testbed()
    rows = []
    res = {}
    for name in ("themis", "th+cassini"):
        jobs = dynamic_trace(
            topo,
            base_models=("gpt1", "gpt2", "gpt3"),
            burst_models=("dlrm", "gpt2"),
            burst_at_ms=120_000.0,
            workers=7,
            iters=300,
        )
        for j in jobs:
            if j.job_id.startswith("burst"):
                j.num_workers = 5
        m, wall, sim = run_trace(topo, jobs, SCHEDULERS[name]())
        its = m.iter_times()
        res[name] = dict(avg=sum(its) / len(its), p99=pct(its, 99),
                         ecn=m.ecn_per_iter())
        r = res[name]
        rows.append({
            "name": f"fig11/{name}", "us_per_call": wall * 1e6,
            "derived": f"avg={r['avg']:.0f}ms p99={r['p99']:.0f}ms ecn={r['ecn']:.0f}",
        })
    a, b = res["themis"], res["th+cassini"]
    rows.append({
        "name": "fig11/speedup", "us_per_call": 0.0,
        "derived": (
            f"avg {a['avg']/b['avg']:.2f}x p99 {a['p99']/b['p99']:.2f}x "
            f"ecn {a['ecn']/max(b['ecn'],1e-9):.1f}x (paper: 1.2x/1.6x, ecn 29x)"
        ),
    })
    return rows
