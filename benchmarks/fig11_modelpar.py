"""Paper Fig. 11: all-model-parallel trace (GPT family + DLRM).  CASSINI
must steer toward the compatible ⟨GPT-1,GPT-2⟩ / ⟨GPT-3,DLRM⟩ pairings.

Driven by the ``modelpar-burst`` entry of the scenario registry."""

from __future__ import annotations

from repro.engine import get_scenario

from .common import pct


def run() -> list[dict]:
    scenario = get_scenario("modelpar-burst")
    rows = []
    res = {}
    for name in ("themis", "th+cassini"):
        r = scenario.run(name)
        its = r.metrics.iter_times()
        res[name] = dict(avg=sum(its) / len(its), p99=pct(its, 99),
                         ecn=r.metrics.ecn_per_iter())
        d = res[name]
        rows.append({
            "name": f"fig11/{name}", "us_per_call": r.wall_s * 1e6,
            "derived": f"avg={d['avg']:.0f}ms p99={d['p99']:.0f}ms ecn={d['ecn']:.0f}",
        })
    a, b = res["themis"], res["th+cassini"]
    rows.append({
        "name": "fig11/speedup", "us_per_call": 0.0,
        "derived": (
            f"avg {a['avg']/b['avg']:.2f}x p99 {a['p99']/b['p99']:.2f}x "
            f"ecn {a['ecn']/max(b['ecn'],1e-9):.1f}x (paper: 1.2x/1.6x, ecn 29x)"
        ),
    })
    return rows
