"""Benchmark harness: one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,roofline] \
        [--json [BENCH.json]]

Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
writes the rows as machine-readable JSON (name, us_per_call, speedup,
derived) — bare ``--json`` defaults to ``BENCH.json``, the artifact CI
uploads from the bench job and diffs against the committed baseline via
``benchmarks/compare.py`` (cross-PR regression gate).  A bench row's own
assertion failing after its measurement was flushed exits nonzero with a
one-line ``BENCH GATE FAILED`` reason, so the partial artifact can never
mask which gate tripped.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

ALL = [
    "fig2_interleave",
    "fig9_poisson",
    "fig10_dynamic",
    "fig11_modelpar",
    "table2_snapshots",
    "fig13_multigpu",
    "fig15_discretization",
    "ablations",
    "kernels",
    "arrival",
    "fluid_advance",
    "fluid_shard",
    "sched_epoch",
    "serve",
    "fault_replay",
    "roofline",
]


def _kernel_bench():
    """Micro-bench the three Pallas kernels (interpret mode) vs oracles.

    A generator (like every bench set here): rows reach the harness — and
    the ``--json`` artifact — as they complete, so a later assertion
    failure cannot swallow the measurements that explain it.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.circle_score.ops import circle_score
    from repro.kernels.circle_score.ref import circle_score_ref
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ssd_scan.ops import ssd_scan

    from .common import timed

    rng = np.random.default_rng(0)
    base = jnp.asarray(rng.random((16, 720)) * 60, jnp.float32)
    cand = jnp.asarray(rng.random((16, 720)) * 60, jnp.float32)
    _, us_ref = timed(lambda: circle_score_ref(base, cand, 50.0).block_until_ready())
    _, us_k = timed(lambda: circle_score(base, cand, 50.0).block_until_ready())
    yield {"name": "kernels/circle_score(16x720)", "us_per_call": us_k,
           "derived": f"jnp_ref={us_ref:.0f}us (interpret-mode kernel; "
                      f"TPU target compiles Mosaic)"}
    q = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    _, us_fa = timed(lambda: flash_attention(q, k, v).block_until_ready(), repeat=1)
    yield {"name": "kernels/flash_attention(512)", "us_per_call": us_fa,
           "derived": "blocked online-softmax; causal GQA"}
    x = jnp.asarray(rng.standard_normal((1, 256, 4, 32)), jnp.float32)
    dt = jnp.asarray(rng.random((1, 256, 4)) * 0.3 + 0.05, jnp.float32)
    al = jnp.asarray(rng.standard_normal(4) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((1, 256, 16)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((1, 256, 16)), jnp.float32)
    _, us_ssd = timed(lambda: ssd_scan(x, dt, al, Bm, Cm, chunk=64).block_until_ready(),
                      repeat=1)
    yield {"name": "kernels/ssd_scan(256)", "us_per_call": us_ssd,
           "derived": "chunked SSD w/ VMEM state carry"}
    yield from _batched_scoring_bench()
    yield from _fused_reduction_bench()
    yield from _ragged_launch_bench()
    yield from _tuned_dispatch_bench()


def _batched_scoring_bench():
    """Batched candidate scoring (``find_rotations_batched``) vs the scalar
    per-link loop the seed scheduler ran — the Algorithm-2 hot path.

    Doubles as the CI smoke check for the batched paths: every
    configuration asserts (via ``BatchStats``) that no problem silently
    fell back to the scalar search, and the k=3 grid configuration asserts
    a >1x measured speedup over the scalar loop.
    """
    from repro.core.compat import BatchStats, find_rotations, find_rotations_batched

    from .common import scoring_problems, timed

    cases = (
        # (precision_deg, links, jobs/link, expected batched path, label)
        (5.0, 24, 2, "grid", "A~72 typical"),
        (0.5, 24, 2, "grid", "A~720 fine-grid"),
        (5.0, 12, 3, "grid", "A~72 k=3 product grid"),
        (0.5, 8, 3, "descent", "A~720 k=3 lockstep descent"),
    )
    for deg, links, k, path, label in cases:
        probs = scoring_problems(num_links=links, jobs_per_link=k)
        scalar = lambda: [
            find_rotations(p, c, precision_deg=deg, backend="numpy")
            for p, c in probs
        ]
        batched = lambda: find_rotations_batched(probs, precision_deg=deg)
        batched()  # warm up (jit compile on the pallas path)
        _, us_scalar = timed(scalar)
        _, us_batch = timed(batched)
        speedup = us_scalar / us_batch

        stats = BatchStats()
        find_rotations_batched(probs, precision_deg=deg, stats=stats)
        yield {
            "name": f"kernels/score_batched({links}x{k}job,{deg:g}deg)",
            "us_per_call": us_batch,
            "speedup": speedup,
            "derived": (
                f"scalar_loop={us_scalar:.0f}us speedup={speedup:.2f}x "
                f"({label}; batched {path} path, "
                f"{stats.grid_rows + stats.descent_rows} rows in "
                f"{stats.batched_calls} calls — pallas kernel for A>=512, "
                f"vectorized numpy below)"
            ),
        }
        # CI smoke assertions: the batched path must actually be taken.
        # (After the yield: a failing gate still leaves the measured row
        # in the --json artifact to explain itself.)
        if stats.scalar_fallbacks:
            raise RuntimeError(
                f"{stats.scalar_fallbacks}/{stats.problems} problems fell "
                f"back to the scalar path at {deg:g}deg k={k}: {stats}"
            )
        taken = stats.grid_problems if path == "grid" else stats.descent_problems
        if taken != len(probs):
            raise RuntimeError(
                f"expected all {len(probs)} problems on the batched {path} "
                f"path at {deg:g}deg k={k}, got {stats}"
            )
        if k == 3 and path == "grid" and speedup <= 1.0:
            raise RuntimeError(
                f"batched k=3 grid must beat the scalar loop: "
                f"{speedup:.2f}x (scalar={us_scalar:.0f}us batched={us_batch:.0f}us)"
            )



def _fused_reduction_bench():
    """Device-resident rotation search vs the PR-2 full-matrix round-trip.

    Large-grid k=3 problems (A=720, 90 product-grid rows per link) where
    the batched path previously shipped the whole ``(B, A)`` excess matrix
    to the host for ``np.argmin`` + acceptance.  With ``device_reduce``
    the fused ``circle_score_argmin`` / ``circle_score_segmin`` kernels
    keep the reduction on device and return O(problems) scalars.

    CI assertions: every chunk of the large-grid config must be device-
    reduced (zero ``(B, A)`` host transfers), the returned bytes must drop
    ≥ 100x vs the matrices, the fused path must be ≥ 2x faster than the
    PR-2 batched path, and the selected shifts must be bit-identical to
    the scalar search.
    """
    from repro.core.compat import BatchStats, find_rotations, find_rotations_batched

    from .common import large_grid_k3_problems, timed

    probs = large_grid_k3_problems(num_links=8)
    deg = 0.5

    fused = lambda: find_rotations_batched(
        probs, precision_deg=deg, device_reduce=True
    )
    matrix = lambda: find_rotations_batched(
        probs, precision_deg=deg, device_reduce=False
    )
    fused()    # warm both jit caches
    matrix()
    res_fused, us_fused = timed(fused)
    res_matrix, us_matrix = timed(matrix)
    speedup = us_matrix / us_fused

    stats = BatchStats()
    find_rotations_batched(probs, precision_deg=deg, stats=stats)
    scalar = [find_rotations(p, c, precision_deg=deg) for p, c in probs]
    # row first, gates after: a failing assertion below still leaves the
    # measured row in the --json artifact to explain itself
    yield {
        "name": "kernels/score_fused_argmin(8x3job,0.5deg)",
        "us_per_call": us_fused,
        "speedup": speedup,
        "derived": (
            f"full_matrix_roundtrip={us_matrix:.0f}us speedup={speedup:.2f}x "
            f"(A=720 grid; {stats.grid_rows} rows device-reduced in "
            f"{stats.batched_calls} calls, {stats.bytes_returned}B returned "
            f"vs {stats.bytes_matrix}B matrices = "
            f"{stats.reduction_ratio:.0f}x less; in-kernel argmin scans only "
            f"admissible shifts + exits at zero excess)"
        ),
    }
    if any(
        f.shifts_steps != s.shifts_steps or f.score != s.score
        for f, s in zip(res_fused, scalar)
    ):
        raise RuntimeError("fused reduction diverged from the scalar search")
    if any(
        f.shifts_steps != m.shifts_steps for f, m in zip(res_fused, res_matrix)
    ):
        raise RuntimeError("device_reduce on/off selected different shifts")
    if stats.device_reduced != stats.batched_calls or stats.batched_calls == 0:
        raise RuntimeError(
            f"large-grid chunks must all be device-reduced "
            f"(zero (B,A) host transfers), got {stats}"
        )
    if stats.reduction_ratio < 100.0:
        raise RuntimeError(
            f"bytes_returned must drop >=100x vs the full matrices: "
            f"{stats.reduction_ratio:.0f}x ({stats.bytes_returned}B vs "
            f"{stats.bytes_matrix}B)"
        )
    if speedup < 2.0:
        raise RuntimeError(
            f"fused k=3 large-grid reduction must be >=2x over the PR-2 "
            f"batched path: {speedup:.2f}x "
            f"(matrix={us_matrix:.0f}us fused={us_fused:.0f}us)"
        )


def _ragged_launch_bench():
    """Ragged single-launch rotation search vs the per-angle-count launch
    grouping it replaces (heterogeneous-fabric regime: links whose unified
    circles have different angle counts).

    CI assertions: the ragged path must issue exactly ONE kernel launch
    for the whole mixed-angle batch (``launches == batched_calls == 1``)
    where the grouped path pays one per distinct angle count, every row
    must ship ragged with bounded padding waste, the selected rotations
    must be bit-identical to both the per-group launches and the scalar
    search, and the single launch must be ≥ 1.5x faster than the grouped
    dispatch fan-out.
    """
    from repro.core.compat import BatchStats, find_rotations, find_rotations_batched

    from .common import mixed_angle_problems, timed

    probs = mixed_angle_problems()
    deg = 0.5
    scalar = [find_rotations(p, c, precision_deg=deg) for p, c in probs]
    num_groups = len({s.circle.num_angles for s in scalar})

    ragged_fn = lambda: find_rotations_batched(
        probs, precision_deg=deg, ragged=True
    )
    grouped_fn = lambda: find_rotations_batched(
        probs, precision_deg=deg, ragged=False
    )
    ragged_fn()    # warm both jit caches
    grouped_fn()
    res_ragged, us_ragged = timed(ragged_fn)
    res_grouped, us_grouped = timed(grouped_fn)
    speedup = us_grouped / us_ragged

    stats_r = BatchStats()
    find_rotations_batched(probs, precision_deg=deg, stats=stats_r, ragged=True)
    stats_g = BatchStats()
    find_rotations_batched(probs, precision_deg=deg, stats=stats_g, ragged=False)
    # row first, gates after: a failing assertion below still leaves the
    # measured row in the --json artifact to explain itself
    yield {
        "name": f"kernels/score_ragged_launch({len(probs)}x2job,{deg:g}deg)",
        "us_per_call": us_ragged,
        "speedup": speedup,
        "derived": (
            f"per_group_launches={us_grouped:.0f}us speedup={speedup:.2f}x "
            f"({num_groups} angle counts; ragged {stats_r.launches} launch "
            f"vs grouped {stats_g.launches}, {stats_r.ragged_rows} rows, "
            f"pad_fraction={stats_r.pad_fraction:.3f}; tournament-tree "
            f"argmin, per-row num_angles/valid masking)"
        ),
    }
    if any(
        r.shifts_steps != g.shifts_steps or r.shifts_steps != s.shifts_steps
        for r, g, s in zip(res_ragged, res_grouped, scalar)
    ):
        raise RuntimeError(
            "ragged launch diverged from the per-group/scalar search"
        )
    if not (stats_r.launches == stats_r.batched_calls == 1):
        raise RuntimeError(
            f"mixed-angle batch must ship as ONE ragged launch, got "
            f"launches={stats_r.launches} batched_calls={stats_r.batched_calls}"
        )
    if stats_g.launches != num_groups or num_groups < 4:
        raise RuntimeError(
            f"grouped comparison path must pay one launch per angle count "
            f"({num_groups}), got {stats_g.launches}"
        )
    if stats_r.ragged_rows != len(probs) or not 0.0 <= stats_r.pad_fraction < 0.5:
        raise RuntimeError(
            f"every row must ship ragged with bounded padding: "
            f"rows={stats_r.ragged_rows}/{len(probs)} "
            f"pad_fraction={stats_r.pad_fraction:.3f}"
        )
    if speedup < 1.5:
        raise RuntimeError(
            f"ragged single launch must be >=1.5x over per-group launches: "
            f"{speedup:.2f}x (grouped={us_grouped:.0f}us ragged={us_ragged:.0f}us)"
        )


def _tuned_dispatch_bench():
    """Tuned-table dispatch vs the untuned module defaults, on the exact
    production-shaped workloads the table was searched on (segmin = the
    tall grid-path launch, argmin = the short descent-path launch).

    CI assertions (after each row's yield): the tuned and untuned paths
    must return **bit-identical** (idx, val) outputs — the circle family's
    schedule parameters are provably output-inert — and the tuned dispatch
    must never be slower than the ``SHIFT_CHUNK=8`` / ``BLOCK_L=32``
    defaults beyond a 10% noise band (the search's 5% hysteresis ships
    defaults on near-ties, so this holds across machines).  After all
    rows: at least one fine-grid (A >= 512) bucket must be >= 1.15x
    faster tuned — the gate that keeps the committed table earning its
    keep; disarmed only if the loader fell back to defaults (no table
    entry for any fine-grid case), which the row text then states.
    """
    import numpy as np

    from repro.kernels import tune
    from repro.kernels.tune.search import make_workload

    def min_us(fn, reps=5):
        # min-of-N, interleaved by the caller: noise on a quiesced runner
        # is strictly additive, so the minimum is the stable statistic to
        # compare two near-identical launches with
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    table = tune.get_table()
    cases = (
        # (variant, short label, workload rows, bucket, fine_grid)
        ("circle_score_segmin", "segmin", 384, 512, True),
        ("circle_score_segmin", "segmin", 384, 1024, True),
        ("circle_score_argmin", "argmin", 32, 1024, True),
        ("circle_score_argmin", "argmin", 32, 256, False),
    )
    best_fine = 0.0
    fine_armed = False
    for variant, label, rows, bucket, fine in cases:
        run = make_workload(variant, bucket)
        entry = table.entries.get(f"{variant}/{bucket}", {})
        want = run({})              # untuned defaults; warms that jit cache
        got = run({}, tuned=True)   # table dispatch; warms the other
        identical = all(np.array_equal(g, w) for g, w in zip(got, want))
        us_def, us_tuned = float("inf"), float("inf")
        for _ in range(2):  # interleave so drift hits both sides alike
            us_def = min(us_def, min_us(lambda: run({})))
            us_tuned = min(us_tuned, min_us(lambda: run({}, tuned=True)))
        speedup = us_def / us_tuned
        if fine and entry:
            fine_armed = True
            best_fine = max(best_fine, speedup)
        sched_txt = (
            "table " + ",".join(f"{k}={v}" for k, v in sorted(entry.items()))
            if entry else "no table entry — defaults"
        )
        yield {
            "name": f"kernels/score_tuned_{label}({rows}x{bucket})",
            "us_per_call": us_tuned,
            "speedup": speedup,
            "derived": (
                f"untuned_default={us_def:.0f}us speedup={speedup:.2f}x "
                f"({sched_txt}; bit_identical={identical})"
            ),
        }
        # gates after the yield: the measured row stays in the artifact
        if not identical:
            raise RuntimeError(
                f"tuned dispatch changed {variant}/{bucket} outputs — the "
                f"circle family's schedule parameters must be output-inert"
            )
        if us_tuned > us_def * 1.10:
            raise RuntimeError(
                f"tuned {variant}/{bucket} slower than the untuned "
                f"defaults: {us_tuned:.0f}us vs {us_def:.0f}us "
                f"({speedup:.2f}x, floor 0.91x with the 10% noise band)"
            )
    if fine_armed and best_fine < 1.15:
        raise RuntimeError(
            f"committed table must win >=1.15x on at least one fine-grid "
            f"(A>=512) bucket: best {best_fine:.2f}x"
        )


def _arrival_bench():
    """Registry-driven CASSINI-vs-host comparison under each arrival
    process (``arrival-{poisson,burst,diurnal}``): the paper's trace
    population, same RNG stream, only the arrival pattern varies.

    One row per pattern; ``speedup`` is Themis avg JCT over th+cassini
    avg JCT (>1 means the CASSINI augmentation helps).  CI assertion
    (after the burst row's yield): under clustered arrivals — the regime
    the paper's §5.2 dynamic experiments stress — the augmented scheduler
    must not lose to its host on average JCT.
    """
    from repro.engine.scenarios import ARRIVAL_SWEEP, get_scenario

    HORIZON_MS = 600_000.0
    for pat in ARRIVAL_SWEEP:
        spec = get_scenario(f"arrival-{pat}")
        runs = {
            name: spec.run(name, horizon_ms=HORIZON_MS)
            for name in ("themis", "th+cassini")
        }
        s_host = runs["themis"].metrics.summary()
        s_cas = runs["th+cassini"].metrics.summary()
        ratio = s_host["avg_jct_ms"] / s_cas["avg_jct_ms"]
        yield {
            "name": f"arrival/{pat}",
            "us_per_call": runs["th+cassini"].wall_s * 1e6,
            "speedup": ratio,
            "derived": (
                f"avg_jct th+cassini={s_cas['avg_jct_ms']:.0f}ms vs "
                f"themis={s_host['avg_jct_ms']:.0f}ms (jct_ratio="
                f"{ratio:.3f}x, ecn/iter {s_cas['ecn_per_iter']:.2f} vs "
                f"{s_host['ecn_per_iter']:.2f}, "
                f"{s_cas['jobs_finished']:.0f}/{s_host['jobs_finished']:.0f} "
                f"jobs finished, {HORIZON_MS:g}ms horizon)"
            ),
        }
        # gate after the yield: the measured row stays in the artifact
        if pat == "burst" and s_cas["avg_jct_ms"] > s_host["avg_jct_ms"]:
            raise RuntimeError(
                f"th+cassini must not lose to themis on avg JCT under "
                f"burst arrivals: {s_cas['avg_jct_ms']:.0f}ms vs "
                f"{s_host['avg_jct_ms']:.0f}ms"
            )


def _fluid_advance_bench():
    """Vectorized fluid-network engine vs the scalar per-event oracle.

    Each row advances the contended ``rack-scaling-{N}`` fluid state (the
    scenario's full trace population, wrap-around chained placements, no
    scheduler in the loop) through a fixed wall-clock window with the
    array-resident engine, and compares against the scalar dict-of-dicts
    progressive-filling loop on the *same* state.

    CI assertions: the two engines must produce identical iteration-time
    traces (the vectorized path is an exact replay, not an approximation),
    and at 64 racks the vectorized engine must be ≥ 5x faster — the gate
    that keeps rack-scale scenario sweeps affordable as the fluid model
    grows.

    The 256/1024-rack rows bench the *incremental re-solver*: the
    delta-maintained water-filling state with dirty-component refills
    against the per-set from-scratch solve, same vectorized event loop on
    both sides.  Gates: ≥ 3x at both sizes, and the two engines must
    complete the same total iteration count over the window (the
    incremental path is tolerance-band equivalent, so per-iteration float
    traces may differ in the last bits — the aggregate must not).
    """
    from repro.cluster import FluidNetworkSim

    from .common import fluid_advance_case, timed

    def run_engine(racks, vectorized, window_ms):
        topo, jobs = fluid_advance_case(racks)
        sim = FluidNetworkSim(topo, vectorized=vectorized)
        sim.configure(jobs)
        sim.advance(window_ms)
        return sim, jobs

    for racks, window_ms, gate in ((16, 15_000.0, None), (64, 6_000.0, 5.0)):
        (sim_v, jobs_v), us_vec = timed(
            lambda: run_engine(racks, True, window_ms), repeat=1
        )
        (_, jobs_s), us_scal = timed(
            lambda: run_engine(racks, False, window_ms), repeat=1
        )
        speedup = us_scal / us_vec
        iters = sum(j.iters_done for j in jobs_v)
        identical = all(
            a.iter_times_ms == b.iter_times_ms and a.ecn_marks == b.ecn_marks
            for a, b in zip(jobs_v, jobs_s)
        )
        yield {
            "name": f"fluid_advance/rack-scaling-{racks}",
            "us_per_call": us_vec,
            "speedup": speedup,
            "derived": (
                f"scalar_oracle={us_scal:.0f}us speedup={speedup:.2f}x "
                f"({len(jobs_v)} jobs, {racks} racks, {window_ms:g}ms window, "
                f"{iters} iterations; {sim_v.alloc_solves} allocation solves "
                f"(cached water-filling), identical={identical})"
            ),
        }
        # gates after the yield: the measured row stays in the artifact
        if not identical:
            raise RuntimeError(
                f"vectorized fluid engine diverged from the scalar oracle "
                f"at {racks} racks (iteration traces differ)"
            )
        if gate is not None and speedup < gate:
            raise RuntimeError(
                f"vectorized fluid advance must be >={gate:g}x over the "
                f"scalar allocator at {racks} racks: {speedup:.2f}x "
                f"(scalar={us_scal:.0f}us vectorized={us_vec:.0f}us)"
            )

    def run_incr(racks, incremental, window_ms):
        topo, jobs = fluid_advance_case(racks)
        sim = FluidNetworkSim(topo, vectorized=True, incremental=incremental)
        sim.configure(jobs)
        sim.advance(window_ms)
        return sim, jobs

    for racks, window_ms in ((256, 1_200.0), (1024, 350.0)):
        (sim_i, jobs_i), us_inc = timed(
            lambda: run_incr(racks, True, window_ms), repeat=1
        )
        (sim_s, jobs_s), us_scr = timed(
            lambda: run_incr(racks, False, window_ms), repeat=1
        )
        speedup = us_scr / us_inc
        iters_i = sum(j.iters_done for j in jobs_i)
        iters_s = sum(j.iters_done for j in jobs_s)
        yield {
            "name": f"fluid_advance/rack-scaling-{racks}",
            "us_per_call": us_inc,
            "speedup": speedup,
            "derived": (
                f"from_scratch={us_scr:.0f}us speedup={speedup:.2f}x "
                f"({len(jobs_i)} jobs, {racks} racks, {window_ms:g}ms "
                f"window, {iters_i} iterations; "
                f"{sim_i.alloc_delta_solves}/{sim_i.alloc_solves} delta "
                f"solves)"
            ),
        }
        # gates after the yield: the measured row stays in the artifact
        if iters_i != iters_s:
            raise RuntimeError(
                f"incremental fluid engine diverged from the from-scratch "
                f"solve at {racks} racks: {iters_i} vs {iters_s} total "
                f"iterations over the {window_ms:g}ms window"
            )
        if speedup < 3.0:
            raise RuntimeError(
                f"incremental re-solver must be >=3x over the per-set "
                f"from-scratch solve at {racks} racks: {speedup:.2f}x "
                f"(from_scratch={us_scr:.0f}us incremental={us_inc:.0f}us)"
            )


def _fluid_shard_bench():
    """Device-sharded component fills vs per-component device dispatch.

    Each row captures the *largest real rebuild-shaped fill* the
    incremental re-solver performs while advancing the contended
    ``rack-scaling-{256,1024}`` state: the dirty-component union at a
    ``_WF_REFRESH`` rebuild, partitioned into its independent
    water-filling components (tens of components at these sizes).  The
    measured quantity is the production sharded path — per-component
    slices padded into power-of-two buckets and dispatched as ONE
    vmap-batched fill per bucket, row axis split across ``jax.devices()``
    with shard_map — against the unbatched device path that keeps the
    same fills device-resident on the same fabric: one mesh dispatch per
    component.  Batching is exactly what the sharded path contributes on
    the device axis, so that is the pair the gate compares.

    CI assertions (gates raised after the yield):
    - >=1.5x for the bucketed sharded dispatch over per-component mesh
      dispatch, armed when >=4 devices are visible (the CI bench leg
      forces 8 host devices via XLA_FLAGS; on fewer devices the row
      still reports, gate disarmed);
    - the sharded rates must match the fused host fill
      (``_wf_fill_core`` over the union — the ``sharded=False``
      incremental path) within the documented 1e-9 tolerance band;
    - both must match the from-scratch ``_solve_alloc`` on the captured
      comm mask (the solve PR 5 pinned bit-exact against the scalar
      oracle) within the same band.

    The fused host fill time and the single-device per-component jit
    time are reported alongside for honesty: on a small-core CI runner
    the numpy cascade over the union is itself fast, and a lone
    pre-compiled single-row jit beats mesh traffic — the sharded path's
    win is amortising *mesh* dispatch across the component batch, which
    is what transfers to real multi-device hardware (the fused fill
    cannot leave the host at all).
    """
    import numpy as np

    from repro.cluster import FluidNetworkSim, contended_snapshot
    from repro.cluster import shard as shard_mod
    from repro.engine.scenarios import get_scenario

    from .common import timed

    ndev = shard_mod.device_count()

    for racks, window_ms in ((256, 1_200.0), (1024, 350.0)):
        spec = get_scenario(f"rack-scaling-{racks}")
        topo = spec.topology()
        jobs = contended_snapshot(topo, lambda: spec.trace(topo), tenants=2)
        sim = FluidNetworkSim(topo, vectorized=True, incremental=True)
        sim.configure(jobs)
        # capture the largest rebuild-shaped fill of the advance window:
        # (comm mask, binding, demand, live) at the solve that dirtied
        # the most members
        cap: dict = {}
        orig_rebuild = sim._wf_rebuild

        def probing_rebuild(comm_mask, caps_now):
            st = orig_rebuild(comm_mask, caps_now)
            rows_all, cols_all = sim._inc.flat_pairs
            bpair = st["binding"][cols_all] & comm_mask[rows_all]
            JR = np.unique(rows_all[bpair])
            if JR.size > cap.get("n", 0):
                cap.update(
                    n=JR.size, JR=JR, mask=comm_mask.copy(),
                    binding=st["binding"].copy(),
                    demand=st["demand"].copy(), live=st["live"].copy(),
                    caps=sim._cap_now.copy(),
                )
            return st

        sim._wf_rebuild = probing_rebuild
        sim.advance(window_ms)
        sim._wf_rebuild = orig_rebuild
        if not cap:
            raise RuntimeError(
                f"no rebuild-shaped fill captured at {racks} racks over "
                f"the {window_ms:g}ms window"
            )
        # replay the captured problem exactly: every path below
        # (sharded, sequential, fused, from-scratch) reads member caps
        # from sim._cap_now, which has drifted past the capture point by
        # the end of the advance — restore the capture-time snapshot so
        # all four solve the same instance
        sim._cap_now = cap["caps"]
        JR, binding = cap["JR"], cap["binding"]
        demand, live = cap["demand"], cap["live"]
        comps = sim._wf_components(JR, binding)
        if len(comps) < shard_mod.MIN_COMPONENTS:
            raise RuntimeError(
                f"captured fill at {racks} racks has only {len(comps)} "
                f"components — below the sharding threshold; the bench "
                f"needs a component batch to measure"
            )
        cap_l = sim._inc.capacities

        def build_rows():
            rows = []
            for mem, lnks in comps:
                eff = np.where(
                    demand[lnks] > cap_l[lnks] + 1e-9,
                    sim.congested_efficiency, 1.0,
                )
                rows.append((
                    sim._cap_now[mem],
                    sim._inc.sub_incidence(mem, lnks),
                    cap_l[lnks] * eff,
                ))
            return rows

        rows = build_rows()
        # warm the jit caches for every bucket shape on every path
        out_b, stats = shard_mod.batched_fill(rows, ndev=ndev)
        for row in rows:
            shard_mod.batched_fill([row], ndev=ndev)
            shard_mod.batched_fill([row], ndev=1)

        (out_b, stats), us_shard = timed(
            lambda: shard_mod.batched_fill(build_rows(), ndev=ndev),
            repeat=3,
        )

        def sequential(dev):
            return [
                shard_mod.batched_fill([row], ndev=dev)[0][0]
                for row in build_rows()
            ]

        out_s, us_seq = timed(lambda: sequential(ndev), repeat=1)
        _, us_seq1 = timed(lambda: sequential(1), repeat=1)
        _, us_fused = timed(
            lambda: sim._wf_fill_core(JR, binding, demand, live), repeat=3
        )
        fused = sim._wf_fill_core(JR, binding, demand, live)

        n = len(sim._slots)
        rates_b = np.zeros(n)
        rates_q = np.zeros(n)
        for (mem, _), vb, vq in zip(comps, out_b, out_s):
            rates_b[mem] = vb
            rates_q[mem] = vq
        rates_f = np.zeros(n)
        rates_f[JR] = fused
        scratch, _ = sim._solve_alloc(cap["mask"])
        band = dict(rtol=1e-9, atol=1e-9)
        ok_fused = np.allclose(rates_b[JR], rates_f[JR], **band)
        ok_seq = np.allclose(rates_q[JR], rates_b[JR], **band)
        ok_scratch = np.allclose(
            rates_b[JR], scratch[JR], **band
        ) and np.allclose(rates_f[JR], scratch[JR], **band)
        speedup = us_seq / us_shard
        armed = ndev >= 4
        yield {
            "name": f"fluid_shard/rack-scaling-{racks}",
            "us_per_call": us_shard,
            "speedup": speedup,
            "derived": (
                f"per_comp_mesh_dispatch={us_seq:.0f}us "
                f"speedup={speedup:.2f}x "
                f"({len(comps)} components, {JR.size} members, "
                f"{stats.dispatches} bucket dispatches over {ndev} "
                f"device(s), {stats.padded_rows} padded rows; reference: "
                f"per_comp 1-device jit={us_seq1:.0f}us, fused host "
                f"fill={us_fused:.0f}us; parity vs fused="
                f"{ok_fused} vs from-scratch={ok_scratch}; gate "
                f"{'armed' if armed else 'disarmed (<4 devices)'})"
            ),
        }
        # gates after the yield: the measured row stays in the artifact
        if not (ok_fused and ok_seq and ok_scratch):
            raise RuntimeError(
                f"sharded fill diverged at {racks} racks: vs fused="
                f"{ok_fused} vs sequential={ok_seq} vs from-scratch="
                f"{ok_scratch} (tolerance band rtol=atol=1e-9)"
            )
        if armed and speedup < 1.5:
            raise RuntimeError(
                f"bucketed sharded dispatch must be >=1.5x over "
                f"per-component mesh dispatch at {racks} racks on "
                f"{ndev} devices: {speedup:.2f}x "
                f"(sequential={us_seq:.0f}us sharded={us_shard:.0f}us)"
            )


def _sched_epoch_bench():
    """End-to-end scheduler-level rows: one full ``SchedulingPipeline.cassini``
    epoch (Allocate → Propose → Score → Align) on the hetero-16rack
    scenario, so kernel-level scoring wins stay visible where they matter.

    Four rows: the paper-default 5° epoch (A=72 circles — numpy grids,
    device reduction not eligible), and fine-grid 0.5° epochs (A≥720
    circles: the scoring stage actually runs through the device-resident
    rotation search) with the fused ragged reduction on, the per-group
    launch fan-out, and the full-matrix round-trip.

    CI assertion (ragged fine-grid row): every grid chunk / descent step
    of the epoch must ship as exactly ONE kernel launch
    (``BatchStats.launches == batched_calls``) with every row ragged —
    the heterogeneous 16-rack fabric no longer pays a dispatch per
    angle-count group.
    """
    from repro.sched import CassiniAugmented, ThemisScheduler

    from .common import sched_epoch_state, timed

    cases = (
        # (precision_deg, device_reduce, ragged, label)
        (5.0, True, True, "paper default"),
        (0.5, True, True, "fine grid, ragged single-launch"),
        (0.5, True, False, "fine grid, per-group launches"),
        (0.5, False, False, "fine grid, full-matrix round-trip"),
    )
    state = sched_epoch_state("hetero-16rack", max_jobs=10)
    for deg, device_reduce, ragged, label in cases:
        def one_epoch():
            # fresh module each call: epoch cost includes every link solve,
            # not a pure cache-hit replay
            s = CassiniAugmented(
                ThemisScheduler(), precision_deg=deg,
                device_reduce=device_reduce, ragged=ragged,
            )
            return s.schedule(state)
        one_epoch()  # warm the jit caches
        _, us_epoch = timed(one_epoch, repeat=3)
        sched = CassiniAugmented(
            ThemisScheduler(), precision_deg=deg,
            device_reduce=device_reduce, ragged=ragged,
        )
        sched.schedule(state)
        score_stage = next(
            s for s in sched.pipeline.stages if s.name == "score"
        )
        stats = score_stage.last_batch_stats
        yield {
            "name": f"sched_epoch/hetero-16rack({deg:g}deg,"
                    f"device_reduce={device_reduce},ragged={ragged})",
            "us_per_call": us_epoch,
            "derived": (
                f"full cassini epoch, 10 jobs, 16 racks ({label}); "
                f"batch={stats}"
            ),
        }
        if deg == 0.5 and device_reduce and ragged:
            # acceptance gate: one kernel launch per grid/descent step on
            # the heterogeneous fabric, all rows through the ragged path
            if stats.launches != stats.batched_calls or stats.launches == 0:
                raise RuntimeError(
                    f"hetero-16rack fine-grid epoch must issue exactly one "
                    f"kernel launch per grid/descent step: launches="
                    f"{stats.launches} batched_calls={stats.batched_calls}"
                )
            if stats.ragged_rows != stats.grid_rows + stats.descent_rows:
                raise RuntimeError(
                    f"every fine-grid row must ship ragged: "
                    f"{stats.ragged_rows} vs "
                    f"{stats.grid_rows + stats.descent_rows} ({stats})"
                )

    # end-to-end rack-scale row: one full cassini epoch on the 64-rack
    # scaling scenario — the candidate/scoring cost the scaling sweeps pay
    # at every scheduling trigger, measured where the fabric is largest
    state64 = sched_epoch_state("rack-scaling-64", max_jobs=12)

    def one_epoch_64():
        s = CassiniAugmented(ThemisScheduler(), precision_deg=5.0)
        return s.schedule(state64)

    one_epoch_64()  # warm the jit caches
    _, us_64 = timed(one_epoch_64, repeat=3)
    yield {
        "name": "sched_epoch/rack-scaling-64(5deg)",
        "us_per_call": us_64,
        "derived": "full cassini epoch, 12 jobs, 64 racks (paper-default "
                   "grid; end-to-end Allocate->Propose->Score->Align)",
    }


def _serve_bench():
    """Online serving rows: the latency SLO + delta-update gates.

    ``serve_query/multitenant-8`` replays the multitenant-8 arrival trace
    through :class:`SchedulerService`, stepping the stream watermark with
    256 placement queries spread across the horizon and draining to the
    end.  ``us_per_call`` is the full replay wall time; the SLO gate is on
    the measured p99 *query service latency* against a fixed budget — two
    orders of magnitude above the worst contended pump (which includes a
    scheduling decision), so heterogeneous CI runners cannot trip it, but
    an accidental O(replay) scan or rebuild-per-query regression will.
    The replay must also reconfigure exclusively through the delta path
    (zero rebuilds) and hit the prefetch-warmed link cache.

    ``serve_delta_update/rack-scaling-64`` times one arrival + one
    departure applied to the contended 112-job 64-rack fluid state via
    the slot-delta primitives (``add_job``/``remove_job``) against the
    same membership change done as full ``configure`` rebuilds.  Gates:
    the delta path must be ≥ 3x faster, and it must *retain* the
    water-filling allocation cache the rebuild path throws away.
    """
    from repro.cluster import FluidNetworkSim
    from repro.engine.scenarios import get_scenario
    from repro.serve import JobArrival, SchedulerService

    from .common import fluid_advance_case, timed

    # ---- serve_query: multitenant-8 arrival replay ------------------- #
    SLO_P99_MS = 100.0
    NUM_QUERIES = 256
    spec = get_scenario("multitenant-8")

    def replay():
        topo = spec.topology()
        svc = SchedulerService(
            topo, spec.make_scheduler("cassini"), epoch_ms=spec.epoch_ms,
            compute_jitter=spec.compute_jitter, vectorized=spec.vectorized,
            seed=spec.sim_seed,
        )
        with svc:
            for job in spec.arrival_stream(topo):
                svc.submit(JobArrival(job))
            for k in range(1, NUM_QUERIES + 1):
                svc.query(at_ms=k * spec.horizon_ms / NUM_QUERIES)
            svc.drain(spec.horizon_ms)
            return svc, svc.telemetry()

    (svc, tel), us_replay = timed(replay, repeat=1)
    pct = svc.metrics.percentiles("QueryPlacement")
    yield {
        "name": "serve_query/multitenant-8",
        "us_per_call": us_replay,
        "derived": (
            f"query p50={pct['p50']:.3f}ms p95={pct['p95']:.3f}ms "
            f"p99={pct['p99']:.3f}ms (SLO p99<={SLO_P99_MS:g}ms, "
            f"{NUM_QUERIES} queries); {tel['decisions']:.0f} decisions, "
            f"configure_delta={tel.get('configure_delta', 0):.0f} "
            f"rebuild={tel.get('configure_rebuild', 0):.0f}, "
            f"prefetch_launched={tel.get('prefetch_launched', 0):.0f}, "
            f"link_cache {tel.get('link_cache_hits', 0):.0f} hits / "
            f"{tel.get('link_cache_misses', 0):.0f} misses"
        ),
    }
    # gates after the yield: the measured row stays in the artifact
    if pct["p99"] > SLO_P99_MS:
        raise RuntimeError(
            f"serve_query p99 latency SLO violated: {pct['p99']:.3f}ms > "
            f"{SLO_P99_MS:g}ms budget (p50={pct['p50']:.3f}ms "
            f"p95={pct['p95']:.3f}ms)"
        )
    if tel.get("configure_rebuild", 0) or (
        tel.get("configure_delta", 0) != tel["decisions"]
    ):
        raise RuntimeError(
            f"the multitenant-8 replay must reconfigure exclusively "
            f"through the delta path: delta="
            f"{tel.get('configure_delta', 0):.0f} "
            f"rebuild={tel.get('configure_rebuild', 0):.0f} of "
            f"{tel['decisions']:.0f} decisions"
        )
    if not tel.get("link_cache_hits", 0):
        raise RuntimeError(
            f"the served replay must hit the (prefetch-warmed) link "
            f"cache, got {tel.get('link_cache_hits', 0):.0f} hits"
        )

    # ---- serve_delta_update: 64-rack add/remove vs rebuild ---------- #
    GATE = 3.0
    CYCLES = 8  # add/remove pairs per timed call (stabilizes the median)
    topo, jobs = fluid_advance_case(64)
    base, extra = jobs[:-1], jobs[-1]

    delta_sim = FluidNetworkSim(topo, vectorized=True)
    delta_sim.configure(base)
    delta_sim.advance(200.0)  # populate the water-filling cache mid-flight
    cache_before = len(delta_sim._alloc_cache)

    def delta_cycle():
        for _ in range(CYCLES):
            delta_sim.add_job(extra)
            delta_sim.remove_job(extra.job_id)

    rebuild_sim = FluidNetworkSim(topo, vectorized=True)
    rebuild_sim.configure(base)
    rebuild_sim.advance(200.0)

    def rebuild_cycle():
        for _ in range(CYCLES):
            rebuild_sim.configure(base + [extra])
            rebuild_sim.configure(base)

    delta_cycle()  # warm both paths
    rebuild_cycle()
    _, us_delta = timed(delta_cycle)
    _, us_rebuild = timed(rebuild_cycle)
    us_delta /= CYCLES
    us_rebuild /= CYCLES
    speedup = us_rebuild / us_delta
    retained = len(delta_sim._alloc_cache)
    yield {
        "name": "serve_delta_update/rack-scaling-64",
        "us_per_call": us_delta,
        "speedup": speedup,
        "derived": (
            f"full_rebuild={us_rebuild:.0f}us speedup={speedup:.1f}x "
            f"({len(base)} jobs, 64 racks; arrival+departure as slot "
            f"deltas vs two configure() rebuilds; water-filling cache "
            f"retained {retained}/{cache_before} entries vs "
            f"{len(rebuild_sim._alloc_cache)} after rebuild)"
        ),
    }
    if speedup < GATE:
        raise RuntimeError(
            f"delta update must be >={GATE:g}x over rebuild at 64 racks: "
            f"{speedup:.2f}x (rebuild={us_rebuild:.0f}us "
            f"delta={us_delta:.0f}us)"
        )
    if not cache_before or retained != cache_before:
        raise RuntimeError(
            f"delta ops must retain the allocation cache: "
            f"{retained}/{cache_before} entries survived"
        )


def _fault_replay_bench():
    """Chaos rows: fault-replay parity + the degraded-mode overhead gate.

    ``fault_replay/churn-linkfail`` runs the seeded link-churn scenario
    (6 capacity incidents mid-trace, each triggering re-alignment) through
    the batch simulator and replays the same arrivals + fault schedule
    through :class:`SchedulerService`.  Gates: the served run must match
    the batch run decision for decision (timestamps, placements,
    time-shifts) and metric for metric — a fault schedule is part of the
    deterministic replay contract, not a tolerance band — and the healthy
    CASSINI pipeline must never have fallen back
    (``degraded_decisions == 0``).

    ``fault_replay/degraded_overhead`` measures what the graceful-
    degradation wrapper (exception trap + fallback decision path around
    every ``scheduler.schedule``) costs when nothing is failing: the same
    multitenant-4 replay drained with ``fallback`` on vs off.  Gate: the
    healthy-path overhead must stay under 5% (plus a small absolute slack
    so sub-second replays on noisy CI runners cannot trip it).
    """
    from repro.engine.scenarios import get_scenario
    from repro.serve import JobArrival, SchedulerService

    from .common import timed

    # ---- fault_replay/churn-linkfail: batch vs serve ---------------- #
    spec = get_scenario("churn-linkfail")
    built = spec.build("th+cassini")
    t0 = time.time()
    m_batch = built.simulator.run(built.jobs, horizon_ms=spec.horizon_ms)
    batch_s = time.time() - t0
    d_batch = built.simulator.decisions
    chaos = built.simulator.chaos

    def serve_replay():
        topo = spec.topology()
        jobs = list(spec.arrival_stream(topo))
        svc = SchedulerService(
            topo, spec.make_scheduler("th+cassini"), epoch_ms=spec.epoch_ms,
            compute_jitter=spec.compute_jitter, vectorized=spec.vectorized,
            seed=spec.sim_seed,
            fault_schedule=spec.make_fault_schedule(topo, jobs),
        )
        with svc:
            for job in jobs:
                svc.submit(JobArrival(job))
            metrics = svc.drain(spec.horizon_ms)
            return metrics, svc.decisions, svc.telemetry()

    (m_serve, d_serve, tel), us_serve = timed(serve_replay, repeat=1)
    tuples = lambda ds: [
        (t, d.placements, d.time_shifts_ms) for t, d in ds
    ]
    identical = (
        m_batch.summary() == m_serve.summary()
        and tuples(d_batch) == tuples(d_serve)
    )
    yield {
        "name": "fault_replay/churn-linkfail",
        "us_per_call": us_serve,
        "derived": (
            f"batch={batch_s * 1e6:.0f}us; {len(d_serve)} decisions, "
            f"{chaos.applied_count} faults applied "
            f"({chaos.skipped} skipped), "
            f"degraded={tel.get('degraded_decisions', 0):.0f}, "
            f"identical={identical} (serve replay matches batch decision "
            f"for decision under link churn)"
        ),
    }
    # gates after the yield: the measured row stays in the artifact
    if not identical:
        raise RuntimeError(
            "served churn-linkfail replay diverged from the batch run "
            "(decisions or metrics differ under the same fault schedule)"
        )
    if tel.get("degraded_decisions", 0):
        raise RuntimeError(
            f"healthy pipeline must never fall back: "
            f"{tel['degraded_decisions']:.0f} degraded decisions"
        )
    if not chaos.applied_count:
        raise RuntimeError(
            "churn-linkfail applied zero faults — the schedule no longer "
            "overlaps the trace; the parity gate is vacuous"
        )

    # ---- fault_replay/degraded_overhead: healthy-path cost ---------- #
    OVERHEAD_GATE = 1.05
    SLACK_US = 500_000.0  # 0.5s: sub-second replays on noisy runners
    mt = get_scenario("multitenant-4")

    def drain_replay(fallback):
        topo = mt.topology()
        svc = SchedulerService(
            topo, mt.make_scheduler("cassini"), epoch_ms=mt.epoch_ms,
            compute_jitter=mt.compute_jitter, vectorized=mt.vectorized,
            seed=mt.sim_seed, fallback=fallback,
        )
        with svc:
            for job in mt.arrival_stream(topo):
                svc.submit(JobArrival(job))
            svc.drain(mt.horizon_ms)
            return svc.telemetry()

    drain_replay(True)  # warm imports / jit caches
    tel_on, us_on = timed(lambda: drain_replay(True))
    tel_off, us_off = timed(lambda: drain_replay(False))
    ratio = us_on / us_off
    yield {
        "name": "fault_replay/degraded_overhead",
        "us_per_call": us_on,
        "derived": (
            f"fallback_off={us_off:.0f}us ratio={ratio:.3f} "
            f"(degradation wrapper on the healthy path: exception trap + "
            f"timeout check per decision, {tel_on['decisions']:.0f} "
            f"decisions; gate <{(OVERHEAD_GATE - 1) * 100:.0f}%)"
        ),
    }
    if us_on > us_off * OVERHEAD_GATE + SLACK_US:
        raise RuntimeError(
            f"degraded-mode wrapper costs too much on the healthy path: "
            f"{us_on:.0f}us vs {us_off:.0f}us without fallback "
            f"({ratio:.3f}x, gate {OVERHEAD_GATE:g}x + {SLACK_US:.0f}us)"
        )
    if tel_on.get("degraded_decisions", 0) or tel_off.get(
        "degraded_decisions", 0
    ):
        raise RuntimeError("healthy multitenant-4 replay must not degrade")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--json", nargs="?", const="BENCH.json", default=None,
                    metavar="PATH",
                    help="also write rows as JSON (machine-readable perf "
                         "trajectory; CI uploads it as an artifact and "
                         "diffs it against the committed baseline via "
                         "benchmarks/compare.py). Bare --json writes "
                         "BENCH.json")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else ALL

    print("name,us_per_call,derived")
    all_rows: list[dict] = []
    t0 = time.time()

    def write_json(error: str | None = None) -> None:
        payload = [
            {
                "name": r["name"],
                "us_per_call": round(float(r["us_per_call"]), 1),
                "speedup": round(float(r["speedup"]), 3) if "speedup" in r else None,
                "derived": str(r["derived"]),
            }
            for r in all_rows
        ]
        doc = {"rows": payload, "wall_s": round(time.time() - t0, 1)}
        if error is not None:
            doc["failed"] = error
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")

    current = "?"
    try:
        for name in names:
            current = name
            if name == "kernels":
                rows = _kernel_bench()
            elif name == "arrival":
                rows = _arrival_bench()
            elif name == "fluid_advance":
                rows = _fluid_advance_bench()
            elif name == "fluid_shard":
                rows = _fluid_shard_bench()
            elif name == "sched_epoch":
                rows = _sched_epoch_bench()
            elif name == "serve":
                rows = _serve_bench()
            elif name == "fault_replay":
                rows = _fault_replay_bench()
            elif name == "roofline":
                from . import roofline

                rows = roofline.run()
            else:
                mod = __import__(f"benchmarks.{name}", fromlist=["run"])
                rows = mod.run()
            # bench sets are generators: consume row by row and rewrite the
            # JSON as each lands, so a bench failing its own assertion gate
            # still leaves every completed measurement in the artifact
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
                all_rows.append(r)
                if args.json:
                    write_json()
    except Exception as e:
        # the partial JSON artifact keeps every completed measurement AND
        # the failure, but a partial artifact alone can mask *which* gate
        # tripped — always exit nonzero with a one-line reason naming it
        # (traceback first, so unexpected crashes stay debuggable)
        reason = f"{type(e).__name__}: {e}"
        if args.json:
            write_json(error=reason)
        traceback.print_exc()
        print(
            f"BENCH GATE FAILED ({current}, after {len(all_rows)} rows): "
            f"{reason}",
            file=sys.stderr, flush=True,
        )
        raise SystemExit(1)
    if args.json:
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)
    print(f"# total wall: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
